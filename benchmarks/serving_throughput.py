"""Serving-farm throughput benchmark -> BENCH_serving.json.

Drives the seeded mixed lstm+conv1d tape (``repro.serving.loadgen``)
through three farm configurations and records the acceptance figures:

* ``steady_state`` — max_batch=128, wave=512: a warm pass compiles every
  ``(B, L, F)`` program, then a second identical pass measures pure
  scheduling + dispatch (the per-run report only counts its own requests,
  so compile time never pollutes the tail). Gate: sustained >= 10k
  windows/s on CPU with a bounded p99.
* ``batch32`` — max_batch=32, wave=128: the batch-32-equivalent load the
  speedup criterion is defined at.
* ``unbatched`` — max_batch=1, pad_batch=False: every window is its own
  dispatch. Gate: batch-32 throughput >= 5x this.
"""
from __future__ import annotations

import dataclasses
import json

from repro.obs import MetricsRegistry
from repro.serving import FarmConfig
from repro.serving.loadgen import TrafficSpec, build_farm, run_loadgen

ARCHS = ("lstm", "conv1d")


def _measure(max_batch: int, pad_batch: bool, spec: TrafficSpec,
             *, replicas: int = 2, seed: int = 0) -> dict:
    """Warm pass (compile), then one timed pass on the same farm."""
    farm, pools = build_farm(
        ARCHS, replicas=replicas, seed=seed,
        cfg=FarmConfig(max_batch=max_batch, pad_batch=pad_batch),
        metrics=MetricsRegistry())
    run_loadgen(farm, pools, spec)               # warm: compile programs
    return run_loadgen(farm, pools, spec)        # steady state


def run(out: str = "BENCH_serving.json", *, requests: int = 4096,
        seed: int = 0) -> dict:
    spec = TrafficSpec(archs=ARCHS, n_requests=requests, wave=512,
                       seed=seed)
    steady = _measure(128, True, spec, seed=seed)
    b32 = _measure(32, True, dataclasses.replace(spec, wave=128),
                   seed=seed)
    # the unbatched pass is ~20x slower per window; a quarter of the tape
    # gives a stable rate without dominating the benchmark's wall time
    unb = _measure(1, False,
                   dataclasses.replace(spec, wave=128,
                                       n_requests=max(256, requests // 4)),
                   seed=seed)

    tput = steady["throughput_windows_per_s"] or 0.0
    tput32 = b32["throughput_windows_per_s"] or 0.0
    tput1 = unb["throughput_windows_per_s"] or 0.0
    report = {
        "config": {"archs": list(ARCHS), "requests": requests,
                   "replicas": 2, "seed": seed,
                   "steady_state": {"max_batch": 128, "wave": 512},
                   "batch32": {"max_batch": 32, "wave": 128},
                   "unbatched": {"max_batch": 1, "pad_batch": False}},
        "steady_state": steady,
        "batch32": {
            "throughput_windows_per_s": tput32,
            "latency_p99_s": b32["latency_p99_s"]},
        "unbatched": {
            "throughput_windows_per_s": tput1,
            "latency_p99_s": unb["latency_p99_s"]},
        "speedup_batch32_vs_unbatched": tput32 / tput1 if tput1 else None,
        "speedup_steady_vs_unbatched": tput / tput1 if tput1 else None,
        "meets_10k_windows_per_s": tput >= 10_000,
        "meets_5x_speedup": tput1 > 0 and tput32 / tput1 >= 5.0,
    }
    if out:
        with open(out, "w", encoding="utf-8") as f:
            f.write(json.dumps(report, indent=2, sort_keys=True) + "\n")

    print(f"steady state (batch 128): {tput:,.0f} windows/s  "
          f"p50/p99 {steady['latency_p50_s']*1e3:.2f}/"
          f"{steady['latency_p99_s']*1e3:.2f} ms  "
          f"dropped={steady['dropped_after_admission']}")
    for fam, d in sorted(steady["per_design"].items()):
        print(f"  {fam}: {d['done']} done, {d['gop_per_j']:.2f} GOP/J")
    print(f"batch 32: {tput32:,.0f} windows/s;  unbatched: "
          f"{tput1:,.0f} windows/s  -> speedup x{tput32 / tput1:.1f} "
          f"(steady x{tput / tput1:.1f})")
    print(f"gates: >=10k win/s {report['meets_10k_windows_per_s']}  "
          f">=5x vs unbatched {report['meets_5x_speedup']}")
    return report


if __name__ == "__main__":
    run()
