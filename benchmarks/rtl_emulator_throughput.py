"""RTL-emulator throughput: fused single-dispatch executor vs per-step.

The emulator is the inner loop of the whole Creator workflow (every generated
accelerator is verified/measured against it), so its throughput gates design
iteration. This benchmark sweeps batch × the paper's seq-6 window on the
elastic-lstm design and times

* ``fused``    — the staged executor (one fused int LSTM kernel dispatch per
  cell per window, jitted graph walk, weight-resident device constants);
* ``per_step`` — the pre-fusion schedule (one interpreted MAC ``pallas_call``
  per timestep from an un-jitted Python walk), the PR-1 baseline.

Writes ``BENCH_rtl_emulator.json`` (the perf trajectory artifact; CI uploads
it on every push).
"""
from __future__ import annotations

import argparse
import json
import time

DEFAULT_BATCHES = (1, 32, 256)
SEQ = 6


def _timeit(fn, n: int) -> float:
    """Mean µs/call over n calls (fn must block on its own result)."""
    fn()                                     # warm: compile/trace once
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def run(batches=DEFAULT_BATCHES, *, n_fused: int = 20, n_per_step: int = 3,
        out: str = "BENCH_rtl_emulator.json") -> dict:
    import jax

    from repro.configs import get_config
    from repro.core.creator import Creator
    from repro.core.types import SHAPES_LSTM
    from repro.energy.hw import XC7S15
    from repro.rtl import RTLEmulator

    cr = Creator(hw=XC7S15)
    st = cr.build(get_config("elastic-lstm"), SHAPES_LSTM["infer_1"])
    _, exe = cr.translate(st, target="rtl")
    fused = exe.emulator                     # staged executor, mode="fused"
    per_step = RTLEmulator(exe.graph, mode="pallas")   # PR-1 schedule

    rows = []
    for batch in batches:
        x = jax.random.normal(jax.random.PRNGKey(0), (batch, SEQ, 1))
        s0 = fused.cache_stats()             # obs counters, per-batch delta
        fused_us = _timeit(
            lambda: jax.block_until_ready(fused.run(x).outputs), n_fused)
        s1 = fused.cache_stats()
        per_step_us = _timeit(
            lambda: jax.block_until_ready(
                per_step.run_per_step(x).outputs), n_per_step)
        row = {
            "batch": batch, "seq": SEQ,
            "fused_us": round(fused_us, 1),
            "per_step_us": round(per_step_us, 1),
            "speedup": round(per_step_us / fused_us, 2),
            "fused_us_per_window": round(fused_us / batch, 2),
            # program-cache behavior over this batch's timed calls: one
            # miss+retrace for the new shape, hits for every other call
            "cache_hits": s1["hits"] - s0["hits"],
            "cache_misses": s1["misses"] - s0["misses"],
            "retraces": s1["retraces"] - s0["retraces"],
        }
        rows.append(row)
        print(f"batch={batch:>4} seq={SEQ}: fused {fused_us:>10.1f} us  "
              f"per-step {per_step_us:>12.1f} us  "
              f"x{row['speedup']:.1f}  ({row['fused_us_per_window']:.2f} "
              f"us/window)  cache {row['cache_hits']}h/"
              f"{row['cache_misses']}m/{row['retraces']}t")

    stats = fused.cache_stats()
    result = {
        "design": "elastic-lstm",
        "backend": jax.default_backend(),
        "trace_count": fused.trace_count,    # == len(batches): one per shape
        "cache": {"hits": stats["hits"], "misses": stats["misses"],
                  "evictions": stats["evictions"],
                  "dispatches": stats["dispatches"]},
        "rows": rows,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {out}")
    return result


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--batch", type=int, nargs="+", default=None,
                   help="batch sizes to sweep (default: 1 32 256)")
    p.add_argument("--n", type=int, default=20,
                   help="timed iterations for the fused path")
    p.add_argument("--out", default="BENCH_rtl_emulator.json",
                   help="output JSON path ('' to skip writing)")
    a = p.parse_args()
    run(tuple(a.batch) if a.batch else DEFAULT_BATCHES,
        n_fused=a.n, out=a.out)


if __name__ == "__main__":
    main()
