"""RTL-emulator throughput: fused single-dispatch executor vs per-step.

The emulator is the inner loop of the whole Creator workflow (every generated
accelerator is verified/measured against it), so its throughput gates design
iteration. This benchmark sweeps batch × the paper's seq-6 window on the
elastic-lstm design and times

* ``fused``    — the staged executor (one fused int LSTM kernel dispatch per
  cell per window, jitted graph walk, weight-resident device constants);
* ``per_step`` — the pre-fusion schedule (one interpreted MAC ``pallas_call``
  per timestep from an un-jitted Python walk), the PR-1 baseline.

The ``multi_design`` section times the DSE turnaround (DESIGN.md §15): K
isomorphic weight-perturbed candidates emulated end-to-end — construct +
trace + compile + run, the cost a design-space search actually pays per
candidate set — sequentially (one fresh emulator per design, the pre-PR-10
world) vs batched (one vmapped program over the stacked design axis), with
a bit-exactness cross-check against the sequential ``fused`` outputs.

Writes ``BENCH_rtl_emulator.json`` (the perf trajectory artifact; CI uploads
it on every push).
"""
from __future__ import annotations

import argparse
import json
import time

DEFAULT_BATCHES = (1, 32, 256)
SEQ = 6


def _timeit(fn, n: int) -> float:
    """Mean µs/call over n calls (fn must block on its own result)."""
    fn()                                     # warm: compile/trace once
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def run(batches=DEFAULT_BATCHES, *, n_fused: int = 20, n_per_step: int = 3,
        out: str = "BENCH_rtl_emulator.json") -> dict:
    import jax

    from repro.configs import get_config
    from repro.core.creator import Creator
    from repro.core.types import SHAPES_LSTM
    from repro.energy.hw import XC7S15
    from repro.rtl import RTLEmulator

    cr = Creator(hw=XC7S15)
    st = cr.build(get_config("elastic-lstm"), SHAPES_LSTM["infer_1"])
    _, exe = cr.translate(st, target="rtl")
    fused = exe.emulator                     # staged executor, mode="fused"
    per_step = RTLEmulator(exe.graph, mode="pallas")   # PR-1 schedule

    rows = []
    for batch in batches:
        x = jax.random.normal(jax.random.PRNGKey(0), (batch, SEQ, 1))
        s0 = fused.cache_stats()             # obs counters, per-batch delta
        fused_us = _timeit(
            lambda: jax.block_until_ready(fused.run(x).outputs), n_fused)
        s1 = fused.cache_stats()
        per_step_us = _timeit(
            lambda: jax.block_until_ready(
                per_step.run_per_step(x).outputs), n_per_step)
        row = {
            "batch": batch, "seq": SEQ,
            "fused_us": round(fused_us, 1),
            "per_step_us": round(per_step_us, 1),
            "speedup": round(per_step_us / fused_us, 2),
            "fused_us_per_window": round(fused_us / batch, 2),
            # program-cache behavior over this batch's timed calls: one
            # miss+retrace for the new shape, hits for every other call
            "cache_hits": s1["hits"] - s0["hits"],
            "cache_misses": s1["misses"] - s0["misses"],
            "retraces": s1["retraces"] - s0["retraces"],
        }
        rows.append(row)
        print(f"batch={batch:>4} seq={SEQ}: fused {fused_us:>10.1f} us  "
              f"per-step {per_step_us:>12.1f} us  "
              f"x{row['speedup']:.1f}  ({row['fused_us_per_window']:.2f} "
              f"us/window)  cache {row['cache_hits']}h/"
              f"{row['cache_misses']}m/{row['retraces']}t")

    stats = fused.cache_stats()
    result = {
        "design": "elastic-lstm",
        "backend": jax.default_backend(),
        "trace_count": fused.trace_count,    # == len(batches): one per shape
        "cache": {"hits": stats["hits"], "misses": stats["misses"],
                  "evictions": stats["evictions"],
                  "dispatches": stats["dispatches"]},
        "rows": rows,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {out}")
    return result


def run_multi(k: int = 32, *, batch: int = 8,
              archs=("elastic-lstm", "elastic-conv1d")) -> list:
    """The multi-design turnaround benchmark: K isomorphic candidates,
    sequential fresh-emulator evaluation vs one vmapped dispatch."""
    import jax
    import numpy as np

    from repro.rtl import MultiDesignEmulator, RTLEmulator
    from repro.verify.vectors import canonical_graph

    rows = []
    for arch in archs:
        graphs = [canonical_graph(arch, seed=s)[0] for s in range(k)]
        in_shape = graphs[0].edges[graphs[0].inputs[0]].shape
        x = np.random.default_rng(0).integers(
            -8, 8, (batch,) + in_shape).astype(np.int32)

        # sequential per-design: the pre-sharing world — every candidate
        # pays its own staging + trace + compile (mode "fused", the
        # production default), which is what bounded DSE turnaround
        t0 = time.perf_counter()
        seq_outs = []
        for g in graphs:
            em = RTLEmulator(g, mode="fused")
            seq_outs.append(np.asarray(
                jax.block_until_ready(em.run_int(x).outputs), np.int64))
        seq_s = time.perf_counter() - t0
        seq_outs = np.stack(seq_outs)

        # batched: stage all K, trace + compile ONE vmapped program, run
        t0 = time.perf_counter()
        multi = MultiDesignEmulator(graphs)
        out = np.asarray(jax.block_until_ready(
            multi.run_int(x).outputs), np.int64)
        vmap_s = time.perf_counter() - t0
        warm_us = _timeit(
            lambda: jax.block_until_ready(multi.run_int(x).outputs), 10)

        row = {
            "arch": arch, "k": k, "batch": batch,
            "sequential_s": round(seq_s, 3),
            "vmapped_s": round(vmap_s, 3),
            "speedup": round(seq_s / vmap_s, 2),
            "vmapped_warm_us": round(warm_us, 1),
            "vmapped_traces": multi.trace_count,
            "bit_exact_vs_sequential_fused":
                bool(np.array_equal(out, seq_outs)),
        }
        rows.append(row)
        print(f"multi_design {arch}: k={k} sequential {seq_s:.2f}s  "
              f"vmapped {vmap_s:.2f}s  x{row['speedup']:.1f}  "
              f"warm {warm_us:.0f} us/dispatch  "
              f"bit_exact={row['bit_exact_vs_sequential_fused']}")
    return rows


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--batch", type=int, nargs="+", default=None,
                   help="batch sizes to sweep (default: 1 32 256)")
    p.add_argument("--n", type=int, default=20,
                   help="timed iterations for the fused path")
    p.add_argument("--multi-k", type=int, default=32,
                   help="candidate count for the multi_design section "
                        "(0 to skip)")
    p.add_argument("--out", default="BENCH_rtl_emulator.json",
                   help="output JSON path ('' to skip writing)")
    a = p.parse_args()
    result = run(tuple(a.batch) if a.batch else DEFAULT_BATCHES,
                 n_fused=a.n, out="")
    if a.multi_k:
        result["multi_design"] = run_multi(a.multi_k)
    if a.out:
        with open(a.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {a.out}")


if __name__ == "__main__":
    main()
