"""Assemble the §Roofline table from the dry-run's per-cell JSON outputs."""
from __future__ import annotations

import json
import pathlib
import sys

HW = {"peak": 197e12, "hbm": 819e9, "link": 50e9}


def load(dir_: str):
    rows = []
    for p in sorted(pathlib.Path(dir_).glob("*.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def render(rows, mesh_filter=None) -> str:
    out = [f"{'arch':>18} {'shape':>11} {'mesh':>8} {'comp_ms':>8} "
           f"{'mem_ms':>8} {'coll_ms':>8} {'bottleneck':>10} "
           f"{'useful':>6} {'MFU':>6}  note"]
    for r in rows:
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        note = ""
        if r.get("collectives_in_while"):
            note = f"({r['collectives_in_while']} colls in while)"
        out.append(
            f"{r['arch']:>18} {r['shape']:>11} {r['mesh']:>8} "
            f"{r['compute_s']*1e3:8.2f} {r['memory_s']*1e3:8.2f} "
            f"{r['collective_s']*1e3:8.2f} {r['bottleneck']:>10} "
            f"{r['useful_ratio']:6.2f} {r['mfu']*100:5.1f}%  {note}")
    return "\n".join(out)


def run(dir_: str = "experiments/dryrun") -> str:
    rows = load(dir_)
    if not rows:
        print(f"(no dry-run JSON under {dir_} — run repro.launch.dryrun "
              f"--all --json {dir_})")
        return ""
    txt = render(rows)
    print(txt)
    return txt


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
