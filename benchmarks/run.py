"""Benchmark harness — one entry per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV lines at the end (harness contract).
"""
from __future__ import annotations

import time


def _timeit(fn, *args, n=3):
    t0 = time.perf_counter()
    for _ in range(n):
        fn(*args)
    return (time.perf_counter() - t0) / n * 1e6


def main() -> None:
    rows = []

    print("=" * 72)
    print("Table I reproduction (paper's only quantitative table)")
    print("=" * 72)
    from benchmarks import table1_energy

    t1 = table1_energy.run()
    rows.append(("table1_lstm_inference", t1["cpu_us"],
                 f"est_vs_meas_latency_err={t1['lat_err']:+.1%}"))

    print()
    print("=" * 72)
    print("RTL codegen: generated accelerator vs Table-I XC7S15 numbers")
    print("=" * 72)
    import jax as _jax

    from repro.configs import get_config as _get
    from repro.core.creator import Creator
    from repro.core.types import SHAPES_LSTM
    from repro.energy.hw import XC7S15
    from repro.model.lstm import lstm_flops

    _cr = Creator(hw=XC7S15)
    _st = _cr.build(_get("elastic-lstm"), SHAPES_LSTM["infer_1"])
    _flops = float(lstm_flops(_get("elastic-lstm")))
    _syn, _exe = _cr.translate(_st, target="rtl", model_flops=_flops)
    _x = _jax.random.normal(_jax.random.PRNGKey(0), (1, 6, 1))
    _exe(_x)                       # warm: compile the fused program once
    emu_us = _timeit(lambda: _jax.block_until_ready(_exe(_x)), n=5)
    _exe.emulator.run_per_step(_x)           # warm the per-step baseline
    per_step_us = _timeit(
        lambda: _jax.block_until_ready(
            _exe.emulator.run_per_step(_x).outputs), n=3)
    _meas = _exe.measure((_x,), model="elastic-lstm",
                         model_flops=_flops, n_runs=5)
    print(f"artifacts: {_syn.n_artifacts}  cycles: "
          f"{_syn.resources['cycles']}  est: {_syn.est_latency_s*1e6:.2f} us "
          f"@ {_syn.est_power_w*1e3:.1f} mW -> {_syn.est_gop_per_j:.2f} GOP/J"
          "  (Table I meas: 57.25 us @ 71.0 mW -> 5.33 GOP/J)")
    print(f"resources: dsp={_syn.resources['dsp']}/20 "
          f"bram36={_syn.resources['bram36']}/10 "
          f"lut={_syn.resources['lut']}/8000  fits={_syn.fits}")
    _cs = _exe.emulator.cache_stats()
    print(f"emulator: fused {emu_us:.0f} us/call vs per-step "
          f"{per_step_us:.0f} us/call -> x{per_step_us/emu_us:.1f}  "
          f"cache {_cs['hits']}h/{_cs['misses']}m "
          f"retraces={_cs['retraces']}")
    rows.append(("rtl_codegen", emu_us,
                 f"gop_per_j={_meas.gop_per_j:.2f}_vs_table1_5.33_"
                 f"err={(_meas.gop_per_j-5.33)/5.33:+.1%}_"
                 f"fused_us={emu_us:.0f}_per_step_us={per_step_us:.0f}_"
                 f"speedup=x{per_step_us/emu_us:.1f}_"
                 f"cache_hits={_cs['hits']}_misses={_cs['misses']}_"
                 f"retraces={_cs['retraces']}"))

    # conv1d arch through the same registry path (the op-library proof)
    from repro.core.types import SHAPES_CONV1D
    from repro.model.conv1d import conv1d_flops

    _ccfg = _get("elastic-conv1d")
    _cst = _cr.build(_ccfg, SHAPES_CONV1D["infer_1"])
    _cflops = float(conv1d_flops(_ccfg))
    _csyn, _cexe = _cr.translate(_cst, target="rtl", model_flops=_cflops)
    _cx = _jax.random.normal(_jax.random.PRNGKey(0),
                             (1, _ccfg.conv1d.seq_len, _ccfg.conv1d.channels))
    _cexe(_cx)                                  # warm
    conv_us = _timeit(lambda: _jax.block_until_ready(_cexe(_cx)), n=5)
    _cmeas = _cexe.measure((_cx,), model="elastic-conv1d",
                           model_flops=_cflops, n_runs=5)
    print(f"conv1d: {_csyn.n_artifacts} artifacts  cycles: "
          f"{_csyn.resources['cycles']}  est: "
          f"{_csyn.est_latency_s*1e6:.2f} us -> "
          f"{_csyn.est_gop_per_j:.2f} GOP/J  "
          f"dsp={_csyn.resources['dsp']}/20 "
          f"bram36={_csyn.resources['bram36']}/10  fits={_csyn.fits}")
    rows.append(("rtl_codegen_conv1d", conv_us,
                 f"gop_per_j={_cmeas.gop_per_j:.2f}_"
                 f"cycles={_csyn.resources['cycles']}_"
                 f"fits={_csyn.fits}"))

    # Static IR verifier: the pre-synthesis feasibility oracle must stay in
    # the milliseconds-per-design regime for DSE to lean on it.
    print()
    print("=" * 72)
    print("Static IR lint (abstract-interpretation analyzer, per design)")
    print("=" * 72)
    from repro.rtl.analyze import analyze_graph

    for _name, _e in (("elastic-lstm", _exe), ("elastic-conv1d", _cexe)):
        analyze_graph(_e.graph, hw=XC7S15)          # warm (lazy imports)
        lint_us = _timeit(lambda g=_e.graph: analyze_graph(g, hw=XC7S15), n=5)
        _rep = analyze_graph(_e.graph, hw=XC7S15)
        print(f"{_name}: {_rep.summary()}  ({lint_us/1e3:.2f} ms)")
        rows.append((f"ir_lint_{_name.split('-')[1]}", lint_us,
                     f"diags={len(_rep.diagnostics)}_"
                     f"lt10ms={lint_us < 10_000}"))

    # Elastic Node conformance stage: full differential verify per arch
    print()
    print("=" * 72)
    print("Conformance (verify stage): differential modes + oracle + protocol")
    print("=" * 72)
    from repro.verify import run_conformance

    for _name, _e in (("elastic-lstm", _exe), ("elastic-conv1d", _cexe)):
        t0 = time.perf_counter()
        _rep = run_conformance(_e.graph)
        _conf_us = (time.perf_counter() - t0) * 1e6
        print(f"{_name}: {_rep.summary()}  ({_conf_us/1e3:.0f} ms)")
        rows.append((f"verify_{_name.split('-')[1]}", _conf_us,
                     f"passed={_rep.passed}_modes_exact="
                     f"{_rep.modes_bit_exact}_oracle_lsb="
                     f"{_rep.oracle_max_lsb:g}_budget="
                     f"{_rep.error_budget_lsb}_vectors={_rep.n_vectors}"))

    print()
    print("=" * 72)
    print("RTL-template vs HLS analogue (Pallas templates vs plain XLA)")
    print("=" * 72)
    from benchmarks import rtl_vs_hls

    rv = rtl_vs_hls.run()
    rows.append(("attention_template_est_speedup", 0.0,
                 f"x{rv['attention']['speedup_est']:.2f}"))
    rows.append(("quant_matmul_wall_f32", rv["quant_matmul"]["wall_f32"] * 1e6,
                 f"int8_wall={rv['quant_matmul']['wall_int8']*1e6:.0f}us"))
    rows.append(("wkv6_chunked_wall", rv["wkv"]["chunked_ms"] * 1e3,
                 f"x{rv['wkv']['speedup']:.1f}_vs_scan"))

    print()
    print("=" * 72)
    print("MoE EP dispatch (8-device host mesh)")
    print("=" * 72)
    try:
        from benchmarks import moe_dispatch

        moe_dispatch.run()
        rows.append(("moe_dispatch", 0.0, "see table above"))
    except Exception as e:  # needs shard_map-era jax + host devices
        print(f"moe_dispatch skipped: {type(e).__name__}: {e}")
        rows.append(("moe_dispatch", 0.0, "skipped(env)"))

    print()
    print("=" * 72)
    print("Serving farm: mixed lstm+conv1d micro-batched throughput")
    print("=" * 72)
    from benchmarks import serving_throughput

    sv = serving_throughput.run(requests=1024)
    _sv_tput = sv["steady_state"]["throughput_windows_per_s"] or 0.0
    rows.append(("serving_mixed", 1e6 / _sv_tput if _sv_tput else 0.0,
                 f"windows_per_s={_sv_tput:.0f}_"
                 f"p99_ms={sv['steady_state']['latency_p99_s']*1e3:.1f}_"
                 f"speedup_b32=x{sv['speedup_batch32_vs_unbatched']:.1f}_"
                 f"dropped={sv['steady_state']['dropped_after_admission']}"))

    print()
    print("=" * 72)
    print("Data pipeline + trainer step (smoke scale)")
    print("=" * 72)
    import jax

    from repro.configs import get_config
    from repro.core.types import SMOKE_MESH, ParallelismConfig, ShapeConfig
    from repro.data.pipeline import LMDataConfig, lm_batch_for_step
    from repro.model.lm import Stepper

    cfg = get_config("yi-9b", smoke=True)
    par = ParallelismConfig(compute_dtype="float32")
    st = Stepper(cfg, ShapeConfig("t", "train", 64, 8), SMOKE_MESH, par)
    params, opt = st.init()
    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    data_us = _timeit(lambda: lm_batch_for_step(dcfg, 0), n=5)
    step = jax.jit(st.train_fn())
    b = {k: jax.numpy.asarray(v) for k, v in lm_batch_for_step(dcfg, 0).items()}
    params, opt, m = step(params, opt, b)   # compile
    jax.block_until_ready(m["loss"])

    state = {"p": params, "o": opt}

    def one():
        state["p"], state["o"], mm = step(state["p"], state["o"], b)
        jax.block_until_ready(mm["loss"])

    step_us = _timeit(one, n=5)
    print(f"data batch gen: {data_us:.0f} us;  smoke train step: "
          f"{step_us:.0f} us")
    rows.append(("data_batch_gen", data_us, ""))
    rows.append(("smoke_train_step", step_us, ""))

    print()
    print("=" * 72)
    print("Roofline table (from dry-run artifacts, if present)")
    print("=" * 72)
    from benchmarks import roofline_table

    roofline_table.run()

    print()
    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.1f},{d}")


if __name__ == "__main__":
    main()
