"""MoE EP dispatch: collective bytes + wall time of the three impls
(dense oracle / psum-EP / all_to_all-EP) on an 8-device host mesh."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

BODY = """
import os, time, dataclasses
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config
from repro.core.types import MeshConfig, ParallelismConfig
from repro.model.layers import Ctx, init_params
from repro.model.moe import moe_schema, moe_dense, moe_psum, moe_a2a
from repro.energy.roofline import parse_collectives

cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=2.0))
mcfg = MeshConfig((2, 4), ("data", "model"))
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
par = ParallelismConfig(compute_dtype="float32")
schema = moe_schema(cfg, tp=4)
params = init_params(schema, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 64, cfg.d_model))
ctx = Ctx(cfg=cfg, mesh_cfg=mcfg, mode="train", mesh=mesh, par=par)

for name, fn in [("dense", moe_dense), ("psum", moe_psum), ("a2a", moe_a2a)]:
    with mesh:
        f = jax.jit(lambda p, xx: fn(p, xx, cfg, ctx)[0])
        c = f.lower(params, x).compile()
        stc = parse_collectives(c.as_text(), 8)
        out = f(params, x); jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(5):
            out = f(params, x)
        jax.block_until_ready(out)
        wt = (time.perf_counter() - t0) / 5
    print(f"{name:>6}: wall={wt*1e3:7.1f} ms  "
          f"wire_bytes={stc.total_wire_bytes:.3e}  counts={stc.counts}")
"""


def run() -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import sys
        sys.path.insert(0, {ROOT + "/src"!r})
    """) + BODY
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    print(r.stdout, end="")
    if r.returncode != 0:
        print(r.stderr, file=sys.stderr)
        raise RuntimeError("moe_dispatch failed")
    return r.stdout


if __name__ == "__main__":
    run()
