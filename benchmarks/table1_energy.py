"""Reproduction of the paper's Table I (XC7S15 @ 100 MHz, LSTM accelerator).

The paper's claim: the workflow's *estimation* stage tracks hardware
*measurement* closely (power 70 vs 71 mW, latency 53.32 vs 57.25 µs,
efficiency 5.04 vs 5.33 GOP/J).

We reproduce the three-row structure with our pipeline:
  row 1 — paper's Vivado estimation        (constants from the paper)
  row 2 — paper's Elastic-Node measurement (constants from the paper)
  row 3 — OUR stage-2 estimate, read off the *generated accelerator*: the
          RTL backend lowers the LSTM to template artifacts and the
          synthesized design's cycle schedule + duty-cycled XC7S15 power
          model produce latency/power/GOP/J (DESIGN.md §5–§6).
The reproduction check: row 3 must sit within ~10 % of row 2, the same
accuracy band the paper demonstrates for its own estimator.
"""
from __future__ import annotations

import time

import jax

from repro.configs import get_config
from repro.energy.hw import XC7S15
from repro.model.layers import init_params
from repro.model.lstm import lstm_apply, lstm_flops, lstm_schema

# Table I constants (from the paper)
PAPER_EST = {"power_mw": 70.0, "latency_us": 53.32, "gop_j": 5.04}
PAPER_MEAS = {"power_mw": 71.0, "latency_us": 57.25, "gop_j": 5.33}


def our_estimate():
    """Stage-2 estimate from the RTL backend's generated artifacts."""
    from repro.rtl import emit_graph, lower_model, synthesize

    cfg = get_config("elastic-lstm")
    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    graph = lower_model(cfg, params)
    artifacts = emit_graph(graph)
    rep = synthesize(graph, hw=XC7S15, model_flops=float(lstm_flops(cfg)),
                     n_artifacts=len(artifacts))
    return {"power_mw": rep.est_power_w * 1e3,
            "latency_us": rep.est_latency_s * 1e6,
            "gop_j": rep.est_gop_per_j,
            "artifacts": len(artifacts),
            "cycles": rep.resources["cycles"]}


def container_measurement(n: int = 200):
    """Wall-clock of the same graph on the container (sanity, not FPGA)."""
    cfg = get_config("elastic-lstm")
    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 1))
    fn = jax.jit(lambda p, xx: lstm_apply(p, xx, cfg)[0])
    fn(params, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(params, x)
    out.block_until_ready()
    return (time.perf_counter() - t0) / n


def run() -> dict:
    est = our_estimate()
    cpu_us = container_measurement() * 1e6
    rows = [("paper_vivado_est", PAPER_EST), ("paper_node_meas", PAPER_MEAS),
            ("our_stage2_est", est)]
    print(f"(row 3 generated from {est['artifacts']} RTL artifacts, "
          f"{est['cycles']} cycles @ 100 MHz)")
    print(f"{'row':>18} {'power(mW)':>10} {'time(us)':>9} {'GOP/J':>7}")
    for name, r in rows:
        print(f"{name:>18} {r['power_mw']:10.1f} {r['latency_us']:9.2f} "
              f"{r['gop_j']:7.2f}")
    lat_err = (est["latency_us"] - PAPER_MEAS["latency_us"]) \
        / PAPER_MEAS["latency_us"]
    eff_err = (est["gop_j"] - PAPER_MEAS["gop_j"]) / PAPER_MEAS["gop_j"]
    paper_err = (PAPER_EST["latency_us"] - PAPER_MEAS["latency_us"]) \
        / PAPER_MEAS["latency_us"]
    print(f"our est vs paper meas: latency {lat_err:+.1%}, "
          f"GOP/J {eff_err:+.1%}  (paper's own est err: {paper_err:+.1%})")
    print(f"container wall-clock (jit, not FPGA): {cpu_us:.1f} us/inference")
    return {"our_est": est, "lat_err": lat_err, "eff_err": eff_err,
            "cpu_us": cpu_us}


if __name__ == "__main__":
    run()
