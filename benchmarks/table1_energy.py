"""Reproduction of the paper's Table I (XC7S15 @ 100 MHz, LSTM accelerator).

The paper's claim: the workflow's *estimation* stage tracks hardware
*measurement* closely (power 70 vs 71 mW, latency 53.32 vs 57.25 µs,
efficiency 5.04 vs 5.33 GOP/J).

We reproduce the three-row structure with our pipeline:
  row 1 — paper's Vivado estimation        (constants from the paper)
  row 2 — paper's Elastic-Node measurement (constants from the paper)
  row 3 — OUR stage-2 estimate: per-template timing model (the LSTM RTL
          template's calibrated initiation interval from ref [11]) + the
          XC7S15 HWSpec power model.
The reproduction check: row 3 must sit within ~10 % of row 2, the same
accuracy band the paper demonstrates for its own estimator.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.energy.hw import XC7S15
from repro.model.layers import init_params
from repro.model.lstm import lstm_apply, lstm_flops, lstm_schema

# Table I constants (from the paper)
PAPER_EST = {"power_mw": 70.0, "latency_us": 53.32, "gop_j": 5.04}
PAPER_MEAS = {"power_mw": 71.0, "latency_us": 57.25, "gop_j": 5.33}

# The LSTM RTL template's calibrated timing: cycles per MAC including the
# sigmoid/tanh PWL pipeline and state writeback (one-time calibration of the
# template on the Elastic Node, ref [11]; stored with the template like any
# RTL timing closure number).
TEMPLATE_CYCLES_PER_MAC = 0.567
CLOCK_HZ = 100e6


def our_estimate():
    cfg = get_config("elastic-lstm")
    ops = lstm_flops(cfg)                      # OP = 2·MAC convention
    macs = ops / 2
    cycles = macs * TEMPLATE_CYCLES_PER_MAC
    latency_s = cycles / CLOCK_HZ
    power_w = XC7S15.active_w * 0.99           # template power model
    energy_j = latency_s * power_w
    return {"power_mw": power_w * 1e3, "latency_us": latency_s * 1e6,
            "gop_j": (ops / 1e9) / energy_j}


def container_measurement(n: int = 200):
    """Wall-clock of the same graph on the container (sanity, not FPGA)."""
    cfg = get_config("elastic-lstm")
    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 1))
    fn = jax.jit(lambda p, xx: lstm_apply(p, xx, cfg)[0])
    fn(params, x).block_until_ready()
    t0 = time.time()
    for _ in range(n):
        out = fn(params, x)
    out.block_until_ready()
    return (time.time() - t0) / n


def run() -> dict:
    est = our_estimate()
    cpu_us = container_measurement() * 1e6
    rows = [("paper_vivado_est", PAPER_EST), ("paper_node_meas", PAPER_MEAS),
            ("our_stage2_est", est)]
    print(f"{'row':>18} {'power(mW)':>10} {'time(us)':>9} {'GOP/J':>7}")
    for name, r in rows:
        print(f"{name:>18} {r['power_mw']:10.1f} {r['latency_us']:9.2f} "
              f"{r['gop_j']:7.2f}")
    lat_err = (est["latency_us"] - PAPER_MEAS["latency_us"]) \
        / PAPER_MEAS["latency_us"]
    eff_err = (est["gop_j"] - PAPER_MEAS["gop_j"]) / PAPER_MEAS["gop_j"]
    print(f"our est vs paper meas: latency {lat_err:+.1%}, "
          f"GOP/J {eff_err:+.1%}  (paper's own est err: "
          f"{(PAPER_EST['latency_us']-PAPER_MEAS['latency_us'])/PAPER_MEAS['latency_us']:+.1%})")
    print(f"container wall-clock (jit, not FPGA): {cpu_us:.1f} us/inference")
    return {"our_est": est, "lat_err": lat_err, "eff_err": eff_err,
            "cpu_us": cpu_us}


if __name__ == "__main__":
    run()
