"""RTL-template vs HLS analogue: Pallas kernel templates vs plain-XLA lowering.

The paper's motivation for hand-written RTL templates is Blott et al.'s 45 %
HLS resource overhead. The TPU analogue: for each hot component, compare the
plain-XLA lowering ("HLS") against the kernel template ("RTL") on:
  * HBM bytes per call (from compiled cost_analysis vs the template's
    streaming-traffic model),
  * estimated TPU v5e time (roofline max of compute/memory terms),
  * container wall-clock of the two numerics (f32 XLA vs int8 path).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.energy.hw import TPU_V5E
from repro.energy.roofline import normalize_cost


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    ca = normalize_cost(c.cost_analysis())
    return float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0)), c


def _walltime(fn, args, n=5):
    f = jax.jit(fn)
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def bench_attention(B=4, S=2048, H=8, hd=128):
    from repro.kernels.flash_attention.ref import attention_ref

    sds = jax.ShapeDtypeStruct((B, S, H, hd), jnp.bfloat16)
    flops, byts, _ = _cost(lambda q, k, v: attention_ref(q, k, v, True),
                           sds, sds, sds)
    # template streaming model: Q,K,V read once + O written once (+ the
    # (bq,Sk) f32 running blocks stay in VMEM)
    t_bytes = 4 * (B * S * H * hd * 2)
    t_flops = flops  # identical math
    est = lambda f, b: max(f / TPU_V5E.peak_flops, b / TPU_V5E.hbm_bw)
    print(f"flash_attention  B{B} S{S} H{H} hd{hd}:")
    print(f"  XLA(HLS-analogue): bytes={byts:.3e}  est={est(flops, byts)*1e6:8.1f} us")
    print(f"  template(RTL):     bytes={t_bytes:.3e}  "
          f"est={est(t_flops, t_bytes)*1e6:8.1f} us"
          f"   traffic x{byts/t_bytes:.1f} less")
    return {"xla_bytes": byts, "tpl_bytes": t_bytes,
            "speedup_est": est(flops, byts) / est(t_flops, t_bytes)}


def bench_quant_matmul(M=512, K=4096, N=4096):
    from repro.quant.ptq import quantize_params_int8

    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    ip = quantize_params_int8({"w": w})
    flops, byts, _ = _cost(lambda a, b: a @ b, x, w)
    # int8 path: weights 1 B/elem, activations quantized once
    t_bytes = M * K * 1 + K * N * 1 + M * N * 4 + M * K * 4
    # int8 MXU runs ~2x bf16 rate on TPU; keep the brief's single constant
    est = lambda f, b, pk: max(f / pk, b / TPU_V5E.hbm_bw)
    t_xla = est(flops, byts, TPU_V5E.peak_flops)
    t_tpl = est(flops, t_bytes, 2 * TPU_V5E.peak_flops)
    wt_f32 = _walltime(lambda a, b: a @ b, (x, w))
    from repro.kernels.quant_matmul.ref import quant_matmul_ref, quantize_act

    xq, xs = quantize_act(x)
    wt_int8 = _walltime(
        lambda a, b: quant_matmul_ref(a, b, xs, ip.scale["w"]),
        (xq, ip.q["w"]))
    print(f"quant_matmul M{M} K{K} N{N}:")
    print(f"  XLA f32:  bytes={byts:.3e}  est={t_xla*1e6:8.1f} us  "
          f"wall={wt_f32*1e6:8.0f} us")
    print(f"  int8 tpl: bytes={t_bytes:.3e}  est={t_tpl*1e6:8.1f} us  "
          f"wall={wt_int8*1e6:8.0f} us"
          "   weight-bytes x4 less")
    return {"est_speedup": t_xla / t_tpl, "wall_f32": wt_f32,
            "wall_int8": wt_int8}


def bench_wkv(B=2, S=1024, H=8, N=64):
    from repro.model.rwkv import wkv6_chunked, wkv6_reference

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(kk, (B, S, H, N)) * 0.5 for kk in ks[:3])
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.5)
    u = jax.random.normal(ks[4], (H, N)) * 0.5
    wt_scan = _walltime(lambda *a: wkv6_reference(*a)[0], (r, k, v, w_log, u),
                        n=3)
    wt_chunk = _walltime(
        lambda *a: wkv6_chunked(*a, chunk=128)[0], (r, k, v, w_log, u), n=3)
    print(f"wkv6 B{B} S{S} H{H} N{N}: scan={wt_scan*1e3:.1f} ms  "
          f"chunked={wt_chunk*1e3:.1f} ms  x{wt_scan/wt_chunk:.1f}")
    return {"scan_ms": wt_scan * 1e3, "chunked_ms": wt_chunk * 1e3,
            "speedup": wt_scan / wt_chunk}


def run() -> dict:
    out = {}
    out["attention"] = bench_attention()
    out["quant_matmul"] = bench_quant_matmul()
    out["wkv"] = bench_wkv()
    return out


if __name__ == "__main__":
    run()
