"""The paper's demo, as a script: the full ElasticAI-Workflow on an edge
workload — design/train -> translate+estimate -> deploy+measure, with the
feedback loop widening the fixed-point format until the requirement is met
(what the PerCom audience would do interactively).

    PYTHONPATH=src python examples/elastic_workflow.py               # XLA loop
    PYTHONPATH=src python examples/elastic_workflow.py --target rtl
    PYTHONPATH=src python examples/elastic_workflow.py --target rtl --arch conv1d

``--arch`` picks the workload: the paper's traffic-flow LSTM (QAT-trained)
or the TCN-style depthwise conv1d sensor stack — both lower through the same
hardware-template registry (DESIGN.md §9). With ``--target rtl`` the loop's
stage 2/3 run against the *generated accelerator*: template artifacts are
emitted and the bit-exact emulator's cycle schedule provides the
measurement. Both targets drive the same ``Workflow.run_once`` — the target
registry resolves the substrate, and the RTL target's own
``options_from_knobs`` clamps the knobs to the exactness envelope (no
per-script format plumbing needed). Either way, the script finishes by
"pressing the button" — translating the final design to RTL artifacts
through the registry (written to ``--build-dir`` when given).
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.creator import Creator
from repro.core.report import DesignReport
from repro.core.target import get_target, list_targets
from repro.core.workflow import Requirement, Workflow
from repro.data.pipeline import (SensorConfig, TrafficConfig,
                                 sensor_window_batch, traffic_flow_batch)
from repro.model.layers import init_params
from repro.model.lstm import lstm_flops, lstm_schema
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.quant.fixedpoint import FxpFormat
from repro.quant.qat import QATConfig, make_qat_loss, make_qat_lstm_apply

TRAIN_STEPS = 120

ARCH_ALIASES = {"lstm": "elastic-lstm", "conv1d": "elastic-conv1d"}


def lstm_train_fn(knobs):
    cfg = get_config("elastic-lstm")
    qcfg = QATConfig(weight_fmt=FxpFormat(knobs["bits"], knobs["frac"]),
                     act_fmt=FxpFormat(knobs["bits"],
                                       max(0, knobs["frac"] - 2)),
                     hard_activations=knobs.get("hard_act", True))
    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    loss_fn = make_qat_loss(cfg, qcfg)
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=150,
                      weight_decay=0.0)
    batch = {k: jnp.asarray(v) for k, v in
             traffic_flow_batch(TrafficConfig(batch=256), 0).items()}

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(lambda pp: loss_fn(pp, batch)[0])(p)
        p2, o2, _ = adamw_update(g, o, p, ocfg)
        return p2, o2, loss

    for _ in range(TRAIN_STEPS):
        params, opt, loss = step(params, opt)
    ev = traffic_flow_batch(TrafficConfig(batch=256, seed=9), 1)
    apply = make_qat_lstm_apply(cfg, qcfg)
    pred, _ = apply(params, jnp.asarray(ev["x"]))
    eval_loss = float(jnp.mean((pred - jnp.asarray(ev["y"])) ** 2))
    rep = DesignReport(model="elastic-lstm", train_loss=float(loss),
                       eval_loss=eval_loss, params=2021,
                       weight_fmt=str(qcfg.weight_fmt),
                       act_fmt=str(qcfg.act_fmt))
    return params, rep, apply


def lstm_step_builder(knobs, params):
    cfg = get_config("elastic-lstm")
    qcfg = QATConfig(weight_fmt=FxpFormat(knobs["bits"], knobs["frac"]),
                     act_fmt=FxpFormat(knobs["bits"],
                                       max(0, knobs["frac"] - 2)))
    apply = make_qat_lstm_apply(cfg, qcfg)
    x = jnp.asarray(traffic_flow_batch(TrafficConfig(batch=1), 0)["x"])
    return (lambda p, xx: apply(p, xx)[0]), (params, x), float(lstm_flops(cfg))


def conv1d_train_fn(knobs):
    """Stage 1 for the sensor stack: the hard activations are already in
    the float graph, so QAT is just fake-quantizing the weights to the
    knobs' format (straight-through) — widening the knobs genuinely moves
    the reported eval loss, which is what the feedback loop reads."""
    from repro.model.conv1d import conv1d_apply, conv1d_schema
    from repro.quant.qat import fake_quant_tree

    cfg = get_config("elastic-conv1d")
    c = cfg.conv1d
    wfmt = FxpFormat(knobs["bits"], knobs["frac"])
    params = init_params(conv1d_schema(cfg), jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=150,
                       weight_decay=0.0)
    scfg = SensorConfig(seq_len=c.seq_len, channels=c.channels, batch=256)
    batch = {k: jnp.asarray(v) for k, v in
             sensor_window_batch(scfg, 0).items()}

    def loss_fn(p):
        pred, _ = conv1d_apply(fake_quant_tree(p, wfmt), batch["x"], cfg)
        return jnp.mean((pred - batch["y"]) ** 2)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(loss_fn)(p)
        p2, o2, _ = adamw_update(g, o, p, ocfg)
        return p2, o2, loss

    for _ in range(TRAIN_STEPS):
        params, opt, loss = step(params, opt)
    ev = sensor_window_batch(SensorConfig(seq_len=c.seq_len,
                                          channels=c.channels,
                                          batch=256, seed=9), 1)
    pred, _ = conv1d_apply(fake_quant_tree(params, wfmt),
                           jnp.asarray(ev["x"]), cfg)
    eval_loss = float(jnp.mean((pred - jnp.asarray(ev["y"])) ** 2))
    rep = DesignReport(model="elastic-conv1d", train_loss=float(loss),
                       eval_loss=eval_loss,
                       params=sum(x.size for x in jax.tree.leaves(params)),
                       weight_fmt=str(wfmt), act_fmt=str(
                           FxpFormat(knobs["bits"],
                                     max(0, knobs["frac"] - 2))))
    return params, rep, None


def conv1d_step_builder(knobs, params):
    from repro.model.conv1d import conv1d_apply, conv1d_flops

    cfg = get_config("elastic-conv1d")
    c = cfg.conv1d
    x = jnp.asarray(sensor_window_batch(
        SensorConfig(seq_len=c.seq_len, channels=c.channels, batch=1),
        0)["x"])
    return ((lambda p, xx: conv1d_apply(p, xx, cfg)[0]), (params, x),
            float(conv1d_flops(cfg)))


BUILDERS = {
    "elastic-lstm": (lstm_train_fn, lstm_step_builder),
    "elastic-conv1d": (conv1d_train_fn, conv1d_step_builder),
}


def optimizer(history):
    """The feedback rule a developer would apply after reading the reports:
    eval loss too high -> widen the fixed-point format."""
    k = dict(history[-1].knobs)
    print(f"  [feedback] eval_loss={history[-1].design.eval_loss:.4f} "
          f"with {history[-1].design.weight_fmt} -> widening")
    if k["bits"] >= 16:
        return None
    k["bits"] += 4
    k["frac"] += 3
    return k


def main():
    import argparse

    global TRAIN_STEPS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--target", "--backend", dest="target",
                    choices=sorted(list_targets()), default="xla",
                    help="registered deployment target (--backend is the "
                         "legacy spelling)")
    ap.add_argument("--arch", default="lstm",
                    choices=sorted(set(ARCH_ALIASES) | set(BUILDERS)),
                    help="workload: the paper's LSTM or the conv1d sensor "
                         "stack (short or full arch id)")
    ap.add_argument("--max-iters", type=int, default=4,
                    help="feedback-loop budget (CI smoke uses 1)")
    ap.add_argument("--train-steps", type=int, default=TRAIN_STEPS,
                    help="stage-1 training steps per iteration")
    ap.add_argument("--build-dir", default=None,
                    help="write the final RTL artifact bundle here "
                         "(<build-dir>/<arch>/)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="capture the whole run (spans + metrics) and write "
                         "Chrome trace-event JSON here — open it in Perfetto "
                         "or chrome://tracing; the full RunTrace bundle "
                         "(trace.jsonl, metrics.json, summary.txt) lands "
                         "next to it, and a copy goes into the --build-dir "
                         "bundle when given")
    ap.add_argument("--verify", action="store_true",
                    help="run the Elastic Node conformance stage: "
                         "Deployment.verify after every loop measurement, "
                         "plus a full differential check + golden vectors "
                         "for the final RTL design (reports land in "
                         "<build-dir>/<arch>/ when given)")
    ap.add_argument("--chaos", default=None, metavar="PLAN_JSON",
                    help="run a scripted chaos scenario against the final "
                         "RTL deployment: the FaultPlan JSON is injected "
                         "under a guarded wrapper (canary + breaker + "
                         "RTL->XLA fallback) and scored on the golden "
                         "vectors; exits non-zero unless the fault is "
                         "detected and traffic recovers with zero "
                         "post-detection corruption (resilience.json "
                         "lands in <build-dir>/<arch>/ when given); "
                         "see examples/chaos_plan.json")
    args = ap.parse_args()
    if args.chaos and args.target != "rtl":
        ap.error("--chaos models SEUs in the generated accelerator; "
                 "use --target rtl")
    target = args.target
    arch = ARCH_ALIASES.get(args.arch, args.arch)
    TRAIN_STEPS = args.train_steps
    from repro.core.types import shapes_for
    from repro.energy.hw import XC7S15

    cap = None
    if args.trace:
        from repro import obs

        cap = obs.capture(f"elastic-workflow[{arch}:{target}]")
        cap.__enter__()                  # closed (and written) at the end

    cfg = get_config(arch)
    infer_shape = shapes_for(cfg)[0]             # "infer_1" for both archs
    creator = Creator(hw=XC7S15) if target == "rtl" else Creator()
    train_fn, step_builder = BUILDERS[arch]

    def stepper_builder(knobs):
        from repro.core.types import shape_table_for

        return creator.build(cfg, shape_table_for(cfg)[infer_shape])

    wf = Workflow(creator=creator, train_fn=train_fn,
                  step_builder=step_builder, target=target,
                  stepper_builder=stepper_builder if target == "rtl"
                  else None, verify=args.verify,
                  analyze="error" if target == "rtl" else None)
    req = Requirement(max_eval_loss=0.01, max_latency_s=1.0)
    hist = wf.run(req, optimizer, {"bits": 4, "frac": 2},
                  max_iters=args.max_iters)
    print(f"\n{'it':>3} {'fmt':>7} {'eval':>8} {'est_ms':>8} {'meas_ms':>8} "
          f"{'est_uJ':>8} {'GOP/J':>7} {'vrfy':>4} {'ok':>3}")
    for r in hist:
        vrfy = "-" if r.conformance is None else \
            ("Y" if r.conformance.passed else "FAIL")
        print(f"{r.iteration:>3} {r.design.weight_fmt:>7} "
              f"{r.design.eval_loss:8.4f} "
              f"{r.synthesis.est_latency_s*1e3:8.3f} "
              f"{r.measurement.latency_s*1e3:8.3f} "
              f"{r.synthesis.est_energy_j*1e6:8.2f} "
              f"{r.measurement.gop_per_j:7.2f} "
              f"{vrfy:>4} "
              f"{'Y' if r.satisfied else 'n':>3}")
    print("\nworkflow finished:",
          "requirement met" if hist[-1].satisfied else "budget exhausted")

    # --- "press the button": translate the final design to RTL ----------- #
    best = hist[-1].knobs
    params, _, _ = train_fn(best)
    rtl = get_target("rtl")
    creator_rtl = Creator(hw=XC7S15)
    st = stepper_builder(best)
    syn, dep = creator_rtl.translate(
        st, target="rtl", params=params,
        options=rtl.options_from_knobs(best))
    if hist[-1].analysis is not None:
        print(f"\nstatic analysis: {hist[-1].analysis.summary()}")
    print(f"\nRTL translate [{arch}]: {syn.n_artifacts} artifacts, "
          f"{syn.resources['cycles']} cycles "
          f"({syn.est_latency_s*1e6:.2f} us @ 100 MHz), "
          f"dsp={syn.resources['dsp']} bram36={syn.resources['bram36']} "
          f"lut={syn.resources['lut']}, fits={syn.fits}")
    for name in sorted(dep.artifacts):
        print(f"  - {name}")
    out = None
    if args.build_dir:
        import os

        out = os.path.join(args.build_dir, arch)
        dep.save(out)
        print(f"artifact bundle written to {out}/")

    # --- Elastic Node conformance of the final design -------------------- #
    if args.verify:
        from repro.model.conv1d import conv1d_flops
        from repro.model.lstm import lstm_flops
        from repro.verify import generate_vectors, save_vectors

        flops = float(lstm_flops(cfg) if cfg.family == "lstm"
                      else conv1d_flops(cfg))
        rep = dep.verify(model=cfg.name, model_flops=flops)
        print(f"\nconformance: {rep.summary()}")
        for note in rep.notes:
            print(f"  note: {note}")
        if out is not None:
            import os

            with open(os.path.join(out, "conformance.json"), "w") as f:
                f.write(rep.to_json())
            save_vectors(generate_vectors(dep.graph),
                         os.path.join(out, "vectors"))
            print(f"ConformanceReport + golden vectors written to {out}/")
        if not rep.passed:
            raise SystemExit("conformance FAILED — see report above")

    # --- scripted chaos: fault-inject the deployed accelerator ----------- #
    if args.chaos:
        from repro.resilience import ChaosSpec, FallbackPolicy, run_chaos
        from repro.resilience import FaultPlan, GuardPolicy
        from repro.rtl.emulator import reference_apply
        from repro.core.target import XLADeployment

        plan = FaultPlan.load(args.chaos)
        spec = ChaosSpec(plan=plan, n_requests=24, seed=plan.seed,
                         policy=GuardPolicy(timeout_s=0.25, max_retries=2,
                                            breaker_threshold=3,
                                            canary_every=4))
        fb = XLADeployment(fn=jax.jit(
            lambda x: reference_apply(dep.graph, x)), hw=XC7S15)
        resil = run_chaos(dep, spec, fallback=FallbackPolicy.to_xla(fb))
        print(f"\n{resil.summary()}")
        for f in resil.faults_injected:
            print(f"  injected: {f}")
        for d in resil.faults_detected:
            print(f"  detected: {d}")
        if out is not None:
            import os

            resil.save(os.path.join(out, "resilience.json"))
            print(f"ResilienceReport written to {out}/resilience.json")
        if not resil.passed:
            raise SystemExit(
                "chaos scenario FAILED: detected="
                f"{resil.detected} recovered={resil.recovered} "
                "corrupted_after_detection="
                f"{resil.corrupted_after_detection}")

    # --- write the captured trace ---------------------------------------- #
    if cap is not None:
        import json
        import os

        cap.__exit__(None, None, None)
        rt = cap.trace
        trace_path = os.path.abspath(args.trace)
        bundle_dir = os.path.dirname(trace_path) or "."
        paths = rt.save(bundle_dir)
        if trace_path != paths["trace.json"]:    # honor a custom filename
            with open(trace_path, "w") as f:
                json.dump(rt.chrome(), f, indent=2, sort_keys=True)
        if out is not None:                      # copy into the RTL bundle
            rt.save(out)
        print(f"\n{rt.summary()}")
        print(f"\nChrome trace written to {args.trace} "
              "(open in Perfetto / chrome://tracing)")


if __name__ == "__main__":
    main()
