"""End-to-end serving driver (the paper's kind is deployment/inference):
train briefly, then serve a stream of batched requests with continuous
batching, reporting throughput and per-request latency.

    PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.core.types import SMOKE_MESH, ParallelismConfig, ShapeConfig
from repro.data.pipeline import LMDataConfig, lm_batch_for_step
from repro.model.lm import Stepper
from repro.runtime.server import Server, ServerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--train-steps", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    par = ParallelismConfig(compute_dtype="float32")
    S, B = 64, 8
    st = Stepper(cfg, ShapeConfig("t", "train", S, B), SMOKE_MESH, par)
    params, opt = st.init()
    step = jax.jit(st.train_fn())
    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B)
    for i in range(args.train_steps):
        params, opt, m = step(params, opt, lm_batch_for_step(dcfg, i))
    print(f"warm model after {args.train_steps} steps: "
          f"loss {float(m['loss']):.3f}")

    srv = Server(cfg, params,
                 ServerConfig(batch_slots=args.slots, max_len=128,
                              eos_token=-1), SMOKE_MESH, par)
    t_submit = {}
    t0 = time.perf_counter()
    for i in range(args.requests):
        rid = srv.submit(list(range(3 + i, 20 + i)),
                         max_new_tokens=args.max_new)
        t_submit[rid] = time.perf_counter()
    reqs = srv.run_until_drained()
    dt = time.perf_counter() - t0
    tok = sum(len(r.out_tokens) for r in reqs)
    print(f"{len(reqs)} requests x {args.max_new} tokens in {dt:.2f}s -> "
          f"{tok/dt:.1f} tok/s with {args.slots} slots")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
