"""Quickstart: the whole ElasticAI-JAX loop in one minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. pick a registered architecture (reduced config),
2. train a few steps on the synthetic corpus,
3. "press the button": translate via the deployment-target registry ->
   (SynthesisReport, Deployment) — the report is the Vivado analogue, the
   Deployment the uniform deployable artifact (callable/measurable/savable),
4. serve a few batched requests from the trained weights.
"""
import jax

from repro.configs import get_config
from repro.core.creator import Creator
from repro.core.target import list_targets
from repro.core.types import SMOKE_MESH, ParallelismConfig, ShapeConfig
from repro.data.pipeline import LMDataConfig, lm_batch_for_step
from repro.runtime.server import Server, ServerConfig


def main():
    cfg = get_config("yi-9b", smoke=True)
    par = ParallelismConfig(compute_dtype="float32")
    creator = Creator()
    print("deployment targets registered:", list_targets())
    print("components used:", sorted(creator.validate(cfg)))

    # --- stage 1: design/train ------------------------------------------
    S, B = 64, 8
    st = creator.build(cfg, ShapeConfig("t", "train", S, B), SMOKE_MESH, par)
    params, opt = st.init()
    step = jax.jit(st.train_fn())
    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B)
    for i in range(20):
        params, opt, m = step(params, opt, lm_batch_for_step(dcfg, i))
        if i % 5 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.3f}")

    # --- stage 2: translate + estimation report ---------------------------
    syn, dep = creator.translate(st)
    print(f"\nSynthesisReport: fits={syn.fits} "
          f"est_latency={syn.est_latency_s*1e3:.2f} ms "
          f"bottleneck={syn.bottleneck}")
    print(f"Deployment: target={dep.target!r} "
          "(uniform artifact: callable / .measure / .save)")
    print("per-channel seconds:",
          {k: f"{v*1e6:.0f}us" for k, v in syn.channels.items()})

    # --- stage 3: deploy (serve) ------------------------------------------
    srv = Server(cfg, params, ServerConfig(batch_slots=2, max_len=96,
                                           eos_token=-1), SMOKE_MESH, par)
    for i in range(3):
        srv.submit(list(range(5 + i, 13 + i)), max_new_tokens=8)
    for r in srv.run_until_drained():
        print(f"req {r.rid} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
