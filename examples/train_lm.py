"""End-to-end driver: train a ~100M-param LM on the synthetic corpus with the
fault-tolerant trainer (checkpoint/restart + deterministic replay).

    PYTHONPATH=src python examples/train_lm.py --preset 25m --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is the brief's "~100M model for a few hundred steps"; 25m
finishes in minutes on the container CPU (same code path).
"""
import argparse

from repro.core.types import ModelConfig, ParallelismConfig, ShapeConfig, \
    SMOKE_MESH
from repro.data.pipeline import LMDataConfig
from repro.model.lm import Stepper
from repro.optim.adamw import AdamWConfig
from repro.runtime.failures import FailureInjector
from repro.runtime.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~26M params: d=512, 8L, v=8192
    "25m": dict(n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
                d_ff=1408, vocab_size=8192, seq=256, batch=8),
    # ~101M params: d=768, 12L, v=32768
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab_size=32768, seq=512, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="25m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="step at which to inject a preemption (demo)")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"lm-{args.preset}", family="dense", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], vocab_pad_multiple=128, act="silu",
        norm="rmsnorm", remat="full")
    par = ParallelismConfig(compute_dtype="float32")
    st = Stepper(cfg, ShapeConfig("t", "train", p["seq"], p["batch"]),
                 SMOKE_MESH, par,
                 opt_cfg=AdamWConfig(lr=6e-4, warmup_steps=20,
                                     total_steps=args.steps))
    n_params = sum(x.size for x in __import__("jax").tree.leaves(st.init()[0]))
    print(f"model: {n_params/1e6:.1f}M params, seq={p['seq']}, "
          f"batch={p['batch']}")

    inj = None
    if args.inject_failure >= 0:
        inj = FailureInjector(fail_at_steps={args.inject_failure})
    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=p["seq"],
                        global_batch=p["batch"])
    tr = Trainer(st, dcfg,
                 TrainerConfig(total_steps=args.steps, ckpt_every=50,
                               ckpt_dir=args.ckpt_dir, log_every=10),
                 injector=inj)
    out = tr.train()
    first, last = out["metrics"][0], out["metrics"][-1]
    print(f"\nloss {first['loss']:.3f} (step {first['step']}) -> "
          f"{last['loss']:.3f} (step {last['step']}); "
          f"recoveries={out['recoveries']}")
    assert last["loss"] < first["loss"], "no learning happened?!"


if __name__ == "__main__":
    main()
