"""Recompute model_flops/useful/mfu in dry-run JSONs (post int32-overflow
fix) without recompiling — flops/bytes/wire in the files are unaffected."""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.core.types import SHAPES, SHAPES_LSTM
from repro.launch.dryrun import model_flops_estimate

PEAK = 197e12


def main(d="experiments/dryrun"):
    n = 0
    for p in pathlib.Path(d).glob("*.json"):
        r = json.loads(p.read_text())
        cfg = get_config(r["arch"])
        shapes = SHAPES_LSTM if cfg.family == "lstm" else SHAPES
        mf = model_flops_estimate(cfg, shapes[r["shape"]])
        total = r["flops_per_device"] * r["n_devices"]
        r["model_flops"] = mf
        r["useful_ratio"] = mf / total if total else 0.0
        r["mfu"] = (mf / (r["n_devices"] * PEAK * r["step_s"])
                    if r["step_s"] else 0.0)
        p.write_text(json.dumps(r, indent=2))
        n += 1
    print(f"fixed {n} files")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
