"""§Perf hillclimbing — hypothesis → change → re-lower → re-analyse.

Runs named variants of the three selected cells and records the roofline
terms before/after. The paper's feedback loop, applied to the 256-chip
roofline instead of a 20-DSP FPGA.

    PYTHONPATH=src python experiments/hillclimb.py --cell yi-9b:train_4k \
        --variant gqa
"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512"
    + " --xla_cpu_enable_concurrency_optimized_scheduler=false")
import argparse
import dataclasses
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.types import ParallelismConfig

# ---------------------------------------------------------------------------
# Flash-template analytic model (used by *flash variants): the Pallas
# template's contribution, added onto the stub-lowered graph costs.
# fwd flops = 2·B·S²·H·hd per self-attn (causal: half the S² rectangle, two
# matmuls); bwd ≈ 2.5×; remat "full" runs fwd twice -> 4.5× total for train,
# 1× for prefill/decode. HBM traffic = Q/K/V reads + O write per pass
# (running softmax state lives in VMEM), grouped-KV aware.
# ---------------------------------------------------------------------------


def template_attn_cost(cfg, shape, n_devices, dp, tp, mode):
    B = shape.global_batch
    S = shape.seq_len if mode != "decode" else 1
    Sk = shape.seq_len
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    B_loc = max(1, B // dp)
    H_loc = max(1, H // tp) if H % tp == 0 else H
    KV_loc = max(1, KV // tp) if KV % tp == 0 else KV
    per_attn_fwd_flops = 2.0 * B_loc * S * Sk * H_loc * hd
    mult = 4.5 if mode == "train" else 1.0
    flops = cfg.n_layers * per_attn_fwd_flops * mult
    passes = 3.0 if mode == "train" else 1.0   # fwd, remat-fwd, bwd streams
    bytes_ = cfg.n_layers * passes * 2.0 * (
        B_loc * S * H_loc * hd * 2      # Q read + O write
        + 2 * B_loc * Sk * KV_loc * hd  # K,V reads (grouped: KV heads only)
    )
    return flops, bytes_


VARIANTS = {
    "baseline": dict(),
    "gqa": dict(par=dict(gqa_grouped=True)),
    "gqa+dots": dict(par=dict(gqa_grouped=True), cfg=dict(remat="dots")),
    "gqa+flash": dict(par=dict(gqa_grouped=True, attn_impl="template_stub"),
                      add_template_attn=True),
    "flash": dict(par=dict(attn_impl="template_stub"),
                  add_template_attn=True),
    "compress": dict(par=dict(grad_compression=True)),
    "gqa+compress": dict(par=dict(gqa_grouped=True, grad_compression=True)),
    "dots": dict(cfg=dict(remat="dots")),
    "noremat": dict(cfg=dict(remat="none")),
    # embedding-gather + CE-accumulation fixes (see §Perf narrative)
    "emb+fullce": dict(cfg=dict(embed_replicated=True, ce_chunked=False)),
    "opt": dict(par=dict(gqa_grouped=True, attn_impl="template_stub"),
                cfg=dict(embed_replicated=True, ce_chunked=False),
                add_template_attn=True),
    "opt+compress": dict(
        par=dict(gqa_grouped=True, attn_impl="template_stub",
                 grad_compression=True),
        cfg=dict(embed_replicated=True, ce_chunked=False),
        add_template_attn=True),
    "gqa+emb+fullce": dict(par=dict(gqa_grouped=True),
                           cfg=dict(embed_replicated=True, ce_chunked=False)),
    # decode: seq-shard the (otherwise model-replicated) KV cache
    "kvshard": dict(par=dict(gqa_grouped=True, seq_shard_decode=True)),
}


def run_variant(arch, shape_name, vname, json_dir="experiments/hillclimb"):
    from repro.core.types import SHAPES
    from repro.launch import dryrun as dr

    spec = VARIANTS[vname]
    par = ParallelismConfig(**spec.get("par", {}))
    cfg_tr = ((lambda c: c.with_(**spec["cfg"])) if "cfg" in spec else None)
    rep, dt = dr.lower_cell(arch, shape_name, multi_pod=False, par=par,
                            mode="extrapolate", cfg_transform=cfg_tr)

    if spec.get("add_template_attn"):
        from repro.configs import get_config

        cfg = get_config(arch)
        if cfg_tr:
            cfg = cfg_tr(cfg)
        shape = SHAPES[shape_name]
        f_t, b_t = template_attn_cost(cfg, shape, 256, dp=16, tp=16,
                                      mode=shape.kind)
        rep.flops_per_device += f_t
        rep.bytes_per_device += b_t
        rep.compute_s = rep.flops_per_device / 197e12
        rep.memory_s = rep.bytes_per_device / 819e9
        terms = {"compute": rep.compute_s, "memory": rep.memory_s,
                 "collective": rep.collective_s}
        rep.bottleneck = max(terms, key=terms.get)
        rep.step_s = max(terms.values())
        rep.mfu = rep.model_flops / (256 * 197e12 * rep.step_s)

    p = pathlib.Path(json_dir)
    p.mkdir(parents=True, exist_ok=True)
    out = dr.report_json(rep, dt)
    out["variant"] = vname
    (p / f"{arch}__{shape_name}__{vname}.json").write_text(
        json.dumps(out, indent=2))
    print(f"[{vname}] comp={rep.compute_s*1e3:.1f}ms "
          f"mem={rep.memory_s*1e3:.1f}ms coll={rep.collective_s*1e3:.1f}ms "
          f"-> step={rep.step_s*1e3:.1f}ms bottleneck={rep.bottleneck} "
          f"MFU={rep.mfu*100:.1f}%")
    return rep


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True,
                    help=",".join(VARIANTS))
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    for v in args.variant.split(","):
        run_variant(arch, shape, v)
