"""§Perf hillclimbing — hypothesis → change → re-lower → re-analyse.

Runs named variants of the three selected cells and records the roofline
terms before/after. The paper's feedback loop, applied to the 256-chip
roofline instead of a 20-DSP FPGA.

    PYTHONPATH=src python experiments/hillclimb.py --cell yi-9b:train_4k \
        --variant gqa

``--rtl-sweep K`` instead runs the batched design-space feasibility loop
(ROADMAP item 1) over K isomorphic candidate accelerators: perturb the
trained weights, pre-filter with the static analyzer, and conformance-
check the whole candidate set through ONE vmapped emulator dispatch
(:class:`repro.rtl.multi.MultiDesignEmulator`):

    PYTHONPATH=src python experiments/hillclimb.py --rtl-sweep 8 \
        --arch elastic-lstm
"""
import argparse
import json
import os
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.types import ParallelismConfig

# roofline variants force many host devices; applied only from this
# script's own entry point (never at import — importing an experiment must
# not mutate the parent process environment), and each flag is appended at
# most once even across repeated calls in one process.
_XLA_DSE_FLAGS = (
    "--xla_force_host_platform_device_count=512",
    "--xla_cpu_enable_concurrency_optimized_scheduler=false",
)


def apply_xla_flags(env=None):
    """Idempotently add the sweep's XLA flags to ``env`` (default: this
    process's environment). A flag whose name is already present — any
    value, e.g. a user-chosen device count — is left alone."""
    env = os.environ if env is None else env
    current = env.get("XLA_FLAGS", "")
    missing = [f for f in _XLA_DSE_FLAGS
               if f.split("=", 1)[0] not in current]
    if missing:
        env["XLA_FLAGS"] = " ".join(([current] if current else []) + missing)
    return env.get("XLA_FLAGS", "")

# ---------------------------------------------------------------------------
# Flash-template analytic model (used by *flash variants): the Pallas
# template's contribution, added onto the stub-lowered graph costs.
# fwd flops = 2·B·S²·H·hd per self-attn (causal: half the S² rectangle, two
# matmuls); bwd ≈ 2.5×; remat "full" runs fwd twice -> 4.5× total for train,
# 1× for prefill/decode. HBM traffic = Q/K/V reads + O write per pass
# (running softmax state lives in VMEM), grouped-KV aware.
# ---------------------------------------------------------------------------


def template_attn_cost(cfg, shape, n_devices, dp, tp, mode):
    B = shape.global_batch
    S = shape.seq_len if mode != "decode" else 1
    Sk = shape.seq_len
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    B_loc = max(1, B // dp)
    H_loc = max(1, H // tp) if H % tp == 0 else H
    KV_loc = max(1, KV // tp) if KV % tp == 0 else KV
    per_attn_fwd_flops = 2.0 * B_loc * S * Sk * H_loc * hd
    mult = 4.5 if mode == "train" else 1.0
    flops = cfg.n_layers * per_attn_fwd_flops * mult
    passes = 3.0 if mode == "train" else 1.0   # fwd, remat-fwd, bwd streams
    bytes_ = cfg.n_layers * passes * 2.0 * (
        B_loc * S * H_loc * hd * 2      # Q read + O write
        + 2 * B_loc * Sk * KV_loc * hd  # K,V reads (grouped: KV heads only)
    )
    return flops, bytes_


VARIANTS = {
    "baseline": dict(),
    "gqa": dict(par=dict(gqa_grouped=True)),
    "gqa+dots": dict(par=dict(gqa_grouped=True), cfg=dict(remat="dots")),
    "gqa+flash": dict(par=dict(gqa_grouped=True, attn_impl="template_stub"),
                      add_template_attn=True),
    "flash": dict(par=dict(attn_impl="template_stub"),
                  add_template_attn=True),
    "compress": dict(par=dict(grad_compression=True)),
    "gqa+compress": dict(par=dict(gqa_grouped=True, grad_compression=True)),
    "dots": dict(cfg=dict(remat="dots")),
    "noremat": dict(cfg=dict(remat="none")),
    # embedding-gather + CE-accumulation fixes (see §Perf narrative)
    "emb+fullce": dict(cfg=dict(embed_replicated=True, ce_chunked=False)),
    "opt": dict(par=dict(gqa_grouped=True, attn_impl="template_stub"),
                cfg=dict(embed_replicated=True, ce_chunked=False),
                add_template_attn=True),
    "opt+compress": dict(
        par=dict(gqa_grouped=True, attn_impl="template_stub",
                 grad_compression=True),
        cfg=dict(embed_replicated=True, ce_chunked=False),
        add_template_attn=True),
    "gqa+emb+fullce": dict(par=dict(gqa_grouped=True),
                           cfg=dict(embed_replicated=True, ce_chunked=False)),
    # decode: seq-shard the (otherwise model-replicated) KV cache
    "kvshard": dict(par=dict(gqa_grouped=True, seq_shard_decode=True)),
}


def run_variant(arch, shape_name, vname, json_dir="experiments/hillclimb"):
    from repro.core.types import SHAPES
    from repro.launch import dryrun as dr

    spec = VARIANTS[vname]
    par = ParallelismConfig(**spec.get("par", {}))
    cfg_tr = ((lambda c: c.with_(**spec["cfg"])) if "cfg" in spec else None)
    rep, dt = dr.lower_cell(arch, shape_name, multi_pod=False, par=par,
                            mode="extrapolate", cfg_transform=cfg_tr)

    if spec.get("add_template_attn"):
        from repro.configs import get_config

        cfg = get_config(arch)
        if cfg_tr:
            cfg = cfg_tr(cfg)
        shape = SHAPES[shape_name]
        f_t, b_t = template_attn_cost(cfg, shape, 256, dp=16, tp=16,
                                      mode=shape.kind)
        rep.flops_per_device += f_t
        rep.bytes_per_device += b_t
        rep.compute_s = rep.flops_per_device / 197e12
        rep.memory_s = rep.bytes_per_device / 819e9
        terms = {"compute": rep.compute_s, "memory": rep.memory_s,
                 "collective": rep.collective_s}
        rep.bottleneck = max(terms, key=terms.get)
        rep.step_s = max(terms.values())
        rep.mfu = rep.model_flops / (256 * 197e12 * rep.step_s)

    p = pathlib.Path(json_dir)
    p.mkdir(parents=True, exist_ok=True)
    out = dr.report_json(rep, dt)
    out["variant"] = vname
    (p / f"{arch}__{shape_name}__{vname}.json").write_text(
        json.dumps(out, indent=2))
    print(f"[{vname}] comp={rep.compute_s*1e3:.1f}ms "
          f"mem={rep.memory_s*1e3:.1f}ms coll={rep.collective_s*1e3:.1f}ms "
          f"-> step={rep.step_s*1e3:.1f}ms bottleneck={rep.bottleneck} "
          f"MFU={rep.mfu*100:.1f}%")
    return rep


# ---------------------------------------------------------------------------
# Batched RTL design-space sweep (ROADMAP item 1, riding on item 3):
# K isomorphic weight-perturbed candidates, static-analyzer feasibility
# pre-filter, then ONE vmapped conformance dispatch for the whole set.
# ---------------------------------------------------------------------------


def perturb_params(params, seed, scale=0.02):
    """One DSE candidate: the trained pytree plus seeded gaussian noise —
    same shapes everywhere, so the lowered graph stays program-isomorphic
    to the base design."""
    import jax
    import numpy as np

    rng = np.random.Generator(np.random.PCG64(seed))
    return jax.tree.map(
        lambda a: (np.asarray(a, np.float32)
                   + rng.normal(0.0, scale, np.shape(a))
                   .astype(np.float32)),
        params)


def rtl_sweep(arch="elastic-lstm", k=8, *, seed=0, scale=0.02,
              json_dir="experiments/hillclimb"):
    """The batched candidate-evaluation loop of the DSE engine.

    1. lower K weight-perturbed candidates of ``arch`` (isomorphic by
       construction — same config, same Q-formats);
    2. feasibility pre-filter: the ~ms static analyzer (DESIGN.md §13)
       drops candidates whose actual weights break the overflow/format
       contract;
    3. one batched differential conformance run over the survivors
       (:func:`repro.verify.conformance.run_conformance_batch`): the
       vmapped jnp path for all K at once, cross-checked per design.

    Candidates share the cycle/resource model (cost is structural), so
    the sweep's verdict is feasibility × conformance; writes a JSON
    summary next to the roofline reports and returns it.
    """
    from repro.configs import get_config
    from repro.rtl.analyze import analyze_graph
    from repro.rtl.ir import lower_model
    from repro.verify.conformance import run_conformance_batch
    from repro.verify.vectors import canonical_params, _schema_for

    cfg = get_config(arch)
    base = canonical_params(_schema_for(cfg), seed=seed)
    t0 = time.perf_counter()
    graphs, feasible, diags = [], [], {}
    for i in range(k):
        g = lower_model(cfg, perturb_params(base, seed + 1000 + i,
                                            scale=scale))
        g.name = f"{arch}#c{i}"
        graphs.append(g)
        analysis = analyze_graph(g)
        if analysis.passed:
            feasible.append(i)
        else:
            diags[i] = [d.code for d in analysis.errors]
    t_filter = time.perf_counter() - t0

    survivors = [graphs[i] for i in feasible]
    reports = run_conformance_batch(survivors) if survivors else []
    t_total = time.perf_counter() - t0
    conformant = [i for i, rep in zip(feasible, reports) if rep.passed]

    out = {
        "arch": arch, "k": k, "seed": seed, "scale": scale,
        "feasible": feasible, "conformant": conformant,
        "analyzer_diags": {str(i): c for i, c in diags.items()},
        "n_vectors": reports[0].n_vectors if reports else 0,
        "oracle_max_lsb": max((r.oracle_max_lsb for r in reports),
                              default=0.0),
        "filter_s": round(t_filter, 4),
        "total_s": round(t_total, 4),
    }
    p = pathlib.Path(json_dir)
    p.mkdir(parents=True, exist_ok=True)
    (p / f"{arch}__rtl_sweep_k{k}.json").write_text(
        json.dumps(out, indent=2))
    print(f"[rtl-sweep] {arch}: {k} candidates -> {len(feasible)} feasible "
          f"-> {len(conformant)} conformant in {t_total:.2f}s "
          f"(filter {t_filter:.2f}s)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape (roofline variant sweep)")
    ap.add_argument("--variant", help=",".join(VARIANTS))
    ap.add_argument("--rtl-sweep", type=int, metavar="K",
                    help="batched RTL DSE sweep over K candidates")
    ap.add_argument("--arch", default="elastic-lstm",
                    help="RTL arch for --rtl-sweep")
    args = ap.parse_args()
    if args.rtl_sweep:
        rtl_sweep(args.arch, args.rtl_sweep)
    elif args.cell and args.variant:
        apply_xla_flags()                # before jax touches its backends
        arch, shape = args.cell.split(":")
        for v in args.variant.split(","):
            run_variant(arch, shape, v)
    else:
        ap.error("pass either --cell/--variant or --rtl-sweep K")
