"""Fault-tolerant trainer + batched server."""
import pytest

from repro.configs import get_config
from repro.core.types import SMOKE_MESH, ShapeConfig
from repro.data.pipeline import LMDataConfig
from repro.model.lm import Stepper
from repro.runtime.failures import FailureInjector, PreemptionError
from repro.runtime.server import Server, ServerConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def _mk(par, td, steps=25, inj=None, seed=7):
    cfg = get_config("yi-9b", smoke=True)
    S, B = 32, 8
    st = Stepper(cfg, ShapeConfig("t", "train", S, B), SMOKE_MESH, par)
    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B,
                        seed=seed)
    return Trainer(st, dcfg,
                   TrainerConfig(total_steps=steps, ckpt_every=10,
                                 ckpt_dir=str(td), log_every=5),
                   injector=inj)


def test_recovery_and_exact_replay(tmp_path, par_f32):
    out = _mk(par_f32, tmp_path / "a",
              inj=FailureInjector(fail_at_steps={13, 21})).train()
    assert out["recoveries"] == 2
    assert out["steps"] == 25
    clean = _mk(par_f32, tmp_path / "b").train()
    l1 = {m["step"]: m["loss"] for m in out["metrics"]}
    l2 = {m["step"]: m["loss"] for m in clean["metrics"]}
    for s in l1:
        assert abs(l1[s] - l2[s]) < 1e-4, s


def test_loss_decreases(tmp_path, par_f32):
    out = _mk(par_f32, tmp_path, steps=40).train()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0], losses


def test_injector_budget():
    inj = FailureInjector(fail_at_steps={5}, max_failures=1)
    with pytest.raises(PreemptionError):
        inj.maybe_fail(5)
    inj.maybe_fail(5)  # second time: budget spent, no raise


def test_server_batched_equals_single(par_f32):
    cfg = get_config("qwen3-32b", smoke=True)
    st = Stepper(cfg, ShapeConfig("p", "prefill", 16, 1), SMOKE_MESH, par_f32)
    params, _ = st.init()
    scfg = ServerConfig(batch_slots=3, max_len=48, eos_token=-1)
    srv = Server(cfg, params, scfg, SMOKE_MESH, par_f32)
    for i in range(5):
        srv.submit(list(range(5 + i, 13 + i)), max_new_tokens=6 + i)
    reqs = srv.run_until_drained()
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    single = Server(cfg, params, ServerConfig(batch_slots=1, max_len=48,
                                              eos_token=-1), SMOKE_MESH,
                    par_f32)
    single.submit(list(range(5, 13)), max_new_tokens=6)
    r0 = single.run_until_drained()[0]
    assert r0.out_tokens == reqs[0].out_tokens


def test_server_rwkv_state_cache(par_f32):
    """Attention-free arch goes through the same serving path."""
    cfg = get_config("rwkv6-7b", smoke=True)
    st = Stepper(cfg, ShapeConfig("p", "prefill", 16, 1), SMOKE_MESH, par_f32)
    params, _ = st.init()
    srv = Server(cfg, params, ServerConfig(batch_slots=2, max_len=32,
                                           eos_token=-1), SMOKE_MESH, par_f32)
    srv.submit(list(range(3, 11)), max_new_tokens=5)
    srv.submit(list(range(4, 12)), max_new_tokens=5)
    reqs = srv.run_until_drained()
    assert all(len(r.out_tokens) == 5 for r in reqs)


def test_server_drain_stats(par_f32):
    """run_until_drained stays list-compatible but carries ServerStats:
    counters, occupancy maxima, and ttft/total-latency histograms."""
    cfg = get_config("qwen3-32b", smoke=True)
    st = Stepper(cfg, ShapeConfig("p", "prefill", 16, 1), SMOKE_MESH, par_f32)
    params, _ = st.init()
    # deterministic clock so latency histograms are exact under test
    t = [0.0]

    def clock():
        t[0] += 0.5
        return t[0]

    srv = Server(cfg, params, ServerConfig(batch_slots=2, max_len=48,
                                           eos_token=-1), SMOKE_MESH,
                 par_f32, clock=clock)
    for i in range(4):
        srv.submit(list(range(5 + i, 13 + i)), max_new_tokens=4)
    reqs = srv.run_until_drained()
    assert isinstance(reqs, list) and len(reqs) == 4   # compat: still a list
    s = reqs.stats
    assert s.submitted == s.admitted == s.retired == 4
    assert s.ticks > 0
    assert s.max_queue_depth == 4          # sampled at tick start, pre-admit
    assert s.max_slots_busy == 2
    assert s.ttft_s["count"] == 4 and s.ttft_s["p50"] > 0
    assert s.latency_s["count"] == 4
    # total latency dominates ttft per request (same clock)
    assert s.latency_s["mean"] > s.ttft_s["mean"]
    for r in reqs:
        assert r.t_submit < r.t_first_token < r.t_done


def test_server_drain_limit_error_names_state(par_f32):
    """strict=True keeps the old contract: tripping max_ticks raises with
    the live queue/slot/stats state."""
    cfg = get_config("qwen3-32b", smoke=True)
    st = Stepper(cfg, ShapeConfig("p", "prefill", 16, 1), SMOKE_MESH, par_f32)
    params, _ = st.init()
    srv = Server(cfg, params, ServerConfig(batch_slots=1, max_len=48,
                                           eos_token=-1), SMOKE_MESH,
                 par_f32)
    srv.submit(list(range(5, 13)), max_new_tokens=8)
    srv.submit(list(range(6, 14)), max_new_tokens=8)
    with pytest.raises(RuntimeError) as ei:
        srv.run_until_drained(max_ticks=2, strict=True)
    msg = str(ei.value)
    assert "max_ticks=2" in msg
    assert "slots busy" in msg and "stats=" in msg


def test_server_drain_limit_partial_result(par_f32):
    """Default (non-strict) max_ticks trip returns partial progress: the
    retired requests, drained=False, and the in-flight rest in pending —
    nothing is thrown away."""
    cfg = get_config("qwen3-32b", smoke=True)
    st = Stepper(cfg, ShapeConfig("p", "prefill", 16, 1), SMOKE_MESH, par_f32)
    params, _ = st.init()
    srv = Server(cfg, params, ServerConfig(batch_slots=1, max_len=48,
                                           eos_token=-1), SMOKE_MESH,
                 par_f32)
    srv.submit(list(range(5, 13)), max_new_tokens=2)
    srv.submit(list(range(6, 14)), max_new_tokens=8)
    srv.submit(list(range(7, 15)), max_new_tokens=8)
    res = srv.run_until_drained(max_ticks=3)
    assert res.drained is False
    assert all(r.done for r in res)                   # retired only
    assert len(res) + len(res.pending) == 3           # nothing lost
    assert all(not r.done for r in res.pending)
    assert srv.metrics.counter("server.drain_truncated").value == 1
    # a clean drain keeps the old shape: drained=True, no pending
    done = srv.run_until_drained()
    assert done.drained is True and done.pending == []
    assert len(done) == 3                             # all retired now


# --------------------------------------------------------------------------- #
# DeploymentPool: health-aware admission + bounded-queue backpressure
# --------------------------------------------------------------------------- #


class _FakeResult:
    def __init__(self, value, source, degraded):
        self.value, self.source, self.degraded = value, source, degraded


class _FakeGuard:
    """Duck-typed pool member: can_serve()/call() like GuardedDeployment."""

    def __init__(self, healthy=True, degraded=False, explode=False):
        self.healthy, self.degraded, self.explode = healthy, degraded, explode
        self.served = 0

    def can_serve(self):
        return self.healthy

    def call(self, x):
        if self.explode:
            raise RuntimeError("boom")
        self.served += 1
        return _FakeResult(x * 2, "fake", self.degraded)


def test_pool_round_robin_and_statuses():
    from repro.serving import DeploymentPool

    a, b = _FakeGuard(), _FakeGuard(degraded=True)
    pool = DeploymentPool([a, b], max_queue=16)
    rids = [pool.submit(i) for i in range(6)]
    st = pool.drain()
    assert st.served_ok == 3 and st.served_degraded == 3 and st.shed == 0
    assert a.served == 3 and b.served == 3        # round-robin split
    assert pool.result(rids[0])["value"] == 0
    statuses = {pool.result(r)["status"] for r in rids}
    assert statuses == {"ok", "degraded"}


def test_pool_sheds_at_submit_when_queue_full():
    from repro.serving import DeploymentPool

    pool = DeploymentPool([_FakeGuard()], max_queue=2)
    rids = [pool.submit(i) for i in range(5)]
    shed = [r for r in rids if pool.result(r)
            and pool.result(r)["status"] == "shed"]
    assert len(shed) == 3                          # bounded backpressure
    assert all(pool.result(r)["reason"] == "queue_full" for r in shed)
    st = pool.drain()
    assert st.submitted == 5 and st.shed == 3 and st.served_ok == 2
    assert pool.metrics.counter("server.pool.shed").value == 3


def test_pool_quarantined_member_takes_no_traffic():
    from repro.serving import DeploymentPool

    sick, well = _FakeGuard(healthy=False), _FakeGuard()
    pool = DeploymentPool([sick, well], max_queue=16)
    for i in range(4):
        pool.submit(i)
    st = pool.drain()
    assert sick.served == 0 and well.served == 4   # health-aware admission
    assert st.served_ok == 4 and st.lost == 0


def test_pool_age_sheds_when_nothing_serves():
    from repro.serving import DeploymentPool

    pool = DeploymentPool([_FakeGuard(healthy=False)], max_queue=16,
                          max_wait_ticks=2)
    for i in range(3):
        pool.submit(i)
    st = pool.drain(max_ticks=50)
    assert st.shed == 3 and st.served_ok == 0      # sustained-open -> shed
    assert all(r["reason"] == "max_wait_ticks"
               for r in pool.results.values())


def test_pool_member_exception_is_lost_not_fatal():
    from repro.serving import DeploymentPool

    pool = DeploymentPool([_FakeGuard(explode=True)], max_queue=4)
    pool.submit(1)
    st = pool.drain()
    assert st.lost == 1
    assert list(pool.results.values())[0]["error"] == "RuntimeError"


def test_pool_old_import_site_is_a_warning_shim():
    """The pre-PR-9 spellings keep working but deprecate loudly: the
    runtime.server constructor and run_until_drained() both warn, forward
    to repro.serving, and return identical results/stats."""
    from repro.runtime.server import DeploymentPool as OldPool
    from repro.serving import DeploymentPool as NewPool, PoolStats

    with pytest.warns(DeprecationWarning, match="repro.serving"):
        pool = OldPool([_FakeGuard()], max_queue=4)
    assert isinstance(pool, NewPool)               # one implementation
    rid = pool.submit(21)
    with pytest.warns(DeprecationWarning, match="drain"):
        st = pool.run_until_drained()
    assert isinstance(st, PoolStats)
    assert st.served_ok == 1 and pool.result(rid)["value"] == 42
