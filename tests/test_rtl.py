"""RTL backend: codegen artifacts, bit-exact emulation, resource model,
and the full Workflow round-trip with backend="rtl"."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # image lacks hypothesis: use shim
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.creator import Creator
from repro.core.types import SHAPES_LSTM
from repro.energy.hw import XC7S15
from repro.model.layers import init_params
from repro.model.lstm import lstm_flops, lstm_schema
from repro.quant.fixedpoint import FxpFormat, fxp_requant_int, fxp_quantize
from repro.rtl import (ActLUTNode, ElementwiseNode, Graph, Edge,
                       RTLEmulator, RTLOptions, assert_bit_exact,
                       emit_graph, estimate, lower_linear_stack,
                       lower_model, reference_apply, synthesize,
                       validate_formats)


def _lstm_graph(n_layers: int = 1, **fmts):
    cfg = get_config("elastic-lstm")
    if n_layers != 1:
        cfg = cfg.with_(lstm=cfg.lstm.__class__(
            hidden=cfg.lstm.hidden, n_layers=n_layers, in_features=1,
            out_features=1, seq_len=6))
    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    return lower_model(cfg, params, **fmts)


# --------------------------------------------------------------------------- #
# Codegen artifacts
# --------------------------------------------------------------------------- #


def test_translate_rtl_emits_artifacts():
    """The acceptance path: translate(target="rtl") -> ≥3 template files."""
    cr = Creator(hw=XC7S15)
    st_ = cr.build(get_config("elastic-lstm"), SHAPES_LSTM["infer_1"])
    syn, exe = cr.translate(st_, target="rtl")
    assert syn.backend == "rtl"
    assert syn.n_artifacts >= 3
    assert len(exe.artifacts) >= 3
    vhds = [n for n in exe.artifacts if n.endswith(".vhd")]
    mems = [n for n in exe.artifacts if n.endswith(".mem")]
    assert len(vhds) >= 3 and len(mems) >= 3
    assert "manifest.json" in exe.artifacts
    man = json.loads(exe.artifacts["manifest.json"])
    assert man["total_macs"] > 0
    assert "Q8.4" in str(man["edges"])
    # entity text mentions the ROM files it loads
    cell_vhd = exe.artifacts["lstm_cell_l0.vhd"]
    assert "lstm_cell_l0_w.mem" in cell_vhd
    assert "entity lstm_cell_l0" in cell_vhd


def test_artifact_hex_round_trips():
    """BRAM init words decode back to the fxp_to_int weight codes."""
    g = _lstm_graph()
    arts = emit_graph(g)
    node = g.node("lstm_cell_l0")
    lines = arts["lstm_cell_l0_w.mem"].splitlines()
    codes = node.weight_int().reshape(-1)
    assert len(lines) == codes.size
    bits = node.w_fmt.total_bits
    for line, code in zip(lines[:64], codes[:64]):
        v = int(line, 16)
        if v >= 1 << (bits - 1):
            v -= 1 << bits
        assert v == int(code)


def test_lut_table_matches_fxp_reference():
    """ROM contents equal fxp_to_int(act(code/scale)) for every code."""
    from repro.quant.qat import hard_sigmoid

    lut = ActLUTNode(name="s", op="act_lut", inputs=[], outputs=[],
                     kind="hard_sigmoid", in_fmt=FxpFormat(8, 4),
                     out_fmt=FxpFormat(8, 4))
    t = lut.table()
    assert t.shape == (256,)
    codes = np.arange(-128, 128)
    ref = np.asarray(jnp.round(jnp.clip(
        fxp_quantize(hard_sigmoid(codes / 16.0), FxpFormat(8, 4)) * 16.0,
        -128, 127)), np.int32)
    assert np.array_equal(t, ref)


# --------------------------------------------------------------------------- #
# Bit-exactness: emulator vs fxp_quantize reference
# --------------------------------------------------------------------------- #


def test_emulator_bit_exact_default_formats():
    g = _lstm_graph()
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 6, 1)) * 2.0
    assert_bit_exact(g, x, use_pallas=True)
    assert_bit_exact(g, x, use_pallas=False)


def test_emulator_pallas_and_jnp_agree():
    g = _lstm_graph()
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 6, 1))
    a = RTLEmulator(g, use_pallas=True).run(x).outputs
    b = RTLEmulator(g, use_pallas=False).run(x).outputs
    assert np.array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(4, 8), st.integers(4, 8), st.integers(10, 16),
       st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_emulator_bit_exact_random_formats(w_total, a_total, s_total, seed):
    """Property: exact integer equality over random Q-formats + inputs."""
    w_fmt = FxpFormat(w_total, max(1, w_total - 2))
    a_fmt = FxpFormat(a_total, max(1, a_total - 3))
    s_fmt = FxpFormat(s_total, max(a_fmt.frac_bits, s_total - 8))
    g = _lstm_graph(w_fmt=w_fmt, act_fmt=a_fmt, state_fmt=s_fmt)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 6, 1)) * 3.0
    assert_bit_exact(g, x, use_pallas=False)


def test_netlist_references_resolve():
    """Every `entity work.X` the top level instantiates must be emitted."""
    import re

    k = jax.random.PRNGKey(0)
    ws = [np.asarray(jax.random.normal(k, (6, 6))) * 0.4] * 2
    bs = [np.zeros(6, np.float32)] * 2
    for g in (_lstm_graph(),
              lower_linear_stack("mlp_ref", list(zip(ws, bs)))):
        arts = emit_graph(g)
        top = arts[f"{g.name}.vhd"]
        refs = set(re.findall(r"entity work\.(\w+)", top))
        ents = {m for a in arts.values()
                for m in re.findall(r"^entity (\w+) is", a, re.M)}
        assert refs <= ents, (g.name, refs - ents)


def test_mlp_stack_bit_exact():
    k = jax.random.PRNGKey(3)
    ws = [np.asarray(jax.random.normal(jax.random.PRNGKey(i), s)) * 0.5
          for i, s in enumerate([(8, 16), (16, 4)])]
    bs = [np.full(16, 0.1, np.float32), np.zeros(4, np.float32)]
    g = lower_linear_stack("mlp_demo", list(zip(ws, bs)))
    x = jax.random.normal(k, (5, 8))
    assert_bit_exact(g, x, use_pallas=True)
    assert_bit_exact(g, x, use_pallas=False)
    arts = emit_graph(g)
    assert "mlp_demo.vhd" in arts and "linear_0_w.mem" in arts


def test_elementwise_node_bit_exact():
    a_fmt = FxpFormat(8, 4)
    out_fmt = FxpFormat(8, 5)
    g = Graph(name="ew")
    g.edges["x"] = Edge("x", (6,), a_fmt)
    g.edges["x2"] = Edge("x2", (6,), a_fmt)
    g.inputs = ["x"]
    g.add(ElementwiseNode(name="sq", op="elementwise", inputs=["x", "x"],
                          outputs=["y"], kind="mul", a_fmt=a_fmt,
                          b_fmt=a_fmt, out_fmt=out_fmt),
          Edge("y", (6,), out_fmt))
    g.outputs = ["y"]
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 6))
    assert_bit_exact(g, x, use_pallas=False)


def test_requant_int_matches_fxp_quantize():
    """The integer rounding shift is fxp_quantize, code-for-code."""
    rng = np.random.default_rng(0)
    for from_frac, fmt in [(8, FxpFormat(8, 4)), (10, FxpFormat(8, 6)),
                           (4, FxpFormat(8, 6)), (6, FxpFormat(16, 6))]:
        v = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, 256), jnp.int32)
        got = fxp_requant_int(v, from_frac, fmt)
        ref = fxp_quantize(v.astype(jnp.float32) / (1 << from_frac), fmt)
        assert np.array_equal(np.asarray(got, np.int64),
                              np.asarray(jnp.round(ref * fmt.scale),
                                         np.int64)), (from_frac, str(fmt))


def test_validate_formats_rejects_overflow_risk():
    with pytest.raises(ValueError):
        validate_formats(act=FxpFormat(16, 8), weight=FxpFormat(16, 8),
                         state=FxpFormat(16, 8), fan_in=1024)
    with pytest.raises(ValueError):
        # state narrower than activations: alignment shift would be lossy
        validate_formats(act=FxpFormat(8, 6), weight=FxpFormat(8, 6),
                         state=FxpFormat(16, 4), fan_in=8)


# --------------------------------------------------------------------------- #
# Staged executor: execution paths × batch × depth, program cache, run_many
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("mode", ["fused", "pallas", "jnp"])
@pytest.mark.parametrize("batch", [1, 7, 64])
@pytest.mark.parametrize("n_layers", [1, 2])
def test_emulator_bit_exact_all_paths(mode, batch, n_layers):
    """Every execution path × batch size × stacked depth, exact equality."""
    g = _lstm_graph(n_layers=n_layers)
    x = jax.random.normal(jax.random.PRNGKey(10 * batch + n_layers),
                          (batch, 6, 1)) * 2.0
    assert_bit_exact(g, x, mode=mode)


def test_compiled_program_cache_hits():
    """Repeated same-shape runs replay one compiled program (no retrace)."""
    g = _lstm_graph()
    em = RTLEmulator(g)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 1))
    first = em.run(x)
    assert em.trace_count == 1
    for _ in range(5):
        rep = em.run(x)
    assert em.trace_count == 1, "same (shape, dtype) must not retrace"
    assert np.array_equal(np.asarray(rep.outputs), np.asarray(first.outputs))
    em.run(x[:2])
    assert em.trace_count == 2              # new batch size: one more trace
    em.run(x)
    assert em.trace_count == 2              # original program still cached


def test_program_cache_lru_evicts():
    g = _lstm_graph()
    em = RTLEmulator(g, max_programs=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 6, 1))
    em.run(x[:1]), em.run(x[:2]), em.run(x[:3])     # 3 shapes, capacity 2
    assert em.trace_count == 3
    em.run(x[:3]), em.run(x[:2])                    # both still resident
    assert em.trace_count == 3
    em.run(x[:1])                                   # was evicted: retrace
    assert em.trace_count == 4


def test_run_many_single_dispatch_matches_individual():
    g = _lstm_graph()
    em = RTLEmulator(g)
    xs = [jax.random.normal(jax.random.PRNGKey(i), (b, 6, 1)) * 2.0
          for i, b in enumerate((1, 3, 4))]
    outs = em.run_many(xs)
    assert em.trace_count == 1, "list input must execute as ONE dispatch"
    assert [o.outputs.shape[0] for o in outs] == [1, 3, 4]
    for x, r in zip(xs, outs):
        solo = RTLEmulator(g).run(x)
        assert np.array_equal(np.asarray(r.outputs),
                              np.asarray(solo.outputs))
        assert np.array_equal(np.asarray(r.trace["h0"]),
                              np.asarray(solo.trace["h0"]))


def test_per_step_legacy_path_matches_fused():
    """The un-jitted per-step schedule (benchmark baseline) stays exact."""
    g = _lstm_graph()
    em = RTLEmulator(g)
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 6, 1))
    a = em.run(x)
    b = em.run_per_step(x)
    assert np.array_equal(np.asarray(a.outputs), np.asarray(b.outputs))


def test_executable_run_many_and_mode_plumbing():
    cr = Creator(hw=XC7S15)
    st_ = cr.build(get_config("elastic-lstm"), SHAPES_LSTM["infer_1"])
    _, exe = cr.translate(st_, target="rtl",
                          options=RTLOptions(emulator_mode="jnp"))
    assert exe.emulator.mode == "jnp"
    _, exe_f = cr.translate(st_, target="rtl")
    assert exe_f.emulator.mode == "fused"
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 1))
    outs = exe_f.run_many([x, x])
    assert len(outs) == 2
    assert np.array_equal(np.asarray(outs[0].outputs),
                          np.asarray(outs[1].outputs))


# --------------------------------------------------------------------------- #
# Resource / cycle model
# --------------------------------------------------------------------------- #


def test_resource_model_monotone_in_hidden():
    cfg = get_config("elastic-lstm")
    prev = None
    for hidden in (8, 16, 32):
        c2 = cfg.with_(lstm=cfg.lstm.__class__(
            hidden=hidden, n_layers=1, in_features=1, out_features=1,
            seq_len=6))
        params = init_params(lstm_schema(c2), jax.random.PRNGKey(0))
        rr = estimate(lower_model(c2, params))
        cur = (rr.cycles, rr.dsp, rr.bram36, rr.lut)
        if prev is not None:
            assert all(a >= b for a, b in zip(cur, prev)), (cur, prev)
        assert rr.cycles > 0 and rr.duty > 0.5
        prev = cur


def test_resource_model_monotone_in_bits():
    a5 = FxpFormat(5, 3)                  # keeps Q16 weights in the envelope
    g8 = _lstm_graph(w_fmt=FxpFormat(8, 6), act_fmt=a5)
    g16 = _lstm_graph(w_fmt=FxpFormat(16, 12), act_fmt=a5)
    r8, r16 = estimate(g8), estimate(g16)
    assert r16.bram36 >= r8.bram36
    assert r16.lut >= r8.lut


def test_synthesis_report_tracks_table1():
    """Generated-artifact estimate must sit in the paper's ~10% band."""
    g = _lstm_graph()
    rep = synthesize(g, hw=XC7S15,
                     model_flops=float(lstm_flops(get_config("elastic-lstm"))))
    assert rep.fits
    lat_err = (rep.est_latency_s * 1e6 - 57.25) / 57.25
    eff_err = (rep.est_gop_per_j - 5.33) / 5.33
    assert abs(lat_err) < 0.12, rep.est_latency_s
    assert abs(eff_err) < 0.12, rep.est_gop_per_j
    assert rep.resources["dsp"] <= 20 and rep.resources["bram36"] <= 10


# --------------------------------------------------------------------------- #
# Workflow round-trip on the generated accelerator
# --------------------------------------------------------------------------- #


def test_workflow_roundtrip_target_rtl():
    from repro.core.report import DesignReport
    from repro.core.workflow import Requirement, Workflow

    cfg = get_config("elastic-lstm")

    def train_fn(knobs):
        params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
        rep = DesignReport(model="elastic-lstm", train_loss=0.0,
                           eval_loss=0.0, weight_fmt=str(
                               FxpFormat(knobs["bits"], knobs["bits"] - 2)))
        return params, rep, None

    def step_builder(knobs, params):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 1))
        return None, (params, x), float(lstm_flops(cfg))

    def stepper_builder(knobs):
        return Creator(hw=XC7S15).build(cfg, SHAPES_LSTM["infer_1"])

    def options_from_knobs(knobs):
        b = knobs["bits"]
        return RTLOptions(w_fmt=FxpFormat(b, b - 2),
                          act_fmt=FxpFormat(b, b - 4))

    wf = Workflow(creator=Creator(hw=XC7S15), train_fn=train_fn,
                  step_builder=step_builder, stepper_builder=stepper_builder,
                  target="rtl", options_from_knobs=options_from_knobs)
    hist = wf.run(Requirement(max_latency_s=1.0), lambda h: None,
                  {"bits": 8}, max_iters=2)
    assert len(hist) == 1 and hist[0].satisfied
    rec = hist[0]
    assert rec.synthesis.backend == "rtl"
    assert rec.synthesis.n_artifacts >= 3
    assert rec.measurement.platform.startswith("rtl-emulator")
    assert rec.measurement.target == "rtl"
    assert rec.measurement.n_runs >= 1
    assert rec.measurement.latency_s > 0
    assert abs(rec.est_vs_meas["latency_rel_err"]) < 1e-9
    assert rec.measurement.gop_per_j > 1.0


def test_rtl_executable_save(tmp_path):
    cr = Creator(hw=XC7S15)
    st_ = cr.build(get_config("elastic-lstm"), SHAPES_LSTM["infer_1"])
    _, exe = cr.translate(st_, target="rtl")
    exe.save(str(tmp_path))
    files = list(tmp_path.iterdir())
    assert len(files) == len(exe.artifacts)
    assert exe.cycles > 0
