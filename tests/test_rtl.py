"""RTL backend: codegen artifacts, bit-exact emulation, resource model,
and the full Workflow round-trip with backend="rtl"."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # image lacks hypothesis: use shim
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.creator import Creator
from repro.core.types import SHAPES_CONV1D, SHAPES_LSTM
from repro.energy.hw import XC7S15
from repro.model.layers import init_params
from repro.model.lstm import lstm_flops, lstm_schema
from repro.quant.fixedpoint import FxpFormat, fxp_requant_int, fxp_quantize
from repro.rtl import (ActLUTNode, Conv1dNode, ElementwiseNode, Graph, Edge,
                       LinearNode, LSTMCellNode, RTLEmulator, RTLOptions,
                       assert_bit_exact, emit_graph, estimate, lower_conv_stack,
                       lower_linear_stack, lower_model, node_cost, synthesize,
                       validate_formats)


def _conv_graph(**fmts):
    from repro.model.conv1d import conv1d_schema

    cfg = get_config("elastic-conv1d")
    params = init_params(conv1d_schema(cfg), jax.random.PRNGKey(0))
    return lower_model(cfg, params, **fmts), cfg, params


def _lstm_graph(n_layers: int = 1, **fmts):
    cfg = get_config("elastic-lstm")
    if n_layers != 1:
        cfg = cfg.with_(lstm=cfg.lstm.__class__(
            hidden=cfg.lstm.hidden, n_layers=n_layers, in_features=1,
            out_features=1, seq_len=6))
    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    return lower_model(cfg, params, **fmts)


# --------------------------------------------------------------------------- #
# Codegen artifacts
# --------------------------------------------------------------------------- #


def test_translate_rtl_emits_artifacts():
    """The acceptance path: translate(target="rtl") -> ≥3 template files."""
    cr = Creator(hw=XC7S15)
    st_ = cr.build(get_config("elastic-lstm"), SHAPES_LSTM["infer_1"])
    syn, exe = cr.translate(st_, target="rtl")
    assert syn.backend == "rtl"
    assert syn.n_artifacts >= 3
    assert len(exe.artifacts) >= 3
    vhds = [n for n in exe.artifacts if n.endswith(".vhd")]
    mems = [n for n in exe.artifacts if n.endswith(".mem")]
    assert len(vhds) >= 3 and len(mems) >= 3
    assert "manifest.json" in exe.artifacts
    man = json.loads(exe.artifacts["manifest.json"])
    assert man["total_macs"] > 0
    assert "Q8.4" in str(man["edges"])
    # entity text mentions the ROM files it loads
    cell_vhd = exe.artifacts["lstm_cell_l0.vhd"]
    assert "lstm_cell_l0_w.mem" in cell_vhd
    assert "entity lstm_cell_l0" in cell_vhd


def test_artifact_hex_round_trips():
    """BRAM init words decode back to the fxp_to_int weight codes."""
    g = _lstm_graph()
    arts = emit_graph(g)
    node = g.node("lstm_cell_l0")
    lines = arts["lstm_cell_l0_w.mem"].splitlines()
    codes = node.weight_int().reshape(-1)
    assert len(lines) == codes.size
    bits = node.w_fmt.total_bits
    for line, code in zip(lines[:64], codes[:64]):
        v = int(line, 16)
        if v >= 1 << (bits - 1):
            v -= 1 << bits
        assert v == int(code)


def test_lut_table_matches_fxp_reference():
    """ROM contents equal fxp_to_int(act(code/scale)) for every code."""
    from repro.quant.qat import hard_sigmoid

    lut = ActLUTNode(name="s", op="act_lut", inputs=[], outputs=[],
                     kind="hard_sigmoid", in_fmt=FxpFormat(8, 4),
                     out_fmt=FxpFormat(8, 4))
    t = lut.table()
    assert t.shape == (256,)
    codes = np.arange(-128, 128)
    ref = np.asarray(jnp.round(jnp.clip(
        fxp_quantize(hard_sigmoid(codes / 16.0), FxpFormat(8, 4)) * 16.0,
        -128, 127)), np.int32)
    assert np.array_equal(t, ref)


# --------------------------------------------------------------------------- #
# Bit-exactness: emulator vs fxp_quantize reference
# --------------------------------------------------------------------------- #


def test_emulator_bit_exact_default_formats():
    g = _lstm_graph()
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 6, 1)) * 2.0
    assert_bit_exact(g, x, use_pallas=True)
    assert_bit_exact(g, x, use_pallas=False)


def test_emulator_pallas_and_jnp_agree():
    g = _lstm_graph()
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 6, 1))
    a = RTLEmulator(g, use_pallas=True).run(x).outputs
    b = RTLEmulator(g, use_pallas=False).run(x).outputs
    assert np.array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(4, 8), st.integers(4, 8), st.integers(10, 16),
       st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_emulator_bit_exact_random_formats(w_total, a_total, s_total, seed):
    """Property: exact integer equality over random Q-formats + inputs."""
    w_fmt = FxpFormat(w_total, max(1, w_total - 2))
    a_fmt = FxpFormat(a_total, max(1, a_total - 3))
    s_fmt = FxpFormat(s_total, max(a_fmt.frac_bits, s_total - 8))
    g = _lstm_graph(w_fmt=w_fmt, act_fmt=a_fmt, state_fmt=s_fmt)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 6, 1)) * 3.0
    assert_bit_exact(g, x, use_pallas=False)


def test_netlist_references_resolve():
    """Every `entity work.X` the top level instantiates must be emitted."""
    import re

    k = jax.random.PRNGKey(0)
    ws = [np.asarray(jax.random.normal(k, (6, 6))) * 0.4] * 2
    bs = [np.zeros(6, np.float32)] * 2
    for g in (_lstm_graph(),
              lower_linear_stack("mlp_ref", list(zip(ws, bs)))):
        arts = emit_graph(g)
        top = arts[f"{g.name}.vhd"]
        refs = set(re.findall(r"entity work\.(\w+)", top))
        ents = {m for a in arts.values()
                for m in re.findall(r"^entity (\w+) is", a, re.M)}
        assert refs <= ents, (g.name, refs - ents)


def test_mlp_stack_bit_exact():
    k = jax.random.PRNGKey(3)
    ws = [np.asarray(jax.random.normal(jax.random.PRNGKey(i), s)) * 0.5
          for i, s in enumerate([(8, 16), (16, 4)])]
    bs = [np.full(16, 0.1, np.float32), np.zeros(4, np.float32)]
    g = lower_linear_stack("mlp_demo", list(zip(ws, bs)))
    x = jax.random.normal(k, (5, 8))
    assert_bit_exact(g, x, use_pallas=True)
    assert_bit_exact(g, x, use_pallas=False)
    arts = emit_graph(g)
    assert "mlp_demo.vhd" in arts and "linear_0_w.mem" in arts


def test_elementwise_node_bit_exact():
    a_fmt = FxpFormat(8, 4)
    out_fmt = FxpFormat(8, 5)
    g = Graph(name="ew")
    g.edges["x"] = Edge("x", (6,), a_fmt)
    g.edges["x2"] = Edge("x2", (6,), a_fmt)
    g.inputs = ["x"]
    g.add(ElementwiseNode(name="sq", op="elementwise", inputs=["x", "x"],
                          outputs=["y"], kind="mul", a_fmt=a_fmt,
                          b_fmt=a_fmt, out_fmt=out_fmt),
          Edge("y", (6,), out_fmt))
    g.outputs = ["y"]
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 6))
    assert_bit_exact(g, x, use_pallas=False)


def test_requant_int_matches_fxp_quantize():
    """The integer rounding shift is fxp_quantize, code-for-code."""
    rng = np.random.default_rng(0)
    for from_frac, fmt in [(8, FxpFormat(8, 4)), (10, FxpFormat(8, 6)),
                           (4, FxpFormat(8, 6)), (6, FxpFormat(16, 6))]:
        v = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, 256), jnp.int32)
        got = fxp_requant_int(v, from_frac, fmt)
        ref = fxp_quantize(v.astype(jnp.float32) / (1 << from_frac), fmt)
        assert np.array_equal(np.asarray(got, np.int64),
                              np.asarray(jnp.round(ref * fmt.scale),
                                         np.int64)), (from_frac, str(fmt))


def test_validate_formats_rejects_overflow_risk():
    with pytest.raises(ValueError):
        validate_formats(act=FxpFormat(16, 8), weight=FxpFormat(16, 8),
                         state=FxpFormat(16, 8), fan_in=1024)
    with pytest.raises(ValueError):
        # state narrower than activations: alignment shift would be lossy
        validate_formats(act=FxpFormat(8, 6), weight=FxpFormat(8, 6),
                         state=FxpFormat(16, 4), fan_in=8)


# --------------------------------------------------------------------------- #
# Staged executor: execution paths × batch × depth, program cache, run_many
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("mode", ["fused", "pallas", "jnp"])
@pytest.mark.parametrize("batch", [1, 7, 64])
@pytest.mark.parametrize("n_layers", [1, 2])
def test_emulator_bit_exact_all_paths(mode, batch, n_layers):
    """Every execution path × batch size × stacked depth, exact equality."""
    g = _lstm_graph(n_layers=n_layers)
    x = jax.random.normal(jax.random.PRNGKey(10 * batch + n_layers),
                          (batch, 6, 1)) * 2.0
    assert_bit_exact(g, x, mode=mode)


def test_compiled_program_cache_hits():
    """Repeated same-shape runs replay one compiled program (no retrace)."""
    g = _lstm_graph()
    em = RTLEmulator(g)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 1))
    first = em.run(x)
    assert em.trace_count == 1
    for _ in range(5):
        rep = em.run(x)
    assert em.trace_count == 1, "same (shape, dtype) must not retrace"
    assert np.array_equal(np.asarray(rep.outputs), np.asarray(first.outputs))
    em.run(x[:2])
    assert em.trace_count == 2              # new batch size: one more trace
    em.run(x)
    assert em.trace_count == 2              # original program still cached


def test_program_cache_lru_evicts():
    g = _lstm_graph()
    em = RTLEmulator(g, max_programs=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 6, 1))
    em.run(x[:1]), em.run(x[:2]), em.run(x[:3])     # 3 shapes, capacity 2
    assert em.trace_count == 3
    em.run(x[:3]), em.run(x[:2])                    # both still resident
    assert em.trace_count == 3
    em.run(x[:1])                                   # was evicted: retrace
    assert em.trace_count == 4


def test_run_many_single_dispatch_matches_individual():
    g = _lstm_graph()
    em = RTLEmulator(g)
    xs = [jax.random.normal(jax.random.PRNGKey(i), (b, 6, 1)) * 2.0
          for i, b in enumerate((1, 3, 4))]
    outs = em.run_many(xs)
    assert em.trace_count == 1, "list input must execute as ONE dispatch"
    assert [o.outputs.shape[0] for o in outs] == [1, 3, 4]
    for x, r in zip(xs, outs):
        solo = RTLEmulator(g).run(x)
        assert np.array_equal(np.asarray(r.outputs),
                              np.asarray(solo.outputs))
        assert np.array_equal(np.asarray(r.trace["h0"]),
                              np.asarray(solo.trace["h0"]))


def test_per_step_legacy_path_matches_fused():
    """The un-jitted per-step schedule (benchmark baseline) stays exact."""
    g = _lstm_graph()
    em = RTLEmulator(g)
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 6, 1))
    a = em.run(x)
    b = em.run_per_step(x)
    assert np.array_equal(np.asarray(a.outputs), np.asarray(b.outputs))


def test_executable_run_many_and_mode_plumbing():
    cr = Creator(hw=XC7S15)
    st_ = cr.build(get_config("elastic-lstm"), SHAPES_LSTM["infer_1"])
    _, exe = cr.translate(st_, target="rtl",
                          options=RTLOptions(emulator_mode="jnp"))
    assert exe.emulator.mode == "jnp"
    _, exe_f = cr.translate(st_, target="rtl")
    assert exe_f.emulator.mode == "fused"
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 1))
    outs = exe_f.run_many([x, x])
    assert len(outs) == 2
    assert np.array_equal(np.asarray(outs[0].outputs),
                          np.asarray(outs[1].outputs))


# --------------------------------------------------------------------------- #
# Resource / cycle model
# --------------------------------------------------------------------------- #


def test_resource_model_monotone_in_hidden():
    cfg = get_config("elastic-lstm")
    prev = None
    for hidden in (8, 16, 32):
        c2 = cfg.with_(lstm=cfg.lstm.__class__(
            hidden=hidden, n_layers=1, in_features=1, out_features=1,
            seq_len=6))
        params = init_params(lstm_schema(c2), jax.random.PRNGKey(0))
        rr = estimate(lower_model(c2, params))
        cur = (rr.cycles, rr.dsp, rr.bram36, rr.lut)
        if prev is not None:
            assert all(a >= b for a, b in zip(cur, prev)), (cur, prev)
        assert rr.cycles > 0 and rr.duty > 0.5
        prev = cur


def test_resource_model_monotone_in_bits():
    a5 = FxpFormat(5, 3)                  # keeps Q16 weights in the envelope
    g8 = _lstm_graph(w_fmt=FxpFormat(8, 6), act_fmt=a5)
    g16 = _lstm_graph(w_fmt=FxpFormat(16, 12), act_fmt=a5)
    r8, r16 = estimate(g8), estimate(g16)
    assert r16.bram36 >= r8.bram36
    assert r16.lut >= r8.lut


def test_synthesis_report_tracks_table1():
    """Generated-artifact estimate must sit in the paper's ~10% band."""
    g = _lstm_graph()
    rep = synthesize(g, hw=XC7S15,
                     model_flops=float(lstm_flops(get_config("elastic-lstm"))))
    assert rep.fits
    lat_err = (rep.est_latency_s * 1e6 - 57.25) / 57.25
    eff_err = (rep.est_gop_per_j - 5.33) / 5.33
    assert abs(lat_err) < 0.12, rep.est_latency_s
    assert abs(eff_err) < 0.12, rep.est_gop_per_j
    assert rep.resources["dsp"] <= 20 and rep.resources["bram36"] <= 10


# --------------------------------------------------------------------------- #
# Workflow round-trip on the generated accelerator
# --------------------------------------------------------------------------- #


def test_workflow_roundtrip_target_rtl():
    from repro.core.report import DesignReport
    from repro.core.workflow import Requirement, Workflow

    cfg = get_config("elastic-lstm")

    def train_fn(knobs):
        params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
        rep = DesignReport(model="elastic-lstm", train_loss=0.0,
                           eval_loss=0.0, weight_fmt=str(
                               FxpFormat(knobs["bits"], knobs["bits"] - 2)))
        return params, rep, None

    def step_builder(knobs, params):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 1))
        return None, (params, x), float(lstm_flops(cfg))

    def stepper_builder(knobs):
        return Creator(hw=XC7S15).build(cfg, SHAPES_LSTM["infer_1"])

    def options_from_knobs(knobs):
        b = knobs["bits"]
        return RTLOptions(w_fmt=FxpFormat(b, b - 2),
                          act_fmt=FxpFormat(b, b - 4))

    wf = Workflow(creator=Creator(hw=XC7S15), train_fn=train_fn,
                  step_builder=step_builder, stepper_builder=stepper_builder,
                  target="rtl", options_from_knobs=options_from_knobs)
    hist = wf.run(Requirement(max_latency_s=1.0), lambda h: None,
                  {"bits": 8}, max_iters=2)
    assert len(hist) == 1 and hist[0].satisfied
    rec = hist[0]
    assert rec.synthesis.backend == "rtl"
    assert rec.synthesis.n_artifacts >= 3
    assert rec.measurement.platform.startswith("rtl-emulator")
    assert rec.measurement.target == "rtl"
    assert rec.measurement.n_runs >= 1
    assert rec.measurement.latency_s > 0
    assert abs(rec.est_vs_meas["latency_rel_err"]) < 1e-9
    assert rec.measurement.gop_per_j > 1.0


def test_rtl_executable_save(tmp_path):
    cr = Creator(hw=XC7S15)
    st_ = cr.build(get_config("elastic-lstm"), SHAPES_LSTM["infer_1"])
    _, exe = cr.translate(st_, target="rtl")
    exe.save(str(tmp_path))
    files = {p.name for p in tmp_path.iterdir()}
    # artifacts + the static verifier's report (DESIGN.md §13)
    assert files == set(exe.artifacts) | {"analysis.json"}
    assert exe.analysis is not None and exe.analysis.passed
    assert exe.cycles > 0


# --------------------------------------------------------------------------- #
# IR construction safety: array fields are required, shape-checked at build
# --------------------------------------------------------------------------- #


def test_nodes_reject_missing_arrays():
    with pytest.raises(TypeError, match="weight.*required"):
        LinearNode(name="l", op="linear", inputs=["x"], outputs=["y"],
                   weight=None, bias=np.zeros(4, np.float32))
    with pytest.raises(TypeError, match="bias.*required"):
        LSTMCellNode(name="c", op="lstm_cell", inputs=["x"], outputs=["h"],
                     weight=np.zeros((21, 80), np.float32), bias=None)
    with pytest.raises(TypeError):
        LinearNode(name="l", op="linear", inputs=["x"], outputs=["y"])  # noqa


def test_nodes_reject_shape_mismatch():
    with pytest.raises(ValueError, match="bias shape"):
        LinearNode(name="l", op="linear", inputs=["x"], outputs=["y"],
                   weight=np.zeros((4, 8), np.float32),
                   bias=np.zeros(4, np.float32))
    with pytest.raises(ValueError, match="weight shape"):
        LSTMCellNode(name="c", op="lstm_cell", inputs=["x"], outputs=["h"],
                     weight=np.zeros((10, 80), np.float32),
                     bias=np.zeros(80, np.float32), d_in=1, hidden=20)
    with pytest.raises(ValueError, match="out_len"):
        Conv1dNode(name="cv", op="conv1d", inputs=["x"], outputs=["y"],
                   weight=np.zeros((5, 2), np.float32),
                   bias=np.zeros(2, np.float32), kernel=5, stride=1,
                   seq_len=4, channels=2)


# --------------------------------------------------------------------------- #
# Golden artifacts: emission is deterministic and pinned to a snapshot
# --------------------------------------------------------------------------- #


def test_emit_graph_deterministic():
    """Emitting the same lowered graph twice yields byte-identical dicts."""
    g = _lstm_graph()
    a1, a2 = emit_graph(g), emit_graph(g)
    assert sorted(a1) == sorted(a2)
    for name in a1:
        assert a1[name] == a2[name], f"{name} differs between emissions"
    gc, _, _ = _conv_graph()
    b1, b2 = emit_graph(gc), emit_graph(gc)
    assert b1 == b2


def test_elastic_lstm_manifest_matches_golden():
    """The reference design's manifest is pinned: codegen drift (formats,
    cycle model, node set) must be an intentional, reviewed change. The
    manifest depends only on the config (shapes/Q-formats/cost model), not
    on trained weights, so the snapshot is platform-stable."""
    import os

    g = _lstm_graph()
    got = emit_graph(g)["manifest.json"]
    golden = os.path.join(os.path.dirname(__file__), "golden",
                          "elastic_lstm_manifest.json")
    with open(golden) as f:
        want = f.read()
    assert got == want, (
        "manifest.json drifted from tests/golden/elastic_lstm_manifest.json"
        " — if the change is intentional, regenerate the snapshot")


# --------------------------------------------------------------------------- #
# Hardware-template (op) registry
# --------------------------------------------------------------------------- #


def test_template_registry_lists_and_resolves():
    from repro.rtl import get_template, list_templates

    kinds = list_templates()
    for kind in ("linear", "lstm_cell", "conv1d", "act_lut", "act_apply",
                 "elementwise"):
        assert kind in kinds
        assert get_template(kind).kind == kind


def test_template_registry_unknown_kind_lists_registered():
    from repro.rtl import get_template

    with pytest.raises(ValueError) as ei:
        get_template("systolic_gemm")
    msg = str(ei.value)
    assert "systolic_gemm" in msg and "lstm_cell" in msg and "conv1d" in msg


def test_template_registry_double_registration_policy():
    from repro.rtl import get_template, register_template
    from repro.rtl.oplib import HWTemplate

    class Dup(HWTemplate):
        kind = "linear"

    with pytest.raises(ValueError, match="already registered"):
        register_template(Dup())
    orig = get_template("linear")
    register_template(Dup(), overwrite=True)      # explicit swap is allowed
    try:
        assert isinstance(get_template("linear"), Dup)
    finally:
        register_template(orig, overwrite=True)


def test_unknown_family_error_lists_lowerable():
    from repro.rtl.oplib import lowering_for

    with pytest.raises(NotImplementedError) as ei:
        lowering_for("dense")
    assert "conv1d" in str(ei.value) and "lstm" in str(ei.value)


def test_custom_template_round_trips():
    """A minimal in-test template: lower -> emit -> emulate -> cost, without
    touching any repro internals — the plugin contract of DESIGN.md §9."""
    from dataclasses import dataclass as dc

    from repro.rtl import (HWTemplate, get_template, register_template,
                           unregister_template)
    from repro.rtl.ir import Node
    from repro.rtl.resources import NodeCost

    @dc
    class NegNode(Node):
        fmt: FxpFormat = FxpFormat(8, 4)

    class NegTemplate(HWTemplate):
        """y = -x: one adder, no memories."""

        kind = "negate"
        node_cls = NegNode

        def execute(self, n, env, em, mode):
            env[n.outputs[0]] = jnp.clip(-env[n.inputs[0]],
                                         n.fmt.lo, n.fmt.hi)

        def reference(self, n, env, luts):
            env[n.outputs[0]] = fxp_quantize(-env[n.inputs[0]], n.fmt)

        def emit(self, graph, n, out):
            out[f"{n.name}.vhd"] = (f"entity {n.name} is\n"
                                    f"-- y <= -x\nend entity {n.name};\n")

        def cost(self, n):
            return NodeCost(n.name, n.op, cycles=1, active_cycles=1,
                            dsp=0, bram36=0, lut=8)

    register_template(NegTemplate())
    try:
        fmt = FxpFormat(8, 4)
        g = Graph(name="neg_demo")
        g.edges["x"] = Edge("x", (6,), fmt)
        g.inputs = ["x"]
        g.add(NegNode(name="neg0", op="negate", inputs=["x"],
                      outputs=["y"], fmt=fmt), Edge("y", (6,), fmt))
        g.outputs = ["y"]
        x = jax.random.normal(jax.random.PRNGKey(7), (3, 6))
        assert_bit_exact(g, x, mode="jnp")            # emulate == reference
        arts = emit_graph(g)                          # emit walks the plugin
        assert "neg0.vhd" in arts and "neg_demo.vhd" in arts
        assert "i_neg0 : entity work.neg0" in arts["neg_demo.vhd"]
        rr = estimate(g)                              # cost walks the plugin
        assert rr.cycles == 1 and rr.lut == 8
        assert get_template("negate").kind == "negate"
    finally:
        unregister_template("negate")


# --------------------------------------------------------------------------- #
# conv1d template: bit-exact, deployable end-to-end, costed
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("mode", ["fused", "pallas", "jnp"])
@pytest.mark.parametrize("batch", [1, 5])
def test_conv1d_bit_exact_all_paths(mode, batch):
    g, cfg, _ = _conv_graph()
    c = cfg.conv1d
    x = jax.random.normal(jax.random.PRNGKey(3 * batch),
                          (batch, c.seq_len, c.channels)) * 2.0
    assert_bit_exact(g, x, mode=mode)


def test_conv1d_stack_strides_and_kernels_bit_exact():
    k = jax.random.PRNGKey(11)
    for kernel, stride, seq in [(2, 1, 8), (3, 2, 16), (4, 3, 15)]:
        C = 2
        t1 = (seq - kernel) // stride + 1
        t2 = (t1 - kernel) // stride + 1
        if t2 < 1:
            continue
        blocks = [(np.asarray(jax.random.normal(
            jax.random.PRNGKey(kernel * 10 + stride + i),
            (kernel, C))) * 0.5, np.full(C, 0.05, np.float32))
            for i in range(2)]
        head = (np.asarray(jax.random.normal(k, (t2 * C, 2))) * 0.4,
                np.zeros(2, np.float32))
        g = lower_conv_stack(f"c{kernel}{stride}", blocks, head,
                             seq_len=seq, stride=stride)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, seq, C))
        assert_bit_exact(g, x, mode="jnp")
        assert_bit_exact(g, x, mode="fused")


def test_conv_stack_envelope_uses_widest_kernel():
    """A later block's bigger kernel must count toward the §4 fan-in."""
    C = 2
    blocks = [(np.zeros((2, C), np.float32), np.zeros(C, np.float32)),
              (np.zeros((200, C), np.float32), np.zeros(C, np.float32))]
    head = (np.zeros((1 * C, 1), np.float32), np.zeros(1, np.float32))
    with pytest.raises(ValueError, match="envelope"):
        lower_conv_stack("wide", blocks, head, seq_len=256, stride=1,
                         w_fmt=FxpFormat(12, 8), act_fmt=FxpFormat(9, 4))


def test_conv1d_artifacts_and_netlist():
    import re

    g, _, _ = _conv_graph()
    arts = emit_graph(g)
    assert "conv1d_0.vhd" in arts and "conv1d_0_w.mem" in arts
    vhd = arts["conv1d_0.vhd"]
    assert "entity conv1d_0" in vhd
    assert "conv1d_0_w.mem" in vhd and 'rom_style' in vhd   # BRAM taps
    assert "STRIDE" in vhd and "KERNEL" in vhd
    # tap .mem round-trips to the fxp_to_int codes
    node = g.node("conv1d_0")
    lines = arts["conv1d_0_w.mem"].splitlines()
    codes = node.weight_int().reshape(-1)
    assert len(lines) == codes.size
    # every instantiated entity resolves
    top = arts[f"{g.name}.vhd"]
    refs = set(re.findall(r"entity work\.(\w+)", top))
    ents = {m for a in arts.values()
            for m in re.findall(r"^entity (\w+) is", a, re.M)}
    assert refs <= ents, refs - ents


def test_conv1d_cost_model():
    g, _, _ = _conv_graph()
    n = g.node("conv1d_0")
    c = node_cost(n)
    assert c.dsp >= 1 and c.bram36 >= 1
    assert c.cycles > c.active_cycles > 0
    assert c.active_cycles == n.macs() + n.out_len * n.channels
    rr = estimate(g)
    assert rr.fits() and rr.cycles > 0
    syn = synthesize(g, hw=XC7S15)
    assert syn.fits and syn.est_latency_s < 57.25e-6   # lighter than Table I


def test_conv1d_end_to_end_deployment(tmp_path):
    """Creator.translate(target="rtl") -> Deployment.measure -> .save."""
    from repro.model.conv1d import conv1d_flops

    cfg = get_config("elastic-conv1d")
    cr = Creator(hw=XC7S15)
    st_ = cr.build(cfg, SHAPES_CONV1D["infer_1"])
    syn, dep = cr.translate(st_, target="rtl")
    assert syn.backend == "rtl" and syn.fits
    x = jax.random.normal(jax.random.PRNGKey(0),
                          (2, cfg.conv1d.seq_len, cfg.conv1d.channels))
    y = dep(x)
    assert y.shape == (2, cfg.conv1d.out_features)
    meas = dep.measure((x,), model=cfg.name,
                       model_flops=float(conv1d_flops(cfg)), n_runs=2)
    assert meas.target == "rtl" and meas.latency_s > 0
    dep.save(str(tmp_path))
    # every artifact, plus the static-analysis report save() adds
    assert ({p.name for p in tmp_path.iterdir()}
            == set(dep.artifacts) | {"analysis.json"})


def test_workflow_roundtrip_target_rtl_conv1d():
    """The same single run_once path drives the conv1d arch."""
    from repro.core.report import DesignReport
    from repro.core.workflow import Requirement, Workflow
    from repro.model.conv1d import conv1d_apply, conv1d_flops, conv1d_schema

    cfg = get_config("elastic-conv1d")

    def train_fn(knobs):
        params = init_params(conv1d_schema(cfg), jax.random.PRNGKey(0))
        rep = DesignReport(model=cfg.name, train_loss=0.0, eval_loss=0.0,
                           weight_fmt=str(FxpFormat(knobs["bits"],
                                                    knobs["bits"] - 2)))
        return params, rep, None

    def step_builder(knobs, params):
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (1, cfg.conv1d.seq_len, cfg.conv1d.channels))
        return ((lambda p, xx: conv1d_apply(p, xx, cfg)[0]), (params, x),
                float(conv1d_flops(cfg)))

    def stepper_builder(knobs):
        return Creator(hw=XC7S15).build(cfg, SHAPES_CONV1D["infer_1"])

    wf = Workflow(creator=Creator(hw=XC7S15), train_fn=train_fn,
                  step_builder=step_builder, stepper_builder=stepper_builder,
                  target="rtl")
    hist = wf.run(Requirement(max_latency_s=1.0), lambda h: None,
                  {"bits": 8}, max_iters=2)
    assert len(hist) == 1 and hist[0].satisfied
    rec = hist[0]
    assert rec.synthesis.backend == "rtl"
    assert rec.measurement.platform.startswith("rtl-emulator")
    assert rec.measurement.target == "rtl"


def test_rtl_options_w_fmt_overrides():
    opts = RTLOptions(w_fmt_overrides={"conv1d": FxpFormat(6, 4)})
    assert opts.w_fmt_overrides["conv1d"] == FxpFormat(6, 4)
    with pytest.raises(ValueError, match="unknown hardware template"):
        RTLOptions(w_fmt_overrides={"cnv1d": FxpFormat(6, 4)})
    with pytest.raises(TypeError, match="FxpFormat"):
        RTLOptions(w_fmt_overrides={"conv1d": (6, 4)})
    # weightless kinds are rejected, not silently ignored
    with pytest.raises(ValueError, match="carries no weight format"):
        RTLOptions(w_fmt_overrides={"act_lut": FxpFormat(6, 4)})
    # an override for a kind ABSENT from the model must not widen (or
    # reject via) that model's envelope check — shared sweep dicts work
    g_lstm = _lstm_graph(w_fmt_overrides={"conv1d": FxpFormat(14, 10)})
    assert g_lstm.node("lstm_cell_l0").w_fmt == FxpFormat(8, 6)
    # overrides reach the lowered nodes (and stay bit-exact)
    g, cfg, params = _conv_graph(
        w_fmt_overrides={"conv1d": FxpFormat(6, 4)})
    assert g.node("conv1d_0").w_fmt == FxpFormat(6, 4)
    assert g.node("linear_head").w_fmt == FxpFormat(8, 6)   # default kept
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (2, cfg.conv1d.seq_len, cfg.conv1d.channels))
    assert_bit_exact(g, x, mode="jnp")


def test_emulator_cache_stats_and_dispatch_counters():
    """cache_stats() mirrors trace_count and splits hits/misses/evictions;
    dispatch spans carry mode + cached flag when a tracer is installed."""
    from repro import obs

    g = _lstm_graph()
    em = RTLEmulator(g, max_programs=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 6, 1))
    with obs.capture("emu") as cap:
        em.run(x[:1])                       # miss
        em.run(x[:1])                       # hit
        em.run(x[:2])                       # miss
        em.run(x[:3])                       # miss -> evicts (1,6,1)
        em.run(x[:1])                       # miss again (was evicted)
    st = em.cache_stats()
    assert st["misses"] == st["retraces"] == em.trace_count == 4
    assert st["hits"] == 1
    assert st["evictions"] >= 1
    assert st["dispatches"]["fused"] == 5
    # spans: one per dispatch, cached flag tracks hit/miss
    ds = obs.find_spans(cap.trace.spans, "rtl.emulator.dispatch")
    assert len(ds) == 5
    assert [d.attrs["cached"] for d in ds] == [False, True, False, False,
                                               False]
    assert all(d.attrs["mode"] == "fused" for d in ds)
    # counters mirrored into the captured registry
    mx = cap.trace.metrics
    assert mx["rtl.emulator.cache_miss"]["value"] == 4
    assert mx["rtl.emulator.cache_hit"]["value"] == 1


def test_measurement_report_percentiles_rtl():
    """RTL measure keeps per-run samples: latency_s stays the deterministic
    cycle model while p50/p99 characterize the executing proxy."""
    cr = Creator(hw=XC7S15)
    st_ = cr.build(get_config("elastic-lstm"), SHAPES_LSTM["infer_1"])
    _, exe = cr.translate(st_, target="rtl")
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 1))
    rep = exe.measure((x,), model="elastic-lstm", model_flops=1e6, n_runs=7)
    assert rep.n_runs == 7
    assert 0 < rep.latency_p50_s <= rep.latency_p99_s
    # the fabric latency is the cycle model, not host wall-clock
    assert rep.latency_s == pytest.approx(exe.cycles / 100e6, rel=1e-6)


def test_emulator_thread_hammer_consistent():
    """Pooled serving dispatches one emulator from worker threads; the lock
    in _program/_count_dispatch must keep the LRU + counters consistent
    under contention (cache churn forced by max_programs < live shapes),
    and every thread must still see bit-exact outputs."""
    import threading

    g = _lstm_graph()
    em = RTLEmulator(g, max_programs=2)
    xs = {b: jax.random.normal(jax.random.PRNGKey(b), (b, 6, 1))
          for b in (1, 2, 3)}
    want = {b: np.asarray(RTLEmulator(g).run(x).outputs)
            for b, x in xs.items()}
    n_threads, n_iters = 4, 6
    errors = []

    def hammer(tid):
        try:
            for i in range(n_iters):
                b = 1 + (tid + i) % 3
                out = np.asarray(em.run(xs[b]).outputs)
                if not np.array_equal(out, want[b]):
                    errors.append((tid, i, b, "mismatch"))
            outs = em.run_many([xs[1], xs[2]])   # one composite dispatch
            for b, r in zip((1, 2), outs):
                if not np.array_equal(np.asarray(r.outputs), want[b]):
                    errors.append((tid, b, "run_many mismatch"))
        except Exception as e:              # noqa: BLE001 - collect, don't die
            errors.append((tid, repr(e)))

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[:5]
    st = em.cache_stats()
    total = n_threads * (n_iters + 1)       # run_many is ONE dispatch
    assert sum(st["dispatches"].values()) == total
    assert st["hits"] + st["misses"] == total
    assert st["misses"] >= 3                # at least one per distinct shape
    # the LRU honored its capacity: live programs = misses - evictions
    assert st["misses"] - st["evictions"] <= 2
