"""Multi-design emulation (DESIGN.md §15): isomorphism key, shared-program
retrace behavior, and vmapped-vs-sequential bit-exactness.

The program-sharing contract under test: designs with identical structure
(node kinds, shapes, LUT sizes, Q-formats) but different trained values
share one :func:`repro.rtl.ir.iso_key` and therefore one compiled program
(weights are traced arguments), while ANY structural change — a LUT's kind
or size, an array's shape, an edge format — produces a distinct key and a
separate program. On top of that key, :class:`MultiDesignEmulator` must be
integer-for-integer identical to per-design emulation in every mode.
"""
import copy
import dataclasses
import functools
import importlib.util
import os
import pathlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # image lacks hypothesis: use shim
    from _hypothesis_compat import given, settings, st

from repro.quant.fixedpoint import FxpFormat
from repro.rtl import (MultiDesignEmulator, ProgramLRU, RTLEmulator,
                       assert_isomorphic, iso_key)
from repro.verify.conformance import run_conformance_batch
from repro.verify.vectors import canonical_graph

ARCHS = ("elastic-lstm", "elastic-conv1d")


@functools.lru_cache(maxsize=None)
def _graph(arch: str, seed: int):
    """Seeded canonical lowering — different seed, different weights, same
    structure (the isomorphic-candidate generator the DSE sweep uses)."""
    return canonical_graph(arch, seed=seed)[0]


def _stimulus(graph, batch=4, seed=0):
    in_edge = graph.edges[graph.inputs[0]]
    rng = np.random.default_rng(seed)
    return rng.integers(in_edge.fmt.lo, in_edge.fmt.hi + 1,
                        (batch,) + tuple(in_edge.shape)).astype(np.int32)


# ---------------------------------------------------------------------------
# the isomorphism key
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 40), st.integers(0, 40))
def test_iso_key_property_weights_do_not_matter(s1, s2):
    """Perturbing ONLY the trained values never changes the key."""
    for arch in ARCHS:
        g1, g2 = _graph(arch, s1), _graph(arch, s2)
        assert iso_key(g1) == iso_key(g2)
        assert g1.iso_key() == iso_key(g1)      # method == module fn
        if s1 != s2:                            # weights genuinely differ...
            arrays = [
                (getattr(a, f.name), getattr(b, f.name))
                for a, b in zip(g1.nodes, g2.nodes)
                for f in dataclasses.fields(a)
                if isinstance(getattr(a, f.name), np.ndarray)
            ]
            assert any(not np.array_equal(x, y) for x, y in arrays)


def _mutate(graph, what: str):
    g = copy.deepcopy(graph)
    if what == "lut_kind":
        n = next(n for n in g.nodes if n.op == "act_lut")
        n.kind = ("hard_tanh" if n.kind == "hard_sigmoid"
                  else "hard_sigmoid")
    elif what == "lut_size":
        n = next(n for n in g.nodes if n.op == "act_lut")
        n.in_fmt = FxpFormat(n.in_fmt.total_bits + 1, n.in_fmt.frac_bits)
    elif what == "weight_shape":
        for n in g.nodes:
            for f in dataclasses.fields(n):
                v = getattr(n, f.name)
                if isinstance(v, np.ndarray):
                    setattr(n, f.name, np.concatenate([v, v], axis=0))
                    return g
        raise AssertionError("no array field found to mutate")
    elif what == "edge_fmt":
        name = sorted(g.edges)[0]
        e = g.edges[name]
        g.edges[name] = dataclasses.replace(
            e, fmt=FxpFormat(e.fmt.total_bits + 2, e.fmt.frac_bits))
    return g


@pytest.mark.parametrize("what",
                         ["lut_kind", "lut_size", "weight_shape", "edge_fmt"])
@pytest.mark.parametrize("arch", ARCHS)
def test_iso_key_distinct_on_structural_change(arch, what):
    base = _graph(arch, 0)
    assert iso_key(_mutate(base, what)) != iso_key(base)


# ---------------------------------------------------------------------------
# one retrace across isomorphic designs (the tentpole's economic claim)
# ---------------------------------------------------------------------------


def test_isomorphic_designs_share_one_program():
    lru = ProgramLRU(4)
    ems = [RTLEmulator(_graph("elastic-lstm", s), mode="jnp", programs=lru)
           for s in (0, 1, 2)]
    x = _stimulus(ems[0].graph)
    outs = [np.asarray(em.run_int(x).outputs, np.int64) for em in ems]

    # one trace TOTAL: designs #1 and #2 reuse #0's compiled program
    assert sum(em.trace_count for em in ems) == 1
    stats = lru.stats()
    assert stats["misses"] == 1 and stats["hits"] == 2
    # has_program probes the shared LRU without building
    assert ems[2].has_program(x.shape, x.dtype)
    # the shared program is weight-GENERIC, not weight-frozen: different
    # traced params through the same program give different outputs
    assert not np.array_equal(outs[0], outs[1])


def test_distinct_structures_do_not_share_a_program():
    lru = ProgramLRU(4)
    a = RTLEmulator(_graph("elastic-lstm", 0), mode="jnp", programs=lru)
    b = RTLEmulator(_graph("elastic-conv1d", 0), mode="jnp", programs=lru)
    a.run_int(_stimulus(a.graph))
    b.run_int(_stimulus(b.graph))
    assert a.trace_count == 1 and b.trace_count == 1
    assert lru.stats()["misses"] == 2


# ---------------------------------------------------------------------------
# vmapped vs sequential bit-exactness — all 3 modes, both shipped archs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_vmapped_bit_exact_vs_every_sequential_mode(arch):
    graphs = [_graph(arch, s) for s in (0, 1)]
    x = _stimulus(graphs[0])
    multi = MultiDesignEmulator(graphs)
    out = np.asarray(multi.run_int(x).outputs, np.int64)
    assert out.shape[0] == multi.k
    assert multi.trace_count == 1

    for mode in ("jnp", "fused", "pallas"):
        for k, g in enumerate(graphs):
            ref = np.asarray(RTLEmulator(g, mode=mode).run_int(x).outputs,
                             np.int64)
            assert np.array_equal(out[k], ref), (arch, mode, k)

    # the built-in sequential cross-check path agrees too
    assert np.array_equal(out, multi.run_int_sequential(x))


def test_per_design_stimulus_routes_row_k_to_design_k():
    graphs = [_graph("elastic-lstm", s) for s in (0, 1, 2)]
    xs = np.stack([_stimulus(graphs[0], seed=s) for s in range(3)])
    multi = MultiDesignEmulator(graphs)
    out = np.asarray(multi.run_int(xs, per_design=True).outputs, np.int64)
    for k, g in enumerate(graphs):
        ref = np.asarray(multi.emulators[k].run_int(xs[k]).outputs, np.int64)
        assert np.array_equal(out[k], ref), k
    with pytest.raises(ValueError, match="design axis"):
        multi.run_int(xs[:2], per_design=True)


def test_assert_isomorphic_names_the_offender():
    graphs = [_graph("elastic-lstm", 0), _graph("elastic-conv1d", 0)]
    with pytest.raises(ValueError, match="not program-isomorphic"):
        assert_isomorphic(graphs)
    with pytest.raises(ValueError, match="at least one graph"):
        MultiDesignEmulator([])


def test_run_conformance_batch_cross_checks_every_design():
    reports = run_conformance_batch([_graph("elastic-lstm", s)
                                     for s in (0, 1)])
    assert len(reports) == 2
    for rep in reports:
        assert rep.passed
        assert rep.modes[0] == "vmap-jnp"
        assert rep.modes_bit_exact and rep.oracle_within_budget
        vs = {k: v for k, v in rep.mode_max_diff.items()
              if k.startswith("vmap-jnp-vs-")}
        assert vs and all(v == 0 for v in vs.values())


# ---------------------------------------------------------------------------
# satellite: experiments/hillclimb.py must not mutate XLA_FLAGS at import
# ---------------------------------------------------------------------------


def _load_hillclimb():
    path = (pathlib.Path(__file__).resolve().parents[1]
            / "experiments" / "hillclimb.py")
    spec = importlib.util.spec_from_file_location("_hillclimb_under_test",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_hillclimb_import_leaves_environment_alone():
    before = os.environ.get("XLA_FLAGS")
    _load_hillclimb()
    assert os.environ.get("XLA_FLAGS") == before


def test_apply_xla_flags_guarded_and_idempotent():
    mod = _load_hillclimb()
    env = {}
    first = mod.apply_xla_flags(env)
    assert "--xla_force_host_platform_device_count=512" in first
    assert mod.apply_xla_flags(env) == first            # second call: no-op
    # a user-chosen value for the same flag NAME is never overridden
    user = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    mod.apply_xla_flags(user)
    assert "device_count=512" not in user["XLA_FLAGS"]
    assert user["XLA_FLAGS"].startswith(
        "--xla_force_host_platform_device_count=4")
    assert "concurrency_optimized_scheduler" in user["XLA_FLAGS"]
