"""prefill(S) + decode(1) must equal a full forward at position S — for every
family's cache type (KV, SSM state, WKV state, conv windows, cross-attn)."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import ALL_IDS, get_config
from repro.core.types import SMOKE_MESH, ShapeConfig
from repro.model.lm import Stepper, make_decode_step, make_prefill_step
from repro.model.transformer import pad_cache

ARCHS = [a for a in ALL_IDS if a not in ("elastic-lstm", "elastic-conv1d")]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full(arch, par_f32):
    cfg = get_config(arch, smoke=True)
    S, B = 16, 2
    st = Stepper(cfg, ShapeConfig("p", "prefill", S, B), SMOKE_MESH, par_f32)
    params, _ = st.init()
    full = make_batch(cfg, B, S + 1, train=False)
    pre_batch = dict(full)
    pre_batch["tokens"] = full["tokens"][:, :S]

    pre = make_prefill_step(cfg, SMOKE_MESH, par_f32)
    logits_full, _ = pre(params, full)
    _, cache = pre(params, pre_batch)
    cache = pad_cache(cache, S + 4)
    dec = make_decode_step(cfg, SMOKE_MESH, par_f32)
    logits_dec, cache2 = dec(params, full["tokens"][:, S:S + 1], cache)
    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    assert err < 2e-3, (arch, err)


@pytest.mark.parametrize("arch", ["yi-9b", "rwkv6-7b", "zamba2-7b"])
def test_multi_step_decode(arch, par_f32):
    """Greedy decode of 4 tokens step-by-step == teacher-forced forward."""
    cfg = get_config(arch, smoke=True)
    S, B, EXTRA = 12, 2, 4
    st = Stepper(cfg, ShapeConfig("p", "prefill", S, B), SMOKE_MESH, par_f32)
    params, _ = st.init()
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + EXTRA), 0,
                              cfg.vocab_size)
    pre = make_prefill_step(cfg, SMOKE_MESH, par_f32)
    dec = make_decode_step(cfg, SMOKE_MESH, par_f32)

    _, cache = pre(params, {"tokens": toks[:, :S]})
    cache = pad_cache(cache, S + EXTRA + 2)
    stepwise = []
    for t in range(EXTRA):
        logits, cache = dec(params, toks[:, S + t:S + t + 1], cache)
        stepwise.append(logits)

    for t in range(EXTRA):
        ref, _ = pre(params, {"tokens": toks[:, :S + t + 1]})
        err = float(jnp.max(jnp.abs(ref - stepwise[t])))
        assert err < 5e-3, (arch, t, err)
