"""Tier-1: the observability layer (repro.obs) — spans, metrics, artifacts.

Everything runs on an injectable fake clock, so span trees and durations
are exact, not flaky-wall-clock assertions.
"""
import json

import numpy as np
import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry, RunTrace, Tracer,
                       ancestors, capture, children_of, find_spans, from_chrome_trace,
                       get_metrics, get_tracer, percentile, set_tracer, span_tree,
                       to_chrome_trace, to_jsonl)


class FakeClock:
    """Deterministic monotonic clock: each read advances by ``step``."""

    def __init__(self, step: float = 1.0):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


# --------------------------------------------------------------------------- #
# Tracer: nesting, determinism, disabled path
# --------------------------------------------------------------------------- #


def test_span_nesting_deterministic_tree():
    trc = Tracer(clock=FakeClock())
    with trc.span("root", knob=8):
        with trc.span("child_a", mode="fused"):
            pass
        with trc.span("child_b") as b:
            b.set_attrs(found=True)
            with trc.span("grand"):
                pass
    assert len(trc.spans) == 4
    root = find_spans(trc.spans, "root")[0]
    a = find_spans(trc.spans, "child_a")[0]
    b = find_spans(trc.spans, "child_b")[0]
    g = find_spans(trc.spans, "grand")[0]
    # parentage encodes the lexical nesting
    assert root.parent_id is None
    assert a.parent_id == root.span_id
    assert b.parent_id == root.span_id
    assert g.parent_id == b.span_id
    # fake clock: every read advances by exactly 1
    assert root.start == 1.0 and root.end == 8.0
    assert a.duration == 1.0
    # attrs: at-creation and mid-span both land
    assert root.attrs == {"knob": 8}
    assert b.attrs == {"found": True}
    # tree helpers agree
    assert [(s.name, d) for s, d in span_tree(trc.spans)] == [
        ("root", 0), ("child_a", 1), ("child_b", 1), ("grand", 2)]
    assert [s.name for s in children_of(trc.spans, root)] == [
        "child_a", "child_b"]
    assert [s.name for s in ancestors(trc.spans, g)] == ["child_b", "root"]


def test_span_ids_unique_and_exception_safe():
    trc = Tracer(clock=FakeClock())
    with pytest.raises(ValueError):
        with trc.span("outer"):
            with trc.span("inner"):
                raise ValueError("boom")
    # both spans still closed and recorded; stack unwound
    assert sorted(s.name for s in trc.spans) == ["inner", "outer"]
    assert not trc._stack
    ids = [s.span_id for s in trc.spans]
    assert len(ids) == len(set(ids))


def test_event_is_zero_duration_child():
    trc = Tracer(clock=FakeClock())
    with trc.span("root"):
        trc.event("mark", k=1)
    ev = find_spans(trc.spans, "mark")[0]
    assert ev.duration == 0.0
    assert ev.parent_id == find_spans(trc.spans, "root")[0].span_id


def test_disabled_tracer_records_nothing():
    trc = Tracer(enabled=False)
    with trc.span("nope", big=list(range(100))) as s:
        s.set_attrs(more=1)          # null span swallows attrs
    trc.event("also-nope")
    assert trc.spans == []
    # the disabled path hands back one shared object (no per-call alloc)
    assert trc.span("a") is trc.span("b")


def test_process_default_disabled_and_swappable():
    assert get_tracer().enabled is False       # default: opt-in only
    mine = Tracer(clock=FakeClock())
    prev = set_tracer(mine)
    try:
        assert get_tracer() is mine
    finally:
        set_tracer(prev)
    assert get_tracer() is prev


# --------------------------------------------------------------------------- #
# Metrics: counters, gauges, histogram percentiles vs numpy
# --------------------------------------------------------------------------- #


def test_counter_and_gauge():
    c = Counter("c")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert c.snapshot() == {"type": "counter", "value": 5}
    g = Gauge("g")
    assert g.snapshot()["n"] == 0
    for v in (3.0, -1.0, 7.0):
        g.set(v)
    assert (g.value, g.min, g.max, g.n) == (7.0, -1.0, 7.0, 3)


@pytest.mark.parametrize("p", [0, 25, 50, 90, 95, 99, 100])
def test_percentile_matches_numpy(p):
    rng = np.random.default_rng(0)
    for n in (1, 2, 5, 100):
        vals = rng.normal(size=n).tolist()
        assert percentile(vals, p) == pytest.approx(
            float(np.percentile(vals, p)), abs=1e-12)


def test_percentile_empty_is_zero():
    assert percentile([], 99) == 0.0
    h = Histogram("empty")
    assert h.summary() == {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                           "max": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}


def test_histogram_summary():
    h = Histogram("lat")
    for v in range(1, 101):          # 1..100
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100 and s["min"] == 1.0 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    assert s["p50"] == pytest.approx(float(np.percentile(h.values, 50)))
    assert s["p99"] == pytest.approx(float(np.percentile(h.values, 99)))


def test_registry_get_or_create_and_snapshot():
    mx = MetricsRegistry()
    assert mx.counter("a") is mx.counter("a")
    mx.counter("z.count").inc(2)
    mx.gauge("a.depth").set(3)
    mx.histogram("m.lat").observe(0.5)
    snap = mx.snapshot()
    assert list(snap) == sorted(snap)            # stable artifact ordering
    assert snap["z.count"]["value"] == 2
    assert snap["m.lat"]["count"] == 1
    mx.reset()
    assert mx.snapshot() == {}


# --------------------------------------------------------------------------- #
# Exporters: Chrome trace round-trip, JSONL
# --------------------------------------------------------------------------- #


def _sample_spans():
    trc = Tracer(clock=FakeClock(0.25))
    with trc.span("root", arch="elastic-lstm"):
        with trc.span("child", mode="fused", cached=True):
            pass
    return trc.spans


def test_chrome_trace_schema_and_roundtrip():
    spans = _sample_spans()
    doc = json.loads(json.dumps(to_chrome_trace(spans)))  # through JSON
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X"
        assert ev["ts"] >= 0 and ev["dur"] >= 0           # µs, rebased
        assert {"name", "pid", "tid", "args"} <= set(ev)
    back = from_chrome_trace(doc)
    assert [(s.name, s.span_id, s.parent_id) for s in back] == \
        [(s.name, s.span_id, s.parent_id) for s in spans]
    for orig, rt in zip(spans, back):
        assert rt.duration == pytest.approx(orig.duration, abs=1e-9)
        assert rt.attrs == orig.attrs
    # the tree survives the format
    assert [(s.name, d) for s, d in span_tree(back)] == [
        ("root", 0), ("child", 1)]


def test_jsonl_one_object_per_span():
    spans = _sample_spans()
    lines = to_jsonl(spans).splitlines()
    assert len(lines) == len(spans)
    objs = [json.loads(ln) for ln in lines]
    assert {o["name"] for o in objs} == {"root", "child"}
    assert to_jsonl([]) == ""


def test_nonserializable_attrs_degrade_to_repr():
    trc = Tracer(clock=FakeClock())
    with trc.span("s", shape=(1, 6, 1)):
        pass
    doc = to_chrome_trace(trc.spans)
    json.dumps(doc)                  # must be JSON-clean
    assert doc["traceEvents"][0]["args"]["shape"] == repr((1, 6, 1))


# --------------------------------------------------------------------------- #
# capture + RunTrace artifact
# --------------------------------------------------------------------------- #


def test_capture_installs_and_restores(tmp_path):
    prev_trc, prev_mx = get_tracer(), get_metrics()
    with capture("unit", clock=FakeClock()) as cap:
        assert get_tracer() is cap.tracer and get_tracer().enabled
        with get_tracer().span("work", k=1):
            get_metrics().counter("n.things").inc(3)
            get_metrics().histogram("lat").observe(0.5)
    assert get_tracer() is prev_trc and get_metrics() is prev_mx
    rt = cap.trace
    assert rt.name == "unit"
    assert [s.name for s in rt.spans] == ["work"]
    assert rt.metrics["n.things"]["value"] == 3

    paths = rt.save(str(tmp_path / "build"))
    with open(paths["trace.json"]) as f:
        doc = json.load(f)
    assert doc["traceEvents"][0]["name"] == "work"
    with open(paths["trace.jsonl"]) as f:
        assert json.loads(f.readline())["name"] == "work"
    with open(paths["metrics.json"]) as f:
        assert json.load(f)["lat"]["count"] == 1
    text = (tmp_path / "build" / "summary.txt").read_text()
    assert "work" in text and "n.things" in text


def test_capture_restores_on_exception():
    prev = get_tracer()
    with pytest.raises(RuntimeError):
        with capture("boom"):
            raise RuntimeError("x")
    assert get_tracer() is prev


def test_runtrace_summary_depth_cap():
    trc = Tracer(clock=FakeClock())
    with trc.span("lvl0"):
        with trc.span("lvl1"):
            with trc.span("lvl2"):
                pass
    rt = RunTrace(name="deep", spans=list(trc.spans))
    assert "lvl2" in rt.summary()
    assert "lvl2" not in rt.summary(max_depth=1)
