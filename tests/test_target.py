"""Deployment-target API: registry, options validation, the uniform
Deployment artifact, and the deprecation shims over the old backend= API."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.creator import Creator
from repro.core.target import (DEFAULT_N_RUNS, Deployment, Target,
                               TargetOptions, XLADeployment, XLAOptions,
                               get_target, list_targets, register_target)
from repro.core.types import SHAPES_LSTM
from repro.energy.hw import XC7S15, get_hw
from repro.quant.fixedpoint import FxpFormat
from repro.rtl import RTLExecutable, RTLOptions


def _creator_and_stepper():
    cr = Creator(hw=XC7S15)
    return cr, cr.build(get_config("elastic-lstm"), SHAPES_LSTM["infer_1"])


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #


def test_registry_lists_both_builtin_targets():
    assert {"xla", "rtl"} <= set(list_targets())


def test_unknown_target_error_names_registered():
    with pytest.raises(ValueError, match=r"unknown target 'hls'") as ei:
        get_target("hls")
    # the error doubles as discovery: it must list what IS registered
    assert "xla" in str(ei.value) and "rtl" in str(ei.value)


def test_get_target_resolves_and_conforms_to_protocol():
    for name in ("xla", "rtl"):
        tgt = get_target(name)
        assert isinstance(tgt, Target)
        assert tgt.name == name
        assert tgt.default_hw.name
        assert issubclass(tgt.options_cls, TargetOptions)
        opts = tgt.options_from_knobs({"bits": 8, "frac": 6})
        assert isinstance(opts, tgt.options_cls)


def test_get_target_passes_instances_through():
    tgt = get_target("rtl")
    assert get_target(tgt) is tgt


def test_register_target_rejects_duplicates():
    class Dupe:
        name = "xla"
        default_hw = XC7S15
        options_cls = XLAOptions
        requires_stepper = False

    with pytest.raises(ValueError, match="already registered"):
        register_target(Dupe())


def test_hw_by_name_round_trip():
    assert get_hw("xc7s15") is XC7S15
    with pytest.raises(KeyError, match="unknown HWSpec"):
        get_hw("virtex-ultrascale")


# --------------------------------------------------------------------------- #
# Options dataclass validation
# --------------------------------------------------------------------------- #


def test_rtl_options_validate_emulator_mode():
    with pytest.raises(ValueError, match="emulator_mode"):
        RTLOptions(emulator_mode="simulated-annealing")


def test_rtl_options_validate_format_types():
    with pytest.raises(TypeError, match="w_fmt"):
        RTLOptions(w_fmt=(8, 6))


def test_xla_options_validate_kind():
    with pytest.raises(ValueError, match="kind"):
        XLAOptions(kind="synthesize")
    assert XLAOptions(kind="prefill").kind == "prefill"


def test_translate_rejects_mismatched_options():
    cr, st = _creator_and_stepper()
    with pytest.raises(TypeError, match="expects options"):
        cr.translate(st, target="rtl", options=XLAOptions())


def test_rtl_options_from_knobs_clamps_to_envelope():
    """The knob hook owns the DSP/LUT bit-width clamps (ex-fmt_builder)."""
    opts = get_target("rtl").options_from_knobs({"bits": 16, "frac": 12})
    assert opts.w_fmt.total_bits <= 12
    assert opts.act_fmt.total_bits <= 9
    assert opts.w_fmt.frac_bits < opts.w_fmt.total_bits


# --------------------------------------------------------------------------- #
# The uniform Deployment artifact
# --------------------------------------------------------------------------- #


def test_rtl_deployment_contract_and_save_round_trip(tmp_path):
    cr, st = _creator_and_stepper()
    syn, dep = cr.translate(st, target="rtl",
                            options=RTLOptions(w_fmt=FxpFormat(8, 6)))
    assert isinstance(dep, Deployment) and isinstance(dep, RTLExecutable)
    assert dep.target == "rtl"
    assert dep.cycles > 0
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 1))
    y = dep(x)                                   # callable on inputs
    assert np.asarray(y).shape[0] == 2
    # artifact round-trip: every emitted file lands on disk byte-identical
    # (save() adds the static-analysis report alongside the artifacts)
    dep.save(str(tmp_path))
    on_disk = {p.name: p.read_text() for p in tmp_path.iterdir()}
    analysis = on_disk.pop("analysis.json")
    assert json.loads(analysis)["design"] == "elastic-lstm"
    assert on_disk == dep.artifacts
    man = json.loads(on_disk["manifest.json"])
    assert man["total_macs"] > 0
    # measure: unified default, target + n_runs recorded
    m = dep.measure((x,), model="elastic-lstm", model_flops=21666.0)
    assert m.target == "rtl" and m.n_runs == DEFAULT_N_RUNS
    assert m.latency_s == pytest.approx(dep.cycles / XC7S15.clock_hz)


def test_measure_defaults_unified_across_targets():
    cr, st = _creator_and_stepper()
    _, dep = cr.translate(st, target="rtl")
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 1))
    m_rtl = dep.measure((x,), model="m", model_flops=1e4)

    xd = XLADeployment(fn=jax.jit(lambda a: a * 2), hw=XC7S15)
    m_xla = xd.measure((x,), model="m", model_flops=1e4)
    assert m_rtl.n_runs == m_xla.n_runs == DEFAULT_N_RUNS
    assert (m_rtl.target, m_xla.target) == ("rtl", "xla")


def test_xla_deployment_bind_step_keeps_metadata():
    xd = XLADeployment(fn=None, hw=XC7S15, hlo_text="HLO", cost={"flops": 1})
    bound = xd.bind_step(jax.jit(lambda a: a + 1))
    assert bound.hlo_text == "HLO" and bound.cost == {"flops": 1}
    assert float(bound(jax.numpy.zeros(()))) == 1.0


def test_rtl_deployment_ignores_bind_step():
    cr, st = _creator_and_stepper()
    _, dep = cr.translate(st, target="rtl")
    assert dep.bind_step(lambda *a: None) is dep


# --------------------------------------------------------------------------- #
# Deprecation shims (the old surface keeps working, loudly)
# --------------------------------------------------------------------------- #


def test_translate_backend_kwarg_warns_and_forwards():
    cr, st = _creator_and_stepper()
    with pytest.warns(DeprecationWarning, match="backend"):
        syn, exe = cr.translate(st, backend="rtl", w_fmt=FxpFormat(8, 6),
                                emulator_mode="jnp")
    assert syn.backend == "rtl"
    assert exe.emulator.mode == "jnp"
    # and the shimmed artifact is bit-for-bit the new-path artifact
    syn2, exe2 = cr.translate(st, target="rtl",
                              options=RTLOptions(w_fmt=FxpFormat(8, 6),
                                                 emulator_mode="jnp"))
    assert exe.artifacts == exe2.artifacts


def test_translate_rejects_mixed_options_and_legacy_kwargs():
    """Mixing the new options= with loose legacy Q-format kwargs must be
    loud — the shim would otherwise rebuild options from defaults."""
    cr, st = _creator_and_stepper()
    with pytest.warns(DeprecationWarning):
        with pytest.raises(TypeError, match="not both"):
            cr.translate(st, backend="rtl",
                         options=RTLOptions(emulator_mode="jnp"),
                         w_fmt=FxpFormat(8, 6))


def test_measure_rtl_warns_and_matches_deployment_measure():
    cr, st = _creator_and_stepper()
    _, exe = cr.translate(st, target="rtl")
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 1))
    with pytest.warns(DeprecationWarning, match="measure_rtl"):
        old = cr.measure_rtl(exe, x, model="m", model_flops=1e4, n_runs=2)
    new = exe.measure((x,), model="m", model_flops=1e4, n_runs=2)
    assert old.latency_s == new.latency_s
    assert old.energy_j == new.energy_j
    assert old.target == new.target == "rtl"


def test_workflow_backend_and_fmt_builder_warn():
    from repro.core.workflow import Workflow

    with pytest.warns(DeprecationWarning, match="backend"):
        wf = Workflow(creator=Creator(), train_fn=None, step_builder=None,
                      backend="rtl")
    assert wf.target == "rtl"
    with pytest.warns(DeprecationWarning, match="fmt_builder"):
        wf2 = Workflow(creator=Creator(), train_fn=None, step_builder=None,
                       target="rtl",
                       fmt_builder=lambda k: {"w_fmt": FxpFormat(8, 6)})
    opts = wf2.options_from_knobs({"bits": 8})
    assert isinstance(opts, RTLOptions)
    assert opts.w_fmt == FxpFormat(8, 6)


def test_workflow_fmt_builder_ignored_off_rtl_like_before():
    """Legacy Workflows could pass fmt_builder with the default (xla)
    backend; it was only consumed by the RTL fork. The shim must keep
    ignoring it rather than forcing RTLOptions onto the xla target."""
    from repro.core.workflow import Workflow

    with pytest.warns(DeprecationWarning, match="fmt_builder"):
        wf = Workflow(creator=Creator(), train_fn=None, step_builder=None,
                      fmt_builder=lambda k: {"w_fmt": FxpFormat(8, 6)})
    assert wf.target == "xla"
    assert wf.options_from_knobs is None        # target's own hook applies
