"""Per-arch smoke: reduced config, one forward/train step, shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import ALL_IDS, get_config
from repro.core.types import SMOKE_MESH, ShapeConfig
from repro.model.lm import Stepper, make_prefill_step


WINDOW_FAMILIES = ("elastic-lstm", "elastic-conv1d")   # x/y window archs


@pytest.mark.parametrize("arch", ALL_IDS)
def test_train_step(arch, par_f32):
    cfg = get_config(arch, smoke=True)
    B, S = 2, 16
    shape = ShapeConfig("t", "train", S if cfg.family != "lstm" else cfg.lstm.seq_len,
                        B if cfg.family != "lstm" else 8)
    st = Stepper(cfg, shape, SMOKE_MESH, par_f32)
    params, opt = st.init()
    batch = make_batch(cfg, shape.global_batch, shape.seq_len)
    p2, o2, m = jax.jit(st.train_fn())(params, opt, batch)
    assert jnp.isfinite(m["loss"]), arch
    assert jnp.isfinite(m["gnorm"]), arch
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, arch
    assert int(o2["step"]) == 1


@pytest.mark.parametrize("arch",
                         [a for a in ALL_IDS if a not in WINDOW_FAMILIES])
def test_forward_shapes(arch, par_f32):
    cfg = get_config(arch, smoke=True)
    B, S = 2, 16
    st = Stepper(cfg, ShapeConfig("p", "prefill", S, B), SMOKE_MESH, par_f32)
    params, _ = st.init()
    batch = make_batch(cfg, B, S, train=False)
    logits, cache = make_prefill_step(cfg, SMOKE_MESH, par_f32)(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits))
    assert cache is not None


@pytest.mark.parametrize("arch", ALL_IDS)
def test_registry_supports_arch(arch):
    from repro.core.registry import validate_config

    cfg = get_config(arch)
    comps = validate_config(cfg)
    assert comps, arch
