"""The ElasticAI-Workflow 3-stage loop on the paper's LSTM, end to end."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.creator import Creator
from repro.core.registry import validate_config
from repro.core.report import DesignReport
from repro.core.workflow import Requirement, Workflow
from repro.data.pipeline import TrafficConfig, traffic_flow_batch
from repro.model.layers import init_params
from repro.model.lstm import lstm_flops, lstm_schema
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.quant.fixedpoint import FxpFormat
from repro.quant.qat import QATConfig, make_qat_lstm_apply, make_qat_loss


def _train(knobs):
    cfg = get_config("elastic-lstm")
    qcfg = QATConfig(weight_fmt=FxpFormat(knobs["bits"], knobs["frac"]),
                     act_fmt=FxpFormat(knobs["bits"], knobs["frac"] - 2))
    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    loss_fn = make_qat_loss(cfg, qcfg)
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=100,
                      weight_decay=0.0)
    batch = traffic_flow_batch(TrafficConfig(batch=128), 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(lambda pp: loss_fn(pp, batch)[0])(p)
        p2, o2, _ = adamw_update(g, o, p, ocfg)
        return p2, o2, loss

    first = last = None
    for _ in range(60):
        params, opt, loss = step(params, opt)
        first = first if first is not None else float(loss)
        last = float(loss)
    ev = traffic_flow_batch(TrafficConfig(batch=128, seed=9), 1)
    apply = make_qat_lstm_apply(cfg, qcfg)
    pred, _ = apply(params, jnp.asarray(ev["x"]))
    eval_loss = float(jnp.mean((pred - jnp.asarray(ev["y"])) ** 2))
    rep = DesignReport(model="elastic-lstm", train_loss=last,
                       eval_loss=eval_loss,
                       weight_fmt=str(qcfg.weight_fmt),
                       act_fmt=str(qcfg.act_fmt))
    return params, rep, apply


def _steps(knobs, params):
    cfg = get_config("elastic-lstm")
    apply = make_qat_lstm_apply(
        cfg, QATConfig(weight_fmt=FxpFormat(knobs["bits"], knobs["frac"]),
                       act_fmt=FxpFormat(knobs["bits"], knobs["frac"] - 2)))
    x = jnp.asarray(traffic_flow_batch(TrafficConfig(batch=1), 0)["x"])
    fn = lambda p, xx: apply(p, xx)[0]
    return fn, (params, x), float(lstm_flops(cfg))


def test_registry_validates_all():
    assert "lstm" in validate_config(get_config("elastic-lstm"))
    with pytest.raises(KeyError):
        from repro.core import registry

        registry.get("nonexistent-component")


def test_workflow_loop_terminates_on_requirement():
    wf = Workflow(creator=Creator(), train_fn=_train, step_builder=_steps)
    req = Requirement(max_eval_loss=0.05, max_latency_s=10.0)

    def optimizer(history):
        k = dict(history[-1].knobs)
        if k["bits"] >= 16:
            return None
        k["bits"] += 4
        k["frac"] += 3
        return k

    hist = wf.run(req, optimizer, {"bits": 8, "frac": 6}, max_iters=3)
    assert hist, "no iterations ran"
    assert hist[-1].satisfied or len(hist) == 3
    # estimation and measurement exist and are comparable (Table-I shape)
    rec = hist[-1]
    assert rec.synthesis.est_latency_s > 0
    assert rec.measurement.latency_s > 0
    assert "latency_rel_err" in rec.est_vs_meas


def test_lstm_flops_matches_paper_scale():
    """Table I implies ~21.7 kOP/inference; our counted graph must agree."""
    flops = lstm_flops(get_config("elastic-lstm"))
    assert 15_000 < flops < 30_000, flops


@pytest.mark.parametrize("target", ["xla", "rtl"])
def test_workflow_single_path_over_targets(target):
    """Both deployment targets execute the same run_once (no backend fork);
    every MeasurementReport records the unified n_runs and target name."""
    from repro.core.target import DEFAULT_N_RUNS
    from repro.core.types import SHAPES_LSTM
    from repro.energy.hw import XC7S15
    from repro.model.lstm import lstm_apply

    cfg = get_config("elastic-lstm")
    assert not hasattr(Workflow, "_run_once_rtl"), \
        "the RTL fork must be gone: one run_once for every target"

    def train(knobs):
        params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
        rep = DesignReport(model="elastic-lstm", train_loss=0.0,
                           eval_loss=0.0)
        return params, rep, None

    def steps(knobs, params):
        x = jnp.asarray(traffic_flow_batch(TrafficConfig(batch=1), 0)["x"])
        fn = lambda p, xx: lstm_apply(p, xx, cfg)[0]
        return fn, (params, x), float(lstm_flops(cfg))

    creator = Creator(hw=XC7S15) if target == "rtl" else Creator()
    wf = Workflow(creator=creator, train_fn=train, step_builder=steps,
                  stepper_builder=(
                      (lambda k: creator.build(cfg, SHAPES_LSTM["infer_1"]))
                      if target == "rtl" else None),
                  target=target)
    rec = wf.run_once({"bits": 8, "frac": 6})
    assert rec.measurement.target == target
    assert rec.measurement.n_runs == DEFAULT_N_RUNS
    assert rec.measurement.latency_s > 0
    # satellite: _synth_from_fn threads the real model name (no more "wf")
    assert rec.synthesis.model == "elastic-lstm"
    assert "latency_rel_err" in rec.est_vs_meas


def test_workflow_run_once_emits_span_tree():
    """The observability tentpole, end to end: an RTL run_once under
    obs.capture decomposes into stage1 -> stage2 -> stage3 (-> verify) with
    emulator dispatch spans nested inside, and the measurement surfaces a
    non-degenerate latency distribution (p50/p99)."""
    from repro import obs
    from repro.core.types import SHAPES_LSTM
    from repro.energy.hw import XC7S15
    from repro.model.lstm import lstm_apply

    cfg = get_config("elastic-lstm")

    def train(knobs):
        params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
        return params, DesignReport(model="elastic-lstm", train_loss=0.0,
                                    eval_loss=0.0), None

    def steps(knobs, params):
        x = jnp.asarray(traffic_flow_batch(TrafficConfig(batch=1), 0)["x"])
        fn = lambda p, xx: lstm_apply(p, xx, cfg)[0]
        return fn, (params, x), float(lstm_flops(cfg))

    creator = Creator(hw=XC7S15)
    wf = Workflow(creator=creator, train_fn=train, step_builder=steps,
                  stepper_builder=lambda k: creator.build(
                      cfg, SHAPES_LSTM["infer_1"]),
                  target="rtl", verify=True)
    with obs.capture("wf") as cap:
        rec = wf.run_once({"bits": 8, "frac": 6})

    spans = cap.trace.spans
    root = obs.find_spans(spans, "workflow.run_once")[0]
    assert root.attrs["target"] == "rtl" and root.attrs["knob.bits"] == 8
    stages = {s.name for s in obs.children_of(spans, root)}
    assert {"workflow.stage1", "workflow.stage2", "workflow.stage3",
            "workflow.verify"} <= stages
    # emulator dispatches nest under the stage that issued them
    dispatches = obs.find_spans(spans, "rtl.emulator.dispatch")
    assert dispatches, "stage 3 must dispatch the emulator"
    s3 = obs.find_spans(spans, "workflow.stage3")[0]
    assert any(s3 in obs.ancestors(spans, d) for d in dispatches)
    # verify stage contains the differential conformance spans
    sv = obs.find_spans(spans, "workflow.verify")[0]
    conf = obs.find_spans(spans, "verify.conformance")[0]
    assert sv in obs.ancestors(spans, conf)
    assert sv.attrs["passed"] is True

    # the Chrome export is valid JSON and preserves the tree
    import json as _json
    doc = _json.loads(_json.dumps(cap.trace.chrome()))
    back = obs.from_chrome_trace(doc)
    assert len(back) == len(spans)

    # non-degenerate latency distribution on the report
    m = rec.measurement
    assert 0 < m.latency_p50_s <= m.latency_p99_s
    # pipeline metrics landed in the captured registry
    snap = cap.trace.metrics
    assert snap["rtl.emulator.dispatch.fused"]["value"] > 0
    assert snap["measure.latency_s.rtl"]["count"] > 0


def test_workflow_tracing_disabled_is_noop():
    """With the default (disabled) tracer, run_once records nothing — the
    near-zero-overhead contract of DESIGN.md §11."""
    from repro.obs import get_tracer

    trc = get_tracer()
    assert trc.enabled is False
    assert trc.spans == []


def test_workflow_resilience_stage_records_report():
    """Workflow(resilience=ChaosSpec): run_once drives the scripted chaos
    scenario against the deployed RTL artifact — SEU detected by the
    canary, breaker quarantined, traffic degraded to the XLA fallback —
    and attaches the ResilienceReport under a workflow.resilience span."""
    from repro import obs
    from repro.core.types import SHAPES_LSTM
    from repro.energy.hw import XC7S15
    from repro.model.lstm import lstm_apply
    from repro.resilience import (ChaosSpec, FaultPlan, FaultSpec,
                                  GuardPolicy)

    cfg = get_config("elastic-lstm")

    def train(knobs):
        params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
        return params, DesignReport(model="elastic-lstm", train_loss=0.0,
                                    eval_loss=0.0), None

    def steps(knobs, params):
        x = jnp.asarray(traffic_flow_batch(TrafficConfig(batch=1), 0)["x"])
        fn = lambda p, xx: lstm_apply(p, xx, cfg)[0]
        return fn, (params, x), float(lstm_flops(cfg))

    spec = ChaosSpec(
        plan=FaultPlan(faults=(
            FaultSpec(kind="bitflip", at_call=3, memory="lstm_cell_l0.w",
                      word=0, bit=7),), seed=3),
        n_requests=10,
        policy=GuardPolicy(max_retries=1, breaker_threshold=3,
                           canary_every=2))
    creator = Creator(hw=XC7S15)
    wf = Workflow(creator=creator, train_fn=train, step_builder=steps,
                  stepper_builder=lambda k: creator.build(
                      cfg, SHAPES_LSTM["infer_1"]),
                  target="rtl", resilience=spec)
    with obs.capture("wf") as cap:
        rec = wf.run_once({"bits": 8, "frac": 6})

    resil = rec.resilience
    assert resil is not None and resil.passed, resil.summary()
    assert resil.detected and resil.recovered
    assert resil.corrupted_after_detection == 0
    assert resil.requests_degraded > 0      # RTL→XLA failover carried it
    assert resil.counters["resilience.faults_injected.bitflip"] == 1
    sr = obs.find_spans(cap.trace.spans, "workflow.resilience")[0]
    assert sr.attrs["passed"] is True and sr.attrs["detected"] is True
    assert obs.find_spans(cap.trace.spans, "resilience.chaos")
    # the record still carries the ordinary stage-3 artifacts
    assert rec.measurement.target == "rtl"


def test_workflow_resilience_needs_graph_target():
    """The chaos stage needs a graph-carrying deployment (golden vectors +
    same-design XLA fallback); host-executed targets fail loudly."""
    from repro.resilience import ChaosSpec, FaultPlan, FaultSpec

    spec = ChaosSpec(plan=FaultPlan(
        faults=(FaultSpec(kind="transient", at_call=0),)), n_requests=2)
    wf = Workflow(creator=Creator(), train_fn=_train, step_builder=_steps,
                  target="xla", resilience=spec)
    with pytest.raises(ValueError, match="graph-carrying"):
        wf.run_once({"bits": 8, "frac": 6})
