"""The ElasticAI-Workflow 3-stage loop on the paper's LSTM, end to end."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.creator import Creator
from repro.core.registry import validate_config
from repro.core.report import DesignReport, compare
from repro.core.workflow import Requirement, Workflow
from repro.data.pipeline import TrafficConfig, traffic_flow_batch
from repro.model.layers import init_params
from repro.model.lstm import lstm_flops, lstm_schema
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.quant.fixedpoint import FxpFormat
from repro.quant.qat import QATConfig, make_qat_lstm_apply, make_qat_loss


def _train(knobs):
    cfg = get_config("elastic-lstm")
    qcfg = QATConfig(weight_fmt=FxpFormat(knobs["bits"], knobs["frac"]),
                     act_fmt=FxpFormat(knobs["bits"], knobs["frac"] - 2))
    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    loss_fn = make_qat_loss(cfg, qcfg)
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=100,
                      weight_decay=0.0)
    batch = traffic_flow_batch(TrafficConfig(batch=128), 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(lambda pp: loss_fn(pp, batch)[0])(p)
        p2, o2, _ = adamw_update(g, o, p, ocfg)
        return p2, o2, loss

    first = last = None
    for i in range(60):
        params, opt, loss = step(params, opt)
        first = first if first is not None else float(loss)
        last = float(loss)
    ev = traffic_flow_batch(TrafficConfig(batch=128, seed=9), 1)
    apply = make_qat_lstm_apply(cfg, qcfg)
    pred, _ = apply(params, jnp.asarray(ev["x"]))
    eval_loss = float(jnp.mean((pred - jnp.asarray(ev["y"])) ** 2))
    rep = DesignReport(model="elastic-lstm", train_loss=last,
                       eval_loss=eval_loss,
                       weight_fmt=str(qcfg.weight_fmt),
                       act_fmt=str(qcfg.act_fmt))
    return params, rep, apply


def _steps(knobs, params):
    cfg = get_config("elastic-lstm")
    apply = make_qat_lstm_apply(
        cfg, QATConfig(weight_fmt=FxpFormat(knobs["bits"], knobs["frac"]),
                       act_fmt=FxpFormat(knobs["bits"], knobs["frac"] - 2)))
    x = jnp.asarray(traffic_flow_batch(TrafficConfig(batch=1), 0)["x"])
    fn = lambda p, xx: apply(p, xx)[0]
    return fn, (params, x), float(lstm_flops(cfg))


def test_registry_validates_all():
    assert "lstm" in validate_config(get_config("elastic-lstm"))
    with pytest.raises(KeyError):
        from repro.core import registry

        registry.get("nonexistent-component")


def test_workflow_loop_terminates_on_requirement():
    wf = Workflow(creator=Creator(), train_fn=_train, step_builder=_steps)
    req = Requirement(max_eval_loss=0.05, max_latency_s=10.0)

    def optimizer(history):
        k = dict(history[-1].knobs)
        if k["bits"] >= 16:
            return None
        k["bits"] += 4
        k["frac"] += 3
        return k

    hist = wf.run(req, optimizer, {"bits": 8, "frac": 6}, max_iters=3)
    assert hist, "no iterations ran"
    assert hist[-1].satisfied or len(hist) == 3
    # estimation and measurement exist and are comparable (Table-I shape)
    rec = hist[-1]
    assert rec.synthesis.est_latency_s > 0
    assert rec.measurement.latency_s > 0
    assert "latency_rel_err" in rec.est_vs_meas


def test_lstm_flops_matches_paper_scale():
    """Table I implies ~21.7 kOP/inference; our counted graph must agree."""
    flops = lstm_flops(get_config("elastic-lstm"))
    assert 15_000 < flops < 30_000, flops


@pytest.mark.parametrize("target", ["xla", "rtl"])
def test_workflow_single_path_over_targets(target):
    """Both deployment targets execute the same run_once (no backend fork);
    every MeasurementReport records the unified n_runs and target name."""
    from repro.core.target import DEFAULT_N_RUNS
    from repro.core.types import SHAPES_LSTM
    from repro.energy.hw import XC7S15
    from repro.model.lstm import lstm_apply

    cfg = get_config("elastic-lstm")
    assert not hasattr(Workflow, "_run_once_rtl"), \
        "the RTL fork must be gone: one run_once for every target"

    def train(knobs):
        params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
        rep = DesignReport(model="elastic-lstm", train_loss=0.0,
                           eval_loss=0.0)
        return params, rep, None

    def steps(knobs, params):
        x = jnp.asarray(traffic_flow_batch(TrafficConfig(batch=1), 0)["x"])
        fn = lambda p, xx: lstm_apply(p, xx, cfg)[0]
        return fn, (params, x), float(lstm_flops(cfg))

    creator = Creator(hw=XC7S15) if target == "rtl" else Creator()
    wf = Workflow(creator=creator, train_fn=train, step_builder=steps,
                  stepper_builder=(
                      (lambda k: creator.build(cfg, SHAPES_LSTM["infer_1"]))
                      if target == "rtl" else None),
                  target=target)
    rec = wf.run_once({"bits": 8, "frac": 6})
    assert rec.measurement.target == target
    assert rec.measurement.n_runs == DEFAULT_N_RUNS
    assert rec.measurement.latency_s > 0
    # satellite: _synth_from_fn threads the real model name (no more "wf")
    assert rec.synthesis.model == "elastic-lstm"
    assert "latency_rel_err" in rec.est_vs_meas
