"""Fault injection + fault-tolerant deployment (repro.resilience).

Covers the DESIGN.md §12 contracts: the seeded SEU/chaos harness over the
emulator's prepared memories, the guarded-deployment state machine
(retry/timeout/breaker/canary/fallback), and the scripted chaos scenario
that is the ISSUE-7 acceptance bar — all with injected clocks and numpy
generators, run-twice-identical.
"""
from pathlib import Path

import numpy as np
import pytest

from repro.core.target import Deployment
from repro.obs import MetricsRegistry
from repro.resilience import (CLOSED, HALF_OPEN, OPEN, ChaosSpec,
                              CircuitBreaker, FallbackPolicy, FaultPlan,
                              FaultSpec, FaultyDeployment, GuardedDeployment,
                              GuardExhausted, GuardPolicy, TransientFault,
                              VirtualClock, run_chaos)
from repro.verify import canary_check, canonical_graph, generate_vectors

PLAN_PATH = str(Path(__file__).resolve().parents[1] / "examples"
                / "chaos_plan.json")


@pytest.fixture(scope="module")
def lstm_graph():
    graph, _, _ = canonical_graph("elastic-lstm")
    return graph


@pytest.fixture(scope="module")
def lstm_vectors(lstm_graph):
    return generate_vectors(lstm_graph)


def _rtl_dep(graph):
    from repro.energy.hw import get_hw
    from repro.rtl.backend import RTLExecutable

    return RTLExecutable(graph=graph, artifacts={}, hw=get_hw("xc7s15"))


def _xla_fallback(graph):
    import jax

    from repro.core.target import XLADeployment
    from repro.energy.hw import XC7S15
    from repro.rtl.emulator import reference_apply

    return XLADeployment(fn=jax.jit(lambda x: reference_apply(graph, x)),
                         hw=XC7S15)


# --------------------------------------------------------------------------- #
# FaultSpec / FaultPlan
# --------------------------------------------------------------------------- #


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(kind="gamma_ray", at_call=0)
    with pytest.raises(ValueError, match="never fires"):
        FaultSpec(kind="transient")              # no trigger at all
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(kind="transient", probability=1.5)
    with pytest.raises(ValueError, match="bit"):
        FaultSpec(kind="bitflip", at_call=0, bit=32)
    with pytest.raises(ValueError, match="delay_s"):
        FaultSpec(kind="latency", at_call=0, delay_s=-1.0)


def test_fault_plan_json_round_trip(tmp_path):
    plan = FaultPlan(seed=2024, faults=(
        FaultSpec(kind="transient", at_call=2),
        FaultSpec(kind="bitflip", at_call=9, memory="lstm_cell_l0.w",
                  word=3, bit=31),
        FaultSpec(kind="latency", probability=0.25, once=False,
                  delay_s=0.5)))
    back = FaultPlan.from_json(plan.to_json())
    assert back == plan
    p = tmp_path / "plan.json"
    plan.save(str(p))
    assert FaultPlan.load(str(p)) == plan
    # the checked-in CI scenario must stay loadable
    shipped = FaultPlan.load(PLAN_PATH)
    assert {f.kind for f in shipped.faults} == {"transient", "latency",
                                                "bitflip"}


def test_virtual_clock():
    clk = VirtualClock(start=1.0)
    assert clk() == clk.now() == 1.0
    clk.sleep(0.5)
    clk.advance(0.25)
    clk.sleep(-3.0)                              # never goes backwards
    assert clk.now() == 1.75


# --------------------------------------------------------------------------- #
# SEU model: emulator memories + flip_bit
# --------------------------------------------------------------------------- #


def test_emulator_memories_and_flip_bit(lstm_graph, lstm_vectors):
    dep = _rtl_dep(lstm_graph)
    emu = dep.emulator
    mems = emu.memories()
    assert ("lstm_cell_l0", "w") in mems and \
        ("hard_sigmoid_lut", "table") in mems
    before = np.asarray(emu.prepared("lstm_cell_l0")["w"]).reshape(-1)
    new = emu.flip_bit("lstm_cell_l0", "w", 0, 7)
    assert new == int(before[0]) ^ (1 << 7)
    assert emu.seu_flips == 1
    # silent: no exception, but the canary catches it on the rail rows
    assert not canary_check(dep, lstm_vectors, n=4).passed
    # XOR is an involution: re-flipping restores bit-exact behavior
    emu.flip_bit("lstm_cell_l0", "w", 0, 7)
    assert canary_check(dep, lstm_vectors, n=4).passed


def test_flip_bit_sign_bit_and_word_wrap(lstm_graph):
    emu = _rtl_dep(lstm_graph).emulator
    flat = np.asarray(emu.prepared("linear_head")["w"], np.int32).reshape(-1)
    # bit 31 (the int32 sign bit) must not overflow int32 arithmetic —
    # the emulator XORs through a uint32 view; mirror that here
    u = flat.copy().view(np.uint32)
    u[0] ^= np.uint32(1) << np.uint32(31)
    expected = int(u.view(np.int32)[0])
    assert emu.flip_bit("linear_head", "w", 0, 31) == expected
    # word index wraps modulo the flat size (a plan can't miss the array);
    # XOR involution: the wrapped flip lands on word 0 and restores it
    assert emu.flip_bit("linear_head", "w", flat.size, 31) == int(flat[0])
    with pytest.raises(KeyError):
        emu.flip_bit("linear_head", "nope", 0, 0)
    with pytest.raises(ValueError):
        emu.flip_bit("linear_head", "w", 0, 32)


def test_flip_bit_invalidates_compiled_programs(lstm_graph, lstm_vectors):
    """The jitted programs close over the prepared constants, so an SEU
    only becomes visible through program invalidation — a flip after a
    dispatch must still corrupt the next dispatch."""
    dep = _rtl_dep(lstm_graph)
    stim = lstm_vectors.stimulus
    first = np.asarray(dep.emulator.run_int(stim).outputs)
    assert dep.emulator.cache_stats()["misses"] == 1
    dep.emulator.flip_bit("lstm_cell_l0", "w", 0, 7)
    second = np.asarray(dep.emulator.run_int(stim).outputs)
    assert not np.array_equal(first, second)
    assert dep.emulator.cache_stats()["misses"] == 2   # re-traced


# --------------------------------------------------------------------------- #
# FaultyDeployment
# --------------------------------------------------------------------------- #


class _EchoDeployment(Deployment):
    target = "echo"

    def __init__(self):
        self.calls = 0

    def __call__(self, x):
        self.calls += 1
        import jax.numpy as jnp

        return jnp.asarray(x)


def test_faulty_transient_and_once(lstm_vectors):
    inner = _EchoDeployment()
    plan = FaultPlan(faults=(FaultSpec(kind="transient", at_call=1),))
    fd = FaultyDeployment(inner, plan)
    x = np.ones((1, 2), np.float32)
    fd(x)
    with pytest.raises(TransientFault):
        fd(x)
    fd(x)                                        # once=True: disarmed
    assert [f["kind"] for f in fd.injected] == ["transient"]


def test_faulty_stuck_output_and_latency():
    inner = _EchoDeployment()
    clk = VirtualClock()
    mx = MetricsRegistry()
    plan = FaultPlan(faults=(
        FaultSpec(kind="stuck_output", at_call=0, value=3.0),
        FaultSpec(kind="latency", at_call=1, delay_s=0.75)))
    fd = FaultyDeployment(inner, plan, clock=clk, metrics=mx)
    out = fd(np.zeros((2, 2), np.float32))
    assert np.all(np.asarray(out) == 3.0)        # wedged output register
    fd(np.zeros((2, 2), np.float32))
    assert clk.now() == 0.75                     # stall on the virtual clock
    assert mx.counter("resilience.faults_injected").value == 2
    assert mx.counter("resilience.faults_injected.latency").value == 1


def test_faulty_bitflip_needs_rtl():
    plan = FaultPlan(faults=(FaultSpec(kind="bitflip", at_call=0),))
    fd = FaultyDeployment(_EchoDeployment(), plan)
    with pytest.raises(ValueError, match="no RTL emulator"):
        fd(np.zeros((1, 1), np.float32))


def test_faulty_bitflip_unknown_memory(lstm_graph):
    plan = FaultPlan(faults=(FaultSpec(kind="bitflip", at_call=0,
                                       memory="nope.w"),))
    fd = FaultyDeployment(_rtl_dep(lstm_graph), plan)
    with pytest.raises(ValueError, match="addressable memories"):
        fd(np.zeros((1, 2), np.float32))


def test_faulty_probabilistic_schedule_is_seeded():
    spec = FaultSpec(kind="transient", probability=0.3, once=False)

    def fire_pattern():
        fd = FaultyDeployment(_EchoDeployment(),
                              FaultPlan(faults=(spec,), seed=11))
        fired = []
        for _ in range(32):
            try:
                fd(np.zeros((1, 1), np.float32))
                fired.append(0)
            except TransientFault:
                fired.append(1)
        return fired

    a, b = fire_pattern(), fire_pattern()
    assert a == b and 0 < sum(a) < 32            # deterministic, non-trivial


# --------------------------------------------------------------------------- #
# CircuitBreaker
# --------------------------------------------------------------------------- #


def test_breaker_state_machine():
    clk = VirtualClock()
    mx = MetricsRegistry()
    pol = GuardPolicy(breaker_threshold=2, breaker_cooldown_s=1.0)
    b = CircuitBreaker(pol, clock=clk, metrics=mx)
    assert b.state == CLOSED and b.allow()
    b.record_failure()
    assert b.state == CLOSED                     # under threshold
    b.record_failure()
    assert b.state == OPEN and b.trips == 1
    assert not b.allow()                         # cooling down
    clk.advance(1.0)
    assert b.allow() and b.state == HALF_OPEN    # probe admitted
    b.record_failure()
    assert b.state == OPEN and b.trips == 2      # failed probe re-opens
    clk.advance(1.0)
    assert b.allow()
    b.record_success()
    assert b.state == CLOSED and b.failures == 0
    assert mx.counter("resilience.breaker.open").value == 2
    assert mx.counter("resilience.breaker.closed").value == 1


def test_breaker_quarantine_never_half_opens():
    clk = VirtualClock()
    b = CircuitBreaker(GuardPolicy(breaker_cooldown_s=0.1), clock=clk)
    b.trip(quarantine=True)
    clk.advance(100.0)
    assert not b.allow() and b.quarantined       # corrupted HW can't heal
    b.reset()                                    # operator reflash
    assert b.state == CLOSED and b.allow() and not b.quarantined


# --------------------------------------------------------------------------- #
# GuardedDeployment
# --------------------------------------------------------------------------- #


class _FlakyDeployment(Deployment):
    """Fails the first ``n_fail`` calls, then succeeds."""

    target = "flaky"

    def __init__(self, n_fail):
        self.n_fail = n_fail
        self.calls = 0

    def __call__(self, x):
        self.calls += 1
        if self.calls <= self.n_fail:
            raise RuntimeError("flaked")
        import jax.numpy as jnp

        return jnp.asarray(x) + 1


def test_guard_retry_heals_transient():
    clk = VirtualClock()
    mx = MetricsRegistry()
    g = GuardedDeployment(_FlakyDeployment(2),
                          policy=GuardPolicy(max_retries=2,
                                             breaker_threshold=5),
                          clock=clk, rng=np.random.default_rng(0),
                          metrics=mx)
    res = g.call(np.zeros((1,), np.float32))
    assert res.retries == 2 and res.source == "primary"
    assert not res.degraded
    assert mx.counter("resilience.retries").value == 2
    assert g.breaker.state == CLOSED             # success reset the count
    # backoff slept on the injected clock: base*(1±j) + base*mult*(1±j)
    pol = g.policy
    lo = (pol.backoff_base_s * (1 - pol.jitter_frac)
          * (1 + pol.backoff_mult))
    hi = (pol.backoff_base_s * (1 + pol.jitter_frac)
          * (1 + pol.backoff_mult))
    assert lo <= clk.now() <= hi


def test_guard_backoff_jitter_is_deterministic():
    def elapsed():
        clk = VirtualClock()
        g = GuardedDeployment(_FlakyDeployment(2),
                              policy=GuardPolicy(max_retries=2,
                                                 breaker_threshold=5),
                              clock=clk, rng=np.random.default_rng(42),
                              metrics=MetricsRegistry())
        g.call(np.zeros((1,), np.float32))
        return clk.now()

    assert elapsed() == elapsed()                # same rng -> same jitter


def test_guard_timeout_counts_as_failure(lstm_graph, lstm_vectors):
    """A latency fault longer than timeout_s fails the attempt even though
    the call returns — the retry (clean: once=True disarmed it) serves."""
    clk = VirtualClock()
    mx = MetricsRegistry()
    plan = FaultPlan(faults=(FaultSpec(kind="latency", at_call=0,
                                       delay_s=1.0),))
    faulty = FaultyDeployment(_rtl_dep(lstm_graph), plan, clock=clk,
                              metrics=mx)
    g = GuardedDeployment(faulty,
                          policy=GuardPolicy(timeout_s=0.5, max_retries=1,
                                             breaker_threshold=5),
                          clock=clk, rng=np.random.default_rng(0),
                          metrics=mx)
    res = g.call(lstm_vectors.stimulus_f()[:1])
    assert res.retries == 1 and res.source == "primary"
    assert mx.counter("resilience.timeouts").value == 1


def test_guard_canary_detects_seu_and_quarantines(lstm_graph, lstm_vectors):
    clk = VirtualClock()
    mx = MetricsRegistry()
    dep = _rtl_dep(lstm_graph)
    g = GuardedDeployment(dep, policy=GuardPolicy(canary_every=2),
                          canary=lstm_vectors, clock=clk,
                          rng=np.random.default_rng(0), metrics=mx)
    x = lstm_vectors.stimulus_f()[:1]
    assert g.call(x).canary_passed is True       # healthy probe at call 0
    dep.emulator.flip_bit("lstm_cell_l0", "w", 0, 7)
    g.call(x)                                    # call 1: no probe due
    with pytest.raises(GuardExhausted):          # call 2: probe detects
        g.call(x)
    assert g.breaker.quarantined
    assert len(g.detections) == 1
    assert mx.counter("resilience.faults_detected").value == 1
    assert mx.counter("resilience.requests_lost").value == 1
    assert not g.can_serve()                     # no fallback -> drained


def test_guard_fallback_chain_order():
    clk = VirtualClock()
    mx = MetricsRegistry()

    def bad(x):
        raise RuntimeError("alternate down too")

    calls = []

    def good(x):
        calls.append(x)
        return "served"

    g = GuardedDeployment(
        _FlakyDeployment(10),                    # primary never succeeds
        policy=GuardPolicy(max_retries=0, breaker_threshold=1),
        fallback=FallbackPolicy(alternates=(("first", bad),
                                            ("second", good))),
        clock=clk, rng=np.random.default_rng(0), metrics=mx)
    res = g.call("x")
    assert res.source == "second" and res.degraded and res.value == "served"
    assert mx.counter("resilience.fallback_errors").value == 1
    assert mx.counter("resilience.fallbacks").value == 1
    assert g.can_serve()                         # fallback keeps it serving


def test_guard_call_dunder_returns_value():
    g = GuardedDeployment(_FlakyDeployment(0),
                          policy=GuardPolicy(breaker_threshold=5),
                          clock=VirtualClock(),
                          rng=np.random.default_rng(0),
                          metrics=MetricsRegistry())
    out = g(np.zeros((2,), np.float32))
    assert np.all(np.asarray(out) == 1.0)        # Deployment contract


def test_deployment_guarded_hook(lstm_graph, lstm_vectors):
    """Deployment.guarded() wraps any registry-produced artifact."""
    dep = _rtl_dep(lstm_graph)
    g = dep.guarded(canary=lstm_vectors, clock=VirtualClock(),
                    rng=np.random.default_rng(0), metrics=MetricsRegistry())
    assert isinstance(g, GuardedDeployment)
    assert g.target == "rtl" and g.graph is lstm_graph
    assert g.probe() is True


# --------------------------------------------------------------------------- #
# Canary slice API
# --------------------------------------------------------------------------- #


def test_vectorset_head_slice(lstm_vectors):
    h = lstm_vectors.head(4)
    assert h.n_vectors == 4
    assert np.array_equal(h.stimulus, lstm_vectors.stimulus[:4])
    assert np.array_equal(h.response, lstm_vectors.response[:4])
    assert h.meta["slice"] == "head(4)"
    assert lstm_vectors.head(10_000).n_vectors == lstm_vectors.n_vectors
    with pytest.raises(ValueError):
        lstm_vectors.head(0)


def test_canary_check_float_path(lstm_graph, lstm_vectors):
    """Host-executed deployments answer in float; the canary re-encodes at
    the output format and still demands integer-exact codes."""
    fb = _xla_fallback(lstm_graph)
    res = canary_check(fb, lstm_vectors, n=4)
    assert res.passed and res.path == "float"


# --------------------------------------------------------------------------- #
# The acceptance scenario (ISSUE 7) + determinism audit
# --------------------------------------------------------------------------- #


def _acceptance_spec():
    return ChaosSpec(
        plan=FaultPlan.load(PLAN_PATH),
        n_requests=24, seed=7,
        policy=GuardPolicy(timeout_s=0.25, max_retries=2,
                           breaker_threshold=3, canary_every=4))


def test_chaos_scenario_elastic_lstm(lstm_graph):
    """Injected BRAM bit-flip -> canary detection within one probe
    interval -> breaker quarantine -> RTL→XLA failover with zero
    post-detection corrupted responses, all recorded in the report and the
    resilience.* counters."""
    dep = _rtl_dep(lstm_graph)
    rep = run_chaos(dep, _acceptance_spec(),
                    fallback=FallbackPolicy.to_xla(_xla_fallback(lstm_graph)))
    assert rep.passed and rep.detected and rep.recovered
    assert rep.corrupted_after_detection == 0
    assert rep.requests_lost == 0                # the workload kept serving
    assert 0 <= rep.mttr_requests <= 4           # within one probe interval
    assert rep.final_breaker_state == OPEN and rep.breaker_trips == 1
    assert rep.counters["resilience.faults_injected"] == 3
    assert rep.counters["resilience.faults_detected"] == 1
    assert rep.counters["resilience.fallbacks"] > 0
    assert rep.counters["resilience.retries"] > 0
    kinds = [f["kind"] for f in rep.faults_injected]
    assert kinds == ["transient", "latency", "bitflip"]
    # post-detection requests all served degraded by the XLA alternate
    det = rep.faults_detected[0]["request"]
    post = [r for r in rep.requests if r["request"] > det]
    assert post and all(r["source"] == "xla" and r["correct"]
                        for r in post)


def test_chaos_run_twice_identical(lstm_graph):
    """Determinism audit: every retry/jitter/fault path draws from injected
    generators and the shared VirtualClock, so the full report JSON is
    byte-identical across runs (the emit-twice golden-artifact pattern)."""
    fb = FallbackPolicy.to_xla(_xla_fallback(lstm_graph))
    r1 = run_chaos(_rtl_dep(lstm_graph), _acceptance_spec(), fallback=fb)
    r2 = run_chaos(_rtl_dep(lstm_graph), _acceptance_spec(), fallback=fb)
    assert r1.to_json() == r2.to_json()


def test_chaos_needs_graph_or_vectors():
    with pytest.raises(ValueError, match="vectors"):
        run_chaos(_EchoDeployment(),
                  ChaosSpec(plan=FaultPlan(
                      faults=(FaultSpec(kind="transient", at_call=0),))))
