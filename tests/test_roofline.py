"""Collective parser + roofline math on handcrafted and real HLO."""
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # image lacks hypothesis: use shim
    from _hypothesis_compat import given, settings, st

from repro.energy.meter import meter_channels
from repro.energy.roofline import (_shape_bytes, parse_collectives, roofline)

HLO = """
HloModule test
ENTRY main {
  %p = bf16[256,1024]{1,0} parameter(0)
  %ar = bf16[256,1024]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[64,512]{1,0} all-gather(%x), replica_groups=[16,8]<=[128], dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%y), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %cp = bf16[32,32]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = f32[16,64]{1,0} all-to-all(%w), replica_groups={{0,1}}
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[256,1024]") == 256 * 1024 * 2
    assert _shape_bytes("f32[8]") == 32
    assert _shape_bytes("(f32[4], bf16[2,2])") == 16 + 8


def test_parse_collectives_counts_and_bytes():
    stc = parse_collectives(HLO, 128)
    assert stc.counts == {"all-reduce": 1, "all-gather": 1,
                          "reduce-scatter": 1, "collective-permute": 1,
                          "all-to-all": 1}
    ar = 256 * 1024 * 2
    assert stc.local_bytes["all-reduce"] == ar
    # ring all-reduce over 4 devices: 2*S*(4-1)/4
    assert abs(stc.wire_bytes["all-reduce"] - 2 * ar * 3 / 4) < 1
    # all-gather out = 64*512*4 over group 8
    ag_out = 64 * 512 * 4
    assert abs(stc.wire_bytes["all-gather"] - ag_out * 7 / 8) < 1
    # reduce-scatter out bytes × (n-1)
    rs_out = 8 * 128 * 4
    assert stc.wire_bytes["reduce-scatter"] == rs_out * 7
    assert stc.wire_bytes["collective-permute"] == 32 * 32 * 2


def test_async_start_not_double_counted():
    hlo = """
  %ars = (bf16[128,8]{1,0}, bf16[128,8]{1,0}) all-reduce-start(%p), replica_groups={{0,1}}
  %ard = bf16[128,8]{1,0} all-reduce-done(%ars)
"""
    stc = parse_collectives(hlo, 2)
    assert stc.counts == {"all-reduce": 1}
    assert stc.local_bytes["all-reduce"] == 128 * 8 * 2


def test_roofline_bottleneck_selection():
    rep = roofline(arch="x", shape="y", mesh="m", n_devices=4,
                   cost={"flops": 197e12, "bytes accessed": 1e9},
                   hlo_text="", model_flops=4 * 197e12)
    assert rep.bottleneck == "compute"
    assert abs(rep.compute_s - 1.0) < 1e-9
    assert abs(rep.mfu - 1.0) < 1e-6


def test_meter_exact_dot_flops():
    """The MXU channel must count 2·M·N·K for a plain matmul."""
    M, K, N = 128, 256, 64

    def f(a, b):
        return a @ b

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                         jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    ch = meter_channels(c.as_text(), 1)
    assert abs(ch.work["mxu"] - 2 * M * N * K) / (2 * M * N * K) < 0.01


@given(st.integers(1, 64), st.integers(1, 64), st.integers(2, 16))
@settings(max_examples=20, deadline=None)
def test_wire_bytes_scale_with_group(m, n, g):
    hlo = (f"%ar = f32[{m},{n}]{{1,0}} all-reduce(%p), "
           f"replica_groups={{{{{','.join(str(i) for i in range(g))}}}}}")
    stc = parse_collectives(hlo, 512)
    expect = 2 * m * n * 4 * (g - 1) / g
    assert abs(stc.wire_bytes["all-reduce"] - expect) < 1e-6
