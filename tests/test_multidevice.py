"""Multi-device behaviour — run in subprocesses with 8 forced host devices
(the main test process must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# The MoE EP paths go through ``repro.shardmap.shard_map`` — the repo-wide
# compat wrapper over ``jax.shard_map`` / ``jax.experimental.shard_map`` —
# so they run for real on either jax generation (no version skip).


def run_sub(body: str, n_dev: int = 8, timeout: int = 900) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_dev}")
        os.environ["JAX_PLATFORMS"] = "cpu"
        import sys
        sys.path.insert(0, {ROOT + "/src"!r})
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_moe_ep_impls_match_dense_oracle():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.core.types import MeshConfig, ParallelismConfig
        from repro.model.layers import Ctx, init_params
        from repro.model.moe import moe_schema, moe_dense, moe_psum, moe_a2a

        cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
        # 8 experts over tp=4 -> 2 local experts/shard
        mcfg = MeshConfig((2, 4), ("data", "model"))
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        par = ParallelismConfig(compute_dtype="float32")
        schema = moe_schema(cfg, tp=4)
        params = init_params(schema, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        # capacity high enough that no token drops -> exact match possible
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        with mesh:
            ctx = Ctx(cfg=cfg, mesh_cfg=mcfg, mode="train", mesh=mesh, par=par)
            y_d, aux_d = moe_dense(params, x, cfg, ctx)
            y_p, aux_p = moe_psum(params, x, cfg, ctx)
            y_a, aux_a = moe_a2a(params, x, cfg, ctx)
        err_p = float(jnp.max(jnp.abs(y_p - y_d)))
        err_a = float(jnp.max(jnp.abs(y_a - y_d)))
        print("psum err", err_p, "a2a err", err_a)
        assert err_p < 2e-4, err_p
        assert err_a < 2e-4, err_a
        # aux: per-DP-shard load-balance stats vs global stats are different
        # (equally valid) estimators — same scale, not bit-equal
        rel = abs(float(aux_p - aux_d)) / max(abs(float(aux_d)), 1e-9)
        assert rel < 0.5, (float(aux_p), float(aux_d))
    """)


def test_elastic_restart_reshards():
    run_sub("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.core.types import MeshConfig, ParallelismConfig, ShapeConfig
        from repro.data.pipeline import LMDataConfig
        from repro.model.lm import Stepper
        from repro.runtime.trainer import Trainer, TrainerConfig

        cfg = get_config("yi-9b", smoke=True)
        par = ParallelismConfig(compute_dtype="float32")
        S, B = 16, 8
        dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=S,
                            global_batch=B)
        td = tempfile.mkdtemp()

        # train 12 steps on a (4 dp, 2 tp) mesh
        mcfg1 = MeshConfig((4, 2), ("data", "model"))
        mesh1 = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                     ("data", "model"))
        st1 = Stepper(cfg, ShapeConfig("t", "train", S, B), mcfg1, par,
                      mesh=mesh1)
        tr1 = Trainer(st1, dcfg, TrainerConfig(total_steps=12, ckpt_every=5,
                                               ckpt_dir=td, log_every=5))
        with mesh1:
            out1 = tr1.train()

        # elastic restart: same checkpoint, (2 dp, 4 tp) mesh
        mcfg2 = MeshConfig((2, 4), ("data", "model"))
        mesh2 = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                     ("data", "model"))
        st2 = Stepper(cfg, ShapeConfig("t", "train", S, B), mcfg2, par,
                      mesh=mesh2)
        shard2 = {"params": st2.shardings(st2.schema), "opt": None}
        step, state = tr1.resume_elastic(st2)
        print("resumed at", step)
        assert step == 11
        # continue training on the new mesh
        with mesh2:
            fn = jax.jit(st2.train_fn())
            from repro.data.pipeline import lm_batch_for_step
            p, o, m = fn(state["params"], state["opt"],
                         lm_batch_for_step(dcfg, step))
        assert jnp.isfinite(m["loss"])
        print("elastic OK, loss", float(m["loss"]))
    """)


def test_dryrun_minimal_mesh_compiles():
    """A miniature production mesh (2x4) exercises the full dry-run path
    (shardings, donation, roofline) quickly."""
    run_sub("""
        import numpy as np, jax
        jax.devices()   # lock device count BEFORE dryrun's XLA_FLAGS line
        from jax.sharding import Mesh
        import repro.launch.dryrun as dr
        from repro.configs import get_config
        from repro.core.types import (MeshConfig, ParallelismConfig, SHAPES,
                                      ShapeConfig)

        cfg = get_config("internvl2-1b")
        cfg = cfg.with_(n_layers=2)
        shape = ShapeConfig("t", "train", 512, 8)
        mcfg = MeshConfig((2, 4), ("data", "model"))
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        par = ParallelismConfig()
        cost, mem, hlo, dt = dr._compile_cell(cfg, shape, mcfg, mesh, par)
        assert cost.get("flops", 0) > 0
        from repro.energy.roofline import parse_collectives
        stc = parse_collectives(hlo, 8)
        print("collectives:", stc.counts, "wire:", stc.total_wire_bytes)
        assert stc.total_wire_bytes > 0
    """)
