"""Property tests on core layer invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # image lacks hypothesis: use shim
    from _hypothesis_compat import given, settings, st

from repro.core.types import ModelConfig
from repro.model.layers import apply_norm, apply_rope, rope_angles, shard_axis


def _cfg(norm="rmsnorm"):
    return ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                       norm=norm)


@given(st.integers(1, 4), st.integers(2, 24), st.floats(0.5, 20))
@settings(max_examples=25, deadline=None)
def test_rmsnorm_scale_invariance(b, s, scale):
    """rmsnorm(c·x) == rmsnorm(x) — the property QAT relies on."""
    cfg = _cfg()
    p = {"scale": jnp.ones((32,))}
    x = jax.random.normal(jax.random.PRNGKey(b * 100 + s), (b, s, 32)) + 0.1
    a = apply_norm(p, x, cfg)
    bb = apply_norm(p, jnp.float32(scale) * x, cfg)
    assert float(jnp.max(jnp.abs(a - bb))) < 1e-4


@given(st.integers(0, 4000), st.integers(2, 16))
@settings(max_examples=25, deadline=None)
def test_rope_preserves_norm(pos0, hd_half):
    hd = 2 * hd_half
    pos = jnp.asarray([[pos0]])
    cos, sin = rope_angles(pos, hd, 10_000.0)
    x = jax.random.normal(jax.random.PRNGKey(pos0), (1, 1, 2, hd))
    y = apply_rope(x, cos, sin)
    nx = jnp.linalg.norm(x, axis=-1)
    ny = jnp.linalg.norm(y, axis=-1)
    assert float(jnp.max(jnp.abs(nx - ny))) < 1e-3


def test_rope_relative_phase():
    """q·k after rope depends only on relative distance."""
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))

    def dot_at(pq, pk):
        cq, sq_ = rope_angles(jnp.asarray([[pq]]), hd, 10_000.0)
        ck, sk_ = rope_angles(jnp.asarray([[pk]]), hd, 10_000.0)
        return float(jnp.sum(apply_rope(q, cq, sq_)
                             * apply_rope(k, ck, sk_)))

    assert dot_at(7, 3) == pytest.approx(dot_at(104, 100), rel=1e-4)
    assert dot_at(0, 0) == pytest.approx(dot_at(55, 55), rel=1e-4)


@given(st.integers(1, 256), st.integers(1, 32))
@settings(max_examples=50, deadline=None)
def test_shard_axis_rule(n, tp):
    ax = shard_axis(n, tp)
    if ax == "model":
        assert n % tp == 0 and n >= tp
    else:
        assert ax is None


def test_layernorm_zero_mean_unit_var():
    cfg = _cfg("layernorm")
    p = {"scale": jnp.ones((32,)), "bias": jnp.zeros((32,))}
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 32)) * 5 + 3
    y = apply_norm(p, x, cfg)
    assert float(jnp.max(jnp.abs(y.mean(-1)))) < 1e-4
    assert float(jnp.max(jnp.abs(y.std(-1) - 1))) < 1e-2


def test_cross_entropy_uniform_logits():
    from repro.model.lm import cross_entropy

    B, S, V = 2, 5, 64
    logits = jnp.zeros((B, S, V))
    t = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0, V)
    loss, n = cross_entropy(logits, t)
    assert float(loss) == pytest.approx(float(jnp.log(V)), rel=1e-5)
    # masked positions drop out
    t2 = t.at[:, 0].set(-1)
    loss2, n2 = cross_entropy(logits, t2)
    assert int(n2) == B * (S - 1)


def test_chunked_ce_equals_dense():
    from repro.model.lm import chunked_ce_loss, cross_entropy

    B, S, D, V = 2, 24, 8, 32
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (D, V))
    t = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    dense, _ = cross_entropy(h @ w, t)
    import repro.model.lm as lm
    old = lm.CE_CHUNK
    lm.CE_CHUNK = 7  # force ragged chunking
    try:
        ck, _ = chunked_ce_loss(h, t, lambda hc: hc @ w)
    finally:
        lm.CE_CHUNK = old
    assert float(jnp.abs(dense - ck)) < 1e-5
