"""Elastic Node conformance subsystem: differential harness, golden-vector
protocol, measurement bands, and property fuzzing over every registered
hardware template (including an in-test custom one, proving third-party
templates inherit the harness for free)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # image lacks hypothesis: use shim
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.creator import Creator
from repro.core.types import SHAPES_CONV1D, SHAPES_LSTM
from repro.energy.hw import XC7S15
from repro.quant.fixedpoint import FxpFormat, fxp_quantize
from repro.rtl import (Edge, Graph, HWTemplate, emit_graph, lower_model,
                       list_templates, register_template,
                       unregister_template)
from repro.rtl.ir import Node
from repro.verify import (GOLDEN_SEED, MeasurementProtocol, canonical_graph,
                          emit_golden, fuzz_template, generate_vectors,
                          load_vectors, run_conformance, save_vectors)

GOLDEN_ROOT = os.path.join(os.path.dirname(__file__), "golden")
VECTOR_ROOT = os.path.join(GOLDEN_ROOT, "vectors")
ARCHS = ("elastic-lstm", "elastic-conv1d")


# --------------------------------------------------------------------------- #
# Property fuzz: every registered template kind, via its sample_inputs hook
# --------------------------------------------------------------------------- #


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=6, deadline=None)
def test_fuzz_every_registered_template(seed):
    """The bit-exactness contract + error budget hold for every registered
    kind over seeded probe designs and stimulus from each template's own
    ``sample_inputs`` hook."""
    probed = 0
    for kind in list_templates():
        rep = fuzz_template(kind, seed=seed)
        if rep is None:                  # no standalone compute (shared ROM)
            assert kind == "act_lut"
            continue
        probed += 1
        assert rep.modes_bit_exact, (kind, seed, rep.to_json())
        assert rep.oracle_within_budget, (kind, seed, rep.to_json())
        assert rep.passed, (kind, seed, rep.to_json())
    assert probed >= 5                   # all built-ins except the bare ROM


class _DoubleNode(Node):
    fmt: FxpFormat = FxpFormat(8, 4)

    def __init__(self, **kw):
        self.fmt = kw.pop("fmt", FxpFormat(8, 4))
        super().__init__(**kw)


class _DoubleTemplate(HWTemplate):
    """y = saturate(2·x): one adder, no memories — a minimal third-party
    template that implements only the plugin hooks."""

    kind = "double_test"
    node_cls = _DoubleNode

    def execute(self, n, env, em, mode):
        x = env[n.inputs[0]].astype(jnp.int32)
        env[n.outputs[0]] = jnp.clip(2 * x, n.fmt.lo, n.fmt.hi)

    def reference(self, n, env, luts):
        env[n.outputs[0]] = fxp_quantize(2.0 * env[n.inputs[0]], n.fmt)

    def emit(self, graph, n, out):
        out[f"{n.name}.vhd"] = f"entity {n.name} is\nend entity {n.name};\n"

    def probe_graph(self, rng):
        fmt = FxpFormat(8, 4)
        g = Graph(name="probe_double")
        g.edges["x"] = Edge("x", (4,), fmt)
        g.inputs = ["x"]
        g.add(_DoubleNode(name="d0", op=self.kind, inputs=["x"],
                          outputs=["y"], fmt=fmt), Edge("y", (4,), fmt))
        g.outputs = ["y"]
        return g


def test_custom_template_inherits_harness():
    """Register → fuzz: a template that only implements the plugin hooks
    gets the full differential check without touching repro internals."""
    register_template(_DoubleTemplate())
    try:
        rep = fuzz_template("double_test", seed=7)
        assert rep is not None and rep.passed, rep and rep.to_json()
        assert rep.modes_bit_exact and rep.oracle_within_budget
        assert rep.n_vectors >= 8
    finally:
        unregister_template("double_test")


def test_error_budget_gates_oracle_mismatch():
    """A template whose int path deviates by 1 LSB fails at the default
    0-LSB budget and passes once it *declares* that slack — the budget is
    derived from declarations, never assumed."""

    class OffByOne(_DoubleTemplate):
        kind = "offbyone_test"

        def execute(self, n, env, em, mode):
            x = env[n.inputs[0]].astype(jnp.int32)
            env[n.outputs[0]] = jnp.clip(2 * x + 1, n.fmt.lo, n.fmt.hi)

    class OffByOneDeclared(OffByOne):
        kind = "offbyone_test"

        def error_budget_lsb(self, node):
            return 1

    register_template(OffByOne())
    try:
        rep = fuzz_template("offbyone_test", seed=1)
        assert not rep.passed and not rep.oracle_within_budget
        assert rep.oracle_max_lsb >= 1 and rep.error_budget_lsb == 0
        register_template(OffByOneDeclared(), overwrite=True)
        rep2 = fuzz_template("offbyone_test", seed=1)
        assert rep2.passed and rep2.oracle_within_budget
        assert rep2.error_budget_lsb == 1
    finally:
        unregister_template("offbyone_test")


def test_conformance_detects_mode_divergence():
    """A schedule that miscompiles in one execution path must fail the
    mutual bit-exactness check, not slide through on the oracle."""

    class ModeSkewed(_DoubleTemplate):
        kind = "modeskew_test"

        def execute(self, n, env, em, mode):
            x = env[n.inputs[0]].astype(jnp.int32)
            bump = 1 if mode == "jnp" else 0
            env[n.outputs[0]] = jnp.clip(2 * x + bump, n.fmt.lo, n.fmt.hi)

    register_template(ModeSkewed())
    try:
        rep = fuzz_template("modeskew_test", seed=2)
        assert not rep.passed and not rep.modes_bit_exact
        assert any(v > 0 for v in rep.mode_max_diff.values())
    finally:
        unregister_template("modeskew_test")


# --------------------------------------------------------------------------- #
# Golden vectors: determinism, round-trip, checked-in snapshots
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("arch", ARCHS)
def test_vector_emit_twice_byte_identical(arch, tmp_path):
    """Generating + serializing the same design's vectors twice yields
    byte-identical .npz and manifest files (the snapshot contract)."""
    d1, d2 = tmp_path / "a", tmp_path / "b"
    graph, _, _ = canonical_graph(arch)
    save_vectors(generate_vectors(graph), str(d1))
    save_vectors(generate_vectors(graph), str(d2))
    for name in ("vectors.npz", "manifest.json"):
        assert (d1 / name).read_bytes() == (d2 / name).read_bytes(), name


def test_vector_set_round_trip(tmp_path):
    graph, _, _ = canonical_graph("elastic-lstm")
    vs = generate_vectors(graph)
    save_vectors(vs, str(tmp_path))
    back = load_vectors(str(tmp_path))
    assert back.design == vs.design and back.seed == GOLDEN_SEED
    assert back.in_fmt == vs.in_fmt and back.out_fmt == vs.out_fmt
    assert np.array_equal(back.stimulus, vs.stimulus)
    assert np.array_equal(back.response, vs.response)
    # corner rows lead: silence, rail-low, rail-high
    assert np.all(back.stimulus[0] == 0)
    assert np.all(back.stimulus[1] == vs.in_fmt.lo)
    assert np.all(back.stimulus[2] == vs.in_fmt.hi)


def test_vector_set_checksum_validation(tmp_path):
    """A tampered vector file must be rejected, not silently replayed."""
    graph, _, _ = canonical_graph("elastic-lstm")
    save_vectors(generate_vectors(graph), str(tmp_path))
    man_path = tmp_path / "manifest.json"
    man = json.loads(man_path.read_text())
    man["response"]["sha256"] = "0" * 64
    man_path.write_text(json.dumps(man))
    with pytest.raises(ValueError, match="sha256 mismatch"):
        load_vectors(str(tmp_path))


@pytest.mark.parametrize("arch", ARCHS)
def test_checked_in_golden_vectors_replay(arch, tmp_path):
    """The checked-in stimulus/response sets are (a) exactly what the
    generator emits today — byte-for-byte — and (b) replayable: the lowered
    design still produces the stored responses integer-for-integer."""
    got = emit_golden(arch, str(tmp_path))
    golden_dir = os.path.join(VECTOR_ROOT, arch)
    for name in ("vectors.npz", "manifest.json"):
        want = open(os.path.join(golden_dir, name), "rb").read()
        have = open(os.path.join(str(tmp_path), arch, name), "rb").read()
        assert have == want, (
            f"{arch}/{name} drifted from tests/golden/vectors — if the "
            "change is intentional, regenerate via "
            f"repro.verify.emit_golden({arch!r}, 'tests/golden/vectors')")
    vs = load_vectors(golden_dir)
    assert vs.n_vectors == got.n_vectors
    graph, _, _ = canonical_graph(arch)
    rep = run_conformance(graph, vs)
    assert rep.golden_match is True and rep.passed, rep.to_json()


def test_elastic_conv1d_manifest_matches_golden():
    """conv1d parity with the lstm snapshot: the second arch's emitted
    manifest is pinned too (weight-independent, so platform-stable)."""
    from repro.model.conv1d import conv1d_schema
    from repro.model.layers import init_params

    cfg = get_config("elastic-conv1d")
    params = init_params(conv1d_schema(cfg), jax.random.PRNGKey(0))
    got = emit_graph(lower_model(cfg, params))["manifest.json"]
    with open(os.path.join(GOLDEN_ROOT,
                           "elastic_conv1d_manifest.json")) as f:
        want = f.read()
    assert got == want, (
        "manifest.json drifted from tests/golden/elastic_conv1d_manifest"
        ".json — if the change is intentional, regenerate the snapshot")


# --------------------------------------------------------------------------- #
# Deployment.verify: both registered archs × both registered targets
# --------------------------------------------------------------------------- #


def _flops(cfg):
    if cfg.family == "lstm":
        from repro.model.lstm import lstm_flops

        return float(lstm_flops(cfg))
    from repro.model.conv1d import conv1d_flops

    return float(conv1d_flops(cfg))


def _shapes(cfg):
    return SHAPES_LSTM if cfg.family == "lstm" else SHAPES_CONV1D


@pytest.mark.parametrize("arch", ARCHS)
def test_deployment_verify_rtl(arch):
    """translate(target="rtl") → verify(): modes mutually bit-exact, oracle
    within budget, protocol bands (incl. Table I for the reference design)
    all pass — the acceptance path."""
    cfg = get_config(arch)
    cr = Creator(hw=XC7S15)
    st_ = cr.build(cfg, _shapes(cfg)["infer_1"])
    _, dep = cr.translate(st_, target="rtl")
    rep = dep.verify(model=cfg.name, model_flops=_flops(cfg))
    assert rep.passed, rep.to_json()
    assert rep.modes == ("fused", "pallas", "jnp") and rep.modes_bit_exact
    assert rep.oracle_within_budget and rep.error_budget_lsb == 0
    assert rep.n_vectors >= 16
    assert rep.protocol is not None and rep.protocol["passed"]
    check_names = {c["name"] for c in rep.protocol["checks"]}
    assert "latency_vs_cycle_model" in check_names
    if arch == "elastic-lstm":
        assert "latency_vs_table1_us" in check_names
        assert "gop_per_j_vs_table1" in check_names


@pytest.mark.parametrize("arch", ARCHS)
def test_deployment_verify_xla(arch):
    """The same verify() contract on the host-executed target: protocol
    plus float-oracle agreement of the deployed executable."""
    cfg = get_config(arch)
    cr = Creator()
    st_ = cr.build(cfg, _shapes(cfg)["infer_1"])
    _, dep = cr.translate(st_, target="xla")
    params, _ = st_.init()
    ab = st_.abstract_inputs()
    batch = {k: (jax.random.normal(jax.random.PRNGKey(0), v.shape)
                 if k == "x" else jnp.zeros(v.shape, v.dtype))
             for k, v in ab["batch"].items()}
    if cfg.family == "lstm":
        from repro.model.lstm import lstm_apply as apply_fn
    else:
        from repro.model.conv1d import conv1d_apply as apply_fn
    rep = dep.verify((params, batch), model=cfg.name,
                     model_flops=_flops(cfg),
                     oracle=lambda p, b: apply_fn(p, b["x"], cfg))
    assert rep.passed, rep.to_json()
    assert rep.target == "xla" and rep.modes == ()
    assert rep.protocol is not None and rep.protocol["passed"]
    assert any("oracle agreement" in n for n in rep.notes)


def test_protocol_band_failure_is_reported():
    """An impossible tolerance band must fail the protocol — proving the
    Table-I comparison has teeth, not just presence."""
    cfg = get_config("elastic-lstm")
    cr = Creator(hw=XC7S15)
    st_ = cr.build(cfg, SHAPES_LSTM["infer_1"])
    _, dep = cr.translate(st_, target="rtl")
    rep = dep.verify(model=cfg.name, model_flops=_flops(cfg),
                     protocol=MeasurementProtocol(n_runs=2,
                                                  table1_rtol=1e-6))
    assert not rep.passed
    assert rep.protocol is not None and not rep.protocol["passed"]
    failed = [c["name"] for c in rep.protocol["checks"]
              if c["enforced"] and not c["passed"]]
    assert any("table1" in n for n in failed)


def test_workflow_verify_stage_records_conformance():
    """Workflow(verify=True): the loop's records carry the ConformanceReport
    from the Elastic Node stage."""
    from repro.core.report import DesignReport
    from repro.core.workflow import Requirement, Workflow
    from repro.model.layers import init_params
    from repro.model.lstm import lstm_schema

    cfg = get_config("elastic-lstm")

    def train_fn(knobs):
        params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
        return params, DesignReport(model=cfg.name, train_loss=0.0,
                                    eval_loss=0.0), None

    def step_builder(knobs, params):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 1))
        return None, (params, x), _flops(cfg)

    wf = Workflow(creator=Creator(hw=XC7S15), train_fn=train_fn,
                  step_builder=step_builder,
                  stepper_builder=lambda k: Creator(hw=XC7S15).build(
                      cfg, SHAPES_LSTM["infer_1"]),
                  target="rtl", verify=True)
    hist = wf.run(Requirement(max_latency_s=1.0), lambda h: None, {},
                  max_iters=1)
    rec = hist[0]
    assert rec.conformance is not None
    assert rec.conformance.passed, rec.conformance.to_json()
    assert rec.conformance.modes_bit_exact
    # verify=False (the default) stays free of the extra stage
    wf2 = Workflow(creator=Creator(hw=XC7S15), train_fn=train_fn,
                   step_builder=step_builder,
                   stepper_builder=lambda k: Creator(hw=XC7S15).build(
                       cfg, SHAPES_LSTM["infer_1"]),
                   target="rtl")
    rec2 = wf2.run_once({}, 0)
    assert rec2.conformance is None


# --------------------------------------------------------------------------- #
# Protocol band edges: inclusive boundaries + the advisory/enforced split
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("rtol", [0.05, 0.15])
def test_protocol_band_boundary_is_inclusive(rtol):
    """A measurement landing exactly on the band edge passes (the check is
    <=, not <) and one just beyond fails — for both the 5% cycle-model
    band and the 15% Table-I band, on both sides of the reference."""
    import math

    from repro.verify.protocol import _band

    ref = 100.0
    edge = rtol * abs(ref)
    assert _band("hi", ref + edge, ref, rtol).passed
    assert _band("lo", ref - edge, ref, rtol).passed
    assert not _band("hi+", math.nextafter(ref + edge, math.inf),
                     ref, rtol).passed
    assert not _band("lo-", math.nextafter(ref - edge, -math.inf),
                     ref, rtol).passed
    # negative references band on |reference|
    assert _band("neg", -ref - edge, -ref, rtol).passed
    # non-finite measurements never pass, whatever the band
    assert not _band("nan", math.nan, ref, rtol).passed
    assert not _band("inf", math.inf, ref, rtol).passed


@pytest.mark.parametrize("arch", ARCHS)
def test_protocol_xla_advisory_vs_enforced_split(arch):
    """Host-executed targets: only the positivity sanity checks gate
    ``passed``; the synthesis-estimate band is recorded as evidence but
    advisory (enforced=False) — host wall-clock has no fabric model."""
    from repro.verify.protocol import run_protocol

    cfg = get_config(arch)
    cr = Creator()
    st_ = cr.build(cfg, _shapes(cfg)["infer_1"])
    syn, dep = cr.translate(st_, target="xla")
    # the estimate band only exists for deployments carrying the synthesis
    # latency estimate; record it the way a saved manifest would
    dep.cost["est_latency_s"] = syn.est_latency_s
    params, _ = st_.init()
    ab = st_.abstract_inputs()
    batch = {k: (jax.random.normal(jax.random.PRNGKey(0), v.shape)
                 if k == "x" else jnp.zeros(v.shape, v.dtype))
             for k, v in ab["batch"].items()}
    rep = run_protocol(dep, (params, batch), model=cfg.name,
                       model_flops=_flops(cfg),
                       protocol=MeasurementProtocol(warmup=1, n_runs=2))
    by_name = {c.name: c for c in rep.checks}
    enforced = {n for n, c in by_name.items() if c.enforced}
    assert enforced == {"latency_positive_finite", "energy_positive_finite"}
    assert "latency_vs_estimate" in by_name          # recorded, not gating
    assert not by_name["latency_vs_estimate"].enforced
    assert rep.passed == all(c.passed for c in rep.checks if c.enforced)
    assert rep.passed, rep.to_json()


def test_protocol_advisory_failure_does_not_gate():
    """An arbitrarily blown advisory band leaves ``passed`` True: only
    enforced checks have teeth."""
    from repro.core.report import MeasurementReport
    from repro.core.target import Deployment
    from repro.verify.protocol import run_protocol

    class _HostDep(Deployment):
        target = "host-fake"
        cost = {"est_latency_s": 1e-12}   # 12 orders off the measurement

        def __call__(self, *args):
            return np.float32(0.0)

        def measure(self, args, **kw):
            return MeasurementReport(model="m", platform="p", latency_s=1.0,
                                     power_w=1.0, energy_j=1.0,
                                     gop_per_j=1.0,
                                     n_runs=kw.get("n_runs", 1),
                                     target=self.target)

    rep = run_protocol(_HostDep(), (np.zeros(1, np.float32),), model="m",
                       model_flops=1e6,
                       protocol=MeasurementProtocol(warmup=0, n_runs=1))
    adv = [c for c in rep.checks if not c.enforced]
    assert adv and not adv[0].passed      # the estimate band is blown...
    assert rep.passed                     # ...but cannot gate the report
