"""Checkpointing: atomicity, GC, async, restore exactness."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   load_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": (jnp.ones((3,)), jnp.zeros((2, 2)))}}


def test_roundtrip_exact(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 7
    r = load_checkpoint(str(tmp_path), 7, jax.tree.map(np.asarray, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_last_k(tmp_path):
    for s in range(6):
        save_checkpoint(str(tmp_path), s, _tree(), keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_tmp_dirs_never_visible_as_latest(tmp_path):
    # a stale tmp dir (simulated crash) must not be picked up
    os.makedirs(tmp_path / "step_00000099.tmp-123")
    save_checkpoint(str(tmp_path), 1, _tree())
    assert latest_step(str(tmp_path)) == 1


def test_async_manager(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(3)
    m.save_async(4, t)
    m.wait()
    step, r = m.restore(jax.tree.map(np.asarray, t))
    assert step == 4
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"a": jnp.ones((4,))})
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), 0, {"a": jnp.ones((5,))})
