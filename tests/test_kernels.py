"""Per-kernel shape/dtype sweeps: Pallas template (interpret=True on CPU)
vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.lstm_cell.ops import lstm_window
from repro.kernels.lstm_cell.ref import lstm_window_ref
from repro.kernels.lstm_cell_int import (CellSpec, lstm_window_int,
                                         lstm_window_int_ref)
from repro.kernels.mamba2.ops import ssd
from repro.kernels.quant_matmul.ops import quant_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref, quantize_act
from repro.kernels.rwkv6.ops import wkv6
from repro.model.rwkv import wkv6_reference
from repro.model.ssm import ssd_reference
from repro.quant.fixedpoint import FxpFormat
from repro.quant.ptq import quantize_params_int8


# --------------------------------------------------------------------------
@pytest.mark.parametrize("mkn", [(128, 128, 128), (64, 200, 96),
                                 (256, 512, 384), (32, 96, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul(mkn, dtype):
    M, K, N = mkn
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    ip = quantize_params_int8({"w": w})
    y_k = quant_matmul(x, ip.q["w"], ip.scale["w"])
    xq, xs = quantize_act(x)
    y_r = quant_matmul_ref(xq, ip.q["w"], xs, ip.scale["w"])
    assert float(jnp.max(jnp.abs(y_k - y_r))) < 1e-3
    rel = float(jnp.linalg.norm(y_k - x.astype(jnp.float32) @ w)
                / jnp.linalg.norm(x.astype(jnp.float32) @ w))
    assert rel < 0.03


# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(2, 256, 4, 64), (1, 512, 2, 128),
                                   (2, 256, 3, 96), (1, 384, 2, 160)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_fwd(shape, causal):
    B, S, H, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, shape, jnp.float32) * 0.5 for kk in ks)
    err = float(jnp.max(jnp.abs(flash_attention(q, k, v, causal)
                                - attention_ref(q, k, v, causal))))
    assert err < 2e-5, err


def test_flash_attention_grads():
    B, S, H, hd = 1, 256, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd)) * 0.5 for kk in ks)
    gk = jax.grad(lambda *a: jnp.sum(flash_attention(*a, True) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: jnp.sum(attention_ref(*a, True) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4


def test_flash_attention_bf16():
    B, S, H, hd = 2, 256, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd), jnp.bfloat16) * 0.5
               for kk in ks)
    o_k = flash_attention(q, k, v, True).astype(jnp.float32)
    o_r = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), True)
    assert float(jnp.max(jnp.abs(o_k - o_r))) < 0.03


# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(64, 6, 1, 20), (128, 6, 1, 20),
                                   (32, 12, 4, 32), (200, 6, 1, 20)])
def test_lstm_window(shape):
    B, S, din, hid = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (B, S, din))
    w = jax.random.normal(ks[1], (din + hid, 4 * hid)) * 0.3
    b = jax.random.normal(ks[2], (4 * hid,)) * 0.1
    err = float(jnp.max(jnp.abs(lstm_window(x, w, b)
                                - lstm_window_ref(x, w, b))))
    assert err < 1e-5, err


# --------------------------------------------------------------------------
def test_template_registry_matches_packages():
    """kernels.TEMPLATES lists exactly the template packages on disk, and
    each follows the kernel.py/ops.py/ref.py layout (ref optional)."""
    import importlib
    import pathlib

    import repro.kernels as K

    pkg_dir = pathlib.Path(K.__file__).parent
    on_disk = sorted(p.parent.name for p in pkg_dir.glob("*/kernel.py"))
    assert sorted(K.TEMPLATES) == on_disk
    for name in K.TEMPLATES:
        importlib.import_module(f"repro.kernels.{name}.kernel")
        importlib.import_module(f"repro.kernels.{name}.ops")


# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 6, 1, 20), (7, 6, 3, 16),
                                   (64, 4, 2, 8), (200, 6, 1, 20)])
def test_lstm_window_int(shape):
    """Fused integer window vs the per-step oracle: EXACT int equality."""
    import numpy as np

    B, S, din, hid = shape
    A, W, C = FxpFormat(8, 4), FxpFormat(8, 6), FxpFormat(16, 8)
    spec = CellSpec(seq_len=S, d_in=din, hidden=hid, act_fmt=A, state_fmt=C,
                    w_fmt=W, sig_lo=A.lo, tanh_lo=A.lo)
    rng = np.random.default_rng(B + S)
    x = jnp.asarray(rng.integers(A.lo, A.hi + 1, (B, S, din)), jnp.int32)
    w = jnp.asarray(rng.integers(W.lo, W.hi + 1, (din + hid, 4 * hid)),
                    jnp.int32)
    b = jnp.asarray(rng.integers(-(1 << 10), 1 << 10, (4 * hid,)), jnp.int32)
    # arbitrary in-range ROMs: exercises the gathers, not the activations
    depth = 2 ** A.total_bits
    sig = jnp.asarray(rng.integers(A.lo, A.hi + 1, depth), jnp.int32)
    tanh = jnp.asarray(rng.integers(A.lo, A.hi + 1, depth), jnp.int32)
    y_k = lstm_window_int(x, w, b, sig, tanh, spec=spec)
    y_r = lstm_window_int_ref(x, w, b, sig, tanh, spec=spec)
    assert y_k.dtype == jnp.int32 and y_k.shape == (B, S, hid)
    assert np.array_equal(np.asarray(y_k), np.asarray(y_r))


# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(2, 64, 3, 16), (1, 128, 2, 32),
                                   (2, 32, 4, 16)])
@pytest.mark.parametrize("with_h0", [False, True])
def test_wkv6_kernel(shape, with_h0):
    B, S, H, N = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r, k, v = (jax.random.normal(kk, shape) * 0.5 for kk in ks[:3])
    w_log = -jnp.exp(jax.random.normal(ks[3], shape) * 0.5)
    u = jax.random.normal(ks[4], (H, N)) * 0.5
    h0 = (jax.random.normal(ks[5], (B, H, N, N)) * 0.1) if with_h0 else None
    y_k, hf_k = wkv6(r, k, v, w_log, u, h0, chunk=32)
    y_r, hf_r = wkv6_reference(r, k, v, w_log, u, h0=h0)
    assert float(jnp.max(jnp.abs(y_k - y_r))) < 1e-4
    assert float(jnp.max(jnp.abs(hf_k - hf_r))) < 1e-4


# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(2, 64, 4, 16, 16), (1, 128, 2, 32, 16)])
@pytest.mark.parametrize("with_h0", [False, True])
def test_mamba2_kernel(shape, with_h0):
    B, S, H, P, N = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, 1, N)) * 0.5
    h0 = (jax.random.normal(ks[5], (B, H, P, N)) * 0.1) if with_h0 else None
    y_k, hf_k = ssd(x, dt, A, Bm, Cm, h0, chunk=16)
    y_r, hf_r = ssd_reference(x, dt, A, Bm, Cm, h0=h0)
    assert float(jnp.max(jnp.abs(y_k - y_r))) < 1e-4
    assert float(jnp.max(jnp.abs(hf_k - hf_r))) < 1e-4
