"""The chunked (matmul-form) WKV6/SSD evaluations vs the step recurrences,
including ragged lengths, chunk-size invariance, and initial states."""
import jax
import jax.numpy as jnp
import pytest

from repro.model.rwkv import wkv6_chunked, wkv6_reference
from repro.model.ssm import ssd_chunked, ssd_reference


@pytest.mark.parametrize("S", [16, 17, 48, 64, 100])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_chunked_matches_scan(S, chunk):
    B, H, N = 2, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r, k, v = (jax.random.normal(kk, (B, S, H, N)) * 0.5 for kk in ks[:3])
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.5)
    u = jax.random.normal(ks[4], (H, N)) * 0.5
    h0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.1
    y_c, hf_c = wkv6_chunked(r, k, v, w_log, u, h0=h0, chunk=chunk)
    y_r, hf_r = wkv6_reference(r, k, v, w_log, u, h0=h0)
    assert float(jnp.max(jnp.abs(y_c - y_r))) < 1e-4
    assert float(jnp.max(jnp.abs(hf_c - hf_r))) < 1e-4


@pytest.mark.parametrize("S,chunk", [(32, 8), (33, 8), (64, 16), (100, 32)])
def test_ssd_chunked_matches_scan(S, chunk):
    B, H, P, G, N = 2, 4, 8, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    h0 = jax.random.normal(ks[5], (B, H, P, N)) * 0.1
    y_c, hf_c = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)
    y_r, hf_r = ssd_reference(x, dt, A, Bm, Cm, h0=h0)
    assert float(jnp.max(jnp.abs(y_c - y_r))) < 1e-4
    assert float(jnp.max(jnp.abs(hf_c - hf_r))) < 1e-4


def test_chunk_size_invariance():
    """Same result for any chunking — the associativity property the
    Mamba2/SSD formulation rests on."""
    B, S, H, P, G, N = 1, 48, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    outs = [ssd_chunked(x, dt, A, Bm, Cm, chunk=c)[0] for c in (8, 16, 48)]
    for o in outs[1:]:
        assert float(jnp.max(jnp.abs(o - outs[0]))) < 1e-4
