"""scan-over-layers must match the unrolled stack (loss AND grads) — this is
what makes the dry-run's scan compile a valid proof for the unrolled costs."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import ALL_IDS, get_config
from repro.core.types import SMOKE_MESH, ParallelismConfig, ShapeConfig
from repro.model.lm import Stepper, make_loss_fn, make_prefill_step, \
    make_decode_step

ARCHS = [a for a in ALL_IDS if a not in ("elastic-lstm", "elastic-conv1d")]


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_equals_unroll_train(arch):
    cfg = get_config(arch, smoke=True)
    S, B = 16, 2
    par_u = ParallelismConfig(compute_dtype="float32", scan_layers=False)
    par_s = ParallelismConfig(compute_dtype="float32", scan_layers=True)
    st = Stepper(cfg, ShapeConfig("t", "train", S, B), SMOKE_MESH, par_u)
    params, _ = st.init()
    batch = make_batch(cfg, B, S)
    lu, gu = jax.value_and_grad(
        lambda p: make_loss_fn(cfg, SMOKE_MESH, par_u, None)(p, batch)[0])(params)
    ls, gs = jax.value_and_grad(
        lambda p: make_loss_fn(cfg, SMOKE_MESH, par_s, None)(p, batch)[0])(params)
    assert abs(float(lu) - float(ls)) < 1e-5, arch
    for a, b in zip(jax.tree.leaves(gu), jax.tree.leaves(gs)):
        rel = float(jnp.max(jnp.abs(a - b))) / (float(jnp.max(jnp.abs(a))) + 1e-3)
        assert rel < 1e-3, arch


@pytest.mark.parametrize("arch", ["yi-9b", "zamba2-7b", "rwkv6-7b",
                                  "whisper-tiny", "deepseek-moe-16b"])
def test_scan_decode_matches_unroll_full(arch):
    """Scan-mode prefill+decode (stacked caches) == unroll full forward."""
    cfg = get_config(arch, smoke=True)
    S, B = 16, 2
    par_u = ParallelismConfig(compute_dtype="float32")
    par_s = ParallelismConfig(compute_dtype="float32", scan_layers=True)
    st = Stepper(cfg, ShapeConfig("p", "prefill", S, B), SMOKE_MESH, par_u)
    params, _ = st.init()
    full = make_batch(cfg, B, S + 1, train=False)
    pre_b = dict(full, tokens=full["tokens"][:, :S])

    ref, _ = make_prefill_step(cfg, SMOKE_MESH, par_u)(params, full)
    _, cache = make_prefill_step(cfg, SMOKE_MESH, par_s)(params, pre_b)
    cache = jax.tree.map(lambda a: a, cache)  # stacked layout
    cache = _pad_stacked(cache, S + 4)
    out, _ = make_decode_step(cfg, SMOKE_MESH, par_s)(
        params, full["tokens"][:, S:S + 1], cache)
    assert float(jnp.max(jnp.abs(ref - out))) < 5e-3, arch


def _pad_stacked(cache, target):
    """pad_cache for the stacked (scan) cache layout."""
    def pad_group(g):
        if not (isinstance(g, dict) and "k" in g and "v" in g):
            return g
        out = dict(g)
        for key in ("k", "v"):
            buf = g[key]          # (L, B, S, KV, hd)
            extra = target - buf.shape[2]
            if extra > 0:
                pad = [(0, 0)] * buf.ndim
                pad[2] = (0, extra)
                out[key] = jnp.pad(buf, pad)
        return out

    return {k: pad_group(v) if isinstance(v, dict) else v
            for k, v in cache.items()}
