"""int8-ring gradient all-reduce: correctness vs psum + trainer integration."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# Every test here runs compressed_psum through shard_map. The subprocess
# bodies import ``repro.shardmap.shard_map`` — the repo-wide compat wrapper
# that resolves to ``jax.shard_map`` on current jax and to
# ``jax.experimental.shard_map`` (auto=/check_rep= spellings) on 0.4.x — so
# the suite runs for real on either generation instead of version-skipping.


def run_sub(body: str, n_dev: int = 8, timeout: int = 900) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_dev}")
        os.environ["JAX_PLATFORMS"] = "cpu"
        import sys
        sys.path.insert(0, {ROOT + "/src"!r})
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_compressed_psum_matches_f32():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim.compress import compressed_psum_vec
        from repro.shardmap import shard_map

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        def both(x):
            return (jax.lax.psum(x, "data"),
                    compressed_psum_vec(x, "data"))
        f = shard_map(both, mesh=mesh, in_specs=P("data"),
                          out_specs=(P(), P()), axis_names={"data"}, check_vma=False)
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 1000))
        with mesh:
            exact, comp = jax.jit(f)(x.reshape(-1))
        rel = float(jnp.linalg.norm(comp - exact) / jnp.linalg.norm(exact))
        print("rel err:", rel)
        assert rel < 0.02, rel
    """)


def test_compressed_wire_bytes_less_than_f32():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim.compress import compressed_psum_vec
        from repro.shardmap import shard_map
        from repro.energy.roofline import parse_collectives

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        SZ = 1 << 16
        f32 = shard_map(lambda x: jax.lax.psum(x, "data"), mesh=mesh,
                            in_specs=P("data"), out_specs=P(),
                            axis_names={"data"}, check_vma=False)
        cmp = shard_map(lambda x: compressed_psum_vec(x, "data"),
                            mesh=mesh, in_specs=P("data"), out_specs=P(),
                            axis_names={"data"}, check_vma=False)
        sds = jax.ShapeDtypeStruct((8 * SZ,), jnp.float32)
        with mesh:
            w_f32 = parse_collectives(
                jax.jit(f32).lower(sds).compile().as_text(), 8)
            w_cmp = parse_collectives(
                jax.jit(cmp).lower(sds).compile().as_text(), 8)
        print("f32 wire:", w_f32.total_wire_bytes,
              "int8 wire:", w_cmp.total_wire_bytes)
        assert w_cmp.total_wire_bytes < 0.45 * w_f32.total_wire_bytes
    """)


def test_trainer_with_compression_learns():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.core.types import MeshConfig, ParallelismConfig, ShapeConfig
        from repro.data.pipeline import LMDataConfig, lm_batch_for_step
        from repro.model.lm import Stepper

        cfg = get_config("yi-9b", smoke=True)
        mcfg = MeshConfig((4, 2), ("data", "model"))
        mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        par = ParallelismConfig(compute_dtype="float32",
                                grad_compression=True)
        st = Stepper(cfg, ShapeConfig("t", "train", 32, 8), mcfg, par,
                     mesh=mesh)
        params, opt = st.init()
        dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=8)
        with mesh:
            step = jax.jit(st.train_fn())
            losses = []
            # overfit one fixed batch: fresh random batches carry no
            # learnable signal in 15 steps, so the integration check is
            # "grads flow through the compressed reduction and the loss
            # memorizes", the standard trainer smoke
            batch = lm_batch_for_step(dcfg, 0)
            for i in range(15):
                params, opt, m = step(params, opt, batch)
                losses.append(float(m["loss"]))
        print("losses:", losses[0], "->", losses[-1])
        assert losses[-1] < losses[0] - 0.1, losses
    """)
