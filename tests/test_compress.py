"""int8-ring gradient all-reduce: correctness vs psum + trainer integration."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# Every test here runs compressed_psum through jax.shard_map, which this
# environment's jax (0.4.x) does not expose yet. Version-guarded skip: on a
# shard_map-era jax these run for real; here they are a known env gap, so
# skipping keeps tier-1 green and makes actual regressions visible.
requires_shard_map = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="needs the jax.shard_map API (pre-existing env gap, "
           f"jax=={jax.__version__})")


def run_sub(body: str, n_dev: int = 8, timeout: int = 900) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_dev}")
        os.environ["JAX_PLATFORMS"] = "cpu"
        import sys
        sys.path.insert(0, {ROOT + "/src"!r})
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@requires_shard_map
def test_compressed_psum_matches_f32():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim.compress import compressed_psum_vec

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        def both(x):
            return (jax.lax.psum(x, "data"),
                    compressed_psum_vec(x, "data"))
        f = jax.shard_map(both, mesh=mesh, in_specs=P("data"),
                          out_specs=(P(), P()), axis_names={"data"}, check_vma=False)
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 1000))
        with mesh:
            exact, comp = jax.jit(f)(x.reshape(-1))
        rel = float(jnp.linalg.norm(comp - exact) / jnp.linalg.norm(exact))
        print("rel err:", rel)
        assert rel < 0.02, rel
    """)


@requires_shard_map
def test_compressed_wire_bytes_less_than_f32():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim.compress import compressed_psum_vec
        from repro.energy.roofline import parse_collectives

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        SZ = 1 << 16
        f32 = jax.shard_map(lambda x: jax.lax.psum(x, "data"), mesh=mesh,
                            in_specs=P("data"), out_specs=P(),
                            axis_names={"data"}, check_vma=False)
        cmp = jax.shard_map(lambda x: compressed_psum_vec(x, "data"),
                            mesh=mesh, in_specs=P("data"), out_specs=P(),
                            axis_names={"data"}, check_vma=False)
        sds = jax.ShapeDtypeStruct((8 * SZ,), jnp.float32)
        with mesh:
            w_f32 = parse_collectives(
                jax.jit(f32).lower(sds).compile().as_text(), 8)
            w_cmp = parse_collectives(
                jax.jit(cmp).lower(sds).compile().as_text(), 8)
        print("f32 wire:", w_f32.total_wire_bytes,
              "int8 wire:", w_cmp.total_wire_bytes)
        assert w_cmp.total_wire_bytes < 0.45 * w_f32.total_wire_bytes
    """)


@requires_shard_map
def test_trainer_with_compression_learns():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.core.types import MeshConfig, ParallelismConfig, ShapeConfig
        from repro.data.pipeline import LMDataConfig, lm_batch_for_step
        from repro.model.lm import Stepper

        cfg = get_config("yi-9b", smoke=True)
        mcfg = MeshConfig((4, 2), ("data", "model"))
        mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        par = ParallelismConfig(compute_dtype="float32",
                                grad_compression=True)
        st = Stepper(cfg, ShapeConfig("t", "train", 32, 8), mcfg, par,
                     mesh=mesh)
        params, opt = st.init()
        dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=8)
        with mesh:
            step = jax.jit(st.train_fn())
            losses = []
            for i in range(15):
                params, opt, m = step(params, opt, lm_batch_for_step(dcfg, i))
                losses.append(float(m["loss"]))
        print("losses:", losses[0], "->", losses[-1])
        assert losses[-1] < losses[0], losses
    """)
