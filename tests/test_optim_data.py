"""Optimizer, schedule, ZeRO sharding specs, data determinism, prefetch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # image lacks hypothesis: use shim
    from _hypothesis_compat import given, settings, st

from repro.data.pipeline import (LMDataConfig, Prefetcher, lm_batch_for_step,
                                 traffic_flow_batch, TrafficConfig)
from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               opt_state_schema, schedule)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=400,
                      weight_decay=0.0, clip_norm=10.0)
    target = jnp.asarray([[1.0, -2.0], [3.0, 0.5]])
    params = {"w": jnp.zeros((2, 2))}
    opt = init_opt_state(params)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(
            lambda pp: jnp.sum((pp["w"] - target) ** 2))(p)
        p2, o2, _ = adamw_update(g, o, p, cfg)
        return p2, o2, loss

    for _ in range(300):
        params, opt, loss = step(params, opt)
    assert float(loss) < 1e-3


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


def test_clip_bounds_update():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4, 4))}
    opt = init_opt_state(params)
    g = {"w": jnp.full((4, 4), 1e6)}
    _, _, info = adamw_update(g, opt, params, cfg)
    assert float(info["gnorm"]) > 1e6  # raw norm reported


def test_zero_sharding_specs():
    """Moments must pick up a data-axis shard on a dim that divides."""
    from jax.sharding import PartitionSpec as P

    from repro.core.types import SINGLE_POD
    from repro.model.layers import PSpec

    schema = {"w": PSpec((5120, 1024), P(None, "model")),
              "tiny": PSpec((48,), P())}
    opt = opt_state_schema(schema, SINGLE_POD)
    assert opt["mu"]["w"].pspec == P("data", "model")
    # 1-d stays replicated (PartitionSpec(None) ≡ PartitionSpec())
    assert all(ax is None for ax in opt["mu"]["tiny"].pspec)


@given(st.integers(0, 1000), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_data_deterministic_and_step_unique(s1, s2):
    cfg = LMDataConfig(vocab_size=512, seq_len=16, global_batch=4, seed=1)
    a = lm_batch_for_step(cfg, s1)
    b = lm_batch_for_step(cfg, s1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    if s1 != s2:
        c = lm_batch_for_step(cfg, s2)
        assert not np.array_equal(a["tokens"], c["tokens"])


def test_targets_are_next_tokens():
    cfg = LMDataConfig(vocab_size=512, seq_len=16, global_batch=4)
    b = lm_batch_for_step(cfg, 0)
    # structure is learnable: targets continue the stream
    assert b["tokens"].shape == (4, 16)
    assert b["targets"].shape == (4, 16)
    assert (b["tokens"][:, 1:] == b["targets"][:, :-1]).all()


def test_traffic_flow_shapes():
    b = traffic_flow_batch(TrafficConfig(batch=8), 3)
    assert b["x"].shape == (8, 6, 1)
    assert b["y"].shape == (8, 1)
    assert np.isfinite(b["x"]).all()


def test_prefetcher_order():
    cfg = LMDataConfig(vocab_size=128, seq_len=8, global_batch=2)
    it = Prefetcher(iter([lm_batch_for_step(cfg, i) for i in range(5)]),
                    depth=2)
    got = [b["tokens"] for b in it]
    assert len(got) == 5
    for i, g in enumerate(got):
        np.testing.assert_array_equal(g, lm_batch_for_step(cfg, i)["tokens"])
