"""Fleet-scale serving: queue, micro-batcher, router, farm, loadgen.

The load-bearing claims (ISSUE 9 acceptance):

* micro-batched results are BIT-EXACT vs per-request execution on the RTL
  target (batch rows are independent in every template);
* the admission queue sheds at capacity and expires on deadline — nothing
  admitted is ever silently dropped;
* affinity routing converges: once steady mixed traffic has compiled its
  shapes, ``RTLEmulator.trace_count`` stops growing;
* the seeded loadgen replays identically (run-twice-identical stats JSON
  under an injected VirtualClock);
* ``Deployment.measure`` percentiles exclude warmup runs.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.obs import MetricsRegistry
from repro.resilience.faults import VirtualClock
from repro.serving import (DONE, EXPIRED, SHED, AcceleratorFarm,
                           AdmissionQueue, AffinityRouter, DesignPool,
                           FarmConfig, MicroBatcher, NoServeableMember,
                           ServeRequest, bucket_for, pack, pad_window,
                           padded_batch_size)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# --------------------------------------------------------------------------- #
# shared fixtures / fakes
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def lstm_exe():
    """The paper's LSTM reference design, translated once per module."""
    import jax

    from repro.configs.elastic_lstm import config
    from repro.model.layers import init_params
    from repro.model.lstm import lstm_schema
    from repro.rtl.backend import translate_rtl

    cfg = config()
    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    _, exe = translate_rtl(cfg, params)
    return exe


class _Member:
    """Duck-typed farm member: callable on (B, L, F), optional health gate
    and program-cache set for affinity, optional failure injection."""

    def __init__(self, healthy=True, fail=False):
        self.healthy = healthy
        self.fail = fail
        self.calls = 0
        self._held = set()

    def can_serve(self):
        return self.healthy

    def holds_program(self, shape, dtype):
        return (tuple(shape), np.dtype(dtype).name) in self._held

    def __call__(self, arr):
        if self.fail:
            raise RuntimeError("member down")
        self.calls += 1
        arr = np.asarray(arr)
        self._held.add((arr.shape, np.dtype(arr.dtype).name))
        return arr.sum(axis=(1, 2))[:, None]


def _fake_farm(members, *, lengths=(8,), clock=None, **cfg_kw):
    clock = clock if clock is not None else VirtualClock()
    pool = DesignPool(family="fake", members={ln: list(members)
                                              for ln in lengths})
    farm = AcceleratorFarm([pool], FarmConfig(**cfg_kw), clock=clock,
                           metrics=MetricsRegistry())
    return farm, clock


def _win(t, f=2, seed=0):
    return np.random.default_rng(seed).standard_normal(
        (t, f)).astype(np.float32)


# --------------------------------------------------------------------------- #
# batcher: bucketing, packing, flush policy
# --------------------------------------------------------------------------- #


def test_bucket_and_pad_helpers():
    assert bucket_for((6, 12), 4) == 6
    assert bucket_for((6, 12), 6) == 6
    assert bucket_for((6, 12), 7) == 12
    with pytest.raises(ValueError, match=r"registered lengths: \[6, 12\]"):
        bucket_for((6, 12), 13)
    w = pad_window(_win(3), 8)
    assert w.shape == (8, 2)
    assert np.all(w[3:] == 0) and np.array_equal(w[:3], _win(3))
    with pytest.raises(ValueError, match="exceeds bucket"):
        pad_window(_win(9), 8)
    assert [padded_batch_size(n, 64) for n in (1, 2, 3, 5, 33)] == \
        [1, 2, 4, 8, 64]
    with pytest.raises(ValueError, match="exceeds max_batch"):
        padded_batch_size(100, 64)              # pack splits groups first


def test_padded_batch_size_respects_the_cap_edge():
    # regression: B == max_batch must not round up past the cap, and an
    # over-cap B is a split-first error, never a silent over-cap dispatch
    assert padded_batch_size(63, 64) == 64
    assert padded_batch_size(64, 64) == 64
    with pytest.raises(ValueError, match="exceeds max_batch"):
        padded_batch_size(65, 64)


def test_pack_pads_batch_and_unpack_slices_back():
    reqs = [ServeRequest(rid=i, design="d", window=_win(3 + i, seed=i))
            for i in range(3)]
    [batch] = pack("d", 8, reqs, pad_batch=True, max_batch=64)
    assert batch.array.shape == (4, 8, 2)       # 3 real rows -> pow2 = 4
    assert batch.fill == 3 / 4
    assert np.all(batch.array[3] == 0)          # filler row
    out = np.arange(8, dtype=np.float32).reshape(4, 2)
    from repro.serving import unpack

    unpack(batch, out)
    for i, r in enumerate(reqs):
        assert np.array_equal(r.result, out[i])


@pytest.mark.parametrize("n", [63, 64, 65])
def test_pack_splits_at_the_max_batch_cap(n):
    # regression (B = 63 / 64 / 65 around cap 64): exactly max_batch real
    # rows never rounds up past the cap, and an overflowing group splits
    # into multiple MicroBatches instead of raising
    reqs = [ServeRequest(rid=i, design="d", window=_win(4, seed=i))
            for i in range(n)]
    batches = pack("d", 8, reqs, pad_batch=True, max_batch=64)
    assert [len(b.requests) for b in batches] == \
        ([63] if n == 63 else [64] if n == 64 else [64, 1])
    assert all(b.array.shape[0] <= 64 for b in batches)
    if n == 63:
        assert batches[0].array.shape[0] == 64      # pow2 pad up to cap
    if n == 64:
        assert batches[0].array.shape[0] == 64      # cap stays the cap
    if n == 65:
        assert batches[1].array.shape[0] == 1       # tail re-quantized
    # row i of each chunk still belongs to request i of that chunk
    got = [r.rid for b in batches for r in b.requests]
    assert got == list(range(n))


def test_batcher_form_splits_oversized_groups():
    # a single form() over > max_batch requests must produce only
    # cap-respecting dispatches (the old path raised from pack)
    mb = MicroBatcher(buckets={"d": (8,)}, max_batch=4, max_wait_s=0.0)
    reqs = [ServeRequest(rid=i, design="d", window=_win(4), t_submit=0.0)
            for i in range(9)]
    batches, linger = mb.form(reqs, now=0.0, flush=True)
    assert linger == []
    assert [len(b.requests) for b in batches] == [4, 4, 1]
    assert all(b.array.shape[0] <= 4 for b in batches)


def test_batcher_flush_policy():
    mb = MicroBatcher(buckets={"d": (8,)}, max_batch=4, max_wait_s=1.0)
    reqs = [ServeRequest(rid=i, design="d", window=_win(4), t_submit=0.0)
            for i in range(3)]
    batches, linger = mb.form(reqs, now=0.5)     # young partial: lingers
    assert batches == [] and [r.rid for r in linger] == [0, 1, 2]
    batches, linger = mb.form(reqs, now=1.5)     # oldest aged past linger
    assert len(batches) == 1 and linger == []
    reqs6 = [ServeRequest(rid=i, design="d", window=_win(4), t_submit=0.0)
             for i in range(6)]
    batches, linger = mb.form(reqs6, now=0.0)    # full batch always flushes
    assert len(batches) == 1 and len(batches[0].requests) == 4
    assert [r.rid for r in linger] == [4, 5]
    batches, _ = mb.form(reqs6, now=0.0, flush=True)
    assert sum(len(b.requests) for b in batches) == 6


# --------------------------------------------------------------------------- #
# queue: overflow shedding + deadline expiry
# --------------------------------------------------------------------------- #


def test_queue_sheds_at_capacity():
    clock = VirtualClock()
    q = AdmissionQueue(2, clock=clock, metrics=MetricsRegistry())
    reqs = [ServeRequest(rid=i, design="d", window=None) for i in range(4)]
    admitted = [q.offer(r) for r in reqs]
    assert admitted == [True, True, False, False]
    assert [r.status for r in reqs] == ["queued", "queued", SHED, SHED]
    assert all(r.error == "queue_full" for r in reqs[2:])
    assert q.metrics.counter("serving.queue.shed_full").value == 2


def test_queue_expires_on_deadline():
    clock = VirtualClock()
    q = AdmissionQueue(8, clock=clock, metrics=MetricsRegistry())
    hurried = ServeRequest(rid=0, design="d", window=None, deadline_s=1.0)
    patient = ServeRequest(rid=1, design="d", window=None)
    q.offer(hurried)
    q.offer(patient)
    clock.advance(2.0)
    expired = q.expire()
    assert expired == [hurried] and hurried.status == EXPIRED
    assert hurried.error == "deadline"
    assert q.peek() == [patient]                 # FIFO survivor intact


def test_queue_expires_at_exactly_the_deadline():
    # regression: a request inspected exactly AT its deadline can no
    # longer be answered in time — `now >= deadline` sheds it (the old
    # strict `>` dispatched it and then missed)
    clock = VirtualClock()
    q = AdmissionQueue(8, clock=clock, metrics=MetricsRegistry())
    req = ServeRequest(rid=0, design="d", window=None, deadline_s=1.0)
    q.offer(req)
    clock.advance(1.0)                           # now == deadline exactly
    assert q.expire() == [req]
    assert req.status == EXPIRED and req.error == "deadline"
    assert q.metrics.counter("serving.queue.expired").value == 1


class _SteppingClock:
    """A clock that advances ``step`` on every read — deterministically
    opens the take()→dispatch window the farm must re-check. Starts past
    zero so ``t_submit`` is never the 0.0 sentinel (which would make the
    queue re-stamp it with an extra clock read)."""

    def __init__(self, step=0.1, start=1.0):
        self.t = start
        self.step = step

    def __call__(self):
        t = self.t
        self.t += self.step
        return t


def test_farm_recheck_deadline_at_dispatch_time():
    # regression: a request can expire BETWEEN queue.take() and dispatch
    # (batch forming takes wall time); the farm must re-check at dispatch,
    # mark it expired under the same serving.queue.expired counter, and
    # never attach a result to it — while batchmates still complete.
    member = _Member()
    farm, clock = _fake_farm([member], clock=_SteppingClock(step=0.1))
    # clock reads: submit A -> 1.0, submit B -> 1.1, tick: expire -> 1.2
    # (A still alive: 1.2 < 1.35), form -> 1.3, dispatch -> 1.4 >= 1.35
    ra = farm.submit("fake", _win(4), deadline_s=1.35)
    rb = farm.submit("fake", _win(4))
    farm.tick(flush=True)
    a, b = farm.result(ra), farm.result(rb)
    assert a.status == EXPIRED and a.error == "deadline"
    assert a.result is None                      # missed SLO grows no result
    assert b.status == DONE and b.result is not None
    s = farm.stats()
    assert s.expired == 1 and s.done == 1 and s.failed == 0
    assert s.admitted == s.done + s.expired      # reconciliation holds
    assert member.calls == 1                     # batchmate still dispatched

    # the all-expired batch never reaches a member at all
    member2 = _Member()
    farm2, _ = _fake_farm([member2], clock=_SteppingClock(step=0.1))
    rid = farm2.submit("fake", _win(4), deadline_s=1.25)
    farm2.tick(flush=True)                       # expire 1.1 < 1.25, disp 1.3
    assert farm2.result(rid).status == EXPIRED
    assert member2.calls == 0
    s2 = farm2.stats()
    assert s2.dispatches == 0 and s2.expired == 1
    assert s2.admitted == s2.done + s2.expired


def test_farm_overflow_and_deadline_end_to_end():
    farm, clock = _fake_farm([_Member()], max_queue=2, max_batch=4)
    rids = [farm.submit("fake", _win(4)) for _ in range(4)]
    shed = [r for r in rids if farm.result(r).status == SHED]
    assert len(shed) == 2                        # bounded backpressure
    late = farm.submit("fake", _win(4))          # wait: queue is full too
    assert farm.result(late).status == SHED
    farm.run_until_drained()
    assert [farm.result(r).status for r in rids[:2]] == [DONE, DONE]

    farm, clock = _fake_farm([_Member()], max_queue=8)
    rid = farm.submit("fake", _win(4), timeout_s=1.0)
    clock.advance(5.0)
    farm.tick()
    assert farm.result(rid).status == EXPIRED
    s = farm.stats()
    assert s.expired == 1 and s.dispatches == 0  # never wasted a dispatch
    assert s.admitted == s.done + s.expired      # zero dropped invariant


def test_farm_unknown_design_and_oversized_window_shed_at_submit():
    farm, _ = _fake_farm([_Member()], lengths=(8,))
    r1 = farm.submit("nope", _win(4))
    assert farm.result(r1).status == SHED
    assert "unknown design" in farm.result(r1).error
    r2 = farm.submit("fake", _win(99))           # no bucket fits length 99
    assert farm.result(r2).status == SHED
    assert "no window bucket" in farm.result(r2).error


# --------------------------------------------------------------------------- #
# router: affinity + health + redispatch
# --------------------------------------------------------------------------- #


def test_router_prefers_member_holding_the_program():
    a, b = _Member(), _Member()
    b((np.zeros((4, 8, 2), np.float32)))         # b compiles (4, 8, 2)
    router = AffinityRouter([a, b], metrics=MetricsRegistry())
    i, m, hit = router.route((4, 8, 2), np.float32)
    assert (i, m, hit) == (1, b, True)
    i, _, hit = router.route((2, 8, 2), np.float32)   # nobody holds: miss
    assert hit is False
    assert router.metrics.counter("serving.router.affinity_hit").value == 1
    assert router.metrics.counter("serving.router.affinity_miss").value == 1


def test_router_health_gate_and_exhaustion():
    sick, well = _Member(healthy=False), _Member()
    router = AffinityRouter([sick, well], metrics=MetricsRegistry())
    for _ in range(4):
        i, _, _ = router.route((1, 8, 2), np.float32)
        assert i == 1                            # quarantined takes nothing
    with pytest.raises(NoServeableMember, match="no serveable member"):
        AffinityRouter([sick], metrics=MetricsRegistry()).route()
    with pytest.raises(NoServeableMember):
        router.route(exclude=(1,))               # well excluded, sick gated


def test_farm_redispatches_once_around_a_failing_member():
    bad, good = _Member(fail=True), _Member()
    farm, _ = _fake_farm([bad, good], max_batch=4)
    rids = [farm.submit("fake", _win(4)) for _ in range(2)]
    farm.run_until_drained()
    assert all(farm.result(r).status == DONE for r in rids)
    s = farm.stats()
    assert s.failed == 0 and s.redispatches >= 1
    assert good.calls >= 1

    # both members down: the batch fails loudly, not silently
    farm, _ = _fake_farm([_Member(fail=True), _Member(fail=True)],
                         max_batch=4)
    rid = farm.submit("fake", _win(4))
    farm.run_until_drained()
    assert farm.result(rid).status == "failed"
    assert farm.result(rid).error == "RuntimeError"
    assert farm.stats().failed == 1


# --------------------------------------------------------------------------- #
# RTL bit-exactness + affinity retrace convergence (the tentpole claims)
# --------------------------------------------------------------------------- #


def test_microbatched_results_bit_exact_vs_per_request(lstm_exe):
    """Ragged windows, packed+padded into shared dispatches, must come back
    integer-identical to calling the deployment per padded window alone."""
    rng = np.random.default_rng(7)
    windows = [rng.standard_normal((t, 1)).astype(np.float32) * 0.5
               for t in (3, 4, 5, 6, 6, 4, 3, 5, 6, 2)]
    pool = DesignPool(family="lstm", members={6: [lstm_exe]})
    farm = AcceleratorFarm([pool], FarmConfig(max_batch=8),
                           metrics=MetricsRegistry())
    rids = [farm.submit("lstm", w) for w in windows]
    farm.run_until_drained()
    for rid, w in zip(rids, windows):
        req = farm.result(rid)
        assert req.status == DONE and req.bucket_len == 6
        solo = np.asarray(lstm_exe(pad_window(w, 6)[None]))[0]
        assert np.array_equal(np.asarray(req.result), solo), rid


def test_affinity_keeps_retraces_bounded(lstm_exe):
    """Steady mixed traffic converges to a stable shape->member assignment:
    after a warm epoch, more identical traffic compiles NOTHING new."""
    replica = dataclasses.replace(lstm_exe)      # fresh emulator
    pool = DesignPool(family="lstm", members={6: [lstm_exe, replica]})
    farm = AcceleratorFarm([pool], FarmConfig(max_batch=8),
                           metrics=MetricsRegistry())

    def epoch(seed):
        rng = np.random.default_rng(seed)
        for t in rng.integers(2, 7, size=24):
            farm.submit("lstm", rng.standard_normal(
                (int(t), 1)).astype(np.float32))
        farm.run_until_drained()

    epoch(0)
    warm = lstm_exe.emulator.trace_count + replica.emulator.trace_count
    assert warm > 0
    epoch(1)                                     # same shape universe
    cold = lstm_exe.emulator.trace_count + replica.emulator.trace_count
    assert cold == warm                          # zero new retraces
    s = farm.stats()
    assert s.affinity_hits > 0
    assert s.failed == 0 and s.admitted == s.done


def test_executable_holds_program_probe(lstm_exe):
    replica = dataclasses.replace(lstm_exe)
    x = np.zeros((4, 6, 1), np.float32)
    assert not replica.holds_program(x.shape, x.dtype)
    replica(x)
    assert replica.holds_program(x.shape, x.dtype)
    assert replica.emulator.has_program(x.shape, np.int32)
    assert not replica.holds_program((2, 6, 1), x.dtype)


# --------------------------------------------------------------------------- #
# loadgen: determinism + zero-loss accounting
# --------------------------------------------------------------------------- #


def _loadgen_once():
    from repro.serving import loadgen

    clock = VirtualClock()
    farm, pools = loadgen.build_farm(
        ("lstm",), replicas=1, buckets={"lstm": (6,)},
        cfg=FarmConfig(max_batch=8), seed=0, clock=clock,
        metrics=MetricsRegistry())
    spec = loadgen.TrafficSpec(archs=("lstm",), n_requests=24, wave=8,
                               seed=3)
    return loadgen.run_loadgen(farm, pools, spec, clock=clock)


def test_loadgen_seeded_runs_are_identical():
    a = json.dumps(_loadgen_once(), indent=2, sort_keys=True)
    b = json.dumps(_loadgen_once(), indent=2, sort_keys=True)
    assert a == b
    rep = json.loads(a)
    assert rep["submitted"] == 24
    assert rep["by_status"] == {"done": 24}
    assert rep["dropped_after_admission"] == 0
    assert rep["per_design"]["lstm"]["gop_per_j"] > 0   # cycle-model energy


def test_loadgen_open_loop_sheds_under_overload():
    from repro.serving import loadgen

    clock = VirtualClock()
    farm, pools = loadgen.build_farm(
        ("lstm",), replicas=1, buckets={"lstm": (6,)},
        cfg=FarmConfig(max_batch=8, max_queue=8), seed=0, clock=clock,
        metrics=MetricsRegistry())
    spec = loadgen.TrafficSpec(archs=("lstm",), n_requests=64, wave=32,
                               mode="open", seed=1)
    rep = loadgen.run_loadgen(farm, pools, spec, clock=clock)
    assert rep["by_status"].get("shed", 0) > 0   # the queue was the brake
    assert rep["dropped_after_admission"] == 0   # but nothing vanished
    total = sum(rep["by_status"].values())
    assert total == rep["submitted"] == 64


def test_loadgen_cli_smoke(tmp_path):
    from repro.serving.loadgen import main

    out = tmp_path / "bench.json"
    rc = main(["--arch", "lstm", "--requests", "16", "--wave", "8",
               "--replicas", "1", "--max-batch", "8",
               "--out", str(out), "--p99-bound", "60"])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["by_status"] == {"done": 16}
    assert rep["dropped_after_admission"] == 0


# --------------------------------------------------------------------------- #
# measure(): warmup runs must not skew the latency percentiles
# --------------------------------------------------------------------------- #


def _slow_start_fn(slow_calls, slow_s=0.02):
    import time as _time

    state = {"n": 0}

    def fn(x):
        state["n"] += 1
        if state["n"] <= slow_calls:
            _time.sleep(slow_s)
        return x

    fn.state = state
    return fn


def test_measure_percentiles_exclude_warmup():
    from repro.core.target import XLADeployment

    x = np.zeros(4, np.float32)
    dep = XLADeployment(fn=_slow_start_fn(3))
    rep = dep.measure((x,), model="m", model_flops=1e6, n_runs=10,
                      warmup=3)
    assert dep.fn.state["n"] == 13               # warmup runs DID execute
    assert rep.latency_p99_s < 0.02              # ...but never entered p99

    # control: same deployment shape, warmup disabled -> the slow first
    # calls land in the samples and the tail blows up (the old bug's shape)
    dep0 = XLADeployment(fn=_slow_start_fn(3))
    rep0 = dep0.measure((x,), model="m", model_flops=1e6, n_runs=10,
                        warmup=0)
    assert rep0.latency_p99_s >= 0.015


def test_protocol_routes_warmup_into_measure():
    from repro.core.report import MeasurementReport
    from repro.core.target import Deployment
    from repro.verify.protocol import MeasurementProtocol, run_protocol

    seen = {}

    class _Dep(Deployment):
        target = "fake"

        def __call__(self, *a):
            return a

        def measure(self, args, *, model, model_flops, n_runs=1,
                    warmup=1, hw=None):
            seen.update(n_runs=n_runs, warmup=warmup)
            return MeasurementReport(
                model=model, platform="fake", latency_s=1e-3,
                power_w=0.1, energy_j=1e-4, gop_per_j=1.0,
                n_runs=n_runs, target=self.target)

    rep = run_protocol(_Dep(), (np.zeros(2),), model="m", model_flops=1e6,
                       protocol=MeasurementProtocol(warmup=5, n_runs=2))
    assert seen == {"n_runs": 2, "warmup": 5}
    assert rep.warmup == 5 and rep.passed


# --------------------------------------------------------------------------- #
# sharding: bit-exact on 1 device, real split in a forced-device subprocess
# --------------------------------------------------------------------------- #


def test_program_lru_shared_and_thread_safe(lstm_exe):
    # regression: shard.py re-implemented the compiled-program LRU without
    # the lock PR 7 added to the emulator — both must now share the one
    # locked ProgramLRU helper, and it must stay consistent under the
    # farm's concurrent dispatch pattern.
    import threading

    from repro.rtl.program_cache import ProgramLRU
    from repro.serving import ShardedExecutable, make_serving_mesh

    sharded = ShardedExecutable(dataclasses.replace(lstm_exe),
                                make_serving_mesh(1))
    assert isinstance(sharded._programs, ProgramLRU)
    assert isinstance(lstm_exe.emulator._programs, ProgramLRU)

    lru = ProgramLRU(max_programs=2)
    built = []
    errors = []

    def hammer(tid):
        try:
            for i in range(200):
                key = ("k", i % 3)

                def factory(key=key):
                    built.append(key)
                    return key

                prog, _hit, _ev = lru.get_or_build(key, factory)
                assert prog == key          # never another key's program
        except Exception as e:              # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    st = lru.stats()
    assert st["hits"] + st["misses"] == 4 * 200
    assert st["misses"] == len(built)       # every miss built exactly once
    assert st["size"] <= 2                  # eviction bound respected


def test_sharded_executable_bit_exact_single_device(lstm_exe):
    from repro.serving import ShardedExecutable, make_serving_mesh

    sharded = ShardedExecutable(dataclasses.replace(lstm_exe),
                                make_serving_mesh(1))
    x = np.random.default_rng(5).standard_normal(
        (4, 6, 1)).astype(np.float32) * 0.5
    assert np.array_equal(np.asarray(sharded(x)),
                          np.asarray(lstm_exe(x)))
    assert sharded.holds_program(x.shape, x.dtype)
    # odd batch pads up to a shard multiple and slices back
    x3 = x[:3]
    assert np.array_equal(np.asarray(sharded(x3)),
                          np.asarray(lstm_exe(x3)))


def test_sharded_executable_multidevice_bit_exact():
    """4 forced host devices: the sharded dispatch must still be integer-
    identical to the unsharded emulator (subprocess so the main test
    process keeps seeing 1 device)."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=4")
        os.environ["JAX_PLATFORMS"] = "cpu"
        import sys
        sys.path.insert(0, {ROOT + "/src"!r})
        import dataclasses
        import jax
        import numpy as np
        from repro.configs.elastic_lstm import config
        from repro.model.layers import init_params
        from repro.model.lstm import lstm_schema
        from repro.rtl.backend import translate_rtl
        from repro.serving import ShardedExecutable, make_serving_mesh

        assert len(jax.devices()) == 4
        cfg = config()
        params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
        _, exe = translate_rtl(cfg, params)
        sharded = ShardedExecutable(dataclasses.replace(exe),
                                    make_serving_mesh(4))
        x = np.random.default_rng(5).standard_normal(
            (8, 6, 1)).astype(np.float32) * 0.5
        y = np.asarray(sharded(x))
        y_ref = np.asarray(exe(x))
        assert np.array_equal(y, y_ref), np.abs(y - y_ref).max()
        print("sharded-bit-exact-ok", y.shape)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "sharded-bit-exact-ok" in r.stdout
