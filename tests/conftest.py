import os
import sys

# Smoke tests must see 1 CPU device (the dry-run entrypoint sets its own
# flags in-process); never force a device count here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.core.types import ParallelismConfig  # noqa: E402


@pytest.fixture(scope="session")
def par_f32():
    """CPU-safe compute dtype (the container's XLA lacks some bf16 dots)."""
    return ParallelismConfig(compute_dtype="float32")


@pytest.fixture(scope="session")
def par_f32_scan():
    return ParallelismConfig(compute_dtype="float32", scan_layers=True)


def make_batch(cfg, B, S, key=0, train=True):
    """Standard smoke batch for any arch family."""
    import jax.numpy as jnp

    k0, k1 = jax.random.split(jax.random.PRNGKey(key))
    if cfg.family == "lstm":
        c = cfg.lstm
        x = jax.random.normal(k0, (B, c.seq_len, c.in_features))
        return {"x": x, "y": x.mean(axis=1) * 0.8}
    if cfg.family == "conv1d":
        import jax.numpy as jnp

        c = cfg.conv1d
        x = jax.random.normal(k0, (B, c.seq_len, c.channels))
        y = jnp.repeat(x.mean(axis=(1, 2))[:, None] * 0.8,
                       c.out_features, axis=1)            # (B, out_features)
        return {"x": x, "y": y}
    tokens = jax.random.randint(k0, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if train:
        batch["targets"] = tokens
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            k1, (B, cfg.n_frontend_tokens, cfg.frontend_dim))
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            k1, (B, cfg.encoder.n_positions, cfg.frontend_dim))
    return batch
