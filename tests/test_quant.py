"""Quantization: fixed-point properties (hypothesis), QAT training, int8 PTQ."""
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # image lacks hypothesis: use shim
    from _hypothesis_compat import given, settings, st

from repro.quant.fixedpoint import (FxpFormat, fake_quant, fxp_quantize, fxp_to_int,
                                    pick_frac_bits)
from repro.quant.ptq import (dequantize_params, int8_matmul_ref,
                             quantize_params_int8)
from repro.quant.qat import QATConfig, hard_sigmoid, hard_tanh


@given(st.integers(4, 16), st.integers(0, 8),
       st.floats(-100, 100, allow_nan=False))
@settings(max_examples=100, deadline=None)
def test_fxp_idempotent_and_bounded(total, frac, val):
    """Quantization is idempotent and error ≤ resolution/2 inside range."""
    frac = min(frac, total - 1)
    fmt = FxpFormat(total, frac)
    x = jnp.float32(val)
    q1 = fxp_quantize(x, fmt)
    q2 = fxp_quantize(q1, fmt)
    assert float(jnp.abs(q1 - q2)) == 0.0
    if abs(val) <= fmt.max_value:
        assert float(jnp.abs(q1 - x)) <= fmt.resolution / 2 + 1e-7


@given(st.integers(4, 16), st.integers(0, 8))
@settings(max_examples=50, deadline=None)
def test_fxp_int_codes_in_range(total, frac):
    frac = min(frac, total - 1)
    fmt = FxpFormat(total, frac)
    x = jnp.linspace(-10, 10, 101)
    codes = fxp_to_int(x, fmt)
    assert int(codes.min()) >= fmt.lo
    assert int(codes.max()) <= fmt.hi


def test_pick_frac_bits_fits_amax():
    for scale in [0.1, 0.9, 1.5, 7.9, 100.0]:
        x = jnp.asarray([scale])
        fb = pick_frac_bits(x, 8)
        fmt = FxpFormat(8, fb)
        assert fmt.max_value >= scale * 0.99, (scale, fb)


def test_ste_gradient():
    """Fake-quant is identity-gradient inside range, zero outside."""
    fmt = FxpFormat(8, 4)
    g = jax.grad(lambda x: jnp.sum(fake_quant(x, fmt)))(
        jnp.asarray([0.5, 100.0, -100.0]))
    assert g[0] == 1.0 and g[1] == 0.0 and g[2] == 0.0


def test_hard_activations_close_to_smooth():
    x = jnp.linspace(-1.2, 1.2, 100)
    assert float(jnp.max(jnp.abs(hard_sigmoid(x) - jax.nn.sigmoid(x)))) < 0.06
    assert float(jnp.max(jnp.abs(hard_tanh(x) - jnp.tanh(x)))) < 0.25


def test_qat_lstm_trains(par_f32):
    from repro.configs import get_config
    from repro.model.layers import init_params
    from repro.model.lstm import lstm_schema
    from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
    from repro.quant.qat import make_qat_loss

    cfg = get_config("elastic-lstm")
    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    loss_fn = make_qat_loss(cfg, QATConfig())
    x = jax.random.normal(jax.random.PRNGKey(42), (256, 6, 1))
    batch = {"x": x, "y": x.mean(axis=1) * 0.8}
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(lambda pp: loss_fn(pp, batch)[0])(p)
        p2, o2, _ = adamw_update(g, o, p, ocfg)
        return p2, o2, loss

    first = None
    for _ in range(80):
        params, opt, loss = step(params, opt)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.3


def test_int8_ptq_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    ip = quantize_params_int8({"w": w})
    wd = dequantize_params(ip, jnp.float32)["w"]
    # per-channel error bounded by scale/2
    err = jnp.abs(wd - w)
    bound = ip.scale["w"].reshape(1, -1) * 0.5 + 1e-6
    assert bool(jnp.all(err <= bound))


def test_int8_matmul_error_scaling():
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 256))
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 64))
    ip = quantize_params_int8({"w": w})
    y = int8_matmul_ref(x, ip.q["w"], ip.scale["w"])
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.02
