"""Grouped-GQA attention (no repeated K/V) must match the repeat-based
reference exactly — fwd, decode-with-cache, and grads."""

import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import get_config
from repro.core.types import SMOKE_MESH, ParallelismConfig, ShapeConfig
from repro.model.lm import Stepper, make_loss_fn, make_prefill_step, \
    make_decode_step
from repro.model.transformer import pad_cache

PAR_R = ParallelismConfig(compute_dtype="float32")
PAR_G = ParallelismConfig(compute_dtype="float32", gqa_grouped=True)


@pytest.mark.parametrize("arch", ["yi-9b", "qwen3-32b", "stablelm-12b",
                                  "internvl2-1b"])
def test_grouped_matches_repeat_train(arch):
    cfg = get_config(arch, smoke=True)
    S, B = 24, 2
    st = Stepper(cfg, ShapeConfig("t", "train", S, B), SMOKE_MESH, PAR_R)
    params, _ = st.init()
    batch = make_batch(cfg, B, S)
    lr, gr = jax.value_and_grad(
        lambda p: make_loss_fn(cfg, SMOKE_MESH, PAR_R, None)(p, batch)[0])(params)
    lg, gg = jax.value_and_grad(
        lambda p: make_loss_fn(cfg, SMOKE_MESH, PAR_G, None)(p, batch)[0])(params)
    assert abs(float(lr) - float(lg)) < 1e-5
    for a, b in zip(jax.tree.leaves(gr), jax.tree.leaves(gg)):
        rel = float(jnp.max(jnp.abs(a - b))) / (float(jnp.max(jnp.abs(a))) + 1e-3)
        assert rel < 1e-3


@pytest.mark.parametrize("arch", ["yi-9b", "qwen3-32b"])
def test_grouped_matches_repeat_decode(arch):
    cfg = get_config(arch, smoke=True)
    S, B = 16, 2
    st = Stepper(cfg, ShapeConfig("p", "prefill", S, B), SMOKE_MESH, PAR_R)
    params, _ = st.init()
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S + 1), 0,
                              cfg.vocab_size)
    ref_pre = make_prefill_step(cfg, SMOKE_MESH, PAR_R)
    grp_pre = make_prefill_step(cfg, SMOKE_MESH, PAR_G)
    l_r, c_r = ref_pre(params, {"tokens": toks[:, :S]})
    l_g, c_g = grp_pre(params, {"tokens": toks[:, :S]})
    assert float(jnp.max(jnp.abs(l_r - l_g))) < 1e-4
    c_g = pad_cache(c_g, S + 4)
    d_g, _ = make_decode_step(cfg, SMOKE_MESH, PAR_G)(
        params, toks[:, S:S + 1], c_g)
    c_r = pad_cache(c_r, S + 4)
    d_r, _ = make_decode_step(cfg, SMOKE_MESH, PAR_R)(
        params, toks[:, S:S + 1], c_r)
    assert float(jnp.max(jnp.abs(d_r - d_g))) < 1e-4
