"""Minimal hypothesis stand-in so property tests collect AND run without it.

The container image may lack ``hypothesis`` (it is in requirements-dev.txt
for CI). Rather than skipping the property suites, this shim re-implements
the tiny subset they use — ``@given`` over ``st.integers``/``st.floats`` with
``@settings(max_examples=..)`` — as deterministic seeded sampling that always
includes the interval endpoints. No shrinking, no database; real hypothesis
is used automatically whenever it is installed (see the try/except import at
the top of each property test module).
"""
from __future__ import annotations

import functools
import zlib
from types import SimpleNamespace


class settings:  # noqa: N801 — mirrors hypothesis' API
    def __init__(self, max_examples: int = 20, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._compat_max_examples = self.max_examples
        return fn


class _Strategy:
    def __init__(self, lo, hi, draw):
        self.lo, self.hi, self._draw = lo, hi, draw

    def example(self, rng, i: int):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return self._draw(rng)


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lo, hi, lambda rng: rng.randint(lo, hi))


def _floats(lo: float, hi: float, allow_nan: bool = False,
            allow_infinity: bool = False, **_kw) -> _Strategy:
    return _Strategy(float(lo), float(hi),
                     lambda rng: rng.uniform(float(lo), float(hi)))


st = SimpleNamespace(integers=_integers, floats=_floats)


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_compat_max_examples",
                        getattr(fn, "_compat_max_examples", 20))
            import random

            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for i in range(n):
                drawn = tuple(s.example(rng, i) for s in strategies)
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {i}: "
                        f"args={drawn!r}") from e
        # pytest must not see the strategy parameters as fixtures
        import inspect

        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
