"""Static IR verifier (DESIGN.md §13): soundness fuzz, the EAI negative-rule
suite, report round-trip, and the end-to-end analyze gates."""
import json

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # image lacks hypothesis: use shim
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.creator import Creator
from repro.core.types import SHAPES_CONV1D, SHAPES_LSTM
from repro.energy.hw import XC7S15
from repro.model.layers import init_params
from repro.quant.fixedpoint import FxpFormat
from repro.rtl import (AnalysisError, AnalysisReport, Edge, Graph,
                       LinearNode, RTLExecutable, RTLOptions, analyze_graph,
                       get_template, list_templates, translate_rtl)
from repro.rtl.analyze import (Interval, requant_interval,
                               worst_case_mac_bound)
from repro.rtl.diagnostics import RULES, Diagnostic, make_diagnostic
from repro.verify.vectors import canonical_graph, stimulus_codes

MODES = ("fused", "pallas", "jnp")


def _probe_graphs(seed: int):
    rng = np.random.default_rng(seed)
    out = []
    for kind in list_templates():
        g = get_template(kind).probe_graph(rng)
        if g is not None:
            out.append(g)
    return out


def _linear_graph(*, w, d_in=4, d_out=3, w_fmt=FxpFormat(8, 6),
                  in_fmt=FxpFormat(8, 4), out_fmt=FxpFormat(16, 8),
                  edge_out_fmt=None, name="neg"):
    g = Graph(name=name)
    g.edges["x"] = Edge("x", (d_in,), in_fmt)
    g.inputs = ["x"]
    g.add(LinearNode(name="lin0", op="linear", inputs=["x"], outputs=["y"],
                     weight=np.full((d_in, d_out), w, np.float32),
                     bias=np.zeros(d_out, np.float32),
                     w_fmt=w_fmt, in_fmt=in_fmt, out_fmt=out_fmt),
          Edge("y", (d_out,), edge_out_fmt or out_fmt))
    g.outputs = ["y"]
    return g


def _error_rules(report):
    return sorted({d.rule for d in report.errors})


# --------------------------------------------------------------------------- #
# Zero false positives on the shipped designs
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("arch", ["elastic-lstm", "elastic-conv1d"])
def test_shipped_designs_analyze_clean(arch):
    g, _, _ = canonical_graph(arch)
    rep = analyze_graph(g)
    assert rep.passed, rep.format()
    assert rep.errors == []
    assert set(rep.intervals) == set(g.edges)
    assert rep.resources["fits"]
    assert rep.resources["cycles"] > 0


# --------------------------------------------------------------------------- #
# Soundness: every emulator-observed value lies inside the static interval
# --------------------------------------------------------------------------- #


def _assert_sound(g, *, n_random=8, seed=0):
    rep = analyze_graph(g)
    e = g.edges[g.inputs[0]]
    stim = stimulus_codes(tuple(e.shape), e.fmt, n_random=n_random,
                          seed=seed)
    for mode in MODES:
        exe = RTLExecutable(graph=g, artifacts={}, hw=XC7S15,
                            emulator_mode=mode)
        trace = exe.emulator.run_int(stim).trace
        for edge, (lo, hi) in rep.intervals.items():
            v = np.asarray(trace[edge])
            assert lo <= v.min() and v.max() <= hi, (
                f"{g.name}:{edge} ({mode}): observed "
                f"[{v.min()}, {v.max()}] escapes static [{lo}, {hi}]")


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 1000))
def test_soundness_fuzz_probe_graphs(seed):
    for g in _probe_graphs(seed):
        _assert_sound(g, seed=seed)


@pytest.mark.parametrize("arch", ["elastic-lstm", "elastic-conv1d"])
def test_soundness_canonical_designs(arch):
    g, _, _ = canonical_graph(arch)
    _assert_sound(g, n_random=12, seed=3)


# --------------------------------------------------------------------------- #
# Negative suite: one deliberately broken design per EAI rule
# --------------------------------------------------------------------------- #


def test_eai001_accumulator_overflow():
    wide = FxpFormat(16, 0)
    g = _linear_graph(w=30000.0, w_fmt=wide, in_fmt=wide, out_fmt=wide)
    rep = analyze_graph(g)
    assert _error_rules(rep) == ["EAI001"]
    (d,) = [x for x in rep.diagnostics if x.rule == "EAI001"]
    assert d.node == "lin0" and "int32" in d.message
    assert "fan_in" in d.hint                 # the rule-table fix hint rides


def test_eai002_requant_shift_out_of_range():
    deep = FxpFormat(32, 31)
    # zero weights: the only defect is the 31+31-0 = 62-bit shift
    g = _linear_graph(w=0.0, w_fmt=deep, in_fmt=deep, out_fmt=FxpFormat(8, 0))
    rep = analyze_graph(g)
    assert _error_rules(rep) == ["EAI002"]
    assert "outside the int32 shifter range" in rep.errors[0].message


def test_eai002_widening_shift_overflows():
    wide = FxpFormat(16, 0)
    # |acc| ~ 4*100*32767 ≈ 1.3e7 fits int32, but << 8 does not
    g = _linear_graph(w=100.0, w_fmt=wide, in_fmt=wide,
                      out_fmt=FxpFormat(32, 8))
    rep = analyze_graph(g)
    assert _error_rules(rep) == ["EAI002"]
    assert "widening requant shift" in rep.errors[0].message


def test_eai003_format_mismatch():
    g = _linear_graph(w=0.1, edge_out_fmt=FxpFormat(8, 4))
    rep = analyze_graph(g)
    assert _error_rules(rep) == ["EAI003"]
    d = rep.errors[0]
    assert d.edge == "y" and "expects" in d.message


def test_eai004_lut_domain_not_covered():
    g = get_template("lstm_cell").probe_graph(np.random.default_rng(0))
    # shrink the sigmoid ROM's address range below the gate format: the
    # Q8.4 pre-activation interval (±128 codes) escapes a Q6.4 ROM (±32)
    g.node("hard_sigmoid_lut").in_fmt = FxpFormat(6, 4)
    rep = analyze_graph(g)
    assert _error_rules(rep) == ["EAI004"]
    assert "address range" in rep.errors[0].message


def test_eai005_resource_overflow():
    # 2000x200 8-bit weights = 3.2 Mbit ≈ 87 BRAM36 on a 10-BRAM part;
    # zero weights keep every interval rule quiet
    g = _linear_graph(w=0.0, d_in=2000, d_out=200)
    rep = analyze_graph(g)
    assert _error_rules(rep) == ["EAI005"]
    d = rep.errors[0]
    assert "bram36" in d.message and "exceeds" in d.message
    assert not rep.resources["fits"]


def test_eai006_output_saturation_is_a_warning():
    # acc ≈ 4*64*127 fits int32, but the post-shift interval (±508)
    # exceeds the declared Q8.4 output edge
    g = _linear_graph(w=1.0, out_fmt=FxpFormat(8, 4))
    rep = analyze_graph(g)
    assert rep.passed                       # warnings never fail a design
    assert rep.rules_fired() == ["EAI006"]
    assert rep.warnings[0].edge == "y"


def test_eai007_resource_pressure_is_a_warning():
    # 900x48 8-bit weights + biases = 347136 bits = exactly 10/10 BRAM36
    g = _linear_graph(w=0.0, d_in=900, d_out=48)
    rep = analyze_graph(g)
    assert rep.passed
    assert rep.rules_fired() == ["EAI007"]
    assert "90%" in RULES["EAI007"].hint


def test_every_rule_has_negative_coverage():
    """The rule table and this suite cannot drift apart silently."""
    import pathlib

    src = pathlib.Path(__file__).read_text(encoding="utf-8")
    for rule in RULES:
        assert f"def test_{rule.lower()}" in src, f"no negative test {rule}"


# --------------------------------------------------------------------------- #
# Malformed graphs and unknown kinds raise, listing what IS known
# --------------------------------------------------------------------------- #


def test_unknown_kind_lists_registered():
    g = Graph(name="bad")
    fmt = FxpFormat(8, 4)
    g.edges["x"] = Edge("x", (4,), fmt)
    g.inputs = ["x"]
    n = LinearNode(name="l", op="linear", inputs=["x"], outputs=["y"],
                   weight=np.zeros((4, 2), np.float32),
                   bias=np.zeros(2, np.float32))
    g.add(n, Edge("y", (2,), fmt))
    g.outputs = ["y"]
    n.op = "linnear"
    with pytest.raises(ValueError, match="registered templates"):
        analyze_graph(g)


def test_malformed_graph_errors_list_declared_edges():
    fmt = FxpFormat(8, 4)

    def base():
        g = Graph(name="bad")
        g.edges["x"] = Edge("x", (4,), fmt)
        g.inputs = ["x"]
        g.add(LinearNode(name="l", op="linear", inputs=["x"],
                         outputs=["y"],
                         weight=np.zeros((4, 2), np.float32),
                         bias=np.zeros(2, np.float32),
                         in_fmt=fmt, out_fmt=fmt),
              Edge("y", (2,), fmt))
        g.outputs = ["y"]
        return g

    g = base()
    g.inputs = ["ghost"]
    with pytest.raises(ValueError, match="declared edges.*'x'"):
        analyze_graph(g)
    g = base()
    g.outputs = ["ghost"]
    with pytest.raises(ValueError, match="undeclared"):
        analyze_graph(g)
    g = base()
    g.node("l").inputs[0] = "y"             # self-driven: nothing drives y
    with pytest.raises(ValueError, match="driven so far"):
        analyze_graph(g)
    g = base()
    del g.edges["y"]
    with pytest.raises(ValueError, match="undeclared"):
        analyze_graph(g)


def test_act_apply_unknown_lut_lists_present():
    g = get_template("act_apply").probe_graph(np.random.default_rng(0))
    g.node("act_0").lut = "missing_lut"
    with pytest.raises(ValueError, match="act_lut nodes present"):
        analyze_graph(g)


# --------------------------------------------------------------------------- #
# Interval algebra + report plumbing
# --------------------------------------------------------------------------- #


def test_interval_algebra():
    a, b = Interval(-3, 5), Interval(2, 4)
    assert a.add(b) == Interval(-1, 9)
    assert a.mul(b) == Interval(-12, 20)
    assert Interval(-1, 2).lshift(3) == Interval(-8, 16)
    assert a.join(Interval(7, 9)) == Interval(-3, 9)
    assert Interval(-500, 500).clip(FxpFormat(8, 0)) == Interval(-128, 127)
    assert Interval.full(FxpFormat(8, 0)).covers(Interval(-128, 127))
    assert not Interval(0, 1).covers(Interval(0, 2))
    with pytest.raises(ValueError, match="empty"):
        Interval(3, 2)
    with pytest.raises(ValueError, match="lshift"):
        Interval(0, 1).lshift(-1)


@settings(max_examples=40, deadline=None)
@given(st.integers(-(2 ** 31), 2 ** 31 - 1), st.integers(1, 31))
def test_requant_interval_bounds_round_half_even(v, shift):
    """The [lo >> s, (hi >> s) + 1] bound really contains the emulator's
    round-half-even shift of every point in the interval."""
    from repro.quant.fixedpoint import fxp_requant_int

    iv = requant_interval(Interval(v, v), shift)
    wide = FxpFormat(32, 0)                  # clip never binds at 32 bits
    got = int(np.asarray(fxp_requant_int(np.int32(v), shift, wide)))
    assert iv.contains(got), (v, shift, got, iv)


def test_worst_case_mac_bound_formula():
    assert worst_case_mac_bound(4, FxpFormat(8, 6), FxpFormat(8, 4),
                                b_magnitude=10) == 4 * 128 * 128 + 10


def test_report_json_round_trip():
    g, _, _ = canonical_graph("elastic-lstm")
    rep = analyze_graph(g)
    back = AnalysisReport.from_json(rep.to_json())
    assert back.design == rep.design and back.hw == rep.hw
    assert back.intervals == rep.intervals
    assert back.resources == rep.resources
    assert [d.to_dict() for d in back.diagnostics] == \
        [d.to_dict() for d in rep.diagnostics]
    with pytest.raises(ValueError, match="format_version"):
        AnalysisReport.from_dict({**rep.to_dict(), "format_version": 99})


def test_diagnostic_contract():
    d = make_diagnostic("EAI001", "node0", "boom", edge="e0")
    assert d.severity == "error" and d.hint == RULES["EAI001"].hint
    assert d.format("dsn") == "dsn:node0:e0: EAI001 [error] boom"
    assert Diagnostic.from_dict(d.to_dict()) == d
    with pytest.raises(ValueError, match="known rules"):
        make_diagnostic("EAI999", "n", "m")
    with pytest.raises(ValueError, match="severity"):
        Diagnostic(rule="EAI001", severity="fatal", node="n", message="m")


def test_default_transfer_is_sound_for_custom_templates():
    """A third-party template without a transfer override gets the
    full-format interval for its outputs — wide, but sound."""
    from repro.rtl.oplib import HWTemplate
    from repro.rtl.ir import Node

    class NopTemplate(HWTemplate):
        kind = "nop"
        node_cls = Node

    g = Graph(name="custom")
    fmt = FxpFormat(8, 4)
    g.edges["x"] = Edge("x", (4,), fmt)
    g.inputs = ["x"]
    g.add(Node(name="n0", op="nop", inputs=["x"], outputs=["y"]),
          Edge("y", (4,), fmt))
    g.outputs = ["y"]
    iv = NopTemplate().transfer(g.node("n0"), {"x": Interval(0, 1)},
                                graph=g, ctx=None)
    assert iv == {"y": Interval(fmt.lo, fmt.hi)}
    assert NopTemplate().wire_contract(g.node("n0"), g) == {}


# --------------------------------------------------------------------------- #
# Degenerate edges (satellite fix)
# --------------------------------------------------------------------------- #


def test_edge_bits_and_brams_degenerate():
    from repro.rtl.resources import brams_for

    fmt = FxpFormat(8, 4)
    assert Edge("s", (), fmt).bits == 8          # scalar: one element
    assert Edge("z", (0, 3), fmt).bits == 0      # zero-element: no storage
    assert brams_for(0) == 0
    assert brams_for(1) == 1
    with pytest.raises(ValueError, match="bits >= 0"):
        brams_for(-1)
    with pytest.raises(ValueError, match="negative dim"):
        _ = Edge("n", (-2, 3), fmt).bits


# --------------------------------------------------------------------------- #
# End-to-end gates: translate, save, Workflow, CLI
# --------------------------------------------------------------------------- #


def test_translate_gate_modes(tmp_path):
    cfg = get_config("elastic-lstm")
    from repro.model.lstm import lstm_schema

    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))
    _, exe = translate_rtl(cfg, params)                  # default: "error"
    assert exe.analysis is not None and exe.analysis.passed
    exe.save(str(tmp_path))
    data = json.loads((tmp_path / "analysis.json").read_text())
    assert data["design"] == "elastic-lstm" and data["passed"]
    _, exe_off = translate_rtl(cfg, params, analyze="off")
    assert exe_off.analysis is None
    with pytest.raises(ValueError, match="analyze must be one of"):
        translate_rtl(cfg, params, analyze="bogus")
    with pytest.raises(ValueError, match="analyze must be one of"):
        RTLOptions(analyze="bogus")


def test_translate_gate_fails_fast_and_warns(monkeypatch):
    """A failing design raises under "error" (before emit) and warns under
    "warn" — driven by forcing the analyzer to find a defect."""
    import repro.rtl.backend as backend

    cfg = get_config("elastic-lstm")
    from repro.model.lstm import lstm_schema

    params = init_params(lstm_schema(cfg), jax.random.PRNGKey(0))

    real = backend.analyze_graph

    def sabotaged(graph, **kw):
        rep = real(graph, **kw)
        rep.diagnostics.append(make_diagnostic(
            "EAI001", "lstm_cell_l0", "forced failure for the gate test"))
        return rep

    monkeypatch.setattr(backend, "analyze_graph", sabotaged)
    with pytest.raises(AnalysisError, match="EAI001") as ei:
        translate_rtl(cfg, params)
    assert not ei.value.report.passed
    with pytest.warns(UserWarning, match="EAI001"):
        _, exe = translate_rtl(cfg, params, analyze="warn")
    assert exe.analysis is not None and not exe.analysis.passed


def _workflow_for(arch, target, analyze):
    from repro.core.report import DesignReport
    from repro.core.workflow import Workflow

    cfg = get_config(arch)
    if cfg.family == "lstm":
        from repro.model.lstm import lstm_flops, lstm_schema

        schema, flops = lstm_schema(cfg), float(lstm_flops(cfg))
        shape = SHAPES_LSTM["infer_1"]
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 1))

        def fn(p, xx):
            from repro.model.lstm import lstm_apply

            return lstm_apply(p, xx, cfg)[0]
    else:
        from repro.model.conv1d import (conv1d_apply, conv1d_flops,
                                        conv1d_schema)

        schema, flops = conv1d_schema(cfg), float(conv1d_flops(cfg))
        shape = SHAPES_CONV1D["infer_1"]
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (1, cfg.conv1d.seq_len, cfg.conv1d.channels))

        def fn(p, xx):
            return conv1d_apply(p, xx, cfg)[0]

    def train_fn(knobs):
        params = init_params(schema, jax.random.PRNGKey(0))
        return params, DesignReport(model=cfg.name, train_loss=0.0,
                                    eval_loss=0.0), None

    def step_builder(knobs, params):
        if target == "rtl":
            return None, (params, x), flops
        return fn, (params, x), flops

    return Workflow(
        creator=Creator(hw=XC7S15), train_fn=train_fn,
        step_builder=step_builder,
        stepper_builder=(lambda knobs: Creator(hw=XC7S15).build(cfg, shape))
        if target == "rtl" else None,
        target=target, analyze=analyze)


@pytest.mark.parametrize("arch", ["elastic-lstm", "elastic-conv1d"])
@pytest.mark.parametrize("target", ["xla", "rtl"])
def test_workflow_analyze_stage(arch, target):
    wf = _workflow_for(arch, target, analyze="error" if target == "rtl"
                       else "off")
    rec = wf.run_once({})
    if target == "rtl":
        assert rec.analysis is not None
        assert rec.analysis.passed and rec.analysis.design == arch
    else:
        assert rec.analysis is None          # XLA lowers no dataflow graph


def test_workflow_analyze_off_and_unsupported():
    wf = _workflow_for("elastic-lstm", "rtl", analyze="off")
    rec = wf.run_once({})
    assert rec.analysis is None
    wf_xla = _workflow_for("elastic-lstm", "xla", analyze="error")
    with pytest.raises(ValueError, match="no 'analyze' field"):
        wf_xla.run_once({})


def test_lint_cli(tmp_path, capsys):
    from repro.rtl import lint

    assert lint.main(["--arch", "lstm"]) == 0
    out = capsys.readouterr().out
    assert "elastic-lstm: static analysis clean" in out
    path = tmp_path / "analysis.json"
    assert lint.main(["--json", str(path)]) == 0
    data = json.loads(path.read_text())
    assert sorted(r["design"] for r in data) == \
        ["elastic-conv1d", "elastic-lstm"]
    assert all(r["passed"] for r in data)
    capsys.readouterr()
    assert lint.main(["--arch", "nope"]) == 2
    assert "known archs" in capsys.readouterr().err
    assert lint.resolve_arch("conv1d") == "elastic-conv1d"
    assert lint.resolve_arch("elastic-lstm") == "elastic-lstm"
