"""Public wrapper: (B,S,H,hd) layout, GQA-repeated inputs, head-dim padding.

Training uses a ``jax.custom_vjp``: kernel forward, reference (recomputed,
q-chunked) backward — the standard template-fwd/XLA-bwd split until a bwd
template lands.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref

LANE = 128


def _to_bh(x):
    b, s, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)


def _from_bh(x, b, h):
    bh, s, d = x.shape
    return x.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True) -> jax.Array:
    """q/k/v: (B, S, H, hd) (kv already GQA-repeated). Returns (B, S, H, hd)."""
    return _flash_fwd_impl(q, k, v, causal)


def _pow2_block(s: int, cap: int = 256) -> int:
    b = 1
    while b * 2 <= cap and s % (b * 2) == 0:
        b *= 2
    return b


def _flash_fwd_impl(q, k, v, causal):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    bq, bk = _pow2_block(sq), _pow2_block(sk)
    if bq < 8 or bk < 8:                      # awkward seq length: oracle path
        return attention_ref(q, k, v, causal)
    pad_d = (-hd) % LANE
    if pad_d:
        pad = ((0, 0), (0, 0), (0, 0), (0, pad_d))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    # scale uses the REAL head dim, not the padded one
    q = q * (hd ** -0.5) * ((hd + pad_d) ** 0.5)  # kernel divides by padded
    o = flash_attention_pallas(_to_bh(q), _to_bh(k), _to_bh(v), causal=causal,
                               block_q=bq, block_k=bk,
                               interpret=use_interpret())
    o = _from_bh(o, b, h)
    return o[..., :hd]


def _fwd(q, k, v, causal):
    return _flash_fwd_impl(q, k, v, causal), (q, k, v)


def _bwd(causal, res, do):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal),
                     q, k, v)
    return vjp(do)


flash_attention.defvjp(_fwd, _bwd)
