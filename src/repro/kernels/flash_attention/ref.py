"""Pure-jnp oracle for the flash-attention template."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q/k/v: (B, S, H, hd) — plain softmax attention, f32 math."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
