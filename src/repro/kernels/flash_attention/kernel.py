"""Flash-attention forward template — online-softmax, O(S) HBM traffic.

The XLA reference path materializes (B,H,Sq,Sk) logits in HBM three times
per layer (the dominant §Roofline memory term for full-attention archs);
this template streams K/V blocks through VMEM with a running (m, l, acc)
online softmax, so HBM traffic drops to Q+K+V+O — the hardware adaptation
of the paper's "hand-written RTL beats HLS" claim, with VMEM as BRAM.

Grid (BH, Sq/bq, Sk/bk), K innermost (sequential on TPU ⇒ scratch carries
across K steps). Causal blocks above the diagonal are skipped via pl.when.
bf16 inputs, f32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ, DEFAULT_BK = 256, 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, bq: int, bk: int, causal: bool, scale: float):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks strictly above the causal diagonal
    run = (not causal) or (ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0]                                   # (bq, hd)
        k = k_ref[0]                                   # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                          # (bq, bk) f32
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,           # (BH, Sq, hd)
    k: jax.Array,           # (BH, Sk, hd)
    v: jax.Array,           # (BH, Sk, hd)
    *, causal: bool = True, block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK, interpret: bool = False,
) -> jax.Array:
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    n_k = Sk // bk
    scale = hd ** -0.5 if q.dtype != jnp.bfloat16 else q.shape[-1] ** -0.5
    grid = (BH, Sq // bq, n_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, n_k=n_k, bq=bq, bk=bk,
                          causal=causal, scale=hd ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
