"""Oracle: the naive per-step WKV6 recurrence (model/rwkv.py)."""
from repro.model.rwkv import wkv6_reference, wkv6_chunked  # noqa: F401
