"""WKV6 chunked-recurrence template.

Grid (B·H, n_chunks) — chunks innermost, so the (N, N) key→value state lives
in VMEM scratch across a head's chunks (the BRAM-resident state of an RTL
WKV pipeline). Within a chunk, subchunks of length l=16 are evaluated with
exact pairwise decay (bounded (l, l, N) working set) and chained through the
state with (l,N)×(N,N) MXU matmuls; all decay exponents are ≤ 0 (stable).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUB = 16


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, hout_ref, s_ref,
                 *, chunk: int, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    N = s_ref.shape[0]
    ns = chunk // SUB
    u = u_ref[0]                                     # (N,)

    for a in range(ns):
        sl = slice(a * SUB, (a + 1) * SUB)
        r = r_ref[0, sl, :].astype(jnp.float32)      # (l, N)
        k = k_ref[0, sl, :].astype(jnp.float32)
        v = v_ref[0, sl, :].astype(jnp.float32)
        w = w_ref[0, sl, :].astype(jnp.float32)      # log-decay ≤ 0
        csub = jnp.cumsum(w, axis=0)
        cprev = csub - w
        tot = csub[-1:]                              # (1, N)

        # intra-subchunk: A[i,j] = Σ_n r_i k_j e^{cprev_i - csub_j}, j<i
        pair = cprev[:, None, :] - csub[None, :, :]  # (l, l, N)
        mask = jnp.tril(jnp.ones((SUB, SUB), bool), -1)[:, :, None]
        dec = jnp.where(mask, jnp.exp(jnp.where(mask, pair, 0.0)), 0.0)
        A = jnp.einsum("in,ijn,jn->ij", r, dec, k,
                       preferred_element_type=jnp.float32)
        A = A + jnp.eye(SUB, dtype=jnp.float32) * jnp.einsum(
            "in,n,in->i", r, u.astype(jnp.float32), k,
            preferred_element_type=jnp.float32)[:, None]
        y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        # state read: (l,N)@(N,N) MXU
        rdec = r * jnp.exp(cprev)
        y = y + jax.lax.dot_general(rdec, s_ref[...],
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        # state update: S = diag(e^{tot}) S + Σ_j (k_j e^{tot-csub_j}) v_j^T
        kdec = k * jnp.exp(tot - csub)               # (l, N)
        T = jax.lax.dot_general(kdec, v, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s_ref[...] = s_ref[...] * jnp.exp(tot).T + T
        o_ref[0, sl, :] = y.astype(o_ref.dtype)

    @pl.when(c == n_chunks - 1)
    def _emit_state():
        hout_ref[0] = s_ref[...]


def wkv6_pallas(
    r: jax.Array,       # (BH, S, N)
    k: jax.Array,
    v: jax.Array,
    w_log: jax.Array,   # (BH, S, N) f32, ≤ 0
    u: jax.Array,       # (BH, N)  (u broadcast per head by the wrapper)
    *, chunk: int = 128, interpret: bool = False,
):
    BH, S, N = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0 and chunk % SUB == 0, (S, chunk)
    n_chunks = S // chunk
    grid = (BH, n_chunks)
    return pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, N), lambda bh, c: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, N, N), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, N), r.dtype),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w_log, u)
