"""Public wrapper: (B,S,H,N) layout -> template layout, state in/out."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.rwkv6.kernel import wkv6_pallas


@partial(jax.jit, static_argnames=("chunk",))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w_log: jax.Array,
         u: jax.Array, h0: Optional[jax.Array] = None, *, chunk: int = 128
         ) -> Tuple[jax.Array, jax.Array]:
    """r/k/v/w_log: (B,S,H,N); u: (H,N). Returns (y, final_state).

    NOTE: the template starts from a zero state; a nonzero ``h0`` is folded
    in afterwards with one extra (S-decay) correction term: y += (r ⊙
    e^{cum w}) h0 and S_final += e^{tot} h0. Exactness is preserved because
    the recurrence is linear in the state.
    """
    B, S, H, N = r.shape
    to = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    ub = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)
    y, hf = wkv6_pallas(to(r), to(k), to(v), to(w_log.astype(jnp.float32)),
                        ub, chunk=chunk, interpret=use_interpret())
    y = y.reshape(B, H, S, N).transpose(0, 2, 1, 3)
    hf = hf.reshape(B, H, N, N)
    if h0 is not None:
        cum = jnp.cumsum(w_log.astype(jnp.float32), axis=1)   # (B,S,H,N)
        rdec = r.astype(jnp.float32) * jnp.exp(cum - w_log)   # e^{c_{t-1}}
        y = y + jnp.einsum("bshn,bhnp->bshp", rdec, h0).astype(y.dtype)
        hf = hf + h0 * jnp.exp(cum[:, -1])[..., None]     # (B,H,N,1) key decay
    return y, hf
