"""Hardware kernel templates (the paper's RTL-template library, on TPU).

Each template: <name>/kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
<name>/ops.py (jit'd public wrapper; interpret=True on CPU), <name>/ref.py
(pure-jnp oracle the kernel is validated against, shape/dtype-swept in
tests/test_kernels_*.py).
"""

# the template library (one package per hardware template)
TEMPLATES = (
    "flash_attention",
    "lstm_cell",        # f32 fused LSTM window (XLA-backend analogue)
    "lstm_cell_int",    # int32 fused LSTM window (RTL emulator hot path)
    "mamba2",
    "quant_matmul",
    "rwkv6",
)

INTERPRET = None  # resolved lazily per-backend


def use_interpret() -> bool:
    """Pallas kernels execute for real only on TPU; elsewhere interpret."""
    global INTERPRET
    if INTERPRET is None:
        import jax

        INTERPRET = jax.default_backend() != "tpu"
    return INTERPRET
