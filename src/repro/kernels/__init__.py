"""Hardware kernel templates (the paper's RTL-template library, on TPU).

Each template: <name>/kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
<name>/ops.py (jit'd public wrapper; interpret=True on CPU), <name>/ref.py
(pure-jnp oracle the kernel is validated against, shape/dtype-swept in
tests/test_kernels_*.py).
"""

INTERPRET = None  # resolved lazily per-backend


def use_interpret() -> bool:
    """Pallas kernels execute for real only on TPU; elsewhere interpret."""
    global INTERPRET
    if INTERPRET is None:
        import jax

        INTERPRET = jax.default_backend() != "tpu"
    return INTERPRET
