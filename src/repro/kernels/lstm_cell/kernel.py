"""Fused LSTM-window template — the TPU port of the paper's RTL LSTM cell.

Ref [11] ("Enhancing energy-efficiency by solving the throughput bottleneck
of LSTM cells for embedded FPGAs") keeps the weights resident in BRAM and
streams the window through the cell. Here: the fused gate matrix W
((in+hid) × 4·hid) is pinned in VMEM for the whole window (BlockSpec maps it
to the same block for every grid step), the (h, c) state lives in VMEM
scratch, and the kernel iterates the 6 time steps in-register — one HBM read
of x and one write of h per window, zero intermediate HBM traffic.

Grid: (B/bb,) batch tiles; the time loop is a fori_loop inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lstm_kernel(x_ref, w_ref, b_ref, o_ref, h_ref, c_ref, *,
                 seq_len: int, hidden: int, d_in: int):
    h_ref[...] = jnp.zeros_like(h_ref)
    c_ref[...] = jnp.zeros_like(c_ref)
    w = w_ref[...]                                   # ((d_in+hid), 4*hid)
    b = b_ref[...]                                   # (1, 4*hid)

    def step(t, _):
        x_t = x_ref[:, t, :]                         # (bb, d_in)
        h = h_ref[...]
        zx = jax.lax.dot_general(x_t, w[:d_in], (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        zh = jax.lax.dot_general(h, w[d_in:], (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        z = zx + zh + b
        i = jax.nn.sigmoid(z[:, :hidden])
        f = jax.nn.sigmoid(z[:, hidden:2 * hidden])
        g = jnp.tanh(z[:, 2 * hidden:3 * hidden])
        o = jax.nn.sigmoid(z[:, 3 * hidden:])
        c = f * c_ref[...] + i * g
        h_ref[...] = o * jnp.tanh(c)
        c_ref[...] = c
        return 0

    jax.lax.fori_loop(0, seq_len, step, 0)
    o_ref[...] = h_ref[...].astype(o_ref.dtype)


def lstm_window_pallas(
    x: jax.Array,          # (B, S, d_in) f32
    w: jax.Array,          # (d_in + hidden, 4*hidden)
    b: jax.Array,          # (4*hidden,)
    *, block_b: int = 128, interpret: bool = False,
) -> jax.Array:
    """Returns the final hidden state (B, hidden)."""
    B, S, d_in = x.shape
    hidden = w.shape[1] // 4
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    return pl.pallas_call(
        functools.partial(_lstm_kernel, seq_len=S, hidden=hidden, d_in=d_in),
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, S, d_in), lambda i: (i, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0)),    # resident in VMEM
            pl.BlockSpec((1, b.shape[0]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hidden), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bb, hidden), jnp.float32),
            pltpu.VMEM((bb, hidden), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, b.reshape(1, -1))
