"""Public wrapper for the fused LSTM-window template."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.lstm_cell.kernel import lstm_window_pallas


@partial(jax.jit, static_argnames=("block_b",))
def lstm_window(x: jax.Array, w: jax.Array, b: jax.Array,
                *, block_b: int = 128) -> jax.Array:
    """(B,S,d_in) × fused gate weights -> final hidden (B, hidden)."""
    B = x.shape[0]
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
    out = lstm_window_pallas(x, w, b, block_b=bb, interpret=use_interpret())
    return out[:B]
