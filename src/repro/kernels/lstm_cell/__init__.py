from repro.kernels.lstm_cell.ops import lstm_window
