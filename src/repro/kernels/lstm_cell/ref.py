"""Oracle: the model-layer LSTM (repro.model.lstm) restricted to one cell."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.model.lstm import lstm_cell_step


def lstm_window_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, d_in) -> final hidden (B, hidden)."""
    B, S, _ = x.shape
    hidden = w.shape[1] // 4
    h = jnp.zeros((B, hidden), x.dtype)
    c = jnp.zeros((B, hidden), x.dtype)
    for t in range(S):
        h, c = lstm_cell_step(w, b, x[:, t], h, c)
    return h
