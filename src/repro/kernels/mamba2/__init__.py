from repro.kernels.mamba2.ops import ssd
