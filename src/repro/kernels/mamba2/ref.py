"""Oracles: the per-step recurrence and the chunked einsum form."""
from repro.model.ssm import ssd_reference, ssd_chunked  # noqa: F401
