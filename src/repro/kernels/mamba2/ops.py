"""Public wrapper: (B,S,H,P) layout, group broadcast, optional h0 fold-in."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.mamba2.kernel import ssd_pallas


@partial(jax.jit, static_argnames=("chunk",))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array, h0: Optional[jax.Array] = None, *, chunk: int = 128
        ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); B/C: (B,S,G,N). n_groups G=1.

    Returns (y (B,S,H,P), final_state (B,H,P,N)). Like the WKV6 template,
    a nonzero initial state is folded in post-hoc (the recurrence is linear
    in the state): y += (C e^{a_cs}) h0ᵀ and S += e^{a_tot} h0.
    """
    B, S, H, P = x.shape
    G = Bm.shape[2]
    assert G == 1, "template instantiated for n_groups=1 (zamba2)"
    xk = x.transpose(0, 2, 1, 3)                      # (B,H,S,P)
    y, hf = ssd_pallas(xk, dt.astype(jnp.float32), A.astype(jnp.float32),
                       Bm[:, :, 0], Cm[:, :, 0], chunk=chunk,
                       interpret=use_interpret())
    y = y.transpose(0, 2, 1, 3)
    if h0 is not None:
        a = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]
        a_cs = jnp.cumsum(a, axis=1)                  # (B,S,H)
        cdec = Cm[:, :, 0].astype(jnp.float32)        # (B,S,N)
        y = y + jnp.einsum("bsn,bsh,bhpn->bshp", cdec, jnp.exp(a_cs),
                           h0).astype(y.dtype)
        hf = hf + h0 * jnp.exp(a_cs[:, -1])[..., None, None]  # (B,H,1,1)
    return y, hf
