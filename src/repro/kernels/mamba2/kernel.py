"""Mamba2 SSD chunk-scan template.

Grid (B, H, n_chunks) — chunks innermost; the (P, N) per-head state is VMEM
scratch carried across a head's chunks. Per chunk, everything is (chunk ×
chunk/N/P) matmuls on the MXU:

    scores = (C Bᵀ) ⊙ L        L from the scalar-per-head segsum (VPU)
    y      = scores (dt·x) + (C ⊙ e^{a_cs}) Sᵀ
    S      = e^{a_tot} S + (dt·x)ᵀ (B ⊙ e^{a_tot - a_cs})

B/C are per-group (n_groups=1): their BlockSpec ignores the head index, so
the same VMEM block serves all heads of a group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, hout_ref, s_ref,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0].astype(jnp.float32)             # (chunk, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (chunk,)
    A = a_ref[0, 0]                                  # scalar (negative)
    Bm = b_ref[0].astype(jnp.float32)                # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)                # (chunk, N)

    a = dt * A                                       # (chunk,) log-decay
    a_cs = jnp.cumsum(a)                             # inclusive
    seg = a_cs[:, None] - a_cs[None, :]              # (chunk, chunk)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), 0)
    L = jnp.where(mask, jnp.exp(jnp.where(mask, seg, 0.0)), 0.0)
    # SSD convention: contribution of j to i (j<=i) carries
    # exp(a_cs[i]-a_cs[j]); the j==i term is dt_j*x_j, diag(L)=1. ✓
    xdt = x * dt[:, None]                            # (chunk, P)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * L
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk state read: y += (C ⊙ e^{a_cs}) Sᵀ ; S is (P, N)
    cdec = Cm * jnp.exp(a_cs)[:, None]
    y = y + jax.lax.dot_general(cdec, s_ref[...], (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # state update
    bdec = Bm * jnp.exp(a_cs[-1] - a_cs)[:, None]    # (chunk, N)
    T = jax.lax.dot_general(xdt, bdec, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (P, N)
    s_ref[...] = s_ref[...] * jnp.exp(a_cs[-1]) + T
    o_ref[0, 0] = y.astype(o_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit():
        hout_ref[0, 0] = s_ref[...]


def ssd_pallas(
    x: jax.Array,      # (B, H, S, P)
    dt: jax.Array,     # (B, S, H) f32 (post-softplus)
    A: jax.Array,      # (H,) f32 negative
    Bm: jax.Array,     # (B, S, N)  (n_groups=1)
    Cm: jax.Array,     # (B, S, N)
    *, chunk: int = 128, interpret: bool = False,
):
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (B, H, nc)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.reshape(H, 1), Bm, Cm)
