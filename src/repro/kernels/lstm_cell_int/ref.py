"""Pure-jnp oracle for the fused integer LSTM window.

One timestep at a time, the same schedule the per-step emulator paths run —
the kernel is validated against this reference integer-for-integer in
``tests/test_kernels.py`` / ``tests/test_rtl.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lstm_cell_int.kernel import CellSpec
from repro.quant.fixedpoint import fxp_requant_int


def lstm_window_int_ref(x, w, b, sig_table, tanh_table, *,
                        spec: CellSpec) -> jax.Array:
    """(B, S, d_in) int codes -> (B, S, hidden) int32, per-step schedule."""
    A, C = spec.act_fmt, spec.state_fmt
    af, wf, cf = A.frac_bits, spec.w_fmt.frac_bits, C.frac_bits
    B = x.shape[0]
    h = jnp.zeros((B, spec.hidden), jnp.int32)
    c = jnp.zeros((B, spec.hidden), jnp.int32)
    outs = []
    for t in range(spec.seq_len):
        xh = jnp.concatenate([x[:, t].astype(jnp.int32), h], axis=-1)
        acc = jax.lax.dot_general(xh, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32) + b
        z = fxp_requant_int(acc, af + wf, A)
        i, f, g, o = jnp.split(z, 4, axis=-1)
        si = jnp.take(sig_table, i - spec.sig_lo)
        sf = jnp.take(sig_table, f - spec.sig_lo)
        so = jnp.take(sig_table, o - spec.sig_lo)
        tg = jnp.take(tanh_table, g - spec.tanh_lo)
        term = sf * c + jax.lax.shift_left(si * tg, cf - af)
        c = fxp_requant_int(term, af + cf, C)
        c_a = fxp_requant_int(c, cf, A)
        tc = jnp.take(tanh_table, c_a - spec.tanh_lo)
        h = fxp_requant_int(so * tc, 2 * af, A)
        outs.append(h)
    return jnp.stack(outs, axis=1)
