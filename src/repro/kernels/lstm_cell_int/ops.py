"""Public wrapper for the fused integer LSTM-window template."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.lstm_cell_int.kernel import (CellSpec,
                                                lstm_window_int_pallas)


@partial(jax.jit, static_argnames=("spec", "block_b"))
def lstm_window_int(x: jax.Array, w: jax.Array, b: jax.Array,
                    sig_table: jax.Array, tanh_table: jax.Array,
                    *, spec: CellSpec, block_b: int = 128) -> jax.Array:
    """(B,S,d_in) int codes × fused int gate weights -> (B, S, hidden) int32.

    One template dispatch per window: pads the batch to the block size, runs
    the fused kernel (weights + biases + both ROMs VMEM-resident), slices the
    padding back off. Padded rows compute on zero inputs and are discarded —
    rows are independent, so real rows are bit-identical to the unpadded run.
    """
    B = x.shape[0]
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
    out = lstm_window_int_pallas(x, w, b, sig_table, tanh_table, spec=spec,
                                 block_b=bb, interpret=use_interpret())
    return out[:B]
