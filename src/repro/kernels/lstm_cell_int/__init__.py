"""Fused integer LSTM-window template (the RTL emulator's hot path)."""
from repro.kernels.lstm_cell_int.kernel import (CellSpec,  # noqa: F401
                                                lstm_window_int_pallas)
from repro.kernels.lstm_cell_int.ops import lstm_window_int  # noqa: F401
from repro.kernels.lstm_cell_int.ref import lstm_window_int_ref  # noqa: F401
