"""Fused *integer* LSTM-window template — the emulator's hot path.

The RTL emulator's original schedule dispatched one interpreted MAC
``pallas_call`` per timestep per cell and gathered the activation LUTs from
host-side tables between dispatches. This kernel is the single-dispatch
replacement, mirroring the f32 ``kernels/lstm_cell`` template: the fused gate
matrix W ((d_in+hid) × 4·hid), the accumulator-scale bias, and *both*
activation ROMs are pinned in VMEM for the whole window (BlockSpec maps them
to the same block for every grid step), the int32 (h, c) state lives in VMEM
scratch, and a ``fori_loop`` iterates the timesteps in-kernel — requant
(round-half-even shift + saturate) and LUT gathers included. One dispatch per
cell per window instead of ``seq_len``, zero intermediate HBM traffic.

Semantics are DESIGN.md §4, integer for integer — the same
``fxp_requant_int`` primitive as the per-step reference paths, so the
bit-exactness contract carries over unchanged.

Grid: (B/bb,) batch tiles; time is a ``fori_loop`` inside the kernel.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.fixedpoint import FxpFormat, fxp_requant_int


@dataclass(frozen=True)
class CellSpec:
    """Static metadata of one lstm_cell node — hashable, jit-static.

    Everything the fused kernel needs beyond the operand arrays: the window
    geometry, the three Q-formats' requant parameters, and the LUT address
    offsets (ROM tables are indexed by ``code - lo``, offset-binary order).
    """

    seq_len: int
    d_in: int
    hidden: int
    act_fmt: FxpFormat               # A: x, h, gate post-LUT values
    state_fmt: FxpFormat             # C: cell state
    w_fmt: FxpFormat                 # W: gate matrix codes
    sig_lo: int                      # sigmoid ROM address offset
    tanh_lo: int                     # tanh ROM address offset


def _lstm_int_kernel(x_ref, w_ref, b_ref, sig_ref, tanh_ref, o_ref,
                     h_ref, c_ref, *, spec: CellSpec):
    A, C = spec.act_fmt, spec.state_fmt
    af, wf, cf = A.frac_bits, spec.w_fmt.frac_bits, C.frac_bits
    H, d_in = spec.hidden, spec.d_in
    h_ref[...] = jnp.zeros_like(h_ref)
    c_ref[...] = jnp.zeros_like(c_ref)
    w = w_ref[...]                                   # ((d_in+hid), 4*hid)
    b = b_ref[...]                                   # (1, 4*hid)
    sig_rom = sig_ref[0]                             # (2**A.bits,)
    tanh_rom = tanh_ref[0]

    def step(t, _):
        x_t = x_ref[:, t, :].astype(jnp.int32)       # (bb, d_in)
        h = h_ref[...]
        zx = jax.lax.dot_general(x_t, w[:d_in], (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)
        zh = jax.lax.dot_general(h, w[d_in:], (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)
        z = fxp_requant_int(zx + zh + b, af + wf, A)  # acc -> act fmt
        i, f = z[:, :H], z[:, H:2 * H]
        g, o = z[:, 2 * H:3 * H], z[:, 3 * H:]
        si = jnp.take(sig_rom, i - spec.sig_lo)
        sf = jnp.take(sig_rom, f - spec.sig_lo)
        so = jnp.take(sig_rom, o - spec.sig_lo)
        tg = jnp.take(tanh_rom, g - spec.tanh_lo)
        # align si*tg (scale 2·af) to sf*c (scale af+cf): << (cf - af)
        term = sf * c_ref[...] + jax.lax.shift_left(si * tg, cf - af)
        c = fxp_requant_int(term, af + cf, C)
        c_a = fxp_requant_int(c, cf, A)
        tc = jnp.take(tanh_rom, c_a - spec.tanh_lo)
        h = fxp_requant_int(so * tc, 2 * af, A)
        h_ref[...] = h
        c_ref[...] = c
        o_ref[:, t, :] = h
        return 0

    jax.lax.fori_loop(0, spec.seq_len, step, 0)


def lstm_window_int_pallas(
    x: jax.Array,           # (B, S, d_in) int codes at act_fmt
    w: jax.Array,           # (d_in + hidden, 4*hidden) int32
    b: jax.Array,           # (4*hidden,) int32, accumulator scale
    sig_table: jax.Array,   # (2**act_bits,) int32 ROM
    tanh_table: jax.Array,  # (2**act_bits,) int32 ROM
    *, spec: CellSpec, block_b: int = 128, interpret: bool = False,
) -> jax.Array:
    """Returns the full hidden sequence (B, S, hidden) int32."""
    B, S, d_in = x.shape
    assert (S, d_in) == (spec.seq_len, spec.d_in), ((S, d_in), spec)
    H = spec.hidden
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    depth = sig_table.shape[0]
    return pl.pallas_call(
        functools.partial(_lstm_int_kernel, spec=spec),
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, S, d_in), lambda i: (i, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0)),      # VMEM-resident
            pl.BlockSpec((1, b.shape[0]), lambda i: (0, 0)),
            pl.BlockSpec((1, depth), lambda i: (0, 0)),
            pl.BlockSpec((1, tanh_table.shape[0]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, S, H), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((bb, H), jnp.int32),
            pltpu.VMEM((bb, H), jnp.int32),
        ],
        interpret=interpret,
    )(x, w, b.reshape(1, -1), sig_table.reshape(1, -1),
      tanh_table.reshape(1, -1))
