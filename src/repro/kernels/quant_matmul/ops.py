"""Public jit'd wrapper for the quant_matmul template."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import use_interpret
from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref, quantize_act


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                   "use_ref"))
def quant_matmul(x: jax.Array, wq: jax.Array, w_scale: jax.Array,
                 *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 128, use_ref: bool = False) -> jax.Array:
    """f32/bf16 activations × pre-quantized int8 weights -> f32.

    Pads M/K/N up to MXU-aligned block multiples (the RTL analogue pads to
    the systolic array width), then dispatches the Pallas template.
    """
    xq, xs = quantize_act(x)
    M, K = xq.shape
    N = wq.shape[1]
    if use_ref:
        return quant_matmul_ref(xq, wq, xs, w_scale)
    pm = (-M) % block_m
    pk = (-K) % block_k
    pn = (-N) % block_n
    xq_p = jnp.pad(xq, ((0, pm), (0, pk)))
    wq_p = jnp.pad(wq, ((0, pk), (0, pn)))
    ws_p = jnp.pad(w_scale.reshape(1, -1), ((0, 0), (0, pn)))
    out = quant_matmul_pallas(xq_p, wq_p, xs, ws_p,
                              block_m=block_m, block_n=block_n,
                              block_k=block_k, interpret=use_interpret())
    return out[:M, :N]
