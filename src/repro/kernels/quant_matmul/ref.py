"""Pure-jnp oracle for the quant_matmul template."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_matmul_ref(xq: jax.Array, wq: jax.Array, x_scale: jax.Array,
                     w_scale: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    acc = jax.lax.dot_general(xq, wq, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale.reshape(())
            * w_scale.reshape(1, -1)).astype(out_dtype)


def quantize_act(x: jax.Array):
    """Per-tensor symmetric int8 quantization of activations."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)
