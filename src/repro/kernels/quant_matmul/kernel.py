"""int8×int8→int32 tiled matmul — the TPU analogue of the paper's DSP-slice
fixed-point MAC template.

Tiling: grid (M/BM, N/BN, K/BK), K innermost (sequential on TPU, so the
int32 accumulator lives in a VMEM scratch across K steps). Weights arrive
pre-quantized (per-output-channel scales); activations are quantized on the
fly against a host-computed amax (per-tensor), matching the RTL template's
static input format. MXU-aligned 128-multiples throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 128


def _qmm_kernel(x_ref, w_ref, xscale_ref, wscale_ref, o_ref, acc_ref, *,
                n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _finish():
        xs = xscale_ref[0]
        ws = wscale_ref[...]                       # (1, BN) per-channel
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * xs
                      * ws).astype(o_ref.dtype)


def quant_matmul_pallas(
    xq: jax.Array,        # (M, K) int8 — pre-quantized activations
    wq: jax.Array,        # (K, N) int8
    x_scale: jax.Array,   # () or (1,) f32
    w_scale: jax.Array,   # (1, N) f32 per-output-channel
    *, block_m: int = DEFAULT_BM, block_n: int = DEFAULT_BN,
    block_k: int = DEFAULT_BK, out_dtype=jnp.float32, interpret: bool = False,
) -> jax.Array:
    M, K = xq.shape
    K2, N = wq.shape
    assert K == K2
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (M, N, K, block_m, block_n, block_k)
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(xq, wq, x_scale.reshape(1), w_scale.reshape(1, N))
