"""Fault injection & fault-tolerant deployment (DESIGN.md §12).

The Elastic Node verifies an accelerator once, at bring-up; pervasive
deployments then run it unattended in the field, where SEU bit-flips,
stalls and transient failures arrive uninvited. This package makes both
halves of that story first-class over the uniform ``Deployment`` API:

* :mod:`repro.resilience.faults` — deterministic, seeded chaos:
  :class:`FaultPlan` scripts (JSON artifacts) injected by
  :class:`FaultyDeployment` — SEU bit-flips in the RTL emulator's prepared
  device memories, stuck-at outputs, latency spikes on an injectable
  :class:`VirtualClock`, raised :class:`TransientFault` s;
* :mod:`repro.resilience.guard` — :class:`GuardedDeployment`: per-call
  timeout, bounded retry with deterministic-jitter backoff, a
  closed→open→half-open :class:`CircuitBreaker`, golden-vector canary
  probes that detect *silent* corruption and quarantine, and a
  :class:`FallbackPolicy` degrading RTL→XLA so the workload keeps serving;
* :mod:`repro.resilience.chaos` — :func:`run_chaos` scores a scripted
  scenario against the golden vectors into a :class:`ResilienceReport`
  (injected/detected/recovered, corrupted-after-detection, MTTR).

Every retry/trip/probe/fallback emits ``resilience.*`` counters and spans
through :mod:`repro.obs`; every random choice and every clock is injected,
so scenarios replay run-twice-identical.
"""
from repro.resilience.chaos import (ChaosSpec, ResilienceReport,  # noqa: F401
                                    run_chaos)
from repro.resilience.faults import (FAULT_KINDS, SILENT_KINDS,  # noqa: F401
                                     FaultPlan, FaultSpec, FaultyDeployment,
                                     TransientFault, VirtualClock)
from repro.resilience.guard import (CLOSED, HALF_OPEN, OPEN,  # noqa: F401
                                    CircuitBreaker, FallbackPolicy,
                                    GuardedDeployment, GuardExhausted,
                                    GuardPolicy, GuardResult)

__all__ = [
    "FAULT_KINDS", "SILENT_KINDS", "FaultSpec", "FaultPlan",
    "FaultyDeployment", "TransientFault", "VirtualClock",
    "CLOSED", "OPEN", "HALF_OPEN", "CircuitBreaker", "GuardPolicy",
    "GuardedDeployment", "GuardResult", "FallbackPolicy", "GuardExhausted",
    "ChaosSpec", "ResilienceReport", "run_chaos",
]
