"""Fault tolerance: guarded deployments — retry, timeout, circuit breaker,
canary health checks, graceful degradation (DESIGN.md §12).

A fleet of accelerators is only viable if one of them can fail, be
*detected* failing, and be routed around without the workload going dark.
:class:`GuardedDeployment` wraps any
:class:`~repro.core.target.Deployment` with the standard guards:

* **per-call timeout** — cooperative: the call runs to completion, but a
  call whose (injectable) clock time exceeds ``timeout_s`` is counted a
  failure and its result discarded (the emulator proxy cannot be
  preempted mid-dispatch; real hardware would be power-cycled);
* **bounded retry** — up to ``max_retries`` re-attempts with exponential
  backoff (``backoff_base_s · backoff_mult^attempt``) plus deterministic
  jitter from an injected ``numpy.random.Generator`` — no wall clock and
  no global RNG anywhere in the path, so tests replay exactly;
* **circuit breaker** — the classic closed → open → half-open machine
  per deployment: ``breaker_threshold`` consecutive failures open it,
  ``breaker_cooldown_s`` later one half-open probe is admitted, and
  ``half_open_probes`` successes close it again. A *canary-tripped*
  breaker is quarantined: corrupted memory does not heal by waiting, so
  ``allow()`` stays False until an explicit :meth:`CircuitBreaker.reset`;
* **canary health checks** — every ``canary_every`` calls the guard
  replays a small slice of the design's golden
  :class:`~repro.verify.vectors.VectorSet` through the primary
  (:func:`repro.verify.canary_check`) and demands integer-exact
  responses; a mismatch is a *detected silent fault*: the breaker trips,
  the deployment is quarantined, and traffic fails over;
* **graceful degradation** — a :class:`FallbackPolicy` names ordered
  alternates; the canonical chain is the RTL accelerator failing over to
  the XLA deployment of the same model (same SynthesisReport lineage):
  the workload keeps serving, flagged ``degraded`` (host-class energy,
  float instead of fixed-point accuracy) instead of going dark.

Every retry/trip/probe/fallback emits ``resilience.*`` counters into the
guard's :class:`~repro.obs.MetricsRegistry` and (when a tracer is
enabled) ``resilience.*`` spans.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core.target import Deployment
from repro.obs import get_metrics, get_tracer
from repro.resilience.faults import VirtualClock  # noqa: F401 (re-export)

#: breaker states (DESIGN.md §12 state machine)
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class GuardExhausted(RuntimeError):
    """The primary is unavailable and every fallback failed (or none is
    configured) — the request is lost."""


@dataclass(frozen=True)
class GuardPolicy:
    """The guard's knobs, one validated frozen dataclass (mirrors the
    options-dataclass idiom of the target registry)."""

    timeout_s: float = float("inf")
    max_retries: int = 2
    backoff_base_s: float = 0.01
    backoff_mult: float = 2.0
    jitter_frac: float = 0.1
    breaker_threshold: int = 3       # consecutive failures -> open
    breaker_cooldown_s: float = 1.0  # open -> half-open after this long
    half_open_probes: int = 1        # successes in half-open -> closed
    canary_every: int = 0            # probe every N calls (0 = off)
    canary_slice: int = 4            # golden rows replayed per probe

    def __post_init__(self):
        if self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {self.timeout_s}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.backoff_mult < 1.0:
            raise ValueError("backoff_mult must be >= 1, "
                             f"got {self.backoff_mult}")
        if not 0.0 <= self.jitter_frac < 1.0:
            raise ValueError("jitter_frac must be in [0, 1), "
                             f"got {self.jitter_frac}")
        if self.breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1, "
                             f"got {self.breaker_threshold}")
        if self.half_open_probes < 1:
            raise ValueError("half_open_probes must be >= 1, "
                             f"got {self.half_open_probes}")
        if self.canary_every < 0 or self.canary_slice < 1:
            raise ValueError("canary_every must be >= 0 and canary_slice "
                             ">= 1")


class CircuitBreaker:
    """Per-deployment closed → open → half-open state machine.

    All transitions go through one place (``_transition``) so each emits
    its ``resilience.breaker.<state>`` counter exactly once; ``trips``
    counts closed/half-open → open edges. Time comes from the injected
    callable clock — a :class:`VirtualClock` under test.
    """

    def __init__(self, policy: GuardPolicy, *, clock=time.perf_counter,
                 name: str = "primary", metrics=None):
        self.policy = policy
        self.clock = clock
        self.name = name
        self.metrics = metrics if metrics is not None else get_metrics()
        self.state = CLOSED
        self.failures = 0                # consecutive
        self.probes = 0                  # half-open successes so far
        self.opened_at: Optional[float] = None
        self.trips = 0
        self.quarantined = False

    def _transition(self, state: str) -> None:
        if state == self.state:
            return
        self.state = state
        self.metrics.counter(f"resilience.breaker.{state}").inc()
        if state == OPEN:
            self.trips += 1
            self.opened_at = self.clock()

    def allow(self) -> bool:
        """May a primary call be attempted now? An expired cooldown turns
        OPEN into HALF_OPEN (and admits the probe); quarantine never
        expires on its own."""
        if self.quarantined:
            return False
        if self.state == OPEN:
            if self.clock() - self.opened_at >= self.policy.breaker_cooldown_s:
                self.probes = 0
                self._transition(HALF_OPEN)
                return True
            return False
        return True

    def record_success(self) -> None:
        if self.state == HALF_OPEN:
            self.probes += 1
            if self.probes >= self.policy.half_open_probes:
                self.failures = 0
                self._transition(CLOSED)
        else:
            self.failures = 0

    def record_failure(self) -> None:
        if self.state == HALF_OPEN:      # a failed probe re-opens at once
            self._transition(OPEN)
            return
        self.failures += 1
        if self.failures >= self.policy.breaker_threshold:
            self._transition(OPEN)

    def trip(self, *, quarantine: bool = False) -> None:
        """Force open — e.g. a canary just proved silent corruption.
        ``quarantine=True`` pins it open (no half-open probes) until
        :meth:`reset`."""
        self.quarantined = self.quarantined or quarantine
        self._transition(OPEN)

    def reset(self) -> None:
        """Operator action: reflash/replace happened, start trusting again."""
        self.quarantined = False
        self.failures = 0
        self.probes = 0
        self._transition(CLOSED)


@dataclass
class GuardResult:
    """What one guarded call actually did — the value plus its provenance
    (which substrate answered, degraded or not, how many retries it took)."""

    value: Any
    source: str                      # guard name, or the fallback's name
    degraded: bool = False
    retries: int = 0
    latency_s: float = 0.0
    canary_ran: bool = False
    canary_passed: Optional[bool] = None


@dataclass(frozen=True)
class FallbackPolicy:
    """Ordered graceful degradation: ``alternates`` are ``(name,
    deployment)`` pairs tried in order once the primary is unavailable.
    The canonical chain degrades the RTL accelerator to the XLA deployment
    of the same model — same SynthesisReport lineage, flagged accuracy and
    energy downgrade, but the workload keeps serving."""

    alternates: Tuple[Tuple[str, Deployment], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "alternates", tuple(self.alternates))

    @staticmethod
    def to_xla(dep: Deployment, name: str = "xla") -> "FallbackPolicy":
        return FallbackPolicy(alternates=((name, dep),))

    def __bool__(self) -> bool:
        return bool(self.alternates)


class GuardedDeployment(Deployment):
    """The fault-tolerant wrapper every pooled deployment serves behind.

    :meth:`call` is the full-fidelity entry (returns a
    :class:`GuardResult`); ``__call__`` keeps the uniform Deployment
    contract (returns the value, raises :class:`GuardExhausted` when the
    request is lost). ``measure``/``save``/``verify`` delegate to the
    primary — guarding changes who answers, not what the artifact is.
    """

    def __init__(self, primary: Deployment, *,
                 policy: GuardPolicy = GuardPolicy(),
                 fallback=None, canary=None,
                 clock=time.perf_counter, sleep=None, rng=None,
                 metrics=None, name: str = "primary"):
        self.primary = primary
        self.policy = policy
        if fallback is not None and not isinstance(fallback, FallbackPolicy):
            fallback = FallbackPolicy.to_xla(fallback)
        self.fallback = fallback
        self.canary_vectors = canary     # a golden VectorSet (or None)
        self.clock = clock
        # sleeps are injectable for determinism; a VirtualClock brings its
        # own (advancing virtual time), wall clocks get time.sleep
        self.sleep = sleep if sleep is not None else (
            clock.sleep if hasattr(clock, "sleep") else time.sleep)
        self.rng = rng if rng is not None else np.random.default_rng(0)
        self.metrics = metrics if metrics is not None else get_metrics()
        self.name = name
        self.breaker = CircuitBreaker(policy, clock=clock, name=name,
                                      metrics=self.metrics)
        self.calls = 0
        self.detections: List[dict] = []

    # -- Deployment contract -------------------------------------------- #
    @property
    def target(self):
        return self.primary.target

    @property
    def graph(self):
        return getattr(self.primary, "graph", None)

    @property
    def emulator(self):
        return getattr(self.primary, "emulator", None)

    @property
    def cycles(self):
        return self.primary.cycles

    def measure(self, args, **kw):
        return self.primary.measure(args, **kw)

    def save(self, build_dir: str) -> None:
        self.primary.save(build_dir)

    @property
    def quarantined(self) -> bool:
        return self.breaker.quarantined

    # -- health --------------------------------------------------------- #
    def probe(self) -> Optional[bool]:
        """Run the canary now: replay ``canary_slice`` golden rows through
        the primary and demand integer-exact responses. A mismatch is a
        detected silent fault — counter, detection log entry, breaker
        tripped with quarantine. Returns the verdict (None without a
        canary set)."""
        if self.canary_vectors is None:
            return None
        from repro.verify import canary_check

        trc = get_tracer()
        with trc.span("resilience.canary", guard=self.name,
                      n=self.policy.canary_slice):
            res = canary_check(self.primary, self.canary_vectors,
                               n=self.policy.canary_slice)
        self.metrics.counter("resilience.canary_probes").inc()
        if not res.passed:
            self.metrics.counter("resilience.faults_detected").inc()
            self.detections.append({"call": self.calls,
                                    "n_mismatch": res.n_mismatch,
                                    "max_diff": res.max_diff})
            self.breaker.trip(quarantine=True)
        return res.passed

    def can_serve(self) -> bool:
        """Health-aware admission: will a request routed here get *an*
        answer? True when the primary is admissible (or will be after its
        cooldown check in ``allow``), or when a fallback stands behind it."""
        if self.fallback:
            return True
        b = self.breaker
        if b.quarantined:
            return False
        if b.state == OPEN:
            return (self.clock() - b.opened_at
                    >= self.policy.breaker_cooldown_s)
        return True

    def health(self) -> dict:
        return {"name": self.name, "state": self.breaker.state,
                "quarantined": self.breaker.quarantined,
                "consecutive_failures": self.breaker.failures,
                "trips": self.breaker.trips, "calls": self.calls,
                "detections": len(self.detections),
                "has_fallback": bool(self.fallback)}

    # -- the guarded call ----------------------------------------------- #
    def _backoff(self, attempt: int) -> float:
        base = self.policy.backoff_base_s * self.policy.backoff_mult ** attempt
        jitter = self.policy.jitter_frac * (2.0 * self.rng.random() - 1.0)
        return base * (1.0 + jitter)

    def _attempt_primary(self, args) -> Tuple[bool, Any]:
        import jax

        t0 = self.clock()
        try:
            out = self.primary(*args)
            jax.block_until_ready(out)
        except Exception:                # noqa: BLE001 - any call failure
            self.metrics.counter("resilience.primary_errors").inc()
            return False, None
        if self.clock() - t0 > self.policy.timeout_s:
            self.metrics.counter("resilience.timeouts").inc()
            return False, None
        return True, out

    def call(self, *args) -> GuardResult:
        """One guarded request. Canary (if due) → primary with
        retry/timeout under the breaker → fallback chain → lost."""
        tick = self.calls
        self.calls += 1
        trc = get_tracer()
        canary_ran, canary_passed = False, None
        if (self.canary_vectors is not None and self.policy.canary_every > 0
                and tick % self.policy.canary_every == 0
                and not self.breaker.quarantined):
            canary_passed = self.probe()
            canary_ran = True
        t_start = self.clock()
        retries = 0
        if self.breaker.allow():
            # a half-open breaker admits exactly one probe call, no retries
            attempts = 1 if self.breaker.state == HALF_OPEN \
                else self.policy.max_retries + 1
            for attempt in range(attempts):
                ok, out = self._attempt_primary(args)
                if ok:
                    self.breaker.record_success()
                    self.metrics.counter("resilience.calls.primary").inc()
                    return GuardResult(value=out, source=self.name,
                                       degraded=False, retries=retries,
                                       latency_s=self.clock() - t_start,
                                       canary_ran=canary_ran,
                                       canary_passed=canary_passed)
                self.breaker.record_failure()
                if attempt + 1 < attempts:
                    retries += 1
                    self.metrics.counter("resilience.retries").inc()
                    delay = self._backoff(attempt)
                    if trc.enabled:
                        with trc.span("resilience.backoff", attempt=attempt,
                                      delay_s=delay):
                            self.sleep(delay)
                    else:
                        self.sleep(delay)
        # primary unavailable (breaker open/quarantined or retries spent):
        # degrade down the fallback chain
        if self.fallback:
            for fname, fdep in self.fallback.alternates:
                try:
                    with trc.span("resilience.fallback", to=fname):
                        out = fdep(*args)
                    self.metrics.counter("resilience.fallbacks").inc()
                    self.metrics.counter(
                        f"resilience.calls.{fname}").inc()
                    return GuardResult(value=out, source=fname,
                                       degraded=True, retries=retries,
                                       latency_s=self.clock() - t_start,
                                       canary_ran=canary_ran,
                                       canary_passed=canary_passed)
                except Exception:        # noqa: BLE001 - try the next one
                    self.metrics.counter("resilience.fallback_errors").inc()
        self.metrics.counter("resilience.requests_lost").inc()
        raise GuardExhausted(
            f"guarded deployment {self.name!r}: primary unavailable "
            f"(breaker {self.breaker.state}"
            f"{', quarantined' if self.breaker.quarantined else ''}, "
            f"{retries} retries) and no fallback answered")

    def __call__(self, *args):
        return self.call(*args).value
