"""Deterministic fault injection — the chaos half of the resilience layer.

The Elastic Node's one-shot verification pass proves an accelerator was
correct *when flashed*; pervasive deployments then leave it in the field,
where embedded FPGAs take single-event upsets (SEUs) in BRAM/LUT memories,
transient link failures, and latency stalls that no bring-up check ever
sees (Venieris et al. 2018 make in-field reliability a first-class
deployment constraint). This module makes those faults a *scripted,
seeded, replayable* input to the toolchain:

* :class:`FaultSpec` / :class:`FaultPlan` — one fault = kind × trigger
  (exact call index or seeded per-call probability) × kind parameters,
  JSON round-trippable so a chaos scenario is a checked-in artifact;
* :class:`FaultyDeployment` — wraps any
  :class:`~repro.core.target.Deployment` and injects the plan on each
  call: ``bitflip`` flips one bit of one word of an RTL deployment's
  prepared device memories (the SEU model, via
  :meth:`~repro.rtl.emulator.RTLEmulator.flip_bit` — *silent*: subsequent
  outputs are wrong with no error raised), ``stuck_output`` forces every
  output element to a constant (a wedged output register), ``latency``
  injects a stall (advancing the injectable clock, so guarded timeouts
  see it deterministically), and ``transient`` raises
  :class:`TransientFault` (a flaked call that a retry may heal).

Determinism is the same contract as the golden vectors: every random
choice (probabilistic triggers, seeded memory/word selection) comes from
one ``numpy`` PCG64 stream keyed by ``FaultPlan.seed``, and time is a
:class:`VirtualClock` under test — the same plan against the same design
injects the same faults at the same calls, twice (tested).
"""
from __future__ import annotations

import json
import time
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.target import Deployment
from repro.obs import get_metrics, get_tracer

#: the fault taxonomy (DESIGN.md §12): silent memory corruption, wedged
#: outputs, stalls, and flaked calls.
FAULT_KINDS = ("bitflip", "stuck_output", "latency", "transient")
#: the kinds that corrupt *responses without raising* — only a canary
#: (golden-vector replay) can detect them.
SILENT_KINDS = ("bitflip", "stuck_output")


class TransientFault(RuntimeError):
    """An injected transient call failure (link flap, brown-out, ...)."""


class VirtualClock:
    """Deterministic time: ``now()``/calling it reads accumulated virtual
    seconds, ``sleep``/``advance`` moves it forward. Inject wherever a wall
    clock would make a retry/backoff/breaker/timeout test flaky — the whole
    resilience layer takes its clock (and its sleeps) from outside."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def now(self) -> float:
        return self.t

    def __call__(self) -> float:         # usable directly as a clock fn
        return self.t

    def sleep(self, dt: float) -> None:
        self.t += max(0.0, float(dt))

    advance = sleep


@dataclass(frozen=True)
class FaultSpec:
    """One scripted fault: what kind, when it fires, and its parameters.

    Triggers: ``at_call`` pins the fault to an exact 0-based call index of
    the wrapped deployment; otherwise each call draws
    ``Bernoulli(probability)`` from the plan's seeded stream. ``once``
    disarms the spec after its first firing (an SEU happens once; a noisy
    link flaps repeatedly — set ``once=False``).
    """

    kind: str
    at_call: Optional[int] = None
    probability: float = 0.0
    once: bool = True
    # -- bitflip (SEU) parameters ------------------------------------- #
    memory: Optional[str] = None     # "node.key" of the prepared memory;
    #                                  None = seeded choice over all
    word: Optional[int] = None       # flat word index; None = seeded
    bit: int = 0                     # bit position within the int32 word
    # -- stuck_output ---------------------------------------------------#
    value: float = 0.0               # every output element forced to this
    # -- latency --------------------------------------------------------#
    delay_s: float = 0.0             # injected stall

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"FaultSpec.kind must be one of {FAULT_KINDS}, "
                             f"got {self.kind!r}")
        if not 0.0 <= self.probability <= 1.0:
            raise ValueError("probability must be in [0, 1], "
                             f"got {self.probability}")
        if self.at_call is None and self.probability == 0.0:
            raise ValueError(f"FaultSpec({self.kind!r}) never fires: give "
                             "at_call or probability > 0")
        if not 0 <= self.bit <= 31:
            raise ValueError(f"bit must be in [0, 31], got {self.bit}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")


@dataclass(frozen=True)
class FaultPlan:
    """A scripted chaos scenario: an ordered tuple of specs + the seed that
    drives every probabilistic trigger and seeded memory/word choice.
    JSON round-trippable (``to_json``/``from_json``/``save``/``load``) so a
    scenario is a reviewable, checked-in artifact
    (``examples/chaos_plan.json``, the CI chaos smoke)."""

    faults: Tuple[FaultSpec, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    def to_json(self) -> str:
        return json.dumps(
            {"seed": self.seed, "faults": [asdict(f) for f in self.faults]},
            indent=2, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "FaultPlan":
        doc = json.loads(text)
        return FaultPlan(faults=tuple(FaultSpec(**f)
                                      for f in doc.get("faults", ())),
                         seed=int(doc.get("seed", 0)))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")

    @staticmethod
    def load(path: str) -> "FaultPlan":
        with open(path) as f:
            return FaultPlan.from_json(f.read())


class FaultyDeployment(Deployment):
    """Injects a :class:`FaultPlan` into any wrapped Deployment.

    Sits *under* a :class:`~repro.resilience.guard.GuardedDeployment` in a
    chaos scenario: the guard sees exactly what a flaky accelerator would
    show it — slow calls, raised transients, and (for the silent kinds)
    wrong answers with no exception. Call indices count raw invocations of
    this wrapper (retries included), which is what a per-call fault model
    means on real hardware.

    ``injected`` keeps a structured log of every firing (call index, kind,
    and the resolved bitflip address) — the evidence half of the
    :class:`~repro.resilience.chaos.ResilienceReport`.
    """

    def __init__(self, dep: Deployment, plan: FaultPlan, *,
                 clock: Optional[VirtualClock] = None, metrics=None):
        self.inner = dep
        self.plan = plan
        self.clock = clock
        self.metrics = metrics if metrics is not None else get_metrics()
        self._rng = np.random.Generator(np.random.PCG64(plan.seed))
        self._armed: List[FaultSpec] = list(plan.faults)
        self.calls = 0
        self.injected: List[Dict] = []

    # -- Deployment proxying ------------------------------------------- #
    @property
    def target(self):                    # noqa: D401 - metadata proxy
        return self.inner.target

    @property
    def graph(self):
        return getattr(self.inner, "graph", None)

    @property
    def emulator(self):
        return getattr(self.inner, "emulator", None)

    @property
    def cycles(self):
        return self.inner.cycles

    def measure(self, args, **kw):
        return self.inner.measure(args, **kw)

    def save(self, build_dir: str) -> None:
        self.inner.save(build_dir)

    # -- injection ------------------------------------------------------ #
    def _fires(self, spec: FaultSpec, call: int) -> bool:
        if spec.at_call is not None:
            return call == spec.at_call
        return self._rng.random() < spec.probability

    def _record(self, spec: FaultSpec, call: int, **detail) -> None:
        self.metrics.counter("resilience.faults_injected").inc()
        self.metrics.counter(f"resilience.faults_injected.{spec.kind}").inc()
        self.injected.append({"call": call, "kind": spec.kind, **detail})

    def _flip(self, spec: FaultSpec, call: int) -> None:
        emu = self.emulator
        if emu is None:
            raise ValueError(
                "bitflip faults model SEUs in prepared device memories; the "
                f"wrapped deployment (target {self.inner.target!r}) carries "
                "no RTL emulator")
        mems = emu.memories()
        if spec.memory is not None:
            node, _, key = spec.memory.rpartition(".")
            if (node, key) not in mems:
                raise ValueError(
                    f"unknown memory {spec.memory!r}; addressable memories: "
                    f"{['.'.join(m) for m in mems]}")
        else:
            node, key = mems[int(self._rng.integers(len(mems)))]
        size = int(np.asarray(emu.prepared(node)[key]).size)
        word = int(spec.word) if spec.word is not None \
            else int(self._rng.integers(size))
        new = emu.flip_bit(node, key, word, spec.bit)
        self._record(spec, call, memory=f"{node}.{key}", word=word % size,
                     bit=spec.bit, new_word=new)

    def __call__(self, *args):
        call = self.calls
        self.calls += 1
        fired = [s for s in self._armed if self._fires(s, call)]
        for s in fired:
            if s.once:
                self._armed.remove(s)
        trc = get_tracer()
        for s in fired:
            if trc.enabled:
                with trc.span("resilience.inject", kind=s.kind, call=call):
                    pass
            if s.kind == "latency":
                self._record(s, call, delay_s=s.delay_s)
                if self.clock is not None:
                    self.clock.advance(s.delay_s)
                else:
                    time.sleep(s.delay_s)
            elif s.kind == "bitflip":
                self._flip(s, call)
            elif s.kind == "transient":
                self._record(s, call)
                raise TransientFault("injected transient fault at call "
                                     f"{call}")
        out = self.inner(*args)
        for s in fired:
            if s.kind == "stuck_output":
                import jax
                import jax.numpy as jnp

                self._record(s, call, value=s.value)
                out = jax.tree.map(lambda a: jnp.full_like(a, s.value), out)
        return out
