"""Scripted chaos scenarios → :class:`ResilienceReport`.

:func:`run_chaos` is the acceptance harness for the resilience layer: it
stacks a :class:`~repro.resilience.faults.FaultyDeployment` (injecting a
seeded :class:`~repro.resilience.faults.FaultPlan`) under a
:class:`~repro.resilience.guard.GuardedDeployment` (canary + breaker +
retry + RTL→XLA fallback), drives a fixed request sequence drawn from the
design's golden :class:`~repro.verify.vectors.VectorSet`, and scores every
response against the golden codes. Because the stimulus doubles as the
ground truth, the report can say not just "requests served" but *"zero
corrupted responses after detection"* — the claim that matters for a
fleet.

Everything is deterministic: one internal :class:`VirtualClock` shared by
injector and guard, numpy PCG64 streams keyed by the plan/spec seeds, and
a fresh :class:`~repro.obs.MetricsRegistry` per run — the same scenario
run twice yields byte-identical ``ResilienceReport.to_json()`` (tested,
mirroring the emit-twice golden-artifact contract).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.target import Deployment
from repro.obs import MetricsRegistry, get_tracer
from repro.resilience.faults import FaultPlan, FaultyDeployment, VirtualClock
from repro.resilience.guard import (FallbackPolicy, GuardedDeployment,
                                    GuardExhausted, GuardPolicy)


@dataclass(frozen=True)
class ChaosSpec:
    """One scripted scenario: the fault plan, how many requests to drive,
    and the guard policy under test."""

    plan: FaultPlan
    n_requests: int = 32
    policy: GuardPolicy = field(default_factory=lambda: GuardPolicy(
        timeout_s=0.25, max_retries=2, backoff_base_s=0.01,
        breaker_threshold=3, breaker_cooldown_s=1.0, canary_every=4))
    seed: int = 0

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError("n_requests must be >= 1, "
                             f"got {self.n_requests}")


@dataclass
class ResilienceReport:
    """The structured outcome of one chaos scenario — what was injected,
    what the guard detected, and what the workload actually experienced.

    ``mttr_requests`` is mean-time-to-recover in request ticks: from the
    first *silent* injection to the first detection (canary trip). -1 when
    nothing silent was injected or nothing was detected.
    """

    design: str
    target: str
    n_requests: int
    seed: int
    faults_injected: List[Dict] = field(default_factory=list)
    faults_detected: List[Dict] = field(default_factory=list)
    detected: bool = False
    recovered: bool = False            # served degraded after detection
    requests_ok: int = 0               # primary-served, response correct
    requests_degraded: int = 0         # fallback-served
    requests_corrupted: int = 0        # served but wrong vs golden codes
    corrupted_after_detection: int = 0
    requests_lost: int = 0             # GuardExhausted
    retries: int = 0
    fallbacks: int = 0
    breaker_trips: int = 0
    mttr_requests: int = -1
    final_breaker_state: str = "closed"
    counters: Dict[str, int] = field(default_factory=dict)
    requests: List[Dict] = field(default_factory=list)

    @property
    def passed(self) -> bool:
        """The ISSUE-7 acceptance bar: silent fault detected, traffic kept
        flowing degraded, and zero corrupted responses after detection."""
        return (self.detected and self.recovered
                and self.corrupted_after_detection == 0)

    def to_dict(self) -> Dict:
        d = dict(self.__dict__)
        d["passed"] = self.passed
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")

    def summary(self) -> str:
        return (f"chaos[{self.design}/{self.target}] "
                f"{self.n_requests} requests: "
                f"{len(self.faults_injected)} injected / "
                f"{len(self.faults_detected)} detected "
                f"(mttr {self.mttr_requests} req), "
                f"{self.requests_ok} ok / {self.requests_degraded} degraded "
                f"/ {self.requests_corrupted} corrupted "
                f"({self.corrupted_after_detection} after detection) / "
                f"{self.requests_lost} lost; "
                f"retries {self.retries}, fallbacks {self.fallbacks}, "
                f"breaker {self.final_breaker_state} "
                f"({self.breaker_trips} trips) -> "
                f"{'PASS' if self.passed else 'FAIL'}")


def run_chaos(dep: Deployment, spec: ChaosSpec, *,
              fallback: Optional[FallbackPolicy] = None,
              vectors=None,
              metrics: Optional[MetricsRegistry] = None) -> ResilienceReport:
    """Drive ``spec.n_requests`` golden-vector requests through
    ``dep`` wrapped in fault injection + guarding, and score the result.

    ``vectors`` defaults to the design's generated golden
    :class:`~repro.verify.vectors.VectorSet` (requires a graph-carrying
    deployment); they provide both the stimulus stream (row ``i % n``,
    singleton batches) and the ground truth for corruption scoring.
    """
    graph = getattr(dep, "graph", None)
    if vectors is None:
        if graph is None:
            raise ValueError(
                "run_chaos needs golden vectors to drive and score the "
                f"scenario; deployment (target {dep.target!r}) carries no "
                "graph to generate them from — pass vectors= explicitly")
        from repro.verify import generate_vectors

        vectors = generate_vectors(graph)

    mx = metrics if metrics is not None else MetricsRegistry()
    clock = VirtualClock()
    faulty = FaultyDeployment(dep, spec.plan, clock=clock, metrics=mx)
    guard = GuardedDeployment(
        faulty, policy=spec.policy, fallback=fallback,
        canary=vectors, clock=clock,
        rng=np.random.Generator(np.random.PCG64(spec.seed)), metrics=mx,
        name=f"{vectors.design}:{dep.target}")

    stim_f = np.asarray(vectors.stimulus_f())
    golden = np.asarray(vectors.response)
    scale = float(vectors.out_fmt.scale)
    n_rows = stim_f.shape[0]

    rep = ResilienceReport(design=vectors.design, target=dep.target,
                           n_requests=spec.n_requests, seed=spec.seed)
    trc = get_tracer()
    detected_at = -1
    with trc.span("resilience.chaos", design=vectors.design,
                  n_requests=spec.n_requests):
        for i in range(spec.n_requests):
            row = i % n_rows
            x = stim_f[row][None]
            inj_before = len(faulty.injected)
            det_before = len(guard.detections)
            entry: Dict = {"request": i, "row": row}
            try:
                res = guard.call(x)
            except GuardExhausted:
                rep.requests_lost += 1
                entry["status"] = "lost"
                rep.requests.append(entry)
                continue
            finally:
                for f in faulty.injected[inj_before:]:
                    f.setdefault("request", i)
                if detected_at < 0 and len(guard.detections) > det_before:
                    detected_at = i
            entry.update(source=res.source, degraded=res.degraded,
                         retries=res.retries, canary_ran=res.canary_ran)
            codes = np.rint(np.asarray(res.value) * scale).astype(np.int64)
            correct = bool(np.array_equal(codes.reshape(golden[row].shape),
                                          golden[row]))
            entry["correct"] = correct
            if not correct:
                rep.requests_corrupted += 1
                if detected_at >= 0:
                    rep.corrupted_after_detection += 1
                entry["status"] = "corrupted"
            elif res.degraded:
                entry["status"] = "degraded"
            else:
                entry["status"] = "ok"
            if res.degraded:
                rep.requests_degraded += 1
                if detected_at >= 0 and correct:
                    rep.recovered = True
            elif correct:
                rep.requests_ok += 1
            rep.requests.append(entry)

    rep.faults_injected = list(faulty.injected)
    rep.faults_detected = [dict(d, request=detected_at)
                           for d in guard.detections]
    rep.detected = bool(guard.detections)
    if rep.detected and detected_at >= 0:
        silent = [f.get("request", -1) for f in faulty.injected
                  if f["kind"] in ("bitflip", "stuck_output")]
        first_silent = min((r for r in silent if r >= 0), default=-1)
        if first_silent >= 0:
            rep.mttr_requests = detected_at - first_silent
    rep.retries = int(mx.counter("resilience.retries").value)
    rep.fallbacks = int(mx.counter("resilience.fallbacks").value)
    rep.breaker_trips = guard.breaker.trips
    rep.final_breaker_state = guard.breaker.state
    rep.counters = {k: v["value"] for k, v in mx.snapshot().items()
                    if k.startswith("resilience.") and v["type"] == "counter"}
    return rep
