"""One locked LRU of compiled programs, shared by every staged executor.

Both the RTL emulator (:mod:`repro.rtl.emulator`) and the serving shard
layer (:mod:`repro.serving.shard`) cache jitted programs keyed by what the
program was traced for — and both are hit from farm worker threads.  PR 7
put a lock around the emulator's ``OrderedDict``; ``shard.py`` had quietly
re-implemented the same pop/insert/evict dance without one, so concurrent
dispatch could corrupt that cache.  This module is the single
implementation both now use.

The LRU is also the unit of *program sharing*: isomorphic designs (same
:func:`repro.rtl.ir.iso_key`) produce identical traced programs once
weights are passed as arguments, so handing several emulators one shared
``ProgramLRU`` makes K candidate designs compile exactly once per
``(iso_key, mode, shape)`` — the multi-design emulation contract
(DESIGN.md §15).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Tuple


class ProgramLRU:
    """Thread-safe least-recently-used cache of compiled programs.

    ``get_or_build(key, factory)`` returns ``(program, hit, n_evicted)``:
    on a miss the factory runs *under the lock* (jit construction is cheap
    — tracing happens on first call — and holding the lock keeps two
    threads from building the same key twice), the entry is inserted
    most-recently-used, and the oldest entries are evicted down to
    ``max_programs``.  Hits refresh recency.  ``key in lru`` is a
    read-only probe that does not touch recency order, so affinity
    routers can probe every pool member side-effect free.
    """

    def __init__(self, max_programs: int = 8):
        if max_programs < 1:
            raise ValueError(f"max_programs must be >= 1, got {max_programs}")
        self.max_programs = max_programs
        self._programs: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_or_build(self, key: Hashable, factory: Callable[[], Any]
                     ) -> Tuple[Any, bool, int]:
        with self._lock:
            prog = self._programs.pop(key, None)
            hit = prog is not None
            evicted = 0
            if prog is None:
                self.misses += 1
                prog = factory()
                while len(self._programs) >= self.max_programs:
                    self._programs.popitem(last=False)
                    evicted += 1
                self.evictions += evicted
            else:
                self.hits += 1
            self._programs[key] = prog   # (re)insert most-recently-used
        return prog, hit, evicted

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._programs

    def __len__(self) -> int:
        with self._lock:
            return len(self._programs)

    def clear(self) -> None:
        """Drop every cached program (e.g. after an SEU corrupts the
        memories a program's arguments are built from)."""
        with self._lock:
            self._programs.clear()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "size": len(self._programs)}

    def __repr__(self) -> str:
        return (f"ProgramLRU(max_programs={self.max_programs}, "
                f"size={len(self)}, hits={self.hits}, "
                f"misses={self.misses}, evictions={self.evictions})")
