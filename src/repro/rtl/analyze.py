"""Static IR verifier — abstract interpretation over the dataflow IR.

The dynamic half of the toolchain (bit-exact emulation, golden vectors,
conformance fuzzing) only finds an overflowing accumulator or a mismatched
wire *after* lowering, compiling and running a design. This pass proves the
same properties statically, in milliseconds, by propagating integer value
intervals edge-by-edge through the graph (DESIGN.md §13):

* every edge gets a sound over-approximating interval ``[lo, hi]`` of the
  int codes the emulator can ever place on it (all three execution modes);
* each registered :class:`~repro.rtl.oplib.HWTemplate` owns its transfer
  function (``HWTemplate.transfer``) the same way it owns emit/emulate/cost;
* violations are emitted as stable-rule-ID :class:`Diagnostic` records
  (``EAI001`` accumulator overflow, ``EAI002`` requant shift, ``EAI003``
  Q-format continuity, ``EAI004`` LUT domain, ``EAI005``/``EAI007``
  resource feasibility, ``EAI006`` output saturation) in a
  JSON-round-trippable :class:`AnalysisReport`.

Soundness is the contract the fuzz suite checks: for every edge, every
value the emulator observes must lie inside the statically derived
interval. The analysis is deliberately a single forward pass — every
recurrent state in the IR (the LSTM h/c) is requant-*clipped* to its
format each step, so its format range is already a post-fixpoint.

``RTLTarget`` runs this before emit (``RTLOptions.analyze``), and the DSE
engine (ROADMAP item 2) uses it as the per-candidate feasibility oracle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.energy.hw import HWSpec, XC7S15
from repro.quant.fixedpoint import FxpFormat
from repro.rtl.diagnostics import (AnalysisReport, Diagnostic,
                                   make_diagnostic)
from repro.rtl.ir import ActLUTNode, Graph, Node
from repro.rtl.resources import estimate

#: int32 hardware word — what the DSP accumulators and every edge hold
INT32_LO = -(2 ** 31)
INT32_HI = 2 ** 31 - 1

#: utilization above this fraction of a device budget raises EAI007
PRESSURE_THRESHOLD = 0.9


class AnalysisError(ValueError):
    """Raised by the ``analyze="error"`` gate when a design fails static
    analysis; carries the full :class:`AnalysisReport` as ``.report``."""

    def __init__(self, report: AnalysisReport) -> None:
        self.report = report
        super().__init__(report.format())


@dataclass(frozen=True)
class Interval:
    """A closed integer interval ``[lo, hi]`` — the abstract value one edge
    (or internal accumulator) can take. Arithmetic is exact python-int
    interval arithmetic: no wraparound, so overflow is *detected* by
    comparing against the int32 word, never silently reproduced."""

    lo: int
    hi: int

    def __post_init__(self) -> None:
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    @staticmethod
    def full(fmt: FxpFormat) -> "Interval":
        """Every representable code of ``fmt`` — the input-edge seed."""
        return Interval(fmt.lo, fmt.hi)

    @staticmethod
    def point(v: int) -> "Interval":
        return Interval(v, v)

    def add(self, other: "Interval") -> "Interval":
        return Interval(self.lo + other.lo, self.hi + other.hi)

    def mul(self, other: "Interval") -> "Interval":
        ps = (self.lo * other.lo, self.lo * other.hi,
              self.hi * other.lo, self.hi * other.hi)
        return Interval(min(ps), max(ps))

    def lshift(self, s: int) -> "Interval":
        if s < 0:
            raise ValueError(f"lshift needs s >= 0, got {s}")
        return Interval(self.lo << s, self.hi << s)

    def join(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    def clip(self, fmt: FxpFormat) -> "Interval":
        """Saturation to ``fmt``: the abstract counterpart of ``jnp.clip``
        (never empty — the rails themselves are representable)."""
        return Interval(min(max(self.lo, fmt.lo), fmt.hi),
                        min(max(self.hi, fmt.lo), fmt.hi))

    def covers(self, other: "Interval") -> bool:
        return self.lo <= other.lo and other.hi <= self.hi

    def contains(self, v: int) -> bool:
        return self.lo <= v <= self.hi

    @property
    def magnitude(self) -> int:
        return max(abs(self.lo), abs(self.hi))

    def fits_int32(self) -> bool:
        return INT32_LO <= self.lo and self.hi <= INT32_HI

    def __str__(self) -> str:
        return f"[{self.lo}, {self.hi}]"


def requant_interval(iv: Interval, shift: int) -> Interval:
    """Sound bound of ``fxp_requant_int``'s shift *before* saturation.

    For a narrowing shift ``s > 0`` the round-half-even quotient is
    ``(v >> s) + inc`` with ``inc`` in {0, 1}, so the image lies in
    ``[lo >> s, (hi >> s) + 1]`` (python ``>>`` floors, matching the
    arithmetic shift). A widening shift is an exact left shift.
    """
    if shift > 0:
        return Interval(iv.lo >> shift, (iv.hi >> shift) + 1)
    if shift < 0:
        return iv.lshift(-shift)
    return iv


class AnalysisContext:
    """The diagnostic sink handed to ``HWTemplate.transfer``.

    ``diag`` appends a rule-table diagnostic; ``saturation`` records the
    *pre-clip* interval a template computed for an edge, so the driver can
    decide wordlength sufficiency (EAI006) on the design's output edges
    without every template knowing what is an output. Tables of the
    graph's LUT nodes are cached per run (``lut_table``).
    """

    def __init__(self, graph: Graph) -> None:
        self.graph = graph
        self.diagnostics: List[Diagnostic] = []
        self.pre_clip: Dict[str, Interval] = {}
        self._tables: Dict[str, np.ndarray] = {}

    def diag(self, rule: str, node: str, message: str,
             edge: Optional[str] = None) -> None:
        self.diagnostics.append(make_diagnostic(rule, node, message, edge))

    def saturation(self, edge: str, pre: Interval) -> None:
        known = self.pre_clip.get(edge)
        self.pre_clip[edge] = pre if known is None else known.join(pre)

    def lut_table(self, lut: ActLUTNode) -> np.ndarray:
        t = self._tables.get(lut.name)
        if t is None:
            t = np.asarray(lut.table(), np.int64)
            self._tables[lut.name] = t
        return t


# --------------------------------------------------------------------------- #
# Shared transfer-function helpers (the math every weighted template reuses)
# --------------------------------------------------------------------------- #


def mac_interval(w_int: np.ndarray, b_int: np.ndarray,
                 row_intervals: List[Tuple[slice, Interval]]) -> Interval:
    """Interval of ``sum_i w[i, j] * x_i + b_j`` over all output columns j,
    with per-row-group input intervals (the LSTM stacks x rows over h rows).

    Uses the *actual* integer weight/bias arrays — per column, each row
    contributes ``min/max(w * x.lo, w * x.hi)`` — computed in python-int
    (object dtype) so the bound itself can never wrap.
    """
    w = np.asarray(w_int, dtype=object)
    if w.ndim != 2:
        raise ValueError(f"mac_interval needs a 2-D weight, got {w.shape}")
    b = np.asarray(b_int, dtype=object).reshape(-1)
    lo_cols = np.zeros(w.shape[1], dtype=object)
    hi_cols = np.zeros(w.shape[1], dtype=object)
    for rows, iv in row_intervals:
        blk = w[rows]
        if blk.size == 0:
            continue
        a, b2 = blk * iv.lo, blk * iv.hi
        lo_cols = lo_cols + np.minimum(a, b2).sum(axis=0)
        hi_cols = hi_cols + np.maximum(a, b2).sum(axis=0)
    lo_cols, hi_cols = lo_cols + b, hi_cols + b
    return Interval(int(lo_cols.min()) if lo_cols.size else 0,
                    int(hi_cols.max()) if hi_cols.size else 0)


def checked_requant(ctx: AnalysisContext, node: Node, acc: Interval,
                    shift: int, out_fmt: FxpFormat, edge: Optional[str], *,
                    what: str) -> Interval:
    """EAI001/EAI002 checks + the sound post-requant interval for one
    accumulator feeding ``edge``. Records the pre-clip interval for the
    driver's EAI006 wordlength pass (``edge=None`` marks an internal
    accumulator: checked, but never a saturation candidate)."""
    if not acc.fits_int32():
        ctx.diag("EAI001", node.name,
                 f"{what} interval {acc} exceeds the int32 accumulator "
                 f"(|max| = {acc.magnitude} >= 2**31)", edge=edge)
        acc = acc.clip(FxpFormat(32, 0))    # keep propagating, soundly wide
    if abs(shift) > 31:
        ctx.diag("EAI002", node.name,
                 f"requant shift {shift} for {what} is outside the int32 "
                 "shifter range [-31, 31]", edge=edge)
        shift = max(-31, min(31, shift))
    pre = requant_interval(acc, shift)
    if shift < 0 and not pre.fits_int32():
        ctx.diag("EAI002", node.name,
                 f"widening requant shift {shift} for {what} overflows "
                 f"int32: {acc} << {-shift} = {pre}", edge=edge)
        pre = pre.clip(FxpFormat(32, 0))
    if edge is not None:
        ctx.saturation(edge, pre)
    return pre.clip(out_fmt)


def lut_interval(ctx: AnalysisContext, lut: ActLUTNode,
                 iv: Interval) -> Interval:
    """Output interval of a ROM lookup whose input codes lie in ``iv``:
    min/max of the *actual* table restricted to the reachable addresses
    (lookups clamp, so the full-table range is the sound fallback when the
    input interval escapes the address range)."""
    table = ctx.lut_table(lut)
    dom = Interval.full(lut.in_fmt)
    lo = max(iv.lo, dom.lo)
    hi = min(iv.hi, dom.hi)
    if lo > hi:                       # disjoint: lookups clamp to a rail
        sub = table
    else:
        sub = table[lo - lut.lo: hi - lut.lo + 1]
    return Interval(int(sub.min()), int(sub.max()))


def check_lut_domain(ctx: AnalysisContext, node: Node, lut: ActLUTNode,
                     iv: Interval, edge: Optional[str], *,
                     what: str) -> None:
    """EAI004: the pre-activation interval must lie inside the LUT's
    address range ``[in_fmt.lo, in_fmt.hi]``."""
    dom = Interval.full(lut.in_fmt)
    if not dom.covers(iv):
        ctx.diag("EAI004", node.name,
                 f"{what} interval {iv} is not covered by LUT "
                 f"{lut.name!r} address range {dom} ({lut.in_fmt})",
                 edge=edge)


def resolve_lut(graph: Graph, node: Node, name: str) -> ActLUTNode:
    """A node's LUT reference, mirroring the registry error convention:
    unknown names raise listing the act_lut nodes that ARE in the graph."""
    luts = graph.act_luts()
    try:
        return luts[name]
    except KeyError:
        raise ValueError(
            f"node {node.name!r} references act_lut {name!r} which is not "
            f"in graph {graph.name!r}; act_lut nodes present: "
            f"{sorted(luts)}") from None


# --------------------------------------------------------------------------- #
# The driver
# --------------------------------------------------------------------------- #


def _structural_error(graph: Graph, msg: str) -> ValueError:
    return ValueError(
        f"graph {graph.name!r} is malformed: {msg}; declared edges: "
        f"{sorted(graph.edges)}")


def analyze_graph(graph: Graph, *, hw: HWSpec = XC7S15,
                  clock_hz: Optional[float] = None) -> AnalysisReport:
    """Run the full static analysis over ``graph``; returns the report.

    Malformed graphs (unknown node kinds, undeclared or undriven edges)
    *raise* — listing what is registered/declared, mirroring the registry
    convention — because they are toolchain bugs, not design findings.
    Design findings (overflow, format skew, LUT domain, resources) come
    back as diagnostics.
    """
    from repro.rtl.oplib import get_template

    for name in graph.inputs:
        if name not in graph.edges:
            raise _structural_error(graph,
                                    f"input edge {name!r} is undeclared")
    for name in graph.outputs:
        if name not in graph.edges:
            raise _structural_error(graph,
                                    f"output edge {name!r} is undeclared")

    ctx = AnalysisContext(graph)
    intervals: Dict[str, Interval] = {
        e: Interval.full(graph.edges[e].fmt) for e in graph.inputs}
    producer: Dict[str, str] = {}

    for n in graph.nodes:
        tmpl = get_template(n.op)       # unknown kind raises, listing
        for ename, want in sorted(tmpl.wire_contract(n, graph).items()):
            if ename not in graph.edges:
                raise _structural_error(
                    graph, f"node {n.name!r} is wired to undeclared edge "
                           f"{ename!r}")
            have = graph.edges[ename].fmt
            if have != want:
                ctx.diag("EAI003", n.name,
                         f"edge {ename!r} carries {have} but the "
                         f"{n.op!r} port expects {want}", edge=ename)
        missing = [e for e in n.inputs if e not in graph.edges]
        if missing:
            raise _structural_error(
                graph, f"node {n.name!r} reads undeclared edge(s) "
                       f"{missing}")
        undriven = [e for e in n.inputs if e not in intervals]
        if undriven:
            raise _structural_error(
                graph, f"node {n.name!r} reads edge(s) {undriven} driven "
                       "by no earlier node (driven so far: "
                       f"{sorted(intervals)})")
        undeclared_out = [e for e in n.outputs if e not in graph.edges]
        if undeclared_out:
            raise _structural_error(
                graph, f"node {n.name!r} drives undeclared edge(s) "
                       f"{undeclared_out}")
        in_iv = {e: intervals[e] for e in n.inputs}
        out_iv = tmpl.transfer(n, in_iv, graph=graph, ctx=ctx)
        for ename, iv in out_iv.items():
            intervals[ename] = iv
            producer[ename] = n.name

    # EAI006 — wordlength sufficiency at the design's readout edges: the
    # pre-saturation interval must fit the declared format, or rail inputs
    # will clip at the output (legal, bit-exact — but almost never meant).
    for ename in graph.outputs:
        pre = ctx.pre_clip.get(ename)
        fmt = graph.edges[ename].fmt
        if pre is not None and not Interval.full(fmt).covers(pre):
            ctx.diag("EAI006", producer.get(ename, graph.name),
                     f"output edge {ename!r} ({fmt}) saturates: worst-case "
                     f"pre-clip interval {pre} exceeds [{fmt.lo}, {fmt.hi}]",
                     edge=ename)

    # EAI005 / EAI007 — static resource & cycle feasibility vs the HWSpec.
    rr = estimate(graph, clock_hz=clock_hz or hw.clock_hz or 100e6)
    util = rr.utilization()
    demand = {"dsp": rr.dsp, "bram36": rr.bram36, "lut": rr.lut}
    for res in sorted(util):
        u = util[res]
        budget = int(round(demand[res] / u)) if u else 0
        if u > 1.0:
            ctx.diag("EAI005", graph.name,
                     f"{res} demand {demand[res]} exceeds the {hw.name} "
                     f"budget {budget} ({u:.0%})")
        elif u > PRESSURE_THRESHOLD:
            ctx.diag("EAI007", graph.name,
                     f"{res} demand {demand[res]} uses {u:.0%} of the "
                     f"{hw.name} budget {budget}")

    resources = {"dsp": rr.dsp, "bram36": rr.bram36, "lut": rr.lut,
                 "cycles": rr.cycles, "latency_s": rr.latency_s,
                 "fits": rr.fits(),
                 **{f"util_{k}": round(v, 4) for k, v in util.items()}}
    return AnalysisReport(
        design=graph.name, hw=hw.name, diagnostics=ctx.diagnostics,
        intervals={k: (iv.lo, iv.hi) for k, iv in intervals.items()},
        resources=resources)


def worst_case_mac_bound(fan_in: int, w_fmt: FxpFormat,
                         in_fmt: FxpFormat, b_magnitude: int = 0) -> int:
    """The format-only (weight-free) accumulator bound
    ``fan_in * max|w_int| * max|x_int| + |b_int|`` — what the analysis
    falls back to when a third-party template carries no weight arrays."""
    w_mag = max(abs(w_fmt.lo), w_fmt.hi)
    x_mag = max(abs(in_fmt.lo), in_fmt.hi)
    return fan_in * w_mag * x_mag + abs(b_magnitude)


__all__ = [
    "AnalysisContext", "AnalysisError", "Interval", "analyze_graph",
    "check_lut_domain", "checked_requant", "lut_interval", "mac_interval",
    "requant_interval", "resolve_lut", "worst_case_mac_bound",
]
