"""Template instantiation: IR graph -> named text artifacts.

``emit_graph`` walks the IR and asks each node's registered
:class:`~repro.rtl.oplib.HWTemplate` to render its entity plus the ``.mem``
initialization files (weights/biases/LUT tables as two's-complement hex,
straight from ``fxp_to_int``), then wires the instances into a top-level
``<design>.vhd`` — the "press the button" output of
``Creator.translate(st, target="rtl")``. A ``manifest.json`` records every
edge's Q-format so the emulator, the Elastic Node loader, and the artifacts
stay mutually consistent.

There is no per-op branching here (DESIGN.md §9): the walk is pure registry
dispatch, so a newly registered template emits without touching this module.
"""
from __future__ import annotations

import json
from typing import Dict

from repro.rtl import templates as T
from repro.rtl.ir import Graph
from repro.rtl.oplib import get_template
from repro.rtl.resources import node_cost


def _emit_top(graph: Graph, out: Dict[str, str]) -> None:
    """Wire the instances: combinational templates (LUT applications) tap
    their shared entity directly; sequential ones chain enable -> done."""
    compute = [(n, t) for n, t in ((n, get_template(n.op))
                                   for n in graph.nodes) if t.in_netlist]
    signals = [f"  signal {e.name} : std_logic_vector({e.bits}-1 downto 0);"
               for e in graph.edges.values()
               if e.name not in graph.inputs and e.name not in graph.outputs]
    instances = []
    seq_nodes = [n for n, t in compute if t.sequential]
    last_seq = seq_nodes[-1] if seq_nodes else None
    prev_done = "enable"
    for n, t in compute:
        if not t.sequential:                  # combinational: no handshake
            instances.append(t.instance(graph, n, enable="", done=""))
            continue
        done = "done" if n is last_seq else f"done_{n.name}"
        if done != "done":
            signals.append(f"  signal {done} : std_logic;")
        instances.append(t.instance(graph, n, enable=prev_done, done=done))
        prev_done = done
    x_e = graph.edges[graph.inputs[0]]
    y_e = graph.edges[graph.outputs[0]]
    out[f"{graph.name}.vhd"] = T.NETWORK.substitute(
        header=T.header(graph.name, graph.name), name=graph.name,
        x_width=x_e.bits, y_width=y_e.bits,
        signals="\n".join(signals), instances="".join(instances))


def _manifest(graph: Graph) -> str:
    per_node = {c.name: {"op": c.op, "cycles": c.cycles, "dsp": c.dsp,
                         "bram36": c.bram36, "lut": c.lut}
                for c in map(node_cost, graph.nodes)}
    return json.dumps({
        "design": graph.name,
        "inputs": graph.inputs, "outputs": graph.outputs,
        "edges": {e.name: {"shape": list(e.shape), "fmt": str(e.fmt)}
                  for e in graph.edges.values()},
        "nodes": per_node,
        "total_macs": graph.total_macs(),
    }, indent=2)


def emit_graph(graph: Graph) -> Dict[str, str]:
    """Render every node through its template; returns {filename: text}."""
    out: Dict[str, str] = {}
    for n in graph.nodes:
        get_template(n.op).emit(graph, n, out)
    _emit_top(graph, out)
    out["manifest.json"] = _manifest(graph)
    return out


def write_artifacts(artifacts: Dict[str, str], build_dir: str) -> None:
    import os

    os.makedirs(build_dir, exist_ok=True)
    for name, text in artifacts.items():
        with open(os.path.join(build_dir, name), "w") as f:
            f.write(text)
