"""Template instantiation: IR graph -> named text artifacts.

``emit_graph`` walks the IR and renders one entity per node plus the
``.mem`` initialization files (weights/biases/LUT tables as two's-complement
hex, straight from ``fxp_to_int``) and a top-level ``<design>.vhd`` that
wires the instances together — the "press the button" output of
``Creator.translate(st, target="rtl")``. A ``manifest.json`` records every
edge's Q-format so the emulator, the Elastic Node loader, and the artifacts
stay mutually consistent.
"""
from __future__ import annotations

import json
from typing import Dict

from repro.rtl import templates as T
from repro.rtl.ir import (ActApplyNode, ActLUTNode, ElementwiseNode, Graph,
                          LinearNode, LSTMCellNode)
from repro.rtl.resources import LINEAR_DSP, LSTM_DSP, node_cost


def _header(graph: Graph, node_name: str) -> str:
    return T.HEADER.substitute(name=node_name, design=graph.name,
                               node=node_name)


def _emit_linear(graph: Graph, n: LinearNode, out: Dict[str, str]) -> None:
    w_mem, b_mem = f"{n.name}_w.mem", f"{n.name}_b.mem"
    out[w_mem] = T.to_hex_lines(n.weight_int(), n.w_fmt.total_bits)
    out[b_mem] = T.to_hex_lines(n.bias_int(), 32)
    in_fmt, out_fmt = n.in_fmt, n.out_fmt
    shift = in_fmt.frac_bits + n.w_fmt.frac_bits - out_fmt.frac_bits
    out[f"{n.name}.vhd"] = T.LINEAR.substitute(
        header=_header(graph, n.name), name=n.name,
        in_features=n.weight.shape[0], out_features=n.weight.shape[1],
        x_generic=T.fmt_generic("X", in_fmt),
        w_generic=T.fmt_generic("W", n.w_fmt),
        y_generic=T.fmt_generic("Y", out_fmt),
        x_width=n.weight.shape[0] * in_fmt.total_bits,
        y_width=n.weight.shape[1] * out_fmt.total_bits,
        macs=n.macs(), n_dsp=LINEAR_DSP, w_mem=w_mem, b_mem=b_mem,
        rom_depth=int(n.weight.size), w_bits=n.w_fmt.total_bits,
        requant_shift=shift)


def _emit_lstm(graph: Graph, n: LSTMCellNode, out: Dict[str, str]) -> None:
    w_mem, b_mem = f"{n.name}_w.mem", f"{n.name}_b.mem"
    out[w_mem] = T.to_hex_lines(n.weight_int(), n.w_fmt.total_bits)
    out[b_mem] = T.to_hex_lines(n.bias_int(), 32)
    out[f"{n.name}.vhd"] = T.LSTM_CELL.substitute(
        header=_header(graph, n.name), name=n.name,
        d_in=n.d_in, hidden=n.hidden, seq_len=n.seq_len,
        x_generic=T.fmt_generic("X", n.act_fmt),
        w_generic=T.fmt_generic("W", n.w_fmt),
        c_generic=T.fmt_generic("C", n.state_fmt),
        x_width=n.d_in * n.act_fmt.total_bits,
        h_width=n.hidden * n.act_fmt.total_bits,
        macs=n.macs(), n_dsp=LSTM_DSP, w_mem=w_mem, b_mem=b_mem,
        sigmoid_lut=n.sigmoid_lut, tanh_lut=n.tanh_lut,
        act_bits=n.act_fmt.total_bits)


def _emit_lut(graph: Graph, n: ActLUTNode, out: Dict[str, str]) -> None:
    mem = f"{n.name}.mem"
    out[mem] = T.to_hex_lines(n.table(), n.out_fmt.total_bits)
    out[f"{n.name}.vhd"] = T.ACT_LUT.substitute(
        header=_header(graph, n.name), name=n.name, kind=n.kind,
        in_bits=n.in_fmt.total_bits, out_bits=n.out_fmt.total_bits,
        depth=n.depth, mem=mem, offset=-n.in_fmt.lo)


def _emit_elementwise(graph: Graph, n: ElementwiseNode,
                      out: Dict[str, str]) -> None:
    out[f"{n.name}.vhd"] = T.ELEMENTWISE.substitute(
        header=_header(graph, n.name), name=n.name,
        a_generic=T.fmt_generic("A", n.a_fmt),
        b_generic=T.fmt_generic("B", n.b_fmt),
        y_generic=T.fmt_generic("Y", n.out_fmt),
        a_width=graph.edges[n.inputs[0]].bits,
        b_width=graph.edges[n.inputs[1]].bits,
        y_width=graph.edges[n.outputs[0]].bits,
        op_sym="*" if n.kind == "mul" else "+")


def _emit_top(graph: Graph, out: Dict[str, str]) -> None:
    """Wire the instances: combinational LUT applications tap the shared ROM
    entity (ports a/q); sequential nodes chain enable -> done."""
    compute = [n for n in graph.nodes
               if isinstance(n, (LinearNode, LSTMCellNode, ElementwiseNode,
                                 ActApplyNode))]
    signals = [f"  signal {e.name} : std_logic_vector({e.bits}-1 downto 0);"
               for e in graph.edges.values()
               if e.name not in graph.inputs and e.name not in graph.outputs]
    instances = []
    seq_nodes = [n for n in compute if not isinstance(n, ActApplyNode)]
    last_seq = seq_nodes[-1] if seq_nodes else None
    prev_done = "enable"
    for n in compute:
        wire_in, wire_out = n.inputs[0], n.outputs[0]
        if isinstance(n, ActApplyNode):       # combinational ROM lookup
            instances.append(T.LUT_INSTANCE.substitute(
                label=f"i_{n.name}", entity=n.lut,
                wire_in=wire_in, wire_out=wire_out))
            continue
        done = "done" if n is last_seq else f"done_{n.name}"
        if done != "done":
            signals.append(f"  signal {done} : std_logic;")
        if isinstance(n, ElementwiseNode):
            instances.append(T.EW_INSTANCE.substitute(
                label=f"i_{n.name}", entity=n.name, enable=prev_done,
                wire_a=n.inputs[0], wire_b=n.inputs[1],
                wire_out=wire_out, done=done))
        else:
            port_out = "h_out" if isinstance(n, LSTMCellNode) else "y"
            instances.append(T.INSTANCE.substitute(
                label=f"i_{n.name}", entity=n.name, enable=prev_done,
                port_in="x", wire_in=wire_in, port_out=port_out,
                wire_out=wire_out, done=done))
        prev_done = done
    x_e = graph.edges[graph.inputs[0]]
    y_e = graph.edges[graph.outputs[0]]
    out[f"{graph.name}.vhd"] = T.NETWORK.substitute(
        header=_header(graph, graph.name), name=graph.name,
        x_width=x_e.bits, y_width=y_e.bits,
        signals="\n".join(signals), instances="".join(instances))


def _manifest(graph: Graph) -> str:
    per_node = {c.name: {"op": c.op, "cycles": c.cycles, "dsp": c.dsp,
                         "bram36": c.bram36, "lut": c.lut}
                for c in map(node_cost, graph.nodes)}
    return json.dumps({
        "design": graph.name,
        "inputs": graph.inputs, "outputs": graph.outputs,
        "edges": {e.name: {"shape": list(e.shape), "fmt": str(e.fmt)}
                  for e in graph.edges.values()},
        "nodes": per_node,
        "total_macs": graph.total_macs(),
    }, indent=2)


def emit_graph(graph: Graph) -> Dict[str, str]:
    """Render every node; returns {filename: text}."""
    out: Dict[str, str] = {}
    for n in graph.nodes:
        if isinstance(n, LinearNode):
            _emit_linear(graph, n, out)
        elif isinstance(n, LSTMCellNode):
            _emit_lstm(graph, n, out)
        elif isinstance(n, ActLUTNode):
            _emit_lut(graph, n, out)
        elif isinstance(n, ElementwiseNode):
            _emit_elementwise(graph, n, out)
        # ActApplyNode is wiring-only: it instantiates the shared LUT entity
    _emit_top(graph, out)
    out["manifest.json"] = _manifest(graph)
    return out


def write_artifacts(artifacts: Dict[str, str], build_dir: str) -> None:
    import os

    os.makedirs(build_dir, exist_ok=True)
    for name, text in artifacts.items():
        with open(os.path.join(build_dir, name), "w") as f:
            f.write(text)
