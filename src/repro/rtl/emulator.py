"""Bit-exact integer emulator of the emitted RTL — the backend's verifier.

Every IR node's integer semantics (DESIGN.md §4) are implemented twice:

* :func:`reference_apply` — the float oracle, built *only* from
  ``fxp_quantize`` / the hard activations, i.e. the semantics the QAT stage
  trains against;
* :class:`RTLEmulator` — vectorized int32 arithmetic (what the DSP slices
  compute), with a Pallas kernel for the hot LSTM-cell MAC loop.

The contract is exact equality, integer for integer, not a tolerance:
``emulator.run(x)`` must satisfy ``y_int == round(reference_apply(x) * 2**f)``
for every sample. This holds by construction for the LUTs (tables are
generated from the float reference) and by the round-half-even shift
(``fxp_requant_int``) everywhere else, provided formats pass
``ir.validate_formats`` — the same envelope that keeps int32 from
overflowing keeps the f32 oracle exact.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import use_interpret
from repro.quant.fixedpoint import (FxpFormat, fxp_quantize, fxp_requant_int,
                                    fxp_to_int)
from repro.quant.qat import hard_sigmoid, hard_tanh
from repro.rtl.ir import (ActApplyNode, ActLUTNode, ElementwiseNode, Graph,
                          LinearNode, LSTMCellNode)

# --------------------------------------------------------------------------- #
# Pallas template: the gate MAC (int matmul + bias + requant + saturate)
# --------------------------------------------------------------------------- #


def _mac_kernel(xh_ref, w_ref, b_ref, o_ref, *, shift: int, lo: int, hi: int):
    acc = jax.lax.dot_general(
        xh_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc = acc + b_ref[...]
    # same requant primitive as the jnp path — one rounding implementation
    q = fxp_requant_int(acc, shift, FxpFormat(32, 0))
    o_ref[...] = jnp.clip(q, lo, hi)


@functools.partial(jax.jit, static_argnames=("shift", "lo", "hi",
                                             "interpret"))
def mac_int_pallas(xh: jax.Array, w: jax.Array, b: jax.Array, *,
                   shift: int, lo: int, hi: int,
                   interpret: bool = True) -> jax.Array:
    """(B, K) int32 @ (K, N) int32 + b, requantized: one template invocation."""
    from jax.experimental import pallas as pl

    B, _ = xh.shape
    N = w.shape[1]
    return pl.pallas_call(
        functools.partial(_mac_kernel, shift=shift, lo=lo, hi=hi),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        interpret=interpret,
    )(xh, w, b.reshape(1, -1))


def _mac_int_jnp(xh, w, b, *, shift, lo, hi):
    acc = jax.lax.dot_general(xh, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32) + b
    return jnp.clip(fxp_requant_int(acc, shift, FxpFormat(32, 0)), lo, hi)


# --------------------------------------------------------------------------- #
# Integer emulator
# --------------------------------------------------------------------------- #


@dataclass
class EmulationResult:
    outputs: jax.Array               # int codes of the design's output edge
    outputs_f: jax.Array             # dequantized
    trace: Dict[str, jax.Array]      # per-edge int codes


class RTLEmulator:
    """Runs the emitted design on integer inputs, batch-vectorized."""

    def __init__(self, graph: Graph, use_pallas: bool = True):
        self.graph = graph
        self.use_pallas = use_pallas
        self._interpret = use_interpret()
        self._luts = {n.name: jnp.asarray(n.table(), jnp.int32)
                      for n in graph.nodes if isinstance(n, ActLUTNode)}
        self._lut_nodes = {n.name: n for n in graph.nodes
                           if isinstance(n, ActLUTNode)}

    # -- primitive schedules -------------------------------------------------
    def _mac(self, xh, w, b, *, shift, fmt: FxpFormat):
        if self.use_pallas:
            return mac_int_pallas(xh, w, b, shift=shift, lo=fmt.lo,
                                  hi=fmt.hi, interpret=self._interpret)
        return _mac_int_jnp(xh, w, b, shift=shift, lo=fmt.lo, hi=fmt.hi)

    def _lookup(self, lut_name: str, codes: jax.Array) -> jax.Array:
        node = self._lut_nodes[lut_name]
        return jnp.take(self._luts[lut_name], codes - node.in_fmt.lo)

    def _linear(self, n: LinearNode, x_int: jax.Array) -> jax.Array:
        w = jnp.asarray(n.weight_int(), jnp.int32)
        b = jnp.asarray(n.bias_int(), jnp.int32)
        shift = n.in_fmt.frac_bits + n.w_fmt.frac_bits - n.out_fmt.frac_bits
        return self._mac(x_int.astype(jnp.int32), w, b, shift=shift,
                         fmt=n.out_fmt)

    def _lstm_cell(self, n: LSTMCellNode, x_int: jax.Array) -> jax.Array:
        B = x_int.shape[0]
        A, C = n.act_fmt, n.state_fmt
        af, wf, cf = A.frac_bits, n.w_fmt.frac_bits, C.frac_bits
        H = n.hidden
        w = jnp.asarray(n.weight_int(), jnp.int32)
        b = jnp.asarray(n.bias_int(), jnp.int32)
        h = jnp.zeros((B, H), jnp.int32)
        c = jnp.zeros((B, H), jnp.int32)
        outs = []
        for t in range(n.seq_len):
            xh = jnp.concatenate([x_int[:, t].astype(jnp.int32), h], axis=-1)
            z = self._mac(xh, w, b, shift=wf, fmt=A)       # acc -> act fmt
            i, f, g, o = jnp.split(z, 4, axis=-1)
            si = self._lookup(n.sigmoid_lut, i)
            sf = self._lookup(n.sigmoid_lut, f)
            so = self._lookup(n.sigmoid_lut, o)
            tg = self._lookup(n.tanh_lut, g)
            # align si*tg (scale 2·af) to sf*c (scale af+cf): << (cf - af)
            term = sf * c + jax.lax.shift_left(si * tg, cf - af)
            c = fxp_requant_int(term, af + cf, C)
            c_a = fxp_requant_int(c, cf, A)
            tc = self._lookup(n.tanh_lut, c_a)
            h = fxp_requant_int(so * tc, 2 * af, A)
            outs.append(h)
        return jnp.stack(outs, axis=1)                     # (B, S, H)

    def _elementwise(self, n: ElementwiseNode, a, b) -> jax.Array:
        fa, fb = n.a_fmt.frac_bits, n.b_fmt.frac_bits
        a = a.astype(jnp.int32)
        b = b.astype(jnp.int32)
        if n.kind == "mul":
            return fxp_requant_int(a * b, fa + fb, n.out_fmt)
        hi = max(fa, fb)
        a = jax.lax.shift_left(a, hi - fa)
        b = jax.lax.shift_left(b, hi - fb)
        return fxp_requant_int(a + b, hi, n.out_fmt)

    # -- graph walk ----------------------------------------------------------
    def run_int(self, x_int: jax.Array) -> EmulationResult:
        g = self.graph
        env: Dict[str, jax.Array] = {g.inputs[0]: jnp.asarray(x_int)}
        for n in g.nodes:
            if isinstance(n, ActLUTNode):
                continue
            src = env[n.inputs[0]]
            if isinstance(n, LSTMCellNode):
                # a stacked cell consumes the previous cell's full sequence
                src = env.get(n.inputs[0] + ".seq", src)
                seq = self._lstm_cell(n, src)
                env[n.outputs[0]] = seq[:, -1]
                env[n.outputs[0] + ".seq"] = seq
            elif isinstance(n, LinearNode):
                env[n.outputs[0]] = self._linear(n, src)
            elif isinstance(n, ActApplyNode):
                env[n.outputs[0]] = self._lookup(n.lut, src)
            elif isinstance(n, ElementwiseNode):
                env[n.outputs[0]] = self._elementwise(
                    n, src, env[n.inputs[1]])
        out_edge = g.edges[g.outputs[0]]
        y = env[g.outputs[0]]
        return EmulationResult(outputs=y,
                               outputs_f=y.astype(jnp.float32)
                               / out_edge.fmt.scale,
                               trace=env)

    def run(self, x: jax.Array) -> EmulationResult:
        in_fmt = self.graph.edges[self.graph.inputs[0]].fmt
        return self.run_int(
            jnp.asarray(fxp_to_int(x, in_fmt), jnp.int32))


# --------------------------------------------------------------------------- #
# Float oracle: identical semantics expressed with fxp_quantize only
# --------------------------------------------------------------------------- #


def _q(x, fmt: FxpFormat):
    return fxp_quantize(x, fmt)


def _ref_bias(b, in_fmt: FxpFormat, w_fmt: FxpFormat):
    return _q(b, FxpFormat(32, in_fmt.frac_bits + w_fmt.frac_bits))


def reference_apply(graph: Graph, x: jax.Array) -> jax.Array:
    """The fxp_quantize reference the emulator must match bit-for-bit."""
    env = {graph.inputs[0]:
           _q(x, graph.edges[graph.inputs[0]].fmt)}
    luts = {n.name: n for n in graph.nodes if isinstance(n, ActLUTNode)}

    def act(node: ActLUTNode, v):
        fn = hard_sigmoid if node.kind == "hard_sigmoid" else hard_tanh
        return _q(fn(_q(v, node.in_fmt)), node.out_fmt)

    for n in graph.nodes:
        if isinstance(n, ActLUTNode):
            continue
        src = env[n.inputs[0]]
        if isinstance(n, LinearNode):
            wq = _q(jnp.asarray(n.weight), n.w_fmt)
            bq = _ref_bias(jnp.asarray(n.bias), n.in_fmt, n.w_fmt)
            env[n.outputs[0]] = _q(src @ wq + bq, n.out_fmt)
        elif isinstance(n, LSTMCellNode):
            src = env.get(n.inputs[0] + ".seq", src)
            A, C = n.act_fmt, n.state_fmt
            sig, tanh = luts[n.sigmoid_lut], luts[n.tanh_lut]
            wq = _q(jnp.asarray(n.weight), n.w_fmt)
            bq = _ref_bias(jnp.asarray(n.bias), A, n.w_fmt)
            B = src.shape[0]
            h = jnp.zeros((B, n.hidden), jnp.float32)
            c = jnp.zeros((B, n.hidden), jnp.float32)
            outs = []
            for t in range(n.seq_len):
                z = _q(jnp.concatenate([src[:, t], h], axis=-1) @ wq + bq, A)
                i, f, g, o = jnp.split(z, 4, axis=-1)
                si, sf, so = act(sig, i), act(sig, f), act(sig, o)
                tg = act(tanh, g)
                c = _q(sf * c + si * tg, C)
                h = _q(so * act(tanh, _q(c, A)), A)
                outs.append(h)
            env[n.outputs[0]] = h
            env[n.outputs[0] + ".seq"] = jnp.stack(outs, axis=1)
        elif isinstance(n, ActApplyNode):
            env[n.outputs[0]] = act(luts[n.lut], src)
        elif isinstance(n, ElementwiseNode):
            a, b = src, env[n.inputs[1]]
            v = a * b if n.kind == "mul" else a + b
            env[n.outputs[0]] = _q(v, n.out_fmt)
    return env[graph.outputs[0]]


def assert_bit_exact(graph: Graph, x: jax.Array,
                     use_pallas: bool = True) -> None:
    """Raises AssertionError on the first integer mismatch (test helper)."""
    res = RTLEmulator(graph, use_pallas=use_pallas).run(x)
    ref = reference_apply(graph, x)
    fmt = graph.edges[graph.outputs[0]].fmt
    ref_int = np.asarray(jnp.round(ref * fmt.scale), np.int64)
    got = np.asarray(res.outputs, np.int64)
    if not np.array_equal(got, ref_int):
        bad = np.argwhere(got != ref_int)
        raise AssertionError(
            f"emulator != fxp reference at {len(bad)} positions; first "
            f"{bad[0].tolist()}: got {got[tuple(bad[0])]} "
            f"ref {ref_int[tuple(bad[0])]} (fmt {fmt})")
