"""Bit-exact integer emulator of the emitted RTL — the backend's verifier.

Every IR node's integer semantics (DESIGN.md §4) are implemented twice, on
the node's registered :class:`~repro.rtl.oplib.HWTemplate`:

* ``HWTemplate.reference`` — the float oracle, built *only* from
  ``fxp_quantize`` / the hard activations, i.e. the semantics the QAT stage
  trains against (driven here by :func:`reference_apply`);
* ``HWTemplate.execute`` — vectorized int32 arithmetic (what the DSP slices
  compute), with a fused Pallas kernel for the LSTM-cell window (driven
  here by :class:`RTLEmulator`).

The contract is exact equality, integer for integer, not a tolerance:
``emulator.run(x)`` must satisfy ``y_int == round(reference_apply(x) * 2**f)``
for every sample. This holds by construction for the LUTs (tables are
generated from the float reference) and by the round-half-even shift
(``fxp_requant_int``) everywhere else, provided formats pass
``ir.validate_formats`` — the same envelope that keeps int32 from
overflowing keeps the f32 oracle exact.

Execution model (DESIGN.md §7, §15): the emulator is a *staged executor*.
``__init__`` hoists every weight/bias/LUT conversion to a device constant
once (``HWTemplate.prepare``); the graph walk is traced into a single
``jax.jit``-compiled program per ``(iso_key, mode, input shape, dtype)``,
held in a small :class:`~repro.rtl.program_cache.ProgramLRU` — so repeated
verification/measurement calls never retrace and never re-upload. The
prepared *array* constants (weights, biases, ROM tables) are passed to the
compiled program as traced arguments, not closed over, so designs with
isomorphic graphs (:func:`repro.rtl.ir.iso_key` — same structure, shapes
and Q-formats, different trained values) share one program: hand several
emulators one shared ``ProgramLRU`` and only the first traces. Requant
shifts and kernel specs stay jit-static (they select code paths), which is
exactly why they are part of the isomorphism key. Three execution paths
share the bit-exactness contract:

* ``mode="fused"`` (default) — one :mod:`repro.kernels.lstm_cell_int`
  dispatch per cell per window (weights + both ROMs VMEM-resident);
* ``mode="pallas"`` — one :func:`~repro.rtl.oplib.mac_int_pallas` dispatch
  per timestep (the PR-1 schedule, kept as a cross-check);
* ``mode="jnp"`` — plain-jnp per-step reference.

The emulator itself is op-agnostic: it owns staging, the program cache and
batching, and exposes ``prepared``/``lookup``/``interpret`` as the execution
context templates run against. Per-op math lives in :mod:`repro.rtl.oplib`.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import use_interpret
from repro.obs import get_metrics, get_tracer
from repro.quant.fixedpoint import fxp_to_int
from repro.rtl.ir import Graph, iso_key
# mac primitives live in the op library now; re-exported for compatibility
from repro.rtl.oplib import (_mac_int_jnp, get_template,  # noqa: F401
                             mac_int, mac_int_pallas)
from repro.rtl.program_cache import ProgramLRU

# --------------------------------------------------------------------------- #
# Integer emulator
# --------------------------------------------------------------------------- #


@dataclass
class EmulationResult:
    outputs: jax.Array               # int codes of the design's output edge
    outputs_f: jax.Array             # dequantized
    trace: Dict[str, jax.Array]      # per-edge int codes


class _ExecCtx:
    """The execution context a *traced* graph walk hands the templates.

    Templates run against three attributes of their executor —
    ``prepared(name)``, ``lookup(lut, codes)`` and ``interpret`` — so a
    traced walk can substitute this lightweight view in which the array
    constants are the walk's traced ``params`` argument (per-node dicts of
    int32 operands) while jit-static values (kernel specs) come from the
    owning emulator's prepared store. Isomorphic designs have identical
    statics by construction (specs/shifts derive from shapes and formats,
    which the iso key pins), so a program traced through one emulator's
    context replays correctly for any emulator with the same key.
    """

    __slots__ = ("_params", "_static", "_lut_lo", "interpret")

    def __init__(self, em: "RTLEmulator", params: Dict[str, Dict]):
        self._params = params
        self._static = em._static
        self._lut_lo = {name: n.lo for name, n in em._lut_nodes.items()}
        self.interpret = em.interpret

    def prepared(self, name: str) -> Dict:
        merged = dict(self._static.get(name, ()))
        merged.update(self._params.get(name, ()))
        return merged

    def lookup(self, lut_name: str, codes: jax.Array) -> jax.Array:
        return jnp.take(self._params[lut_name]["table"],
                        codes - self._lut_lo[lut_name])


class RTLEmulator:
    """Runs the emitted design on integer inputs, batch-vectorized.

    A staged executor: all parameters live on device from construction, and
    each distinct ``(input shape, dtype)`` compiles exactly once into the
    program LRU (``trace_count`` observes this; see the retrace test).
    """

    MODES = ("fused", "pallas", "jnp")

    def __init__(self, graph: Graph, use_pallas: bool = True,
                 mode: str = None, max_programs: int = 8,
                 programs: Optional[ProgramLRU] = None):
        self.graph = graph
        self.use_pallas = use_pallas
        self.mode = mode if mode is not None else \
            ("fused" if use_pallas else "jnp")
        if self.mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, "
                             f"got {self.mode!r}")
        if max_programs < 1:
            raise ValueError(f"max_programs must be >= 1, got {max_programs}")
        self.interpret = use_interpret()
        self.iso_key = iso_key(graph)
        # ---- stage 0: hoist every host->device conversion, once ----------
        # each template declares its constants (weights, biases, ROM tables,
        # jit-static specs); ndarray values become device int32 residents
        # (the traced operands of the compiled walk), non-arrays stay
        # jit-static.
        self._lut_nodes = graph.act_luts()
        self._prep: Dict[str, Dict] = {}
        self._param_keys: Dict[str, tuple] = {}   # node -> its array fields
        self._static: Dict[str, Dict] = {}        # node -> jit-static fields
        for n in graph.nodes:
            raw = get_template(n.op).prepare(n, graph)
            self._prep[n.name] = {
                k: (jnp.asarray(v, jnp.int32)
                    if isinstance(v, np.ndarray) else v)
                for k, v in raw.items()}
            self._param_keys[n.name] = tuple(
                sorted(k for k, v in raw.items()
                       if isinstance(v, np.ndarray)))
            self._static[n.name] = {
                k: v for k, v in raw.items()
                if not isinstance(v, np.ndarray)}
        # ---- compiled-program cache ---------------------------------------
        # (iso_key, mode, interpret, shape, dtype) -> jitted graph walk.
        # Per-instance by default; pass a shared ProgramLRU to let
        # isomorphic emulators reuse each other's programs (DESIGN.md §15).
        self._programs = programs if programs is not None \
            else ProgramLRU(max_programs)
        self._max_programs = self._programs.max_programs
        self.trace_count = 0             # how many times the walk was traced
        # observability (DESIGN.md §11): cache behavior + dispatch counts
        # are plain int attrs (always on, ~free) mirrored into the process
        # metrics registry; per-dispatch spans only fire when a tracer is
        # enabled (one attribute check on the hot path).
        self.cache_hits = 0
        self.cache_misses = 0
        self.cache_evictions = 0
        self.dispatch_counts: Dict[str, int] = {}
        self.seu_flips = 0               # injected bit-flips (resilience)
        # pooled serving calls run_many from worker threads; the program
        # cache locks itself (ProgramLRU); this lock covers the remaining
        # shared mutable state — dispatch counts and the prepared memories.
        self._lock = threading.Lock()

    # -- execution context handed to the templates ---------------------------
    def prepared(self, name: str) -> Dict:
        """The hoisted device constants of node ``name``."""
        return self._prep[name]

    def lookup(self, lut_name: str, codes: jax.Array) -> jax.Array:
        """Shared-ROM gather: table is indexed by ``code - lo``."""
        return jnp.take(self._prep[lut_name]["table"],
                        codes - self._lut_nodes[lut_name].lo)

    def params(self) -> Dict[str, Dict[str, jax.Array]]:
        """The traced-operand pytree: per-node dicts of the prepared array
        constants (weights, biases, ROM tables), keyed by node name. This
        is what every compiled program takes as its second argument — and
        what :class:`~repro.rtl.multi.MultiDesignEmulator` stacks across
        isomorphic candidates."""
        with self._lock:
            return {name: {k: self._prep[name][k] for k in keys}
                    for name, keys in self._param_keys.items() if keys}

    # -- graph walk (traced once per shape, then replayed) -------------------
    def _execute(self, x_int: jax.Array, *, mode: str,
                 params: Optional[Dict[str, Dict]] = None
                 ) -> Dict[str, jax.Array]:
        g = self.graph
        em = self if params is None else _ExecCtx(self, params)
        env: Dict[str, jax.Array] = {g.inputs[0]: x_int}
        for n in g.nodes:
            get_template(n.op).execute(n, env, em, mode)
        return env

    def _cache_key(self, shape, dtype):
        # keyed on everything the traced program depends on besides the
        # array arguments: the design's isomorphism class, execution mode,
        # pallas interpret flag, and the input aval
        return (self.iso_key, self.mode, self.interpret,
                tuple(int(d) for d in shape), jnp.dtype(dtype).name)

    def _program(self, shape, dtype):
        """The compiled graph walk for one (shape, dtype), LRU-cached.

        Returns ``(program, cache_hit)`` and keeps the cache observable:
        ``cache_hits``/``cache_misses``/``cache_evictions`` on the instance
        plus the matching ``rtl.emulator.cache_*`` process counters. The
        program signature is ``prog(x_int, params)`` — array constants are
        traced arguments, so any emulator whose graph shares this
        emulator's iso key can replay the program with its own params.
        """
        mx = get_metrics()

        def build():
            def walk(x_int, params):
                self.trace_count += 1    # python side effect: trace-time
                return self._execute(x_int, mode=self.mode, params=params)

            return jax.jit(walk)

        prog, hit, evicted = self._programs.get_or_build(
            self._cache_key(shape, dtype), build)
        if hit:
            self.cache_hits += 1
            mx.counter("rtl.emulator.cache_hit").inc()
        else:
            self.cache_misses += 1
            mx.counter("rtl.emulator.cache_miss").inc()
            if evicted:
                self.cache_evictions += evicted
                mx.counter("rtl.emulator.cache_evict").inc(evicted)
        return prog, hit

    def has_program(self, shape, dtype) -> bool:
        """Whether the LRU already holds a compiled program for this
        input — the serving router's affinity probe
        (:mod:`repro.serving.router`). Read-only: does not touch LRU
        order, so probing every pool member is side-effect free. Keys
        include the design's iso key, so with a shared ProgramLRU a
        replica counts as warm for any isomorphic sibling's program."""
        return self._cache_key(shape, dtype) in self._programs

    def cache_stats(self) -> Dict[str, int]:
        """Program-cache behavior + per-mode dispatch counts, one dict."""
        with self._lock:
            return {"hits": self.cache_hits, "misses": self.cache_misses,
                    "evictions": self.cache_evictions,
                    "retraces": self.trace_count,
                    "dispatches": dict(self.dispatch_counts)}

    # -- SEU model (repro.resilience): the prepared device constants ARE
    # -- the design's BRAM/ROM memories; flipping one bit of one word
    # -- models a single-event upset in the flashed accelerator. ----------
    def memories(self) -> List[tuple]:
        """Addressable (node, key) pairs: every sized array constant a
        fault plan may target — weights, biases, LUT tables."""
        out = []
        for name in sorted(self._prep):
            for key in sorted(self._prep[name]):
                v = self._prep[name][key]
                if hasattr(v, "shape") and np.asarray(v).size > 0:
                    out.append((name, key))
        return out

    def flip_bit(self, node: str, key: str, word: int, bit: int) -> int:
        """Flip ``bit`` of flat ``word`` in memory ``node.key``; returns the
        corrupted word's new int32 value.

        The corrupted array flows into the very next dispatch (prepared
        memories are traced arguments of the compiled programs), but the
        compiled programs are still invalidated — the reflash semantics:
        a bitstream rewrite under a running design drops its loaded
        configuration, and with a shared ProgramLRU this also keeps any
        isomorphic sibling from replaying a program whose trace predates
        the fault plan. Silent by construction: no error is raised,
        subsequent outputs are simply wrong, and only a golden-vector
        canary can tell.
        """
        if not 0 <= bit <= 31:
            raise ValueError(f"bit must be in [0, 31], got {bit}")
        if node not in self._prep or key not in self._prep[node]:
            raise KeyError(f"no prepared memory {node!r}.{key!r}; see "
                           "memories()")
        flat = np.asarray(self._prep[node][key], np.int32).copy().reshape(-1)
        w = int(word) % flat.size
        # XOR through a uint32 view: flipping bit 31 of an int32 would
        # overflow in python-int arithmetic, the reinterpret-cast doesn't.
        u = flat.view(np.uint32)
        u[w] ^= np.uint32(1) << np.uint32(bit)
        shaped = flat.reshape(np.asarray(self._prep[node][key]).shape)
        with self._lock:
            self._prep[node][key] = jnp.asarray(shaped, jnp.int32)
            self._programs.clear()       # force re-trace on corrupted memory
            self.seu_flips += 1
        get_metrics().counter("rtl.emulator.seu_flips").inc()
        return int(flat[w])

    def _result(self, env: Dict[str, jax.Array]) -> EmulationResult:
        out_edge = self.graph.edges[self.graph.outputs[0]]
        y = env[self.graph.outputs[0]]
        return EmulationResult(outputs=y,
                               outputs_f=y.astype(jnp.float32)
                               / out_edge.fmt.scale,
                               trace=env)

    def _count_dispatch(self, mode: str) -> None:
        with self._lock:
            self.dispatch_counts[mode] = self.dispatch_counts.get(mode, 0) + 1
        get_metrics().counter(f"rtl.emulator.dispatch.{mode}").inc()

    def run_int(self, x_int: jax.Array) -> EmulationResult:
        x_int = jnp.asarray(x_int)
        prog = self._program(x_int.shape, x_int.dtype)
        params = self.params()
        self._count_dispatch(self.mode)
        trc = get_tracer()
        if trc.enabled:                      # hoisted guard: skip the attrs
            with trc.span("rtl.emulator.dispatch", mode=self.mode,
                          shape=str(tuple(x_int.shape)), cached=prog[1],
                          design=self.graph.name):
                env = prog[0](x_int, params)
        else:
            env = prog[0](x_int, params)
        return self._result(env)

    def run(self, x: jax.Array) -> EmulationResult:
        in_fmt = self.graph.edges[self.graph.inputs[0]].fmt
        return self.run_int(
            jnp.asarray(fxp_to_int(x, in_fmt), jnp.int32))

    # -- batched-throughput entry -------------------------------------------
    def run_many(self, xs: Union[jax.Array, Sequence[jax.Array]]
                 ) -> Union[EmulationResult, List[EmulationResult]]:
        """Many independent float windows in ONE compiled dispatch.

        A plain array is treated as an already-stacked batch (same as
        :meth:`run`). A list/tuple of ``(B_i, ...)`` windows is concatenated
        along batch, executed once, and split back into one
        :class:`EmulationResult` per input — rows are independent, so each
        result is bit-identical to running its window alone. Note distinct
        *total* batch sizes compile distinct programs (the LRU absorbs the
        usual handful of shapes).
        """
        if not isinstance(xs, (list, tuple)):
            return self.run(xs)
        xs = [jnp.asarray(x) for x in xs]
        sizes = [int(x.shape[0]) for x in xs]
        res = self.run(jnp.concatenate(xs, axis=0))
        out, off = [], 0
        for s in sizes:
            sl = slice(off, off + s)
            off += s
            out.append(EmulationResult(
                outputs=res.outputs[sl], outputs_f=res.outputs_f[sl],
                trace={k: v[sl] for k, v in res.trace.items()}))
        return out

    # -- legacy per-step schedule (the PR-1 dispatch pattern) ----------------
    def run_int_per_step(self, x_int: jax.Array) -> EmulationResult:
        """Un-jitted eager walk, one MAC dispatch per timestep per cell.

        This is the pre-fusion execution schedule, kept as the benchmark
        baseline and as an extra cross-check path (it still uses the hoisted
        device constants, so any speed difference is pure dispatch/trace
        overhead, not upload traffic).
        """
        mode = "jnp" if self.mode == "jnp" else "pallas"
        self._count_dispatch("per_step")
        with get_tracer().span("rtl.emulator.dispatch", mode="per_step",
                               design=self.graph.name):
            return self._result(self._execute(jnp.asarray(x_int), mode=mode))

    def run_per_step(self, x: jax.Array) -> EmulationResult:
        in_fmt = self.graph.edges[self.graph.inputs[0]].fmt
        return self.run_int_per_step(
            jnp.asarray(fxp_to_int(x, in_fmt), jnp.int32))


def outputs_by_mode(graph: Graph, x_int,
                    modes: Sequence[str] = RTLEmulator.MODES
                    ) -> Dict[str, np.ndarray]:
    """Run the same integer stimulus through each execution path.

    The conformance harness's raw material: one fresh emulator per mode (so
    no program cache can alias the paths), int32 outputs keyed by mode name.
    """
    return {m: np.asarray(RTLEmulator(graph, mode=m).run_int(x_int).outputs,
                          np.int64)
            for m in modes}


# --------------------------------------------------------------------------- #
# Float oracle: identical semantics expressed with fxp_quantize only
# --------------------------------------------------------------------------- #


def reference_apply(graph: Graph, x: jax.Array) -> jax.Array:
    """The fxp_quantize reference the emulator must match bit-for-bit.

    Registry-dispatched like the integer walk: every node's float semantics
    live on its template (``HWTemplate.reference``).
    """
    from repro.rtl.oplib import ref_q

    env = {graph.inputs[0]: ref_q(x, graph.edges[graph.inputs[0]].fmt)}
    luts = graph.act_luts()
    for n in graph.nodes:
        get_template(n.op).reference(n, env, luts)
    return env[graph.outputs[0]]


def assert_bit_exact(graph: Graph, x: jax.Array,
                     use_pallas: bool = True, mode: str = None) -> None:
    """Raises AssertionError on the first integer mismatch (test helper)."""
    res = RTLEmulator(graph, use_pallas=use_pallas, mode=mode).run(x)
    ref = reference_apply(graph, x)
    fmt = graph.edges[graph.outputs[0]].fmt
    ref_int = np.asarray(jnp.round(ref * fmt.scale), np.int64)
    got = np.asarray(res.outputs, np.int64)
    if not np.array_equal(got, ref_int):
        bad = np.argwhere(got != ref_int)
        raise AssertionError(
            f"emulator != fxp reference at {len(bad)} positions; first "
            f"{bad[0].tolist()}: got {got[tuple(bad[0])]} "
            f"ref {ref_int[tuple(bad[0])]} (fmt {fmt})")
