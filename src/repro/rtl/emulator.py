"""Bit-exact integer emulator of the emitted RTL — the backend's verifier.

Every IR node's integer semantics (DESIGN.md §4) are implemented twice, on
the node's registered :class:`~repro.rtl.oplib.HWTemplate`:

* ``HWTemplate.reference`` — the float oracle, built *only* from
  ``fxp_quantize`` / the hard activations, i.e. the semantics the QAT stage
  trains against (driven here by :func:`reference_apply`);
* ``HWTemplate.execute`` — vectorized int32 arithmetic (what the DSP slices
  compute), with a fused Pallas kernel for the LSTM-cell window (driven
  here by :class:`RTLEmulator`).

The contract is exact equality, integer for integer, not a tolerance:
``emulator.run(x)`` must satisfy ``y_int == round(reference_apply(x) * 2**f)``
for every sample. This holds by construction for the LUTs (tables are
generated from the float reference) and by the round-half-even shift
(``fxp_requant_int``) everywhere else, provided formats pass
``ir.validate_formats`` — the same envelope that keeps int32 from
overflowing keeps the f32 oracle exact.

Execution model (DESIGN.md §7): the emulator is a *staged executor*.
``__init__`` hoists every weight/bias/LUT conversion to a device constant
once (``HWTemplate.prepare``); the graph walk is traced into a single
``jax.jit``-compiled program per ``(input shape, dtype)``, held in a small
LRU — so repeated verification/measurement calls never retrace and never
re-upload. Three execution paths share the bit-exactness contract:

* ``mode="fused"`` (default) — one :mod:`repro.kernels.lstm_cell_int`
  dispatch per cell per window (weights + both ROMs VMEM-resident);
* ``mode="pallas"`` — one :func:`~repro.rtl.oplib.mac_int_pallas` dispatch
  per timestep (the PR-1 schedule, kept as a cross-check);
* ``mode="jnp"`` — plain-jnp per-step reference.

The emulator itself is op-agnostic: it owns staging, the program cache and
batching, and exposes ``prepared``/``lookup``/``interpret`` as the execution
context templates run against. Per-op math lives in :mod:`repro.rtl.oplib`.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import use_interpret
from repro.obs import get_metrics, get_tracer
from repro.quant.fixedpoint import fxp_to_int
from repro.rtl.ir import Graph
# mac primitives live in the op library now; re-exported for compatibility
from repro.rtl.oplib import (_mac_int_jnp, get_template,  # noqa: F401
                             mac_int, mac_int_pallas)

# --------------------------------------------------------------------------- #
# Integer emulator
# --------------------------------------------------------------------------- #


@dataclass
class EmulationResult:
    outputs: jax.Array               # int codes of the design's output edge
    outputs_f: jax.Array             # dequantized
    trace: Dict[str, jax.Array]      # per-edge int codes


class RTLEmulator:
    """Runs the emitted design on integer inputs, batch-vectorized.

    A staged executor: all parameters live on device from construction, and
    each distinct ``(input shape, dtype)`` compiles exactly once into the
    program LRU (``trace_count`` observes this; see the retrace test).
    """

    MODES = ("fused", "pallas", "jnp")

    def __init__(self, graph: Graph, use_pallas: bool = True,
                 mode: str = None, max_programs: int = 8):
        self.graph = graph
        self.use_pallas = use_pallas
        self.mode = mode if mode is not None else \
            ("fused" if use_pallas else "jnp")
        if self.mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, "
                             f"got {self.mode!r}")
        if max_programs < 1:
            raise ValueError(f"max_programs must be >= 1, got {max_programs}")
        self.interpret = use_interpret()
        # ---- stage 0: hoist every host->device conversion, once ----------
        # each template declares its constants (weights, biases, ROM tables,
        # jit-static specs); ndarray values become device int32 residents.
        self._lut_nodes = graph.act_luts()
        self._prep: Dict[str, Dict] = {}
        for n in graph.nodes:
            raw = get_template(n.op).prepare(n, graph)
            self._prep[n.name] = {
                k: (jnp.asarray(v, jnp.int32)
                    if isinstance(v, np.ndarray) else v)
                for k, v in raw.items()}
        # ---- compiled-program cache: (shape, dtype) -> jitted graph walk -
        self._programs: "OrderedDict" = OrderedDict()
        self._max_programs = max_programs
        self.trace_count = 0             # how many times the walk was traced
        # observability (DESIGN.md §11): cache behavior + dispatch counts
        # are plain int attrs (always on, ~free) mirrored into the process
        # metrics registry; per-dispatch spans only fire when a tracer is
        # enabled (one attribute check on the hot path).
        self.cache_hits = 0
        self.cache_misses = 0
        self.cache_evictions = 0
        self.dispatch_counts: Dict[str, int] = {}
        self.seu_flips = 0               # injected bit-flips (resilience)
        # pooled serving calls run_many from worker threads; the program
        # LRU pop/insert/evict and the dispatch-count dict are the only
        # shared mutable state on that path — one lock covers both.
        self._lock = threading.Lock()

    # -- execution context handed to the templates ---------------------------
    def prepared(self, name: str) -> Dict:
        """The hoisted device constants of node ``name``."""
        return self._prep[name]

    def lookup(self, lut_name: str, codes: jax.Array) -> jax.Array:
        """Shared-ROM gather: table is indexed by ``code - lo``."""
        return jnp.take(self._prep[lut_name]["table"],
                        codes - self._lut_nodes[lut_name].lo)

    # -- graph walk (traced once per shape, then replayed) -------------------
    def _execute(self, x_int: jax.Array, *, mode: str) -> Dict[str, jax.Array]:
        g = self.graph
        env: Dict[str, jax.Array] = {g.inputs[0]: x_int}
        for n in g.nodes:
            get_template(n.op).execute(n, env, self, mode)
        return env

    def _program(self, shape, dtype):
        """The compiled graph walk for one (shape, dtype), LRU-cached.

        Returns ``(program, cache_hit)`` and keeps the cache observable:
        ``cache_hits``/``cache_misses``/``cache_evictions`` on the instance
        plus the matching ``rtl.emulator.cache_*`` process counters.
        """
        key = (tuple(shape), jnp.dtype(dtype).name)
        mx = get_metrics()
        with self._lock:
            prog = self._programs.pop(key, None)
            hit = prog is not None
            if prog is None:
                self.cache_misses += 1
                mx.counter("rtl.emulator.cache_miss").inc()

                def walk(x_int):
                    self.trace_count += 1    # python side effect: trace-time
                    return self._execute(x_int, mode=self.mode)

                prog = jax.jit(walk)
                while len(self._programs) >= self._max_programs:
                    self._programs.popitem(last=False)
                    self.cache_evictions += 1
                    mx.counter("rtl.emulator.cache_evict").inc()
            else:
                self.cache_hits += 1
                mx.counter("rtl.emulator.cache_hit").inc()
            self._programs[key] = prog       # (re)insert most-recently-used
        return prog, hit

    def has_program(self, shape, dtype) -> bool:
        """Whether the LRU already holds a compiled program for this
        ``(shape, dtype)`` key — the serving router's affinity probe
        (:mod:`repro.serving.router`). Read-only: does not touch LRU
        order, so probing every pool member is side-effect free."""
        key = (tuple(int(d) for d in shape), jnp.dtype(dtype).name)
        with self._lock:
            return key in self._programs

    def cache_stats(self) -> Dict[str, int]:
        """Program-cache behavior + per-mode dispatch counts, one dict."""
        with self._lock:
            return {"hits": self.cache_hits, "misses": self.cache_misses,
                    "evictions": self.cache_evictions,
                    "retraces": self.trace_count,
                    "dispatches": dict(self.dispatch_counts)}

    # -- SEU model (repro.resilience): the prepared device constants ARE
    # -- the design's BRAM/ROM memories; flipping one bit of one word
    # -- models a single-event upset in the flashed accelerator. ----------
    def memories(self) -> List[tuple]:
        """Addressable (node, key) pairs: every sized array constant a
        fault plan may target — weights, biases, LUT tables."""
        out = []
        for name in sorted(self._prep):
            for key in sorted(self._prep[name]):
                v = self._prep[name][key]
                if hasattr(v, "shape") and np.asarray(v).size > 0:
                    out.append((name, key))
        return out

    def flip_bit(self, node: str, key: str, word: int, bit: int) -> int:
        """Flip ``bit`` of flat ``word`` in memory ``node.key``; returns the
        corrupted word's new int32 value.

        The compiled programs close over the prepared constants at trace
        time, so — exactly like reflashing a BRAM under a running design —
        the mutation only takes effect by invalidating every compiled
        program (the next dispatch re-traces against the corrupted memory).
        Silent by construction: no error is raised, subsequent outputs are
        simply wrong, and only a golden-vector canary can tell.
        """
        if not 0 <= bit <= 31:
            raise ValueError(f"bit must be in [0, 31], got {bit}")
        if node not in self._prep or key not in self._prep[node]:
            raise KeyError(f"no prepared memory {node!r}.{key!r}; see "
                           "memories()")
        flat = np.asarray(self._prep[node][key], np.int32).copy().reshape(-1)
        w = int(word) % flat.size
        # XOR through a uint32 view: flipping bit 31 of an int32 would
        # overflow in python-int arithmetic, the reinterpret-cast doesn't.
        u = flat.view(np.uint32)
        u[w] ^= np.uint32(1) << np.uint32(bit)
        shaped = flat.reshape(np.asarray(self._prep[node][key]).shape)
        with self._lock:
            self._prep[node][key] = jnp.asarray(shaped, jnp.int32)
            self._programs.clear()       # force re-trace on corrupted memory
            self.seu_flips += 1
        get_metrics().counter("rtl.emulator.seu_flips").inc()
        return int(flat[w])

    def _result(self, env: Dict[str, jax.Array]) -> EmulationResult:
        out_edge = self.graph.edges[self.graph.outputs[0]]
        y = env[self.graph.outputs[0]]
        return EmulationResult(outputs=y,
                               outputs_f=y.astype(jnp.float32)
                               / out_edge.fmt.scale,
                               trace=env)

    def _count_dispatch(self, mode: str) -> None:
        with self._lock:
            self.dispatch_counts[mode] = self.dispatch_counts.get(mode, 0) + 1
        get_metrics().counter(f"rtl.emulator.dispatch.{mode}").inc()

    def run_int(self, x_int: jax.Array) -> EmulationResult:
        x_int = jnp.asarray(x_int)
        prog = self._program(x_int.shape, x_int.dtype)
        self._count_dispatch(self.mode)
        trc = get_tracer()
        if trc.enabled:                      # hoisted guard: skip the attrs
            with trc.span("rtl.emulator.dispatch", mode=self.mode,
                          shape=str(tuple(x_int.shape)), cached=prog[1],
                          design=self.graph.name):
                env = prog[0](x_int)
        else:
            env = prog[0](x_int)
        return self._result(env)

    def run(self, x: jax.Array) -> EmulationResult:
        in_fmt = self.graph.edges[self.graph.inputs[0]].fmt
        return self.run_int(
            jnp.asarray(fxp_to_int(x, in_fmt), jnp.int32))

    # -- batched-throughput entry -------------------------------------------
    def run_many(self, xs: Union[jax.Array, Sequence[jax.Array]]
                 ) -> Union[EmulationResult, List[EmulationResult]]:
        """Many independent float windows in ONE compiled dispatch.

        A plain array is treated as an already-stacked batch (same as
        :meth:`run`). A list/tuple of ``(B_i, ...)`` windows is concatenated
        along batch, executed once, and split back into one
        :class:`EmulationResult` per input — rows are independent, so each
        result is bit-identical to running its window alone. Note distinct
        *total* batch sizes compile distinct programs (the LRU absorbs the
        usual handful of shapes).
        """
        if not isinstance(xs, (list, tuple)):
            return self.run(xs)
        xs = [jnp.asarray(x) for x in xs]
        sizes = [int(x.shape[0]) for x in xs]
        res = self.run(jnp.concatenate(xs, axis=0))
        out, off = [], 0
        for s in sizes:
            sl = slice(off, off + s)
            off += s
            out.append(EmulationResult(
                outputs=res.outputs[sl], outputs_f=res.outputs_f[sl],
                trace={k: v[sl] for k, v in res.trace.items()}))
        return out

    # -- legacy per-step schedule (the PR-1 dispatch pattern) ----------------
    def run_int_per_step(self, x_int: jax.Array) -> EmulationResult:
        """Un-jitted eager walk, one MAC dispatch per timestep per cell.

        This is the pre-fusion execution schedule, kept as the benchmark
        baseline and as an extra cross-check path (it still uses the hoisted
        device constants, so any speed difference is pure dispatch/trace
        overhead, not upload traffic).
        """
        mode = "jnp" if self.mode == "jnp" else "pallas"
        self._count_dispatch("per_step")
        with get_tracer().span("rtl.emulator.dispatch", mode="per_step",
                               design=self.graph.name):
            return self._result(self._execute(jnp.asarray(x_int), mode=mode))

    def run_per_step(self, x: jax.Array) -> EmulationResult:
        in_fmt = self.graph.edges[self.graph.inputs[0]].fmt
        return self.run_int_per_step(
            jnp.asarray(fxp_to_int(x, in_fmt), jnp.int32))


def outputs_by_mode(graph: Graph, x_int,
                    modes: Sequence[str] = RTLEmulator.MODES
                    ) -> Dict[str, np.ndarray]:
    """Run the same integer stimulus through each execution path.

    The conformance harness's raw material: one fresh emulator per mode (so
    no program cache can alias the paths), int32 outputs keyed by mode name.
    """
    return {m: np.asarray(RTLEmulator(graph, mode=m).run_int(x_int).outputs,
                          np.int64)
            for m in modes}


# --------------------------------------------------------------------------- #
# Float oracle: identical semantics expressed with fxp_quantize only
# --------------------------------------------------------------------------- #


def reference_apply(graph: Graph, x: jax.Array) -> jax.Array:
    """The fxp_quantize reference the emulator must match bit-for-bit.

    Registry-dispatched like the integer walk: every node's float semantics
    live on its template (``HWTemplate.reference``).
    """
    from repro.rtl.oplib import ref_q

    env = {graph.inputs[0]: ref_q(x, graph.edges[graph.inputs[0]].fmt)}
    luts = graph.act_luts()
    for n in graph.nodes:
        get_template(n.op).reference(n, env, luts)
    return env[graph.outputs[0]]


def assert_bit_exact(graph: Graph, x: jax.Array,
                     use_pallas: bool = True, mode: str = None) -> None:
    """Raises AssertionError on the first integer mismatch (test helper)."""
    res = RTLEmulator(graph, use_pallas=use_pallas, mode=mode).run(x)
    ref = reference_apply(graph, x)
    fmt = graph.edges[graph.outputs[0]].fmt
    ref_int = np.asarray(jnp.round(ref * fmt.scale), np.int64)
    got = np.asarray(res.outputs, np.int64)
    if not np.array_equal(got, ref_int):
        bad = np.argwhere(got != ref_int)
        raise AssertionError(
            f"emulator != fxp reference at {len(bad)} positions; first "
            f"{bad[0].tolist()}: got {got[tuple(bad[0])]} "
            f"ref {ref_int[tuple(bad[0])]} (fmt {fmt})")
