"""Bit-exact integer emulator of the emitted RTL — the backend's verifier.

Every IR node's integer semantics (DESIGN.md §4) are implemented twice:

* :func:`reference_apply` — the float oracle, built *only* from
  ``fxp_quantize`` / the hard activations, i.e. the semantics the QAT stage
  trains against;
* :class:`RTLEmulator` — vectorized int32 arithmetic (what the DSP slices
  compute), with a fused Pallas kernel for the LSTM-cell window.

The contract is exact equality, integer for integer, not a tolerance:
``emulator.run(x)`` must satisfy ``y_int == round(reference_apply(x) * 2**f)``
for every sample. This holds by construction for the LUTs (tables are
generated from the float reference) and by the round-half-even shift
(``fxp_requant_int``) everywhere else, provided formats pass
``ir.validate_formats`` — the same envelope that keeps int32 from
overflowing keeps the f32 oracle exact.

Execution model (DESIGN.md §7): the emulator is a *staged executor*.
``__init__`` hoists every weight/bias/LUT conversion to a device constant
once; the graph walk is traced into a single ``jax.jit``-compiled program
per ``(input shape, dtype)``, held in a small LRU — so repeated
verification/measurement calls never retrace and never re-upload. Three
execution paths share the bit-exactness contract:

* ``mode="fused"`` (default) — one :mod:`repro.kernels.lstm_cell_int`
  dispatch per cell per window (weights + both ROMs VMEM-resident);
* ``mode="pallas"`` — one :func:`mac_int_pallas` dispatch per timestep
  (the PR-1 schedule, kept as a cross-check);
* ``mode="jnp"`` — plain-jnp per-step reference.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import use_interpret
from repro.kernels.lstm_cell_int import CellSpec, lstm_window_int
from repro.quant.fixedpoint import (FxpFormat, fxp_quantize, fxp_requant_int,
                                    fxp_to_int)
from repro.quant.qat import hard_sigmoid, hard_tanh
from repro.rtl.ir import (ActApplyNode, ActLUTNode, ElementwiseNode, Graph,
                          LinearNode, LSTMCellNode)

# --------------------------------------------------------------------------- #
# Pallas template: the gate MAC (int matmul + bias + requant + saturate)
# --------------------------------------------------------------------------- #


def _mac_kernel(xh_ref, w_ref, b_ref, o_ref, *, shift: int, lo: int, hi: int):
    acc = jax.lax.dot_general(
        xh_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc = acc + b_ref[...]
    # same requant primitive as the jnp path — one rounding implementation
    q = fxp_requant_int(acc, shift, FxpFormat(32, 0))
    o_ref[...] = jnp.clip(q, lo, hi)


@functools.partial(jax.jit, static_argnames=("shift", "lo", "hi",
                                             "interpret"))
def mac_int_pallas(xh: jax.Array, w: jax.Array, b: jax.Array, *,
                   shift: int, lo: int, hi: int,
                   interpret: bool = True) -> jax.Array:
    """(B, K) int32 @ (K, N) int32 + b, requantized: one template invocation."""
    from jax.experimental import pallas as pl

    B, _ = xh.shape
    N = w.shape[1]
    return pl.pallas_call(
        functools.partial(_mac_kernel, shift=shift, lo=lo, hi=hi),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        interpret=interpret,
    )(xh, w, b.reshape(1, -1))


def _mac_int_jnp(xh, w, b, *, shift, lo, hi):
    acc = jax.lax.dot_general(xh, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32) + b
    return jnp.clip(fxp_requant_int(acc, shift, FxpFormat(32, 0)), lo, hi)


# --------------------------------------------------------------------------- #
# Integer emulator
# --------------------------------------------------------------------------- #


@dataclass
class EmulationResult:
    outputs: jax.Array               # int codes of the design's output edge
    outputs_f: jax.Array             # dequantized
    trace: Dict[str, jax.Array]      # per-edge int codes


class RTLEmulator:
    """Runs the emitted design on integer inputs, batch-vectorized.

    A staged executor: all parameters live on device from construction, and
    each distinct ``(input shape, dtype)`` compiles exactly once into the
    program LRU (``trace_count`` observes this; see the retrace test).
    """

    MODES = ("fused", "pallas", "jnp")

    def __init__(self, graph: Graph, use_pallas: bool = True,
                 mode: str = None, max_programs: int = 8):
        self.graph = graph
        self.use_pallas = use_pallas
        self.mode = mode if mode is not None else \
            ("fused" if use_pallas else "jnp")
        if self.mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, "
                             f"got {self.mode!r}")
        if max_programs < 1:
            raise ValueError(f"max_programs must be >= 1, got {max_programs}")
        self._interpret = use_interpret()
        # ---- stage 0: hoist every host->device conversion, once ----------
        self._lut_nodes = graph.act_luts()
        self._luts = {name: jnp.asarray(n.table(), jnp.int32)
                      for name, n in self._lut_nodes.items()}
        self._params = {
            n.name: (jnp.asarray(n.weight_int(), jnp.int32),
                     jnp.asarray(n.bias_int(), jnp.int32))
            for n in graph.nodes
            if isinstance(n, (LinearNode, LSTMCellNode))}
        self._specs = {
            n.name: CellSpec(
                seq_len=n.seq_len, d_in=n.d_in, hidden=n.hidden,
                act_fmt=n.act_fmt, state_fmt=n.state_fmt, w_fmt=n.w_fmt,
                sig_lo=self._lut_nodes[n.sigmoid_lut].lo,
                tanh_lo=self._lut_nodes[n.tanh_lut].lo)
            for n in graph.nodes if isinstance(n, LSTMCellNode)}
        # ---- compiled-program cache: (shape, dtype) -> jitted graph walk -
        self._programs: "OrderedDict" = OrderedDict()
        self._max_programs = max_programs
        self.trace_count = 0             # how many times the walk was traced

    # -- primitive schedules -------------------------------------------------
    def _mac(self, xh, w, b, *, shift, fmt: FxpFormat, mode: str):
        if mode == "jnp":
            return _mac_int_jnp(xh, w, b, shift=shift, lo=fmt.lo, hi=fmt.hi)
        return mac_int_pallas(xh, w, b, shift=shift, lo=fmt.lo,
                              hi=fmt.hi, interpret=self._interpret)

    def _lookup(self, lut_name: str, codes: jax.Array) -> jax.Array:
        node = self._lut_nodes[lut_name]
        return jnp.take(self._luts[lut_name], codes - node.lo)

    def _linear(self, n: LinearNode, x_int: jax.Array,
                mode: str) -> jax.Array:
        w, b = self._params[n.name]
        shift = n.in_fmt.frac_bits + n.w_fmt.frac_bits - n.out_fmt.frac_bits
        return self._mac(x_int.astype(jnp.int32), w, b, shift=shift,
                         fmt=n.out_fmt, mode=mode)

    def _lstm_cell(self, n: LSTMCellNode, x_int: jax.Array,
                   mode: str) -> jax.Array:
        w, b = self._params[n.name]
        if mode == "fused":
            return lstm_window_int(
                x_int.astype(jnp.int32), w, b,
                self._luts[n.sigmoid_lut], self._luts[n.tanh_lut],
                spec=self._specs[n.name])
        B = x_int.shape[0]
        A, C = n.act_fmt, n.state_fmt
        af, cf = A.frac_bits, C.frac_bits
        h = jnp.zeros((B, n.hidden), jnp.int32)
        c = jnp.zeros((B, n.hidden), jnp.int32)
        outs = []
        for t in range(n.seq_len):
            xh = jnp.concatenate([x_int[:, t].astype(jnp.int32), h], axis=-1)
            z = self._mac(xh, w, b, shift=n.mac_shift, fmt=A, mode=mode)
            i, f, g, o = jnp.split(z, 4, axis=-1)
            si = self._lookup(n.sigmoid_lut, i)
            sf = self._lookup(n.sigmoid_lut, f)
            so = self._lookup(n.sigmoid_lut, o)
            tg = self._lookup(n.tanh_lut, g)
            # align si*tg (scale 2·af) to sf*c (scale af+cf): << (cf - af)
            term = sf * c + jax.lax.shift_left(si * tg, n.state_align_shift)
            c = fxp_requant_int(term, af + cf, C)
            c_a = fxp_requant_int(c, cf, A)
            tc = self._lookup(n.tanh_lut, c_a)
            h = fxp_requant_int(so * tc, 2 * af, A)
            outs.append(h)
        return jnp.stack(outs, axis=1)                     # (B, S, H)

    def _elementwise(self, n: ElementwiseNode, a, b) -> jax.Array:
        fa, fb = n.a_fmt.frac_bits, n.b_fmt.frac_bits
        a = a.astype(jnp.int32)
        b = b.astype(jnp.int32)
        if n.kind == "mul":
            return fxp_requant_int(a * b, fa + fb, n.out_fmt)
        hi = max(fa, fb)
        a = jax.lax.shift_left(a, hi - fa)
        b = jax.lax.shift_left(b, hi - fb)
        return fxp_requant_int(a + b, hi, n.out_fmt)

    # -- graph walk (traced once per shape, then replayed) -------------------
    def _execute(self, x_int: jax.Array, *, mode: str) -> Dict[str, jax.Array]:
        g = self.graph
        env: Dict[str, jax.Array] = {g.inputs[0]: x_int}
        for n in g.nodes:
            if isinstance(n, ActLUTNode):
                continue
            src = env[n.inputs[0]]
            if isinstance(n, LSTMCellNode):
                # a stacked cell consumes the previous cell's full sequence
                src = env.get(n.inputs[0] + ".seq", src)
                seq = self._lstm_cell(n, src, mode)
                env[n.outputs[0]] = seq[:, -1]
                env[n.outputs[0] + ".seq"] = seq
            elif isinstance(n, LinearNode):
                env[n.outputs[0]] = self._linear(n, src, mode)
            elif isinstance(n, ActApplyNode):
                env[n.outputs[0]] = self._lookup(n.lut, src)
            elif isinstance(n, ElementwiseNode):
                env[n.outputs[0]] = self._elementwise(
                    n, src, env[n.inputs[1]])
        return env

    def _program(self, shape, dtype):
        """The compiled graph walk for one (shape, dtype), LRU-cached."""
        key = (tuple(shape), jnp.dtype(dtype).name)
        prog = self._programs.pop(key, None)
        if prog is None:
            def walk(x_int):
                self.trace_count += 1        # python side effect: trace-time
                return self._execute(x_int, mode=self.mode)

            prog = jax.jit(walk)
            while len(self._programs) >= self._max_programs:
                self._programs.popitem(last=False)
        self._programs[key] = prog           # (re)insert most-recently-used
        return prog

    def _result(self, env: Dict[str, jax.Array]) -> EmulationResult:
        out_edge = self.graph.edges[self.graph.outputs[0]]
        y = env[self.graph.outputs[0]]
        return EmulationResult(outputs=y,
                               outputs_f=y.astype(jnp.float32)
                               / out_edge.fmt.scale,
                               trace=env)

    def run_int(self, x_int: jax.Array) -> EmulationResult:
        x_int = jnp.asarray(x_int)
        env = self._program(x_int.shape, x_int.dtype)(x_int)
        return self._result(env)

    def run(self, x: jax.Array) -> EmulationResult:
        in_fmt = self.graph.edges[self.graph.inputs[0]].fmt
        return self.run_int(
            jnp.asarray(fxp_to_int(x, in_fmt), jnp.int32))

    # -- batched-throughput entry -------------------------------------------
    def run_many(self, xs: Union[jax.Array, Sequence[jax.Array]]
                 ) -> Union[EmulationResult, List[EmulationResult]]:
        """Many independent float windows in ONE compiled dispatch.

        A plain array is treated as an already-stacked batch (same as
        :meth:`run`). A list/tuple of ``(B_i, ...)`` windows is concatenated
        along batch, executed once, and split back into one
        :class:`EmulationResult` per input — rows are independent, so each
        result is bit-identical to running its window alone. Note distinct
        *total* batch sizes compile distinct programs (the LRU absorbs the
        usual handful of shapes).
        """
        if not isinstance(xs, (list, tuple)):
            return self.run(xs)
        xs = [jnp.asarray(x) for x in xs]
        sizes = [int(x.shape[0]) for x in xs]
        res = self.run(jnp.concatenate(xs, axis=0))
        out, off = [], 0
        for s in sizes:
            sl = slice(off, off + s)
            off += s
            out.append(EmulationResult(
                outputs=res.outputs[sl], outputs_f=res.outputs_f[sl],
                trace={k: v[sl] for k, v in res.trace.items()}))
        return out

    # -- legacy per-step schedule (the PR-1 dispatch pattern) ----------------
    def run_int_per_step(self, x_int: jax.Array) -> EmulationResult:
        """Un-jitted eager walk, one MAC dispatch per timestep per cell.

        This is the pre-fusion execution schedule, kept as the benchmark
        baseline and as an extra cross-check path (it still uses the hoisted
        device constants, so any speed difference is pure dispatch/trace
        overhead, not upload traffic).
        """
        mode = "jnp" if self.mode == "jnp" else "pallas"
        return self._result(self._execute(jnp.asarray(x_int), mode=mode))

    def run_per_step(self, x: jax.Array) -> EmulationResult:
        in_fmt = self.graph.edges[self.graph.inputs[0]].fmt
        return self.run_int_per_step(
            jnp.asarray(fxp_to_int(x, in_fmt), jnp.int32))


# --------------------------------------------------------------------------- #
# Float oracle: identical semantics expressed with fxp_quantize only
# --------------------------------------------------------------------------- #


def _q(x, fmt: FxpFormat):
    return fxp_quantize(x, fmt)


def _ref_bias(b, in_fmt: FxpFormat, w_fmt: FxpFormat):
    return _q(b, FxpFormat(32, in_fmt.frac_bits + w_fmt.frac_bits))


def reference_apply(graph: Graph, x: jax.Array) -> jax.Array:
    """The fxp_quantize reference the emulator must match bit-for-bit."""
    env = {graph.inputs[0]:
           _q(x, graph.edges[graph.inputs[0]].fmt)}
    luts = {n.name: n for n in graph.nodes if isinstance(n, ActLUTNode)}

    def act(node: ActLUTNode, v):
        fn = hard_sigmoid if node.kind == "hard_sigmoid" else hard_tanh
        return _q(fn(_q(v, node.in_fmt)), node.out_fmt)

    for n in graph.nodes:
        if isinstance(n, ActLUTNode):
            continue
        src = env[n.inputs[0]]
        if isinstance(n, LinearNode):
            wq = _q(jnp.asarray(n.weight), n.w_fmt)
            bq = _ref_bias(jnp.asarray(n.bias), n.in_fmt, n.w_fmt)
            env[n.outputs[0]] = _q(src @ wq + bq, n.out_fmt)
        elif isinstance(n, LSTMCellNode):
            src = env.get(n.inputs[0] + ".seq", src)
            A, C = n.act_fmt, n.state_fmt
            sig, tanh = luts[n.sigmoid_lut], luts[n.tanh_lut]
            wq = _q(jnp.asarray(n.weight), n.w_fmt)
            bq = _ref_bias(jnp.asarray(n.bias), A, n.w_fmt)
            B = src.shape[0]
            h = jnp.zeros((B, n.hidden), jnp.float32)
            c = jnp.zeros((B, n.hidden), jnp.float32)
            outs = []
            for t in range(n.seq_len):
                z = _q(jnp.concatenate([src[:, t], h], axis=-1) @ wq + bq, A)
                i, f, g, o = jnp.split(z, 4, axis=-1)
                si, sf, so = act(sig, i), act(sig, f), act(sig, o)
                tg = act(tanh, g)
                c = _q(sf * c + si * tg, C)
                h = _q(so * act(tanh, _q(c, A)), A)
                outs.append(h)
            env[n.outputs[0]] = h
            env[n.outputs[0] + ".seq"] = jnp.stack(outs, axis=1)
        elif isinstance(n, ActApplyNode):
            env[n.outputs[0]] = act(luts[n.lut], src)
        elif isinstance(n, ElementwiseNode):
            a, b = src, env[n.inputs[1]]
            v = a * b if n.kind == "mul" else a + b
            env[n.outputs[0]] = _q(v, n.out_fmt)
    return env[graph.outputs[0]]


def assert_bit_exact(graph: Graph, x: jax.Array,
                     use_pallas: bool = True, mode: str = None) -> None:
    """Raises AssertionError on the first integer mismatch (test helper)."""
    res = RTLEmulator(graph, use_pallas=use_pallas, mode=mode).run(x)
    ref = reference_apply(graph, x)
    fmt = graph.edges[graph.outputs[0]].fmt
    ref_int = np.asarray(jnp.round(ref * fmt.scale), np.int64)
    got = np.asarray(res.outputs, np.int64)
    if not np.array_equal(got, ref_int):
        bad = np.argwhere(got != ref_int)
        raise AssertionError(
            f"emulator != fxp reference at {len(bad)} positions; first "
            f"{bad[0].tolist()}: got {got[tuple(bad[0])]} "
            f"ref {ref_int[tuple(bad[0])]} (fmt {fmt})")
