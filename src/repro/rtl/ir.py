"""Fixed-point dataflow IR — stage one of the RTL backend.

The ElasticAI-Creator lowers a trained, quantized model into a small graph of
hardware-template instances before emitting VHDL. This module is that
lowering: a :class:`Graph` of node kinds, one per registered hardware
template (:mod:`repro.rtl.oplib`):

    linear     — y = requant(x·W + b)            (BRAM weights, serial MACs)
    lstm_cell  — the paper's gate-fused LSTM template over one window
    conv1d     — depthwise/strided 1-D convolution (BRAM tap weights)
    act_lut    — ROM lookup for hard_sigmoid / hard_tanh
    elementwise— mul/add of two same-shape operands + requant

whose *edges* carry :class:`~repro.quant.fixedpoint.FxpFormat` annotations, so
every wire in the design has an exact Q-format. The integer semantics of each
node are defined once (DESIGN.md §4) and implemented twice: the float
``fxp_quantize`` reference and the int32 emulator in :mod:`repro.rtl.emulator`
must agree integer-for-integer. Both implementations live on the node's
:class:`~repro.rtl.oplib.HWTemplate` (DESIGN.md §9) — this module only owns
the node/edge datatypes and the model-level lowering entry points.

``lower_model`` dispatches on ``cfg.family`` through the template registry
(``lstm`` → the gate-fused cell stack, ``conv1d`` → the TCN-style depthwise
stack); ``lower_linear_stack`` / ``lower_conv_stack`` lower plain parameter
stacks directly.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import ModelConfig
from repro.quant.fixedpoint import FxpFormat, fxp_to_int

# f32 mantissa budget: the float reference is exact only while every
# intermediate integer-scaled value stays below 2**24 (DESIGN.md §4).
_F32_EXACT_BITS = 24

ACT_KINDS = ("hard_sigmoid", "hard_tanh")


@dataclass(frozen=True)
class Edge:
    """A typed wire: shape is per-sample (no batch dim), fmt its Q-format."""

    name: str
    shape: Tuple[int, ...]
    fmt: FxpFormat

    @property
    def bits(self) -> int:
        if any(d < 0 for d in self.shape):
            raise ValueError(f"edge {self.name!r} has negative dim(s) in "
                             f"shape {self.shape}")
        # math.prod: exact ints, and () / zero-element shapes stay degenerate
        # (1 resp. 0) instead of float-promoting through np.prod
        return math.prod(self.shape) * self.fmt.total_bits


@dataclass
class Node:
    name: str
    op: str              # a registered template kind (oplib.list_templates())
    inputs: List[str]
    outputs: List[str]

    def macs(self) -> int:
        return 0


def _require_array(node: Node, name: str, value, ndim: int) -> np.ndarray:
    """Array fields are mandatory at construction: a half-built node must
    fail here with a clear message, not deep inside emission/emulation."""
    if value is None:
        raise TypeError(
            f"{type(node).__name__} {node.name!r}: field {name!r} is "
            "required (got None) — pass the trained array when "
            "constructing the node")
    arr = np.asarray(value, np.float32)
    if arr.ndim != ndim:
        raise ValueError(
            f"{type(node).__name__} {node.name!r}: {name} must be "
            f"{ndim}-D, got shape {arr.shape}")
    return arr


@dataclass
class LinearNode(Node):
    """y = requant(x @ W + b): accum at scale a.frac+w.frac -> out_fmt.

    The input is flattened per sample before the MAC loop (a serial-MAC
    template reads its operand BRAM linearly), so an upstream node may
    legally produce a multi-axis edge — e.g. the (T, C) output of a conv1d
    stack feeding a dense head.
    """

    weight: np.ndarray               # (in, out) f32 — required
    bias: np.ndarray                 # (out,) f32 — required
    w_fmt: FxpFormat = FxpFormat(8, 6)
    in_fmt: FxpFormat = FxpFormat(8, 4)
    out_fmt: FxpFormat = FxpFormat(16, 8)

    def __post_init__(self):
        self.weight = _require_array(self, "weight", self.weight, 2)
        self.bias = _require_array(self, "bias", self.bias, 1)
        if self.bias.shape[0] != self.weight.shape[1]:
            raise ValueError(
                f"LinearNode {self.name!r}: bias shape {self.bias.shape} "
                "does not match weight out-features "
                f"{self.weight.shape[1]}")

    def macs(self) -> int:
        return int(self.weight.shape[0] * self.weight.shape[1])

    def weight_int(self) -> np.ndarray:
        return np.asarray(fxp_to_int(self.weight, self.w_fmt))

    def bias_int(self) -> np.ndarray:
        """Bias at the accumulator scale (wide two's-complement word)."""
        bfmt = FxpFormat(32, self.in_fmt.frac_bits + self.w_fmt.frac_bits)
        return np.asarray(fxp_to_int(self.bias, bfmt))


@dataclass
class LSTMCellNode(Node):
    """The gate-fused LSTM template over a full window (DESIGN.md §4).

    Weights are the fused (d_in+hidden, 4*hidden) gate matrix, gate order
    i, f, g, o. Activations (x, h) share ``act_fmt``; the cell state c is
    held at ``state_fmt``. Gate pre-activations are requantized to
    ``act_fmt`` before the sigmoid/tanh LUTs — narrow LUT inputs keep the
    ROMs at 2**act_bits words, the standard RTL trick.
    """

    weight: np.ndarray               # (d_in + hidden, 4*hidden) — required
    bias: np.ndarray                 # (4*hidden,) — required
    w_fmt: FxpFormat = FxpFormat(8, 6)
    act_fmt: FxpFormat = FxpFormat(8, 4)
    state_fmt: FxpFormat = FxpFormat(16, 8)
    seq_len: int = 6
    d_in: int = 1
    hidden: int = 20
    sigmoid_lut: str = ""            # name of the ActLUTNode serving σ
    tanh_lut: str = ""

    def __post_init__(self):
        self.weight = _require_array(self, "weight", self.weight, 2)
        self.bias = _require_array(self, "bias", self.bias, 1)
        want = (self.d_in + self.hidden, 4 * self.hidden)
        if tuple(self.weight.shape) != want:
            raise ValueError(
                f"LSTMCellNode {self.name!r}: weight shape "
                f"{tuple(self.weight.shape)} != {want} "
                f"(d_in={self.d_in}, hidden={self.hidden})")
        if self.bias.shape[0] != 4 * self.hidden:
            raise ValueError(
                f"LSTMCellNode {self.name!r}: bias shape "
                f"{self.bias.shape} != ({4 * self.hidden},)")

    def macs(self) -> int:
        per_step = (self.d_in + self.hidden) * 4 * self.hidden
        elementwise = 4 * self.hidden      # f*c, i*g, o*tanh(c), + state add
        return self.seq_len * (per_step + elementwise)

    def weight_int(self) -> np.ndarray:
        return np.asarray(fxp_to_int(self.weight, self.w_fmt))

    def bias_int(self) -> np.ndarray:
        bfmt = FxpFormat(32, self.act_fmt.frac_bits + self.w_fmt.frac_bits)
        return np.asarray(fxp_to_int(self.bias, bfmt))

    @property
    def mac_shift(self) -> int:
        """Right-shift taking the gate accumulator (scale A.f+W.f) to A."""
        return self.w_fmt.frac_bits

    @property
    def state_align_shift(self) -> int:
        """Left-shift aligning σi·tg (scale 2·A.f) to σf·c (A.f+C.f)."""
        return self.state_fmt.frac_bits - self.act_fmt.frac_bits


@dataclass
class Conv1dNode(Node):
    """Depthwise, strided 1-D convolution over a (seq, channels) window.

    The TCN-style sensor template (the paper's pervasive-computing setting):
    each channel carries its own ``kernel``-tap filter held in BRAM, the tap
    MACs time-multiplex the same serial DSP schedule as the linear template,
    and the accumulator is requantized exactly like a linear node —

        y[t, c] = requant( sum_k x[t*stride + k, c] · w[k, c] + b[c] )

    with the bias at the accumulator scale (in.frac + w.frac). Output length
    is ``(seq_len - kernel) // stride + 1``; fan-in per output is ``kernel``,
    which is what the §4 envelope check must cover.
    """

    weight: np.ndarray               # (kernel, channels) f32 — required
    bias: np.ndarray                 # (channels,) f32 — required
    kernel: int = 3
    stride: int = 1
    seq_len: int = 16
    channels: int = 1
    w_fmt: FxpFormat = FxpFormat(8, 6)
    in_fmt: FxpFormat = FxpFormat(8, 4)
    out_fmt: FxpFormat = FxpFormat(8, 4)

    def __post_init__(self):
        self.weight = _require_array(self, "weight", self.weight, 2)
        self.bias = _require_array(self, "bias", self.bias, 1)
        want = (self.kernel, self.channels)
        if tuple(self.weight.shape) != want:
            raise ValueError(
                f"Conv1dNode {self.name!r}: weight shape "
                f"{tuple(self.weight.shape)} != {want} "
                f"(kernel={self.kernel}, channels={self.channels})")
        if self.bias.shape[0] != self.channels:
            raise ValueError(
                f"Conv1dNode {self.name!r}: bias shape {self.bias.shape} "
                f"!= ({self.channels},)")
        if self.stride < 1 or self.kernel < 1:
            raise ValueError(
                f"Conv1dNode {self.name!r}: kernel/stride must be >= 1")
        if self.out_len < 1:
            raise ValueError(
                f"Conv1dNode {self.name!r}: window seq_len={self.seq_len} "
                f"too short for kernel={self.kernel} (out_len < 1)")

    @property
    def out_len(self) -> int:
        return (self.seq_len - self.kernel) // self.stride + 1

    def macs(self) -> int:
        return self.out_len * self.kernel * self.channels

    def weight_int(self) -> np.ndarray:
        return np.asarray(fxp_to_int(self.weight, self.w_fmt))

    def bias_int(self) -> np.ndarray:
        bfmt = FxpFormat(32, self.in_fmt.frac_bits + self.w_fmt.frac_bits)
        return np.asarray(fxp_to_int(self.bias, bfmt))


@dataclass
class ActLUTNode(Node):
    """ROM: out_int[i] = fxp_to_int(act(i / 2**in_frac), out_fmt).

    The table is generated from the float reference itself, so LUT lookup is
    bit-exact against ``fxp_quantize(act(x))`` *by construction* for every
    representable input code.
    """

    kind: str = "hard_sigmoid"
    in_fmt: FxpFormat = FxpFormat(8, 4)
    out_fmt: FxpFormat = FxpFormat(8, 4)

    def table(self) -> np.ndarray:
        """Indexed by (code - lo), i.e. offset-binary address order."""
        from repro.quant.qat import hard_sigmoid, hard_tanh

        codes = np.arange(self.in_fmt.lo, self.in_fmt.hi + 1, dtype=np.int32)
        x = codes.astype(np.float32) / self.in_fmt.scale
        fn = hard_sigmoid if self.kind == "hard_sigmoid" else hard_tanh
        return np.asarray(fxp_to_int(fn(x), self.out_fmt), dtype=np.int32)

    @property
    def depth(self) -> int:
        return 2 ** self.in_fmt.total_bits

    @property
    def lo(self) -> int:
        """Address offset: table is indexed by ``code - lo``."""
        return self.in_fmt.lo


@dataclass
class ActApplyNode(Node):
    """Applies a shared :class:`ActLUTNode`'s table to its input edge."""

    lut: str = ""


@dataclass
class ElementwiseNode(Node):
    """out = requant(a (mul|add) b); operand scales are aligned in-int."""

    kind: str = "mul"                # "mul" | "add"
    a_fmt: FxpFormat = FxpFormat(8, 4)
    b_fmt: FxpFormat = FxpFormat(8, 4)
    out_fmt: FxpFormat = FxpFormat(8, 4)

    def macs(self) -> int:
        return 1


@dataclass
class Graph:
    """Nodes in execution order; edges keyed by name."""

    name: str
    nodes: List[Node] = field(default_factory=list)
    edges: Dict[str, Edge] = field(default_factory=dict)
    inputs: List[str] = field(default_factory=list)
    outputs: List[str] = field(default_factory=list)

    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def act_luts(self) -> Dict[str, "ActLUTNode"]:
        """The shared ROM nodes, by name — the tables an executor preloads."""
        return {n.name: n for n in self.nodes if n.op == "act_lut"}

    def total_macs(self) -> int:
        return sum(n.macs() for n in self.nodes)

    def iso_key(self) -> str:
        """Program-isomorphism digest (see module-level :func:`iso_key`)."""
        return iso_key(self)

    def add(self, node: Node, *edges: Edge) -> Node:
        self.nodes.append(node)
        for e in edges:
            self.edges[e.name] = e
        return node


def iso_key(graph: Graph) -> str:
    """Program-isomorphism key: a stable digest of everything the staged
    executor's traced program depends on *except* the values inside the
    weight/bias arrays.

    Two graphs share a key iff the emulator would trace the identical
    program for them: same topology (node names, kinds, wiring), same
    edge shapes and Q-formats, and same template scalars — sequence
    lengths, kernel/stride, LUT kinds/depths/offsets, and every
    ``FxpFormat`` (formats determine the requant *shifts*, which stay
    jit-static; see DESIGN.md §15).  Array-valued fields contribute only
    their shape: perturbing trained weights never changes the key, which
    is what lets K design-space candidates share one compiled program
    (weights ride along as traced arguments).

    The digest is order-sensitive over ``graph.nodes`` — execution order
    is part of the program — and includes node names because the traced
    parameter pytree is keyed by them.
    """
    import hashlib
    from dataclasses import fields as dc_fields

    parts: List = []
    for n in graph.nodes:
        rec: List = [type(n).__name__, n.name, n.op,
                     tuple(n.inputs), tuple(n.outputs)]
        for f in dc_fields(n):
            if f.name in ("name", "op", "inputs", "outputs"):
                continue
            v = getattr(n, f.name)
            if isinstance(v, np.ndarray):
                rec.append((f.name, "array", tuple(v.shape)))
            elif isinstance(v, FxpFormat):
                rec.append((f.name, "fmt", v.total_bits, v.frac_bits))
            else:                        # ints, strs (LUT refs, kinds), ...
                rec.append((f.name, v))
        parts.append(tuple(rec))
    for name in sorted(graph.edges):
        e = graph.edges[name]
        parts.append((name, tuple(e.shape),
                      e.fmt.total_bits, e.fmt.frac_bits))
    parts.append(("io", tuple(graph.inputs), tuple(graph.outputs)))
    return hashlib.sha256(repr(parts).encode()).hexdigest()[:16]


def validate_formats(*, act: FxpFormat, weight: FxpFormat, state: FxpFormat,
                     fan_in: int) -> None:
    """Reject formats outside the exactness envelope (DESIGN.md §4).

    Two independent ceilings collapse to the same check: the int32 emulator
    must not overflow, and the f32 float reference must stay exact. Both hold
    while accumulated magnitudes stay below 2**24.
    """
    mac_bits = (act.total_bits - 1) + (weight.total_bits - 1) \
        + math.ceil(math.log2(max(fan_in, 1) + 1))
    ew_bits = (act.total_bits - 1) + (state.total_bits - 1) + 1
    worst = max(mac_bits, ew_bits)
    if worst > _F32_EXACT_BITS:
        raise ValueError(
            f"format combo act={act} weight={weight} state={state} "
            f"fan_in={fan_in} needs {worst} accumulator bits > "
            f"{_F32_EXACT_BITS}-bit exactness envelope")
    if state.frac_bits < act.frac_bits:
        raise ValueError(
            f"state fmt {state} must carry at least the activation "
            f"precision {act} (cell-state alignment is a left shift)")


def _kind_fmt(overrides: Optional[Mapping[str, FxpFormat]], kind: str,
              default: FxpFormat) -> FxpFormat:
    """Per-template-kind weight-format override (RTLOptions.w_fmt_overrides)."""
    if not overrides:
        return default
    return overrides.get(kind, default)


def _widest(*fmts: FxpFormat) -> FxpFormat:
    """Envelope input: the widest of the weight formats actually lowered
    (an override for a kind absent from this model must not widen it)."""
    return max(fmts, key=lambda f: f.total_bits)


# --------------------------------------------------------------------------- #
# Lowering entry points
# --------------------------------------------------------------------------- #


def lower_model(cfg: ModelConfig, params, *,
                w_fmt: FxpFormat = FxpFormat(8, 6),
                act_fmt: FxpFormat = FxpFormat(8, 4),
                state_fmt: FxpFormat = FxpFormat(16, 8),
                w_fmt_overrides: Optional[Mapping[str, FxpFormat]] = None
                ) -> Graph:
    """Lower a quantized ModelConfig + trained params into the dataflow IR.

    Dispatches on ``cfg.family`` through the hardware-template registry: the
    template that declares the family (``lstm`` → ``lstm_cell``, ``conv1d`` →
    ``conv1d``) owns the model-level lowering. Unknown families raise listing
    the families that ARE lowerable, mirroring the registry errors.
    """
    from repro.rtl.oplib import lowering_for

    return lowering_for(cfg.family)(
        cfg, params, w_fmt=w_fmt, act_fmt=act_fmt, state_fmt=state_fmt,
        w_fmt_overrides=w_fmt_overrides)


def lower_lstm_model(cfg: ModelConfig, params, *,
                     w_fmt: FxpFormat = FxpFormat(8, 6),
                     act_fmt: FxpFormat = FxpFormat(8, 4),
                     state_fmt: FxpFormat = FxpFormat(16, 8),
                     w_fmt_overrides: Optional[Mapping[str, FxpFormat]] = None
                     ) -> Graph:
    """The paper's ``elastic-lstm`` family: stacked gate-fused cells + head."""
    if cfg.family != "lstm":
        raise NotImplementedError(
            f"lower_lstm_model lowers family='lstm', got {cfg.family!r}")
    c = cfg.lstm
    cell_w = _kind_fmt(w_fmt_overrides, "lstm_cell", w_fmt)
    head_w = _kind_fmt(w_fmt_overrides, "linear", w_fmt)
    validate_formats(act=act_fmt, weight=_widest(cell_w, head_w),
                     state=state_fmt, fan_in=c.in_features + c.hidden)
    g = Graph(name=cfg.name)
    g.edges["x"] = Edge("x", (c.seq_len, c.in_features), act_fmt)
    g.inputs = ["x"]

    sig = ActLUTNode(name="hard_sigmoid_lut", op="act_lut", inputs=[],
                     outputs=[], kind="hard_sigmoid", in_fmt=act_fmt,
                     out_fmt=act_fmt)
    tanh = ActLUTNode(name="hard_tanh_lut", op="act_lut", inputs=[],
                      outputs=[], kind="hard_tanh", in_fmt=act_fmt,
                      out_fmt=act_fmt)
    g.nodes += [sig, tanh]

    prev = "x"
    for li, cell in enumerate(params["cells"]):
        d_in = c.in_features if li == 0 else c.hidden
        out_edge = Edge(f"h{li}", (c.hidden,), act_fmt)
        node = LSTMCellNode(
            name=f"lstm_cell_l{li}", op="lstm_cell", inputs=[prev],
            outputs=[out_edge.name],
            weight=np.asarray(cell["w"], np.float32),
            bias=np.asarray(cell["b"], np.float32),
            w_fmt=cell_w, act_fmt=act_fmt, state_fmt=state_fmt,
            seq_len=c.seq_len, d_in=d_in, hidden=c.hidden,
            sigmoid_lut=sig.name, tanh_lut=tanh.name)
        g.add(node, out_edge)
        prev = out_edge.name

    y_edge = Edge("y", (c.out_features,), state_fmt)
    g.add(LinearNode(name="linear_head", op="linear", inputs=[prev],
                     outputs=[y_edge.name],
                     weight=np.asarray(params["head_w"], np.float32),
                     bias=np.asarray(params["head_b"], np.float32),
                     w_fmt=head_w, in_fmt=act_fmt, out_fmt=state_fmt),
          y_edge)
    g.outputs = [y_edge.name]
    return g


def lower_linear_stack(name: str,
                       layers: Sequence[Tuple[np.ndarray, np.ndarray]],
                       *, w_fmt: FxpFormat = FxpFormat(8, 6),
                       act_fmt: FxpFormat = FxpFormat(8, 4),
                       accum_fmt: FxpFormat = FxpFormat(16, 8),
                       act: Optional[str] = "hard_sigmoid") -> Graph:
    """Lower a plain MLP — [(W, b), ...] with ``act`` between layers."""
    if act is not None and act not in ACT_KINDS:
        raise ValueError(f"act must be one of {ACT_KINDS} or None")
    fan_in = max(int(w.shape[0]) for w, _ in layers)
    validate_formats(act=act_fmt, weight=w_fmt, state=accum_fmt,
                     fan_in=fan_in)
    g = Graph(name=name)
    g.edges["x"] = Edge("x", (int(layers[0][0].shape[0]),), act_fmt)
    g.inputs = ["x"]
    lut = None
    if act is not None and len(layers) > 1:
        lut = ActLUTNode(name=f"{act}_lut", op="act_lut", inputs=[],
                         outputs=[], kind=act, in_fmt=act_fmt,
                         out_fmt=act_fmt)
        g.nodes.append(lut)
    prev = "x"
    for i, (w, b) in enumerate(layers):
        last = i == len(layers) - 1
        out_fmt = accum_fmt if last else act_fmt
        edge = Edge(f"a{i}" if not last else "y", (int(w.shape[1]),), out_fmt)
        g.add(LinearNode(name=f"linear_{i}", op="linear", inputs=[prev],
                         outputs=[edge.name],
                         weight=np.asarray(w, np.float32),
                         bias=np.asarray(b, np.float32),
                         w_fmt=w_fmt, in_fmt=act_fmt, out_fmt=out_fmt),
              edge)
        prev = edge.name
        if not last and lut is not None:
            edge2 = Edge(f"z{i}", (int(w.shape[1]),), act_fmt)
            g.add(ActApplyNode(name=f"{act}_{i}", op="act_apply",
                               inputs=[prev], outputs=[edge2.name],
                               lut=lut.name), edge2)
            prev = edge2.name
    g.outputs = [prev]
    return g


def lower_conv_stack(name: str,
                     blocks: Sequence[Tuple[np.ndarray, np.ndarray]],
                     head: Tuple[np.ndarray, np.ndarray],
                     *, seq_len: int,
                     stride: int = 1,
                     w_fmt: FxpFormat = FxpFormat(8, 6),
                     act_fmt: FxpFormat = FxpFormat(8, 4),
                     state_fmt: FxpFormat = FxpFormat(16, 8),
                     act: str = "hard_tanh",
                     w_fmt_overrides: Optional[Mapping[str, FxpFormat]] = None
                     ) -> Graph:
    """Lower a TCN-style depthwise conv stack + dense head.

    ``blocks`` is ``[(w (K, C), b (C,)), ...]`` applied with ``stride`` and
    ``act`` between blocks; ``head`` is the dense readout ``(W (T·C, out),
    b (out,))`` applied to the flattened final feature map. All conv
    activations stay at ``act_fmt`` (conv → LUT → conv chains keep the ROMs
    shared); the head accumulates into ``state_fmt`` like every other
    readout.
    """
    if act not in ACT_KINDS:
        raise ValueError(f"act must be one of {ACT_KINDS}")
    if not blocks:
        raise ValueError("lower_conv_stack needs at least one conv block")
    channels = int(np.asarray(blocks[0][0]).shape[1])
    conv_w = _kind_fmt(w_fmt_overrides, "conv1d", w_fmt)
    head_w_fmt = _kind_fmt(w_fmt_overrides, "linear", w_fmt)
    # envelope fan-in: every block accumulates its own kernel's tap count
    max_kernel = max(int(np.asarray(w).shape[0]) for w, _ in blocks)
    head_fan_in = int(np.asarray(head[0]).shape[0])
    validate_formats(act=act_fmt, weight=_widest(conv_w, head_w_fmt),
                     state=state_fmt, fan_in=max(max_kernel, head_fan_in))

    g = Graph(name=name)
    g.edges["x"] = Edge("x", (seq_len, channels), act_fmt)
    g.inputs = ["x"]
    lut = ActLUTNode(name=f"{act}_lut", op="act_lut", inputs=[], outputs=[],
                     kind=act, in_fmt=act_fmt, out_fmt=act_fmt)
    g.nodes.append(lut)

    prev, t = "x", seq_len
    for i, (w, b) in enumerate(blocks):
        node = Conv1dNode(
            name=f"conv1d_{i}", op="conv1d", inputs=[prev],
            outputs=[f"c{i}"],
            weight=np.asarray(w, np.float32), bias=np.asarray(b, np.float32),
            kernel=int(np.asarray(w).shape[0]), stride=stride, seq_len=t,
            channels=channels, w_fmt=conv_w, in_fmt=act_fmt,
            out_fmt=act_fmt)
        t = node.out_len
        g.add(node, Edge(f"c{i}", (t, channels), act_fmt))
        g.add(ActApplyNode(name=f"{act}_{i}", op="act_apply",
                           inputs=[f"c{i}"], outputs=[f"z{i}"],
                           lut=lut.name),
              Edge(f"z{i}", (t, channels), act_fmt))
        prev = f"z{i}"

    hw, hb = head
    if head_fan_in != t * channels:
        raise ValueError(
            f"head weight expects {head_fan_in} inputs but the conv stack "
            f"produces {t}x{channels}={t * channels} features")
    y_edge = Edge("y", (int(np.asarray(hw).shape[1]),), state_fmt)
    g.add(LinearNode(name="linear_head", op="linear", inputs=[prev],
                     outputs=[y_edge.name],
                     weight=np.asarray(hw, np.float32),
                     bias=np.asarray(hb, np.float32),
                     w_fmt=head_w_fmt, in_fmt=act_fmt, out_fmt=state_fmt),
          y_edge)
    g.outputs = [y_edge.name]
    return g


def lower_conv_model(cfg: ModelConfig, params, *,
                     w_fmt: FxpFormat = FxpFormat(8, 6),
                     act_fmt: FxpFormat = FxpFormat(8, 4),
                     state_fmt: FxpFormat = FxpFormat(16, 8),
                     w_fmt_overrides: Optional[Mapping[str, FxpFormat]] = None
                     ) -> Graph:
    """The ``conv1d`` family (TCN-style sensor workload) → conv stack IR."""
    if cfg.family != "conv1d":
        raise NotImplementedError(
            f"lower_conv_model lowers family='conv1d', got {cfg.family!r}")
    c = cfg.conv1d
    return lower_conv_stack(
        cfg.name,
        [(blk["w"], blk["b"]) for blk in params["blocks"]],
        (params["head_w"], params["head_b"]),
        seq_len=c.seq_len, stride=c.stride, w_fmt=w_fmt, act_fmt=act_fmt,
        state_fmt=state_fmt, act=c.act, w_fmt_overrides=w_fmt_overrides)
