"""Pluggable hardware-template (op) library — one registry entry per layer
kind, end-to-end (DESIGN.md §9).

The ElasticAI-Creator's core promise is a *library of hardware templates*
that a developer composes per model. This module is that library as a
first-class API, mirroring the deployment-target registry (DESIGN.md §8):
each :class:`HWTemplate` is one self-contained object owning the full
vertical for its op —

* **lower**   — the IR node class, plus (for templates that anchor a model
  family) the model-level lowering hook behind ``ir.lower_model``;
* **emit**    — the VHDL-like entity + ``.mem`` BRAM/ROM init files and the
  top-netlist instantiation line;
* **emulate** — the bit-exact int32 semantics (jitted jnp/Pallas execution
  paths) *and* the ``fxp_quantize`` float oracle (``reference_apply``);
* **cost**    — the XC7S15 resource/cycle formula (DESIGN.md §5).

``emit.emit_graph``, ``RTLEmulator``/``reference_apply`` and
``resources.node_cost`` are registry-dispatched walks: supporting a new
layer means registering one template here — no edits to the walkers.
Unknown kinds raise listing what IS registered, so the error doubles as
discovery; double registration is an error unless ``overwrite=True``.

The integer MAC primitives (the Pallas "DSP array" template shared by the
linear/conv/per-step-LSTM schedules) live here too, so templates and the
executor import them from one place.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lstm_cell_int import CellSpec, lstm_window_int
from repro.quant.fixedpoint import FxpFormat, fxp_quantize, fxp_requant_int
from repro.quant.qat import hard_sigmoid, hard_tanh
from repro.rtl import templates as T
from repro.rtl.analyze import (AnalysisContext, Interval, check_lut_domain,
                               checked_requant, lut_interval, mac_interval,
                               requant_interval, resolve_lut)
from repro.rtl.ir import (ActApplyNode, ActLUTNode, Conv1dNode, Edge,
                          ElementwiseNode, Graph, LinearNode, LSTMCellNode,
                          Node, lower_conv_model, lower_lstm_model)
from repro.rtl.resources import (CONV_DSP, LINEAR_DSP, LSTM_DSP,
                                 LUT_ROM_BITS, PIPE, NodeCost, brams_for)

# --------------------------------------------------------------------------- #
# Pallas template: the gate MAC (int matmul + bias + requant + saturate)
# --------------------------------------------------------------------------- #


def _mac_kernel(xh_ref, w_ref, b_ref, o_ref, *, shift: int, lo: int, hi: int):
    acc = jax.lax.dot_general(
        xh_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc = acc + b_ref[...]
    # same requant primitive as the jnp path — one rounding implementation
    q = fxp_requant_int(acc, shift, FxpFormat(32, 0))
    o_ref[...] = jnp.clip(q, lo, hi)


@functools.partial(jax.jit, static_argnames=("shift", "lo", "hi",
                                             "interpret"))
def mac_int_pallas(xh: jax.Array, w: jax.Array, b: jax.Array, *,
                   shift: int, lo: int, hi: int,
                   interpret: bool = True) -> jax.Array:
    """(B, K) int32 @ (K, N) int32 + b, requantized: one template invocation."""
    from jax.experimental import pallas as pl

    B, _ = xh.shape
    N = w.shape[1]
    return pl.pallas_call(
        functools.partial(_mac_kernel, shift=shift, lo=lo, hi=hi),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        interpret=interpret,
    )(xh, w, b.reshape(1, -1))


def _mac_int_jnp(xh, w, b, *, shift, lo, hi):
    acc = jax.lax.dot_general(xh, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32) + b
    return jnp.clip(fxp_requant_int(acc, shift, FxpFormat(32, 0)), lo, hi)


def mac_int(xh: jax.Array, w: jax.Array, b: jax.Array, *, shift: int,
            fmt: FxpFormat, mode: str, interpret: bool) -> jax.Array:
    """The shared serial-MAC schedule, on either execution substrate."""
    if mode == "jnp":
        return _mac_int_jnp(xh, w, b, shift=shift, lo=fmt.lo, hi=fmt.hi)
    return mac_int_pallas(xh, w, b, shift=shift, lo=fmt.lo, hi=fmt.hi,
                          interpret=interpret)


def requant_shift(in_fmt: FxpFormat, w_fmt: FxpFormat,
                  out_fmt: FxpFormat) -> int:
    """Right-shift taking a MAC accumulator (scale in.f + w.f) to out.f —
    the one requant convention every weighted template shares."""
    return in_fmt.frac_bits + w_fmt.frac_bits - out_fmt.frac_bits


# --------------------------------------------------------------------------- #
# Float-oracle helpers (identical semantics expressed with fxp_quantize only)
# --------------------------------------------------------------------------- #


def ref_q(x, fmt: FxpFormat):
    return fxp_quantize(x, fmt)


def ref_bias(b, in_fmt: FxpFormat, w_fmt: FxpFormat):
    return ref_q(b, FxpFormat(32, in_fmt.frac_bits + w_fmt.frac_bits))


def ref_act(lut: ActLUTNode, v):
    fn = hard_sigmoid if lut.kind == "hard_sigmoid" else hard_tanh
    return ref_q(fn(ref_q(v, lut.in_fmt)), lut.out_fmt)


# --------------------------------------------------------------------------- #
# The template contract
# --------------------------------------------------------------------------- #


class HWTemplate:
    """One hardware template: the full vertical for one IR node kind.

    Subclasses set ``kind`` (the ``Node.op`` string they serve) and
    ``node_cls``, and implement the five hooks. ``family`` is optional: a
    template that anchors a whole model family (the LSTM cell, the conv1d
    block) also provides ``lower_model_fn`` so ``ir.lower_model`` can
    dispatch on ``cfg.family``.

    Netlist flags: ``in_netlist`` — the node appears in the top-level
    netlist (shared ROM entities don't; they are instantiated where used);
    ``sequential`` — it takes a slot in the enable→done handshake chain
    (combinational LUT applications don't).
    """

    kind: str = ""
    node_cls: type = Node
    family: Optional[str] = None
    lower_model_fn: Optional[Callable[..., Graph]] = None
    in_netlist: bool = True
    sequential: bool = True
    #: the node carries a quantized weight array (targets of the per-kind
    #: ``RTLOptions.w_fmt_overrides`` knob)
    has_weights: bool = False
    #: top-netlist port names for the default single-in/single-out instance
    port_in: str = "x"
    port_out: str = "y"

    # ---- verify -----------------------------------------------------------
    def input_spec(self, node: Node, graph: Graph):
        """(per-sample shape, FxpFormat) of the edge driving this node —
        what a stimulus generator must produce. Default: the first input."""
        e = graph.edges[node.inputs[0]]
        return e.shape, e.fmt

    def sample_inputs(self, node: Node, graph: Graph, rng, *,
                      batch: int = 8) -> np.ndarray:
        """Deterministic float stimulus for property-based conformance
        fuzzing (``repro.verify``): the three corner rows (all-zero /
        rail-low / rail-high codes) followed by seeded uniform codes over
        the representable range, dequantized — so ``fxp_to_int`` recovers
        exactly the drawn codes and the run is reproducible from ``rng``'s
        seed. Templates with structured stimulus needs override this.
        """
        from repro.verify.vectors import corner_codes

        shape, fmt = self.input_spec(node, graph)
        corners = corner_codes(shape, fmt)[:batch]
        n_rand = batch - corners.shape[0]
        codes = corners
        if n_rand > 0:
            rand = rng.integers(fmt.lo, fmt.hi + 1, size=(n_rand, *shape),
                                dtype=np.int64).astype(np.int32)
            codes = np.concatenate([corners, rand], axis=0)
        return codes.astype(np.float32) / fmt.scale

    def probe_graph(self, rng) -> Optional[Graph]:
        """A minimal standalone design exercising just this template, with
        ``rng``-drawn constants — the unit the conformance harness fuzzes
        per registered kind. ``None`` means the template has no standalone
        compute (shared ROMs) and is covered through the kinds that use it.
        """
        return None

    def error_budget_lsb(self, node: Node) -> int:
        """Allowed |int − float-oracle| at this node's output, in output
        LSBs, for the conformance error budget (DESIGN.md §10). The
        built-in templates return 0: inside the §4 exactness envelope
        (``ir.validate_formats``) int32 arithmetic and the f32 oracle agree
        integer-for-integer, so any nonzero difference is a bug, not noise.
        A third-party template whose schedule reorders accumulation beyond
        the envelope declares its slack here instead of weakening the
        global contract."""
        return 0

    # ---- analyze (DESIGN.md §13) ------------------------------------------
    def wire_contract(self, node: Node,
                      graph: Graph) -> Dict[str, FxpFormat]:
        """Edge name -> the Q-format this template's ports assume on that
        wire. The static verifier compares each entry against the declared
        ``Edge.fmt`` and reports EAI003 on mismatch (Q-format continuity:
        producer out_fmt == consumer in_fmt on every wire). Default: no
        declared port formats, nothing to check."""
        return {}

    def transfer(self, node: Node, in_intervals: Dict[str, Interval], *,
                 graph: Graph, ctx: AnalysisContext) -> Dict[str, Interval]:
        """Abstract-interpretation hook: map input-edge intervals to
        output-edge intervals (integer codes), emitting diagnostics
        through ``ctx`` (:mod:`repro.rtl.analyze`). The default bound is
        sound for any template that saturates its outputs to the edge
        format — every output takes the full range of its edge's format.
        Templates whose outputs can escape their declared edge format
        must override."""
        return {e: Interval.full(graph.edges[e].fmt)
                for e in node.outputs}

    # ---- emulate ----------------------------------------------------------
    def prepare(self, node: Node, graph: Graph) -> Dict:
        """Host-side constants to hoist once at executor construction.

        np.ndarray values are converted to device int32 constants; anything
        else (e.g. a jit-static CellSpec) is stored as-is.
        """
        return {}

    def execute(self, node: Node, env: Dict, em, mode: str) -> None:
        """Int32 semantics: read input edges from ``env``, write outputs.

        ``em`` is the executing :class:`~repro.rtl.emulator.RTLEmulator`
        (``em.prepared(name)``, ``em.lookup(lut, codes)``,
        ``em.interpret``); ``mode`` is one of its execution paths.
        """
        raise NotImplementedError

    def reference(self, node: Node, env: Dict,
                  luts: Dict[str, ActLUTNode]) -> None:
        """Float-oracle semantics, built only from ``fxp_quantize``."""
        raise NotImplementedError

    # ---- emit -------------------------------------------------------------
    def emit(self, graph: Graph, node: Node, out: Dict[str, str]) -> None:
        """Render the entity text + ``.mem`` init files into ``out``."""
        raise NotImplementedError

    def instance(self, graph: Graph, node: Node, *, enable: str,
                 done: str) -> str:
        """The top-netlist instantiation line for this node."""
        return T.INSTANCE.substitute(
            label=f"i_{node.name}", entity=node.name, enable=enable,
            port_in=self.port_in, wire_in=node.inputs[0],
            port_out=self.port_out, wire_out=node.outputs[0], done=done)

    # ---- cost -------------------------------------------------------------
    def cost(self, node: Node) -> NodeCost:
        return NodeCost.zero(node.name, node.op)


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #

_REGISTRY: Dict[str, HWTemplate] = {}


def register_template(template: HWTemplate, *,
                      overwrite: bool = False) -> HWTemplate:
    """Register ``template`` under ``template.kind``. Registering a kind
    twice is an error unless ``overwrite=True`` (the escape hatch for a
    deployment that swaps a built-in for a tuned variant)."""
    kind = template.kind
    if not kind:
        raise ValueError(f"{type(template).__name__} has no kind set")
    if not overwrite and kind in _REGISTRY:
        raise ValueError(f"hardware template {kind!r} already registered "
                         f"(registered: {list_templates()})")
    _REGISTRY[kind] = template
    return template


def unregister_template(kind: str) -> None:
    """Remove a registered kind (primarily for tests swapping templates)."""
    _REGISTRY.pop(kind, None)


def list_templates() -> List[str]:
    """Names of every registered template kind, sorted."""
    return sorted(_REGISTRY)


def get_template(kind: str) -> HWTemplate:
    """Resolve a node kind. Unknown kinds raise ``ValueError`` listing what
    *is* registered, so the error message doubles as discovery."""
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise ValueError(
            f"unknown hardware template {kind!r}; registered templates: "
            f"{list_templates()}") from None


def lowerable_families() -> List[str]:
    """Model families some registered template can lower end-to-end."""
    return sorted({t.family for t in _REGISTRY.values() if t.family})


def lowering_for(family: str) -> Callable[..., Graph]:
    """The model-level lowering hook for ``family`` (``ir.lower_model``)."""
    for t in _REGISTRY.values():
        if t.family == family and t.lower_model_fn is not None:
            return t.lower_model_fn
    raise NotImplementedError(
        f"no registered hardware template lowers family {family!r}; "
        f"lowerable families: {lowerable_families()} "
        "(use lower_linear_stack/lower_conv_stack for parameter stacks)")


# --------------------------------------------------------------------------- #
# Built-in templates
# --------------------------------------------------------------------------- #


class LinearTemplate(HWTemplate):
    """y = requant(flatten(x) @ W + b) — serial MACs, BRAM weights."""

    kind = "linear"
    node_cls = LinearNode
    has_weights = True

    def wire_contract(self, n: LinearNode,
                      graph: Graph) -> Dict[str, FxpFormat]:
        return {n.inputs[0]: n.in_fmt, n.outputs[0]: n.out_fmt}

    def transfer(self, n: LinearNode, in_intervals: Dict[str, Interval], *,
                 graph: Graph, ctx: AnalysisContext) -> Dict[str, Interval]:
        acc = mac_interval(n.weight_int(), n.bias_int(),
                           [(slice(None), in_intervals[n.inputs[0]])])
        out = checked_requant(
            ctx, n, acc, requant_shift(n.in_fmt, n.w_fmt, n.out_fmt),
            n.out_fmt, n.outputs[0], what="x@W+b accumulator")
        return {n.outputs[0]: out}

    def prepare(self, n: LinearNode, graph: Graph) -> Dict:
        return {"w": n.weight_int(), "b": n.bias_int()}

    def execute(self, n: LinearNode, env: Dict, em, mode: str) -> None:
        x = env[n.inputs[0]].astype(jnp.int32)
        x = x.reshape(x.shape[0], -1)            # serial MACs read linearly
        p = em.prepared(n.name)
        shift = requant_shift(n.in_fmt, n.w_fmt, n.out_fmt)
        env[n.outputs[0]] = mac_int(x, p["w"], p["b"], shift=shift,
                                    fmt=n.out_fmt, mode=mode,
                                    interpret=em.interpret)

    def reference(self, n: LinearNode, env: Dict, luts: Dict) -> None:
        src = env[n.inputs[0]]
        src = src.reshape(src.shape[0], -1)
        wq = ref_q(jnp.asarray(n.weight), n.w_fmt)
        bq = ref_bias(jnp.asarray(n.bias), n.in_fmt, n.w_fmt)
        env[n.outputs[0]] = ref_q(src @ wq + bq, n.out_fmt)

    def emit(self, graph: Graph, n: LinearNode, out: Dict[str, str]) -> None:
        w_mem, b_mem = f"{n.name}_w.mem", f"{n.name}_b.mem"
        out[w_mem] = T.to_hex_lines(n.weight_int(), n.w_fmt.total_bits)
        out[b_mem] = T.to_hex_lines(n.bias_int(), 32)
        out[f"{n.name}.vhd"] = T.LINEAR.substitute(
            header=T.header(graph.name, n.name), name=n.name,
            in_features=n.weight.shape[0], out_features=n.weight.shape[1],
            x_generic=T.fmt_generic("X", n.in_fmt),
            w_generic=T.fmt_generic("W", n.w_fmt),
            y_generic=T.fmt_generic("Y", n.out_fmt),
            x_width=n.weight.shape[0] * n.in_fmt.total_bits,
            y_width=n.weight.shape[1] * n.out_fmt.total_bits,
            macs=n.macs(), n_dsp=LINEAR_DSP, w_mem=w_mem, b_mem=b_mem,
            rom_depth=int(n.weight.size), w_bits=n.w_fmt.total_bits,
            requant_shift=requant_shift(n.in_fmt, n.w_fmt,
                                        n.out_fmt))

    def probe_graph(self, rng) -> Graph:
        in_fmt, out_fmt = FxpFormat(8, 4), FxpFormat(16, 8)
        g = Graph(name="probe_linear")
        g.edges["x"] = Edge("x", (5,), in_fmt)
        g.inputs = ["x"]
        g.add(LinearNode(
            name="linear_0", op=self.kind, inputs=["x"], outputs=["y"],
            weight=(rng.standard_normal((5, 3)) * 0.5).astype(np.float32),
            bias=(rng.standard_normal(3) * 0.1).astype(np.float32),
            w_fmt=FxpFormat(8, 6), in_fmt=in_fmt, out_fmt=out_fmt),
            Edge("y", (3,), out_fmt))
        g.outputs = ["y"]
        return g

    def cost(self, n: LinearNode) -> NodeCost:
        macs = n.macs()
        mac_cycles = math.ceil(macs / LINEAR_DSP)
        out = n.weight.shape[1]
        w_bits = n.weight.size * n.w_fmt.total_bits
        b_bits = n.bias.size * 32
        return NodeCost(
            n.name, n.op,
            cycles=mac_cycles + out + PIPE,
            active_cycles=mac_cycles + out,
            dsp=LINEAR_DSP, bram36=brams_for(w_bits + b_bits),
            lut=60 + 8 * n.out_fmt.total_bits)


class LSTMCellTemplate(HWTemplate):
    """The paper's gate-fused LSTM window template (DESIGN.md §4)."""

    kind = "lstm_cell"
    node_cls = LSTMCellNode
    has_weights = True
    family = "lstm"
    lower_model_fn = staticmethod(lower_lstm_model)
    port_out = "h_out"

    def wire_contract(self, n: LSTMCellNode,
                      graph: Graph) -> Dict[str, FxpFormat]:
        return {n.inputs[0]: n.act_fmt, n.outputs[0]: n.act_fmt}

    def transfer(self, n: LSTMCellNode, in_intervals: Dict[str, Interval],
                 *, graph: Graph,
                 ctx: AnalysisContext) -> Dict[str, Interval]:
        """Single forward pass, no fixpoint needed: h and c are requant-
        clipped to act/state format each step, so their format ranges are
        already post-fixpoints — the gate bound below (x rows at the input
        interval, h rows at the full act range) covers every timestep."""
        A, C = n.act_fmt, n.state_fmt
        sig = resolve_lut(graph, n, n.sigmoid_lut)
        tanh = resolve_lut(graph, n, n.tanh_lut)
        acc = mac_interval(n.weight_int(), n.bias_int(),
                           [(slice(0, n.d_in), in_intervals[n.inputs[0]]),
                            (slice(n.d_in, None), Interval.full(A))])
        z = checked_requant(ctx, n, acc, n.mac_shift, A, None,
                            what="gate accumulator")
        for lut in (sig, tanh):
            check_lut_domain(ctx, n, lut, z, None,
                             what="gate pre-activation")
        si = lut_interval(ctx, sig, z)          # i/f/o share the σ table
        tg = lut_interval(ctx, tanh, z)
        af, cf = A.frac_bits, C.frac_bits
        align = n.state_align_shift
        if align < 0:
            ctx.diag("EAI002", n.name,
                     f"state alignment shift {align} is negative — "
                     f"state_fmt {C} carries fewer fraction bits than "
                     f"act_fmt {A}")
            align = 0
        term = si.mul(Interval.full(C)).add(si.mul(tg).lshift(align))
        if not term.fits_int32():
            ctx.diag("EAI001", n.name,
                     f"cell-state accumulator interval {term} exceeds "
                     "the int32 word")
        c_iv = requant_interval(term, af).clip(C)
        c_a = requant_interval(c_iv, cf - af).clip(A)
        check_lut_domain(ctx, n, tanh, c_a, None,
                         what="cell-state tanh input")
        tc = lut_interval(ctx, tanh, c_a)
        h = checked_requant(ctx, n, si.mul(tc), af, A, n.outputs[0],
                            what="output-gate product")
        return {n.outputs[0]: h}

    def prepare(self, n: LSTMCellNode, graph: Graph) -> Dict:
        luts = graph.act_luts()
        return {"w": n.weight_int(), "b": n.bias_int(),
                "spec": CellSpec(
                    seq_len=n.seq_len, d_in=n.d_in, hidden=n.hidden,
                    act_fmt=n.act_fmt, state_fmt=n.state_fmt, w_fmt=n.w_fmt,
                    sig_lo=luts[n.sigmoid_lut].lo,
                    tanh_lo=luts[n.tanh_lut].lo)}

    def execute(self, n: LSTMCellNode, env: Dict, em, mode: str) -> None:
        # a stacked cell consumes the previous cell's full sequence
        src = env.get(n.inputs[0] + ".seq", env[n.inputs[0]])
        p = em.prepared(n.name)
        w, b = p["w"], p["b"]
        if mode == "fused":
            seq = lstm_window_int(
                src.astype(jnp.int32), w, b,
                em.prepared(n.sigmoid_lut)["table"],
                em.prepared(n.tanh_lut)["table"], spec=p["spec"])
        else:
            B = src.shape[0]
            A, C = n.act_fmt, n.state_fmt
            af, cf = A.frac_bits, C.frac_bits
            h = jnp.zeros((B, n.hidden), jnp.int32)
            c = jnp.zeros((B, n.hidden), jnp.int32)
            outs = []
            for t in range(n.seq_len):
                xh = jnp.concatenate([src[:, t].astype(jnp.int32), h],
                                     axis=-1)
                z = mac_int(xh, w, b, shift=n.mac_shift, fmt=A, mode=mode,
                            interpret=em.interpret)
                i, f, g, o = jnp.split(z, 4, axis=-1)
                si = em.lookup(n.sigmoid_lut, i)
                sf = em.lookup(n.sigmoid_lut, f)
                so = em.lookup(n.sigmoid_lut, o)
                tg = em.lookup(n.tanh_lut, g)
                # align si*tg (scale 2·af) to sf*c (af+cf): << (cf - af)
                term = sf * c + jax.lax.shift_left(si * tg,
                                                   n.state_align_shift)
                c = fxp_requant_int(term, af + cf, C)
                c_a = fxp_requant_int(c, cf, A)
                tc = em.lookup(n.tanh_lut, c_a)
                h = fxp_requant_int(so * tc, 2 * af, A)
                outs.append(h)
            seq = jnp.stack(outs, axis=1)                   # (B, S, H)
        env[n.outputs[0]] = seq[:, -1]
        env[n.outputs[0] + ".seq"] = seq

    def reference(self, n: LSTMCellNode, env: Dict, luts: Dict) -> None:
        src = env.get(n.inputs[0] + ".seq", env[n.inputs[0]])
        A, C = n.act_fmt, n.state_fmt
        sig, tanh = luts[n.sigmoid_lut], luts[n.tanh_lut]
        wq = ref_q(jnp.asarray(n.weight), n.w_fmt)
        bq = ref_bias(jnp.asarray(n.bias), A, n.w_fmt)
        B = src.shape[0]
        h = jnp.zeros((B, n.hidden), jnp.float32)
        c = jnp.zeros((B, n.hidden), jnp.float32)
        outs = []
        for t in range(n.seq_len):
            z = ref_q(jnp.concatenate([src[:, t], h], axis=-1) @ wq + bq, A)
            i, f, g, o = jnp.split(z, 4, axis=-1)
            si, sf, so = ref_act(sig, i), ref_act(sig, f), ref_act(sig, o)
            tg = ref_act(tanh, g)
            c = ref_q(sf * c + si * tg, C)
            h = ref_q(so * ref_act(tanh, ref_q(c, A)), A)
            outs.append(h)
        env[n.outputs[0]] = h
        env[n.outputs[0] + ".seq"] = jnp.stack(outs, axis=1)

    def emit(self, graph: Graph, n: LSTMCellNode,
             out: Dict[str, str]) -> None:
        w_mem, b_mem = f"{n.name}_w.mem", f"{n.name}_b.mem"
        out[w_mem] = T.to_hex_lines(n.weight_int(), n.w_fmt.total_bits)
        out[b_mem] = T.to_hex_lines(n.bias_int(), 32)
        out[f"{n.name}.vhd"] = T.LSTM_CELL.substitute(
            header=T.header(graph.name, n.name), name=n.name,
            d_in=n.d_in, hidden=n.hidden, seq_len=n.seq_len,
            x_generic=T.fmt_generic("X", n.act_fmt),
            w_generic=T.fmt_generic("W", n.w_fmt),
            c_generic=T.fmt_generic("C", n.state_fmt),
            x_width=n.d_in * n.act_fmt.total_bits,
            h_width=n.hidden * n.act_fmt.total_bits,
            macs=n.macs(), n_dsp=LSTM_DSP, w_mem=w_mem, b_mem=b_mem,
            sigmoid_lut=n.sigmoid_lut, tanh_lut=n.tanh_lut,
            act_bits=n.act_fmt.total_bits)

    def probe_graph(self, rng) -> Graph:
        d_in, hidden, seq = 1, 4, 3
        act, state = FxpFormat(8, 4), FxpFormat(16, 8)
        g = Graph(name="probe_lstm_cell")
        g.edges["x"] = Edge("x", (seq, d_in), act)
        g.inputs = ["x"]
        sig = ActLUTNode(name="hard_sigmoid_lut", op="act_lut", inputs=[],
                         outputs=[], kind="hard_sigmoid", in_fmt=act,
                         out_fmt=act)
        tanh = ActLUTNode(name="hard_tanh_lut", op="act_lut", inputs=[],
                          outputs=[], kind="hard_tanh", in_fmt=act,
                          out_fmt=act)
        g.nodes += [sig, tanh]
        g.add(LSTMCellNode(
            name="lstm_cell_0", op=self.kind, inputs=["x"], outputs=["h"],
            weight=(rng.standard_normal((d_in + hidden, 4 * hidden)) * 0.4)
            .astype(np.float32),
            bias=(rng.standard_normal(4 * hidden) * 0.1).astype(np.float32),
            act_fmt=act, state_fmt=state, seq_len=seq, d_in=d_in,
            hidden=hidden, sigmoid_lut=sig.name, tanh_lut=tanh.name),
            Edge("h", (hidden,), act))
        g.outputs = ["h"]
        return g

    def cost(self, n: LSTMCellNode) -> NodeCost:
        per_step_macs = (n.d_in + n.hidden) * 4 * n.hidden
        mac_cycles = math.ceil(per_step_macs / LSTM_DSP)
        # elementwise state update: 4 DSP ops per hidden unit, 1/cycle each
        # on the same MAC units -> hidden cycles; + pipeline refill
        step = mac_cycles + n.hidden + PIPE
        w_bits = n.weight.size * n.w_fmt.total_bits
        b_bits = n.bias.size * 32
        return NodeCost(
            n.name, n.op,
            cycles=n.seq_len * step,
            active_cycles=n.seq_len * (mac_cycles + n.hidden),
            dsp=LSTM_DSP, bram36=brams_for(w_bits + b_bits),
            lut=150 + 12 * n.act_fmt.total_bits)


class Conv1dTemplate(HWTemplate):
    """Depthwise/strided 1-D convolution (TCN-style sensor workloads).

    Execution reuses the shared serial-MAC template exactly the way the
    fabric would: the (kernel, channels) taps are expanded once, at
    prepare time, into a channel-block-diagonal (kernel·channels, channels)
    matrix, and each output step is an im2col frame MAC'd through
    :func:`mac_int` — the zero entries contribute nothing, so integer
    values (and the §4 envelope, whose fan-in is ``kernel``) are identical
    to the per-channel tap loop the entity describes.
    """

    kind = "conv1d"
    node_cls = Conv1dNode
    has_weights = True
    family = "conv1d"
    lower_model_fn = staticmethod(lower_conv_model)

    @staticmethod
    def _frames(x: jax.Array, n: Conv1dNode) -> jax.Array:
        """(B, S, C) -> (B, out_len, kernel, C) strided tap windows — the
        same framing the float model trains through (one implementation)."""
        from repro.model.conv1d import conv1d_frames

        return conv1d_frames(x, n.kernel, n.stride)

    def wire_contract(self, n: Conv1dNode,
                      graph: Graph) -> Dict[str, FxpFormat]:
        return {n.inputs[0]: n.in_fmt, n.outputs[0]: n.out_fmt}

    def transfer(self, n: Conv1dNode, in_intervals: Dict[str, Interval], *,
                 graph: Graph, ctx: AnalysisContext) -> Dict[str, Interval]:
        # weight_int() is (K, C): axis-0 summation bounds the per-channel
        # tap accumulator, whose fan-in is exactly `kernel`.
        acc = mac_interval(n.weight_int(), n.bias_int(),
                           [(slice(None), in_intervals[n.inputs[0]])])
        out = checked_requant(
            ctx, n, acc, requant_shift(n.in_fmt, n.w_fmt, n.out_fmt),
            n.out_fmt, n.outputs[0], what="tap accumulator")
        return {n.outputs[0]: out}

    def prepare(self, n: Conv1dNode, graph: Graph) -> Dict:
        K, C = n.kernel, n.channels
        w = np.asarray(n.weight_int(), np.int32)           # (K, C)
        w_mat = np.zeros((K * C, C), np.int32)
        for k in range(K):
            w_mat[k * C + np.arange(C), np.arange(C)] = w[k]
        return {"w_mat": w_mat, "b": np.asarray(n.bias_int(), np.int32)}

    def execute(self, n: Conv1dNode, env: Dict, em, mode: str) -> None:
        x = env[n.inputs[0]].astype(jnp.int32)             # (B, S, C)
        p = em.prepared(n.name)
        B, t_out = x.shape[0], n.out_len
        xh = self._frames(x, n).reshape(B * t_out, n.kernel * n.channels)
        shift = requant_shift(n.in_fmt, n.w_fmt, n.out_fmt)
        y = mac_int(xh, p["w_mat"], p["b"], shift=shift,
                    fmt=n.out_fmt, mode=mode, interpret=em.interpret)
        env[n.outputs[0]] = y.reshape(B, t_out, n.channels)

    def reference(self, n: Conv1dNode, env: Dict, luts: Dict) -> None:
        x = env[n.inputs[0]]
        wq = ref_q(jnp.asarray(n.weight), n.w_fmt)         # (K, C)
        bq = ref_bias(jnp.asarray(n.bias), n.in_fmt, n.w_fmt)
        frames = self._frames(x, n)                        # (B, T, K, C)
        z = jnp.einsum("btkc,kc->btc", frames, wq) + bq
        env[n.outputs[0]] = ref_q(z, n.out_fmt)

    def emit(self, graph: Graph, n: Conv1dNode, out: Dict[str, str]) -> None:
        w_mem, b_mem = f"{n.name}_w.mem", f"{n.name}_b.mem"
        out[w_mem] = T.to_hex_lines(n.weight_int(), n.w_fmt.total_bits)
        out[b_mem] = T.to_hex_lines(n.bias_int(), 32)
        out[f"{n.name}.vhd"] = T.CONV1D.substitute(
            header=T.header(graph.name, n.name), name=n.name,
            channels=n.channels, kernel=n.kernel, stride=n.stride,
            seq_len=n.seq_len, out_len=n.out_len,
            x_generic=T.fmt_generic("X", n.in_fmt),
            w_generic=T.fmt_generic("W", n.w_fmt),
            y_generic=T.fmt_generic("Y", n.out_fmt),
            x_width=n.seq_len * n.channels * n.in_fmt.total_bits,
            y_width=n.out_len * n.channels * n.out_fmt.total_bits,
            macs=n.macs(), n_dsp=CONV_DSP, w_mem=w_mem, b_mem=b_mem,
            rom_depth=int(n.weight.size), w_bits=n.w_fmt.total_bits,
            requant_shift=requant_shift(n.in_fmt, n.w_fmt,
                                        n.out_fmt))

    def probe_graph(self, rng) -> Graph:
        K, C, S = 3, 2, 8
        fmt = FxpFormat(8, 4)
        node = Conv1dNode(
            name="conv1d_0", op=self.kind, inputs=["x"], outputs=["y"],
            weight=(rng.standard_normal((K, C)) * 0.5).astype(np.float32),
            bias=(rng.standard_normal(C) * 0.1).astype(np.float32),
            kernel=K, stride=1, seq_len=S, channels=C,
            in_fmt=fmt, out_fmt=fmt)
        g = Graph(name="probe_conv1d")
        g.edges["x"] = Edge("x", (S, C), fmt)
        g.inputs = ["x"]
        g.add(node, Edge("y", (node.out_len, C), fmt))
        g.outputs = ["y"]
        return g

    def cost(self, n: Conv1dNode) -> NodeCost:
        macs = n.macs()
        mac_cycles = math.ceil(macs / CONV_DSP)
        out_elems = n.out_len * n.channels
        w_bits = n.weight.size * n.w_fmt.total_bits
        b_bits = n.bias.size * 32
        return NodeCost(
            n.name, n.op,
            cycles=mac_cycles + out_elems + PIPE,
            active_cycles=mac_cycles + out_elems,
            dsp=CONV_DSP, bram36=brams_for(w_bits + b_bits),
            lut=60 + 8 * n.out_fmt.total_bits)


class ActLUTTemplate(HWTemplate):
    """Shared activation ROM entity: no netlist instance of its own (the
    act_apply wiring and the LSTM cell instantiate it where used), no
    cycles (combinational, hidden in the MAC pipeline)."""

    kind = "act_lut"
    node_cls = ActLUTNode
    in_netlist = False
    sequential = False

    def transfer(self, n: ActLUTNode, in_intervals: Dict[str, Interval], *,
                 graph: Graph, ctx: AnalysisContext) -> Dict[str, Interval]:
        return {}                               # a ROM computes nothing alone

    def prepare(self, n: ActLUTNode, graph: Graph) -> Dict:
        return {"table": n.table()}

    def execute(self, n: ActLUTNode, env: Dict, em, mode: str) -> None:
        pass                                    # a ROM computes nothing alone

    def reference(self, n: ActLUTNode, env: Dict, luts: Dict) -> None:
        pass

    def emit(self, graph: Graph, n: ActLUTNode, out: Dict[str, str]) -> None:
        mem = f"{n.name}.mem"
        out[mem] = T.to_hex_lines(n.table(), n.out_fmt.total_bits)
        out[f"{n.name}.vhd"] = T.ACT_LUT.substitute(
            header=T.header(graph.name, n.name), name=n.name, kind=n.kind,
            in_bits=n.in_fmt.total_bits, out_bits=n.out_fmt.total_bits,
            depth=n.depth, mem=mem, offset=-n.lo)

    def cost(self, n: ActLUTNode) -> NodeCost:
        rom_bits = n.depth * n.out_fmt.total_bits
        return NodeCost(n.name, n.op, cycles=0, active_cycles=0,
                        dsp=0, bram36=0,
                        lut=math.ceil(rom_bits / LUT_ROM_BITS))


class ActApplyTemplate(HWTemplate):
    """Wiring-only application of a shared ROM: combinational lookup, part
    of the act_lut vertical (it emits no entity of its own)."""

    kind = "act_apply"
    node_cls = ActApplyNode
    sequential = False

    def probe_graph(self, rng) -> Graph:
        """Also the act_lut vertical's probe: the shared ROM only computes
        through an application node, so they are fuzzed together."""
        fmt = FxpFormat(8, 4)
        kind = ("hard_sigmoid", "hard_tanh")[int(rng.integers(0, 2))]
        g = Graph(name="probe_act_apply")
        g.edges["x"] = Edge("x", (6,), fmt)
        g.inputs = ["x"]
        lut = ActLUTNode(name=f"{kind}_lut", op="act_lut", inputs=[],
                         outputs=[], kind=kind, in_fmt=fmt, out_fmt=fmt)
        g.nodes.append(lut)
        g.add(ActApplyNode(name="act_0", op=self.kind, inputs=["x"],
                           outputs=["y"], lut=lut.name), Edge("y", (6,), fmt))
        g.outputs = ["y"]
        return g

    def wire_contract(self, n: ActApplyNode,
                      graph: Graph) -> Dict[str, FxpFormat]:
        lut = resolve_lut(graph, n, n.lut)
        return {n.inputs[0]: lut.in_fmt, n.outputs[0]: lut.out_fmt}

    def transfer(self, n: ActApplyNode, in_intervals: Dict[str, Interval], *,
                 graph: Graph, ctx: AnalysisContext) -> Dict[str, Interval]:
        lut = resolve_lut(graph, n, n.lut)
        x = in_intervals[n.inputs[0]]
        check_lut_domain(ctx, n, lut, x, n.inputs[0], what="LUT input")
        # The lookup writes raw table values to the wire (no requant), so
        # the output interval is the table's — NOT clipped to the edge
        # format. Recording it as the pre-clip interval lets the driver's
        # EAI006 pass flag an output edge too narrow for the table.
        out = lut_interval(ctx, lut, x)
        ctx.saturation(n.outputs[0], out)
        return {n.outputs[0]: out}

    def execute(self, n: ActApplyNode, env: Dict, em, mode: str) -> None:
        env[n.outputs[0]] = em.lookup(n.lut, env[n.inputs[0]])

    def reference(self, n: ActApplyNode, env: Dict, luts: Dict) -> None:
        env[n.outputs[0]] = ref_act(luts[n.lut], env[n.inputs[0]])

    def emit(self, graph: Graph, n: ActApplyNode,
             out: Dict[str, str]) -> None:
        pass           # instantiates the shared LUT entity in the top level

    def instance(self, graph: Graph, n: ActApplyNode, *, enable: str,
                 done: str) -> str:
        return T.LUT_INSTANCE.substitute(
            label=f"i_{n.name}", entity=n.lut,
            wire_in=n.inputs[0], wire_out=n.outputs[0])

    def cost(self, n: ActApplyNode) -> NodeCost:
        return NodeCost(n.name, n.op, cycles=1, active_cycles=1,
                        dsp=0, bram36=0, lut=4)


class ElementwiseTemplate(HWTemplate):
    """out = requant(a (mul|add) b) on one DSP slice."""

    kind = "elementwise"
    node_cls = ElementwiseNode

    def probe_graph(self, rng) -> Graph:
        fmt, out_fmt = FxpFormat(8, 4), FxpFormat(8, 5)
        ew_kind = ("mul", "add")[int(rng.integers(0, 2))]
        g = Graph(name="probe_elementwise")
        g.edges["x"] = Edge("x", (6,), fmt)
        g.inputs = ["x"]
        g.add(ElementwiseNode(name="ew_0", op=self.kind, inputs=["x", "x"],
                              outputs=["y"], kind=ew_kind, a_fmt=fmt,
                              b_fmt=fmt, out_fmt=out_fmt),
              Edge("y", (6,), out_fmt))
        g.outputs = ["y"]
        return g

    def wire_contract(self, n: ElementwiseNode,
                      graph: Graph) -> Dict[str, FxpFormat]:
        return {n.inputs[0]: n.a_fmt, n.inputs[1]: n.b_fmt,
                n.outputs[0]: n.out_fmt}

    def transfer(self, n: ElementwiseNode,
                 in_intervals: Dict[str, Interval], *,
                 graph: Graph, ctx: AnalysisContext) -> Dict[str, Interval]:
        a = in_intervals[n.inputs[0]]
        b = in_intervals[n.inputs[1]]
        fa, fb = n.a_fmt.frac_bits, n.b_fmt.frac_bits
        if n.kind == "mul":
            raw, from_frac = a.mul(b), fa + fb
        else:
            hi_f = max(fa, fb)
            a2, b2 = a.lshift(hi_f - fa), b.lshift(hi_f - fb)
            for side, iv in (("a", a2), ("b", b2)):
                if not iv.fits_int32():
                    ctx.diag("EAI002", n.name,
                             f"aligning operand {side!r} by "
                             f"{hi_f - (fa if side == 'a' else fb)} bits "
                             f"leaves int32 (interval {iv})",
                             edge=n.inputs[0 if side == "a" else 1])
            raw, from_frac = a2.add(b2), hi_f
        out = checked_requant(
            ctx, n, raw, from_frac - n.out_fmt.frac_bits, n.out_fmt,
            n.outputs[0], what=f"elementwise {n.kind}")
        return {n.outputs[0]: out}

    def execute(self, n, env: Dict, em, mode: str) -> None:
        a = env[n.inputs[0]].astype(jnp.int32)
        b = env[n.inputs[1]].astype(jnp.int32)
        fa, fb = n.a_fmt.frac_bits, n.b_fmt.frac_bits
        if n.kind == "mul":
            y = fxp_requant_int(a * b, fa + fb, n.out_fmt)
        else:
            hi = max(fa, fb)
            a = jax.lax.shift_left(a, hi - fa)
            b = jax.lax.shift_left(b, hi - fb)
            y = fxp_requant_int(a + b, hi, n.out_fmt)
        env[n.outputs[0]] = y

    def reference(self, n, env: Dict, luts: Dict) -> None:
        a, b = env[n.inputs[0]], env[n.inputs[1]]
        v = a * b if n.kind == "mul" else a + b
        env[n.outputs[0]] = ref_q(v, n.out_fmt)

    def emit(self, graph: Graph, n, out: Dict[str, str]) -> None:
        out[f"{n.name}.vhd"] = T.ELEMENTWISE.substitute(
            header=T.header(graph.name, n.name), name=n.name,
            a_generic=T.fmt_generic("A", n.a_fmt),
            b_generic=T.fmt_generic("B", n.b_fmt),
            y_generic=T.fmt_generic("Y", n.out_fmt),
            a_width=graph.edges[n.inputs[0]].bits,
            b_width=graph.edges[n.inputs[1]].bits,
            y_width=graph.edges[n.outputs[0]].bits,
            op_sym="*" if n.kind == "mul" else "+")

    def instance(self, graph: Graph, n, *, enable: str, done: str) -> str:
        return T.EW_INSTANCE.substitute(
            label=f"i_{n.name}", entity=n.name, enable=enable,
            wire_a=n.inputs[0], wire_b=n.inputs[1],
            wire_out=n.outputs[0], done=done)

    def cost(self, n) -> NodeCost:
        return NodeCost(n.name, n.op, cycles=1 + PIPE,
                        active_cycles=1, dsp=1, bram36=0, lut=16)


register_template(LinearTemplate())
register_template(LSTMCellTemplate())
register_template(Conv1dTemplate())
register_template(ActLUTTemplate())
register_template(ActApplyTemplate())
register_template(ElementwiseTemplate())
