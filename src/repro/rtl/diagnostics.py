"""Structured diagnostics for the static IR verifier (DESIGN.md §13).

The analyzer (:mod:`repro.rtl.analyze`) reports everything it proves — or
fails to prove — as :class:`Diagnostic` records with *stable* rule IDs, so
CI gates, the ``repro.rtl.lint`` CLI and the DSE feasibility oracle can key
on ``EAI001`` forever, not on message text. The full run rolls up into an
:class:`AnalysisReport` that round-trips through JSON (``analysis.json`` is
written next to every saved RTL bundle).

Rule table (severity is the *default*; the analyzer never upgrades it):

=======  ========  ====================================================
EAI001   error     int32 accumulator overflow
EAI002   error     invalid requant shift (|s| > 31, or a widening shift
                   that leaves int32)
EAI003   error     Q-format discontinuity between an edge and a port
EAI004   error     LUT address range does not cover its input interval
EAI005   error     resource demand exceeds the device budget
EAI006   warning   output edge saturates (pre-clip interval exceeds fmt)
EAI007   warning   resource utilization above 90% of a budget
=======  ========  ====================================================
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

SEVERITY_ERROR = "error"
SEVERITY_WARNING = "warning"
_SEVERITIES = (SEVERITY_ERROR, SEVERITY_WARNING)


@dataclass(frozen=True)
class Rule:
    """One entry of the stable rule table: id, default severity, fix hint."""

    id: str
    severity: str
    title: str
    hint: str


RULES: Dict[str, Rule] = {r.id: r for r in (
    Rule("EAI001", SEVERITY_ERROR, "accumulator-overflow",
         "narrow the weight/activation formats (or reduce fan-in) so "
         "fan_in * max|w_int| * max|x_int| + |b_int| stays below 2**31; "
         "see ir.validate_formats"),
    Rule("EAI002", SEVERITY_ERROR, "requant-shift",
         "keep |in.frac + w.frac - out.frac| <= 31 and widening "
         "(negative) shifts small enough that the shifted accumulator "
         "still fits int32"),
    Rule("EAI003", SEVERITY_ERROR, "format-mismatch",
         "make the edge's FxpFormat equal to the port's format — the "
         "producer's out_fmt must equal the consumer's in_fmt on every "
         "wire"),
    Rule("EAI004", SEVERITY_ERROR, "lut-domain",
         "widen the LUT's in_fmt so its [lo, hi] address range covers "
         "the incoming interval, or requantize the producer to the "
         "LUT's input format"),
    Rule("EAI005", SEVERITY_ERROR, "resource-overflow",
         "shrink the design (narrower w_fmt, fewer taps/units) or "
         "target a larger device; see ResourceReport.utilization"),
    Rule("EAI006", SEVERITY_WARNING, "output-saturation",
         "widen the output edge's total_bits (or lower its frac_bits) "
         "so the worst-case accumulator fits without clipping"),
    Rule("EAI007", SEVERITY_WARNING, "resource-pressure",
         "over 90% of a device budget is committed; leave headroom for "
         "routing or choose a narrower format"),
)}


@dataclass(frozen=True)
class Diagnostic:
    """One finding: stable rule id, severity, the node (and optionally the
    edge) it anchors to, a message, and the rule's fix hint."""

    rule: str
    severity: str
    node: str
    message: str
    edge: Optional[str] = None
    hint: str = ""

    def __post_init__(self) -> None:
        if self.severity not in _SEVERITIES:
            raise ValueError(f"severity must be one of {_SEVERITIES}, "
                             f"got {self.severity!r}")

    def format(self, design: str = "") -> str:
        """One ruff-style line: ``design:node[:edge]: EAI00x message``."""
        where = f"{design}:{self.node}" if design else self.node
        if self.edge:
            where = f"{where}:{self.edge}"
        return f"{where}: {self.rule} [{self.severity}] {self.message}"

    def to_dict(self) -> Dict[str, Any]:
        return {"rule": self.rule, "severity": self.severity,
                "node": self.node, "message": self.message,
                "edge": self.edge, "hint": self.hint}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Diagnostic":
        return Diagnostic(rule=d["rule"], severity=d["severity"],
                          node=d["node"], message=d["message"],
                          edge=d.get("edge"), hint=d.get("hint", ""))


def make_diagnostic(rule: str, node: str, message: str,
                    edge: Optional[str] = None) -> Diagnostic:
    """Construct a Diagnostic with severity + hint drawn from the rule
    table; unknown rule ids raise listing the table (so a typo'd rule in a
    transfer function fails loudly, mirroring the registry errors)."""
    try:
        r = RULES[rule]
    except KeyError:
        raise ValueError(f"unknown diagnostic rule {rule!r}; known rules: "
                         f"{sorted(RULES)}") from None
    return Diagnostic(rule=rule, severity=r.severity, node=node,
                      message=message, edge=edge, hint=r.hint)


#: version stamp for the serialized report (bump on incompatible change)
ANALYSIS_FORMAT_VERSION = 1


@dataclass
class AnalysisReport:
    """The static verifier's artifact: per-edge integer intervals, the full
    diagnostic list, and the resource/cycle summary — JSON-round-trippable
    so ``analysis.json`` can gate CI without this repo's code."""

    design: str
    hw: str
    diagnostics: List[Diagnostic] = field(default_factory=list)
    #: edge name -> (lo, hi) integer-code interval proved by the analyzer
    intervals: Dict[str, Tuple[int, int]] = field(default_factory=dict)
    resources: Dict[str, Any] = field(default_factory=dict)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity == SEVERITY_ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity == SEVERITY_WARNING]

    @property
    def passed(self) -> bool:
        """No error-severity diagnostics (warnings do not fail a design)."""
        return not self.errors

    def rules_fired(self) -> List[str]:
        return sorted({d.rule for d in self.diagnostics})

    def summary(self) -> str:
        verdict = "clean" if self.passed else "FAILED"
        return (f"{self.design}: static analysis {verdict} — "
                f"{len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s) over "
                f"{len(self.intervals)} edge(s)")

    def format(self) -> str:
        """The full ruff-style listing: one line per diagnostic (with its
        fix hint indented below), then the summary line."""
        lines = []
        for d in self.diagnostics:
            lines.append(d.format(self.design))
            if d.hint:
                lines.append(f"    hint: {d.hint}")
        lines.append(self.summary())
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "format_version": ANALYSIS_FORMAT_VERSION,
            "design": self.design,
            "hw": self.hw,
            "passed": self.passed,
            "diagnostics": [d.to_dict() for d in self.diagnostics],
            "intervals": {k: [int(lo), int(hi)]
                          for k, (lo, hi) in sorted(self.intervals.items())},
            "resources": dict(self.resources),
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "AnalysisReport":
        ver = d.get("format_version", ANALYSIS_FORMAT_VERSION)
        if ver != ANALYSIS_FORMAT_VERSION:
            raise ValueError(
                f"analysis report has format_version {ver}, this reader "
                f"understands {ANALYSIS_FORMAT_VERSION}")
        return AnalysisReport(
            design=d["design"], hw=d["hw"],
            diagnostics=[Diagnostic.from_dict(x)
                         for x in d.get("diagnostics", [])],
            intervals={k: (int(v[0]), int(v[1]))
                       for k, v in d.get("intervals", {}).items()},
            resources=dict(d.get("resources", {})))

    @staticmethod
    def from_json(text: str) -> "AnalysisReport":
        return AnalysisReport.from_dict(json.loads(text))
