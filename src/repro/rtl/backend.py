"""The "press the button" entry point: model -> artifacts + report + emulator.

``translate_rtl`` is what ``Creator.translate(st, backend="rtl")`` delegates
to: lower the quantized model to the dataflow IR, instantiate the hardware
templates, cost the design against the FPGA HWSpec, and hand back an
:class:`RTLExecutable` whose emulator stands in for the deployed accelerator
in the Workflow's stage-3 measurement (cycles × clock, duty-cycled power).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax

from repro.core.report import MeasurementReport
from repro.core.types import ModelConfig
from repro.energy.hw import HWSpec, XC7S15
from repro.quant.fixedpoint import FxpFormat
from repro.rtl.emit import emit_graph
from repro.rtl.emulator import RTLEmulator
from repro.rtl.ir import Graph, lower_model
from repro.rtl.resources import estimate, synthesize


@dataclass
class RTLExecutable:
    """The compiled-artifact analogue returned by ``translate(backend="rtl")``.

    Callable like the jitted executables the XLA backend returns: feeding it a
    float batch runs the bit-exact emulator and yields dequantized outputs.
    The emulator is the staged executor (DESIGN.md §7): weights live on
    device from construction and repeated calls replay compiled programs, so
    this object is cheap to call in verification/measurement loops.
    """

    graph: Graph
    artifacts: Dict[str, str]
    hw: HWSpec
    emulator_mode: str = "fused"     # "fused" | "pallas" | "jnp"
    emulator: RTLEmulator = field(init=False)

    def __post_init__(self):
        self.emulator = RTLEmulator(self.graph, mode=self.emulator_mode)

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.emulator.run(x).outputs_f

    def run_many(self, xs) -> list:
        """Batched-throughput entry: see :meth:`RTLEmulator.run_many`."""
        return self.emulator.run_many(xs)

    @property
    def cycles(self) -> int:
        return estimate(self.graph,
                        clock_hz=self.hw.clock_hz or 100e6).cycles

    def save(self, build_dir: str) -> None:
        from repro.rtl.emit import write_artifacts

        write_artifacts(self.artifacts, build_dir)


def translate_rtl(cfg: ModelConfig, params, *,
                  hw: HWSpec = XC7S15,
                  w_fmt: FxpFormat = FxpFormat(8, 6),
                  act_fmt: FxpFormat = FxpFormat(8, 4),
                  state_fmt: FxpFormat = FxpFormat(16, 8),
                  model_flops: float = 0.0,
                  emulator_mode: str = "fused"):
    """Returns (SynthesisReport, RTLExecutable)."""
    graph = lower_model(cfg, params, w_fmt=w_fmt, act_fmt=act_fmt,
                        state_fmt=state_fmt)
    artifacts = emit_graph(graph)
    rep = synthesize(graph, hw=hw, model_flops=model_flops,
                     n_artifacts=len(artifacts))
    return rep, RTLExecutable(graph=graph, artifacts=artifacts, hw=hw,
                              emulator_mode=emulator_mode)


def measure_rtl(exe: RTLExecutable, x: jax.Array, *, model: str,
                model_flops: float, hw: Optional[HWSpec] = None,
                n_runs: int = 1) -> MeasurementReport:
    """Stage-3 for the RTL backend: run the emulator (the deployed-design
    proxy), then read latency/power off the cycle-accurate schedule.

    ``n_runs > 1`` re-executes the design that many times — after the first
    call every repeat replays the same compiled program (the emulator's
    program cache), which is what makes measurement loops cheap.
    """
    hw = hw or exe.hw
    clock = hw.clock_hz or 100e6
    rr = estimate(exe.graph, clock_hz=clock)
    for _ in range(max(1, n_runs)):           # actually execute the design
        out = exe(x)
    jax.block_until_ready(out)
    latency = rr.latency_s
    energy = hw.energy_j(latency, duty=rr.duty)
    return MeasurementReport(
        model=model, platform=f"rtl-emulator({hw.name})",
        latency_s=latency,
        power_w=energy / latency if latency else 0.0,
        energy_j=energy,
        gop_per_j=(model_flops / 1e9) / energy if energy else 0.0,
        n_runs=max(1, n_runs))
