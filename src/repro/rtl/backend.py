"""The "press the button" entry point: model -> artifacts + report + emulator.

``RTL_TARGET`` is the registered deployment target behind
``Creator.translate(st, target="rtl")`` (DESIGN.md §8): lower the quantized
model to the dataflow IR, instantiate the hardware templates, cost the design
against the FPGA HWSpec, and hand back an :class:`RTLExecutable` — the RTL
flavor of the uniform :class:`~repro.core.target.Deployment` artifact, whose
bit-exact emulator stands in for the deployed accelerator in the Workflow's
stage-3 measurement (cycles × clock, duty-cycled power).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

import jax

from repro.core.report import MeasurementReport, SynthesisReport
from repro.core.target import DEFAULT_N_RUNS, Deployment, TargetOptions
from repro.core.types import ModelConfig
from repro.energy.hw import HWSpec, XC7S15
from repro.quant.fixedpoint import FxpFormat
from repro.rtl.analyze import AnalysisError, analyze_graph
from repro.rtl.diagnostics import AnalysisReport
from repro.rtl.emit import emit_graph
from repro.rtl.emulator import RTLEmulator
from repro.rtl.ir import Graph, lower_model
from repro.rtl.resources import estimate, synthesize

_EMULATOR_MODES = ("fused", "pallas", "jnp")
_ANALYZE_MODES = ("error", "warn", "off")


@dataclass(frozen=True)
class RTLOptions(TargetOptions):
    """Translate knobs for the RTL target — the Q-formats the design is
    quantized to and which emulator schedule executes it. Validation happens
    at construction so a Workflow knob sweep fails fast, not mid-lowering.

    ``w_fmt_overrides`` maps a registered template kind to the weight format
    *that* layer kind is quantized with (e.g. keep the conv taps at Q8.6
    while narrowing everything else) — keys are validated against the
    hardware-template registry so a typo'd kind fails here, with the list of
    registered kinds, not silently mid-sweep.
    """

    w_fmt: FxpFormat = FxpFormat(8, 6)
    act_fmt: FxpFormat = FxpFormat(8, 4)
    state_fmt: FxpFormat = FxpFormat(16, 8)
    emulator_mode: str = "fused"     # "fused" | "pallas" | "jnp"
    w_fmt_overrides: Optional[Mapping[str, FxpFormat]] = None
    #: static-verifier gate (DESIGN.md §13): "error" fails translate on any
    #: error-severity diagnostic, "warn" downgrades to a UserWarning,
    #: "off" skips the analysis pass entirely.
    analyze: str = "error"

    def __post_init__(self):
        if self.emulator_mode not in _EMULATOR_MODES:
            raise ValueError("emulator_mode must be one of "
                             f"{_EMULATOR_MODES}, got "
                             f"{self.emulator_mode!r}")
        if self.analyze not in _ANALYZE_MODES:
            raise ValueError(f"analyze must be one of {_ANALYZE_MODES}, "
                             f"got {self.analyze!r}")
        for name in ("w_fmt", "act_fmt", "state_fmt"):
            fmt = getattr(self, name)
            if not isinstance(fmt, FxpFormat):
                raise TypeError(f"{name} must be an FxpFormat, got "
                                f"{type(fmt).__name__}")
        if self.w_fmt_overrides is not None:
            from repro.rtl.oplib import get_template, list_templates

            for kind, fmt in self.w_fmt_overrides.items():
                tmpl = get_template(kind)    # unknown kind raises, listing
                if not tmpl.has_weights:
                    weighted = [k for k in list_templates()
                                if get_template(k).has_weights]
                    raise ValueError(
                        f"w_fmt_overrides[{kind!r}]: template {kind!r} "
                        "carries no weight format; weight-carrying "
                        f"kinds: {weighted}")
                if not isinstance(fmt, FxpFormat):
                    raise TypeError(
                        f"w_fmt_overrides[{kind!r}] must be an FxpFormat, "
                        f"got {type(fmt).__name__}")


@dataclass
class RTLExecutable(Deployment):
    """The compiled-artifact analogue returned by ``translate(target="rtl")``.

    Callable like the jitted executables the XLA target returns: feeding it a
    float batch runs the bit-exact emulator and yields dequantized outputs.
    The emulator is the staged executor (DESIGN.md §7): weights live on
    device from construction and repeated calls replay compiled programs, so
    this object is cheap to call in verification/measurement loops.

    As a :class:`Deployment`, it measures itself off the cycle-accurate
    schedule (``bind_step`` is a no-op — the emulator *is* the deployed
    design; timing a host-jitted step fn would measure the wrong substrate).
    """

    graph: Graph
    artifacts: Dict[str, str]
    hw: HWSpec
    emulator_mode: str = "fused"     # "fused" | "pallas" | "jnp"
    #: the static verifier's report (None when translated with analyze="off")
    analysis: Optional[AnalysisReport] = None
    emulator: RTLEmulator = field(init=False)

    target = "rtl"

    def __post_init__(self):
        self.emulator = RTLEmulator(self.graph, mode=self.emulator_mode)

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.emulator.run(x).outputs_f

    def run_many(self, xs) -> list:
        """Batched-throughput entry: see :meth:`RTLEmulator.run_many`."""
        return self.emulator.run_many(xs)

    def holds_program(self, shape, dtype) -> bool:
        """Serving-router affinity probe: is a program for this float input
        ``(shape, dtype)`` already compiled? Float inputs quantize to int32
        before dispatch, so the emulator key is ``(shape, int32)``."""
        import jax.numpy as jnp

        return self.emulator.has_program(shape, jnp.int32)

    @property
    def cycles(self) -> int:
        return estimate(self.graph,
                        clock_hz=self.hw.clock_hz or 100e6).cycles

    def measure(self, args, *, model: str, model_flops: float,
                n_runs: int = DEFAULT_N_RUNS, warmup: int = 1,
                hw: Optional[HWSpec] = None) -> MeasurementReport:
        """Stage 3 on the generated accelerator: execute the emulator (the
        deployed design's proxy) ``n_runs`` times, then read latency/power
        off the cycle-accurate schedule — emulator cycles × clock,
        duty-cycled power via :meth:`HWSpec.energy_j`.

        ``args`` follows the Deployment convention: the trailing positional
        is the input batch (leading entries, e.g. params from a Workflow
        step_builder, are already baked into the deployed design). Repeats
        replay the emulator's compiled program — no retrace, no weight
        re-upload — so the unified ``n_runs`` default is cheap here too.

        ``warmup`` runs execute first and are **excluded** from the latency
        samples (and thus from ``latency_p50/p99_s``): compile/trace time
        is a deployment cost, not a per-request tail.
        """
        import time

        from repro.obs import get_metrics, get_tracer, percentile

        x = args[-1] if isinstance(args, (tuple, list)) else args
        hw = hw or self.hw
        clock = hw.clock_hz or 100e6
        rr = estimate(self.graph, clock_hz=clock)
        n_runs = max(1, n_runs)
        samples = []
        with get_tracer().span("rtl.measure", model=model, n_runs=n_runs,
                               warmup=warmup):
            for _ in range(max(0, warmup)):     # excluded from percentiles
                jax.block_until_ready(self(x))
            for _ in range(n_runs):             # actually execute the design
                t0 = time.perf_counter()
                out = self(x)
                jax.block_until_ready(out)
                samples.append(time.perf_counter() - t0)
        hist = get_metrics().histogram("measure.latency_s.rtl")
        for s in samples:
            hist.observe(s)
        latency = rr.latency_s
        energy = hw.energy_j(latency, duty=rr.duty)
        return MeasurementReport(
            model=model, platform=f"rtl-emulator({hw.name})",
            latency_s=latency,
            power_w=energy / latency if latency else 0.0,
            energy_j=energy,
            gop_per_j=(model_flops / 1e9) / energy if energy else 0.0,
            n_runs=n_runs, target=self.target,
            # the fabric latency above is the cycle model (deterministic);
            # the percentiles characterize the per-run distribution of the
            # executing proxy — what a tail-latency acceptance gate reads
            latency_p50_s=percentile(samples, 50),
            latency_p99_s=percentile(samples, 99))

    def save(self, build_dir: str) -> None:
        import os

        from repro.rtl.emit import write_artifacts

        write_artifacts(self.artifacts, build_dir)
        if self.analysis is not None:
            path = os.path.join(build_dir, "analysis.json")
            with open(path, "w", encoding="utf-8") as f:
                f.write(self.analysis.to_json())


class RTLTarget:
    """The ElasticAI-Creator codegen analogue as a registered target."""

    name = "rtl"
    default_hw = XC7S15
    options_cls = RTLOptions
    requires_stepper = True          # must lower the real model graph

    def options_from_knobs(self, knobs) -> RTLOptions:
        """Workflow knobs -> valid RTL Q-formats, clamped to the exactness
        envelope (DESIGN.md §4): the DSP path caps weights at 12 bits and
        LUT inputs at 9. This replaces the old per-Workflow ``fmt_builder``
        hook. Knob dicts without ``bits`` get the target defaults."""
        if "bits" not in knobs:
            return RTLOptions()
        bits = int(knobs["bits"])
        frac = int(knobs.get("frac", max(1, bits - 2)))
        wb = min(bits, 12)
        ab = min(bits, 9)
        return RTLOptions(
            w_fmt=FxpFormat(wb, min(frac, wb - 1)),
            act_fmt=FxpFormat(ab, min(max(0, frac - 2), ab - 1, 8)))

    def translate(self, cfg, params, stepper,
                  options: RTLOptions) -> Tuple[SynthesisReport,
                                                RTLExecutable]:
        if params is None:
            params, _ = stepper.init()
        # a clock-less HWSpec (a TPU) can't be the fabric target: fall back
        hw = options.hw if (options.hw is not None
                            and options.hw.clock_hz) else self.default_hw
        return translate_rtl(cfg, params, hw=hw,
                             model_flops=options.model_flops or 0.0,
                             w_fmt=options.w_fmt, act_fmt=options.act_fmt,
                             state_fmt=options.state_fmt,
                             emulator_mode=options.emulator_mode,
                             w_fmt_overrides=options.w_fmt_overrides,
                             analyze=options.analyze)


RTL_TARGET = RTLTarget()


def translate_rtl(cfg: ModelConfig, params, *,
                  hw: HWSpec = XC7S15,
                  w_fmt: FxpFormat = FxpFormat(8, 6),
                  act_fmt: FxpFormat = FxpFormat(8, 4),
                  state_fmt: FxpFormat = FxpFormat(16, 8),
                  model_flops: float = 0.0,
                  emulator_mode: str = "fused",
                  w_fmt_overrides=None,
                  analyze: str = "error"):
    """Returns (SynthesisReport, RTLExecutable).

    ``analyze`` gates the static verifier (DESIGN.md §13) between lowering
    and emit: ``"error"`` raises :class:`~repro.rtl.analyze.AnalysisError`
    on any error-severity diagnostic (fail fast, before codegen),
    ``"warn"`` surfaces them as a UserWarning, ``"off"`` skips the pass.
    """
    import warnings

    from repro.obs import get_tracer

    if analyze not in _ANALYZE_MODES:
        raise ValueError(f"analyze must be one of {_ANALYZE_MODES}, "
                         f"got {analyze!r}")
    trc = get_tracer()
    with trc.span("rtl.lower", arch=cfg.name):
        graph = lower_model(cfg, params, w_fmt=w_fmt, act_fmt=act_fmt,
                            state_fmt=state_fmt,
                            w_fmt_overrides=w_fmt_overrides)
    analysis = None
    if analyze != "off":
        with trc.span("rtl.analyze", arch=cfg.name):
            analysis = analyze_graph(graph, hw=hw)
        if not analysis.passed:
            if analyze == "error":
                raise AnalysisError(analysis)
            warnings.warn("static analysis found "
                          f"{len(analysis.errors)} error(s):\n"
                          f"{analysis.format()}", UserWarning,
                          stacklevel=2)
    with trc.span("rtl.emit", arch=cfg.name):
        artifacts = emit_graph(graph)
    with trc.span("rtl.synthesize", arch=cfg.name):
        rep = synthesize(graph, hw=hw, model_flops=model_flops,
                         n_artifacts=len(artifacts))
    return rep, RTLExecutable(graph=graph, artifacts=artifacts, hw=hw,
                              emulator_mode=emulator_mode,
                              analysis=analysis)


def measure_rtl(exe: RTLExecutable, x: jax.Array, *, model: str,
                model_flops: float, hw: Optional[HWSpec] = None,
                n_runs: int = DEFAULT_N_RUNS) -> MeasurementReport:
    """Functional spelling of :meth:`RTLExecutable.measure` (kept for
    direct use; the Workflow goes through the Deployment method)."""
    return exe.measure((x,), model=model, model_flops=model_flops,
                       hw=hw, n_runs=n_runs)
