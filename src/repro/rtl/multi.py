"""Batched multi-design emulation — vmap the Elastic Node (DESIGN.md §15).

Design-space search evaluates K candidate accelerators that differ only in
their trained values: same node kinds, shapes, LUT sizes and Q-formats,
different weights. After the PR-10 executor refactor those candidates are
*program-isomorphic* (:func:`repro.rtl.ir.iso_key`) — the staged graph walk
traces to one program taking the array constants as arguments — so the
whole candidate set can be emulated as ONE dispatch: stack every design's
params along a leading design axis and ``jax.vmap`` the shared walk over
it. Toolflow turnaround, not per-run latency, bounds embedded DSE
throughput; this turns K sequential trace+compile+run cycles into one.

The design-axis program runs the pure-``jnp`` walk — the one execution
path whose primitives all carry batching rules, and bit-exact against
``fused``/``pallas`` by the §4 contract (re-pinned per design by the
multi-emulation tests and :func:`repro.verify.conformance.run_conformance_batch`).
On a multi-device host (`XLA_FLAGS=--xla_force_host_platform_device_count`
counts) ``shard=True`` additionally splits the design axis across a 1-D
mesh with :func:`repro.shardmap.shard_map` — candidates are independent,
so the partitioning is embarrassing.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import get_metrics, get_tracer
from repro.quant.fixedpoint import fxp_to_int
from repro.rtl.emulator import EmulationResult, RTLEmulator
from repro.rtl.ir import Graph, iso_key
from repro.rtl.program_cache import ProgramLRU


def assert_isomorphic(graphs: Sequence[Graph]) -> str:
    """The shared iso key of ``graphs``; raises listing every mismatch."""
    if not graphs:
        raise ValueError("need at least one graph")
    keys = [iso_key(g) for g in graphs]
    bad = [(i, graphs[i].name, k)
           for i, k in enumerate(keys) if k != keys[0]]
    if bad:
        lines = ", ".join(f"#{i} {name!r} ({k})" for i, name, k in bad)
        raise ValueError(
            f"graphs are not program-isomorphic to #0 "
            f"{graphs[0].name!r} ({keys[0]}): {lines} — same node "
            "kinds/shapes/LUT sizes and Q-formats are required; only "
            "weight/bias values may differ")
    return keys[0]


def stack_params(emulators: Sequence[RTLEmulator]):
    """Stack K isomorphic emulators' traced-param pytrees along a new
    leading design axis (the axis the shared program is vmapped over)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[em.params() for em in emulators])


class MultiDesignEmulator:
    """K isomorphic candidate designs behind one vmapped compiled program.

    Construction validates isomorphism, stages every candidate's constants
    (one :class:`RTLEmulator` per design, all sharing one
    :class:`ProgramLRU` — so even their *single*-design dispatches compile
    once), and stacks the params. :meth:`run_int` then emulates all K
    designs in one dispatch:

    * ``per_design=False`` (default) — one shared stimulus ``(B, ...)``
      broadcast to every design (the conformance-sweep shape);
    * ``per_design=True`` — stacked stimulus ``(K, B, ...)``, row k to
      design k.

    Outputs carry a leading design axis: ``result.outputs[k]`` is
    bit-identical to ``self.emulators[k].run_int(x).outputs`` (and, by the
    §4 contract, to the ``fused``/``pallas`` paths of a per-design
    emulator — the acceptance check of DESIGN.md §15).
    """

    def __init__(self, graphs: Sequence[Graph], *, max_programs: int = 4,
                 shard: bool = False,
                 programs: Optional[ProgramLRU] = None):
        self.graphs: List[Graph] = list(graphs)
        self.iso_key = assert_isomorphic(self.graphs)
        self.k = len(self.graphs)
        self.programs = programs if programs is not None \
            else ProgramLRU(max_programs)
        self.emulators = [RTLEmulator(g, mode="jnp", programs=self.programs)
                          for g in self.graphs]
        self._base = self.emulators[0]
        self._params = stack_params(self.emulators)
        self.mesh = self._design_mesh() if shard else None
        self.sharded = self.mesh is not None
        self.trace_count = 0

    def _design_mesh(self):
        """A 1-D ``("design", "model")`` mesh when the host's devices
        divide K; None (pure vmap) otherwise."""
        n = len(jax.devices())
        if n <= 1 or self.k % n != 0:
            return None
        from repro.launch.mesh import make_smoke_mesh

        return make_smoke_mesh(shape=(n, 1), axes=("design", "model"))

    # -- the shared program -------------------------------------------------
    def _program(self, shape: Tuple[int, ...], dtype, per_design: bool):
        key = ("multi", self.iso_key, self.k, per_design, self.sharded,
               self._base.interpret, tuple(int(d) for d in shape),
               jnp.dtype(dtype).name)

        def build():
            def walk(x_int, params):
                self.trace_count += 1    # python side effect: trace-time
                return self._base._execute(x_int, mode="jnp", params=params)

            fn = jax.vmap(walk, in_axes=(0 if per_design else None, 0))
            if self.mesh is not None:
                from jax.sharding import PartitionSpec as P

                from repro.shardmap import shard_map

                fn = shard_map(
                    fn, mesh=self.mesh,
                    in_specs=(P("design") if per_design else P(),
                              P("design")),
                    out_specs=P("design"), check_vma=False)
            return jax.jit(fn)

        prog, hit, _ = self.programs.get_or_build(key, build)
        return prog, hit

    # -- dispatch -----------------------------------------------------------
    def run_int(self, x_int, *, per_design: bool = False) -> EmulationResult:
        """Emulate all K designs in one compiled dispatch; every array in
        the result gains a leading design axis of size K."""
        x_int = jnp.asarray(x_int)
        if per_design and int(x_int.shape[0]) != self.k:
            raise ValueError(
                f"per_design stimulus must lead with the design axis "
                f"(K={self.k}), got shape {tuple(x_int.shape)}")
        prog, hit = self._program(x_int.shape, x_int.dtype, per_design)
        get_metrics().counter("rtl.multi.dispatch").inc()
        trc = get_tracer()
        if trc.enabled:
            with trc.span("rtl.multi.dispatch", k=self.k,
                          shape=str(tuple(x_int.shape)), cached=hit,
                          sharded=self.sharded,
                          design=self._base.graph.name):
                env = prog(x_int, self._params)
        else:
            env = prog(x_int, self._params)
        g = self._base.graph
        fmt = g.edges[g.outputs[0]].fmt
        y = env[g.outputs[0]]
        return EmulationResult(outputs=y,
                               outputs_f=y.astype(jnp.float32) / fmt.scale,
                               trace=env)

    def run(self, x, *, per_design: bool = False) -> EmulationResult:
        g = self._base.graph
        in_fmt = g.edges[g.inputs[0]].fmt
        return self.run_int(jnp.asarray(fxp_to_int(jnp.asarray(x), in_fmt),
                                        jnp.int32),
                            per_design=per_design)

    # -- the sequential cross-check path ------------------------------------
    def run_int_sequential(self, x_int) -> np.ndarray:
        """Per-design dispatches through the shared LRU (one trace total);
        the reference the vmapped axis must match integer-for-integer."""
        return np.stack([np.asarray(em.run_int(x_int).outputs, np.int64)
                         for em in self.emulators])
