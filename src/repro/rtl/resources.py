"""Per-node FPGA resource counts + cycle model — the "Vivado estimation" half.

Targets the paper's platform (Spartan-7 XC7S15 @ 100 MHz, Table I): 20 DSP48
slices, 10 BRAM36, 8000 6-input LUTs. The cycle model is the serial-MAC
schedule of the emitted templates, calibrated once against ref [11]'s
measured LSTM accelerator (57.25 µs / window): the gate-fused LSTM template
time-multiplexes its window over ``LSTM_DSP`` MAC units, paying a state
update + pipeline refill per step. Power is duty-cycled through
:meth:`HWSpec.energy_j` — MAC/elementwise cycles at ``active_w``, pipeline
fill at ``idle_w`` (DESIGN.md §5–§6).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List

from repro.energy.hw import HWSpec, XC7S15
from repro.core.report import SynthesisReport
from repro.rtl.ir import (ActLUTNode, ActApplyNode, ElementwiseNode, Graph,
                          LinearNode, LSTMCellNode)

# Template schedule constants (one-time calibration vs ref [11], DESIGN.md §5)
LSTM_DSP = 2          # MAC units the gate-fused cell template instantiates
LINEAR_DSP = 1        # serial-MAC linear template
PIPE = 8              # pipeline fill/drain cycles per template invocation
BRAM36_BITS = 36 * 1024
LUT_ROM_BITS = 64     # one LUT6 stores 64 bits of distributed ROM

XC7S15_DSP = 20
XC7S15_BRAM36 = 10
XC7S15_LUTS = 8000


@dataclass
class NodeCost:
    name: str
    op: str
    cycles: int          # total schedule length
    active_cycles: int   # cycles with MAC/elementwise work in flight
    dsp: int
    bram36: int
    lut: int

    @staticmethod
    def zero(name: str, op: str) -> "NodeCost":
        return NodeCost(name, op, 0, 0, 0, 0, 0)


@dataclass
class ResourceReport:
    design: str
    target: str
    per_node: List[NodeCost] = field(default_factory=list)
    clock_hz: float = 100e6

    @property
    def cycles(self) -> int:
        return sum(c.cycles for c in self.per_node)

    @property
    def active_cycles(self) -> int:
        return sum(c.active_cycles for c in self.per_node)

    @property
    def duty(self) -> float:
        return self.active_cycles / self.cycles if self.cycles else 0.0

    @property
    def dsp(self) -> int:
        return sum(c.dsp for c in self.per_node)

    @property
    def bram36(self) -> int:
        return sum(c.bram36 for c in self.per_node)

    @property
    def lut(self) -> int:
        return sum(c.lut for c in self.per_node)

    @property
    def latency_s(self) -> float:
        return self.cycles / self.clock_hz

    def utilization(self) -> Dict[str, float]:
        return {"dsp": self.dsp / XC7S15_DSP,
                "bram36": self.bram36 / XC7S15_BRAM36,
                "lut": self.lut / XC7S15_LUTS}

    def fits(self) -> bool:
        return all(v <= 1.0 for v in self.utilization().values())


def _brams(bits: int) -> int:
    return max(1, math.ceil(bits / BRAM36_BITS)) if bits else 0


def node_cost(node) -> NodeCost:
    if isinstance(node, LSTMCellNode):
        per_step_macs = (node.d_in + node.hidden) * 4 * node.hidden
        mac_cycles = math.ceil(per_step_macs / LSTM_DSP)
        # elementwise state update: 4 DSP ops per hidden unit, 1/cycle each
        # on the same MAC units -> hidden cycles; + pipeline refill
        step = mac_cycles + node.hidden + PIPE
        w_bits = node.weight.size * node.w_fmt.total_bits
        b_bits = node.bias.size * 32
        return NodeCost(
            node.name, node.op,
            cycles=node.seq_len * step,
            active_cycles=node.seq_len * (mac_cycles + node.hidden),
            dsp=LSTM_DSP, bram36=_brams(w_bits + b_bits),
            lut=150 + 12 * node.act_fmt.total_bits)
    if isinstance(node, LinearNode):
        macs = node.macs()
        mac_cycles = math.ceil(macs / LINEAR_DSP)
        out = node.weight.shape[1]
        w_bits = node.weight.size * node.w_fmt.total_bits
        b_bits = node.bias.size * 32
        return NodeCost(
            node.name, node.op,
            cycles=mac_cycles + out + PIPE,
            active_cycles=mac_cycles + out,
            dsp=LINEAR_DSP, bram36=_brams(w_bits + b_bits),
            lut=60 + 8 * node.out_fmt.total_bits)
    if isinstance(node, ActLUTNode):
        rom_bits = node.depth * node.out_fmt.total_bits
        return NodeCost(node.name, node.op, cycles=0, active_cycles=0,
                        dsp=0, bram36=0,
                        lut=math.ceil(rom_bits / LUT_ROM_BITS))
    if isinstance(node, ActApplyNode):
        return NodeCost(node.name, node.op, cycles=1, active_cycles=1,
                        dsp=0, bram36=0, lut=4)
    if isinstance(node, ElementwiseNode):
        return NodeCost(node.name, node.op, cycles=1 + PIPE,
                        active_cycles=1, dsp=1, bram36=0, lut=16)
    return NodeCost.zero(node.name, node.op)


def estimate(graph: Graph, *, clock_hz: float = 100e6) -> ResourceReport:
    rep = ResourceReport(design=graph.name, target="xc7s15",
                         clock_hz=clock_hz)
    rep.per_node = [node_cost(n) for n in graph.nodes]
    return rep


def synthesize(graph: Graph, *, hw: HWSpec = XC7S15,
               model_flops: float = 0.0,
               n_artifacts: int = 0) -> SynthesisReport:
    """ResourceReport -> SynthesisReport, the stage-2 artifact the Workflow
    loop reads. Latency = cycles × clock; energy duty-cycled via HWSpec."""
    clock = hw.clock_hz or 100e6
    rr = estimate(graph, clock_hz=clock)
    latency = rr.latency_s
    energy = hw.energy_j(latency, duty=rr.duty)
    if not model_flops:
        model_flops = 2.0 * graph.total_macs()
    util = rr.utilization()
    weight_bits = sum(e.bits for e in graph.edges.values())
    return SynthesisReport(
        model=graph.name, target=hw.name, backend="rtl",
        argument_bytes=sum(graph.edges[e].bits for e in graph.inputs) // 8,
        output_bytes=sum(graph.edges[e].bits for e in graph.outputs) // 8,
        temp_bytes=weight_bits // 8,
        fits=rr.fits(), utilization=max(util.values()),
        flops=model_flops, bytes_accessed=float(weight_bits // 8),
        est_latency_s=latency,
        est_power_w=energy / latency if latency else 0.0,
        est_energy_j=energy,
        est_gop_per_j=(model_flops / 1e9) / energy if energy else 0.0,
        bottleneck="compute",
        resources={"dsp": rr.dsp, "bram36": rr.bram36, "lut": rr.lut,
                   "cycles": rr.cycles, "duty": round(rr.duty, 4),
                   **{f"util_{k}": round(v, 4) for k, v in util.items()}},
        n_artifacts=n_artifacts)
