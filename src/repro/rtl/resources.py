"""Per-node FPGA resource counts + cycle model — the "Vivado estimation" half.

Targets the paper's platform (Spartan-7 XC7S15 @ 100 MHz, Table I): 20 DSP48
slices, 10 BRAM36, 8000 6-input LUTs. The cycle model is the serial-MAC
schedule of the emitted templates, calibrated once against ref [11]'s
measured LSTM accelerator (57.25 µs / window): the gate-fused LSTM template
time-multiplexes its window over ``LSTM_DSP`` MAC units, paying a state
update + pipeline refill per step. Power is duty-cycled through
:meth:`HWSpec.energy_j` — MAC/elementwise cycles at ``active_w``, pipeline
fill at ``idle_w`` (DESIGN.md §5–§6).

Since the op-library redesign (DESIGN.md §9) the per-op cost formulas live on
each :class:`~repro.rtl.oplib.HWTemplate`; this module owns the shared
schedule constants, the :class:`NodeCost`/:class:`ResourceReport` datatypes,
and the graph-level ``estimate``/``synthesize`` roll-ups. ``node_cost`` is a
registry dispatch.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List

from repro.core.report import SynthesisReport
from repro.energy.hw import HWSpec, XC7S15
from repro.rtl.ir import Graph, Node

# Template schedule constants (one-time calibration vs ref [11], DESIGN.md §5)
LSTM_DSP = 2          # MAC units the gate-fused cell template instantiates
LINEAR_DSP = 1        # serial-MAC linear template
CONV_DSP = 1          # serial tap-MAC conv1d template (one DSP, BRAM taps)
PIPE = 8              # pipeline fill/drain cycles per template invocation
BRAM36_BITS = 36 * 1024
LUT_ROM_BITS = 64     # one LUT6 stores 64 bits of distributed ROM

XC7S15_DSP = 20
XC7S15_BRAM36 = 10
XC7S15_LUTS = 8000


@dataclass
class NodeCost:
    name: str
    op: str
    cycles: int          # total schedule length
    active_cycles: int   # cycles with MAC/elementwise work in flight
    dsp: int
    bram36: int
    lut: int

    @staticmethod
    def zero(name: str, op: str) -> "NodeCost":
        return NodeCost(name, op, 0, 0, 0, 0, 0)


@dataclass
class ResourceReport:
    design: str
    target: str
    per_node: List[NodeCost] = field(default_factory=list)
    clock_hz: float = 100e6

    @property
    def cycles(self) -> int:
        return sum(c.cycles for c in self.per_node)

    @property
    def active_cycles(self) -> int:
        return sum(c.active_cycles for c in self.per_node)

    @property
    def duty(self) -> float:
        return self.active_cycles / self.cycles if self.cycles else 0.0

    @property
    def dsp(self) -> int:
        return sum(c.dsp for c in self.per_node)

    @property
    def bram36(self) -> int:
        return sum(c.bram36 for c in self.per_node)

    @property
    def lut(self) -> int:
        return sum(c.lut for c in self.per_node)

    @property
    def latency_s(self) -> float:
        return self.cycles / self.clock_hz

    def utilization(self) -> Dict[str, float]:
        return {"dsp": self.dsp / XC7S15_DSP,
                "bram36": self.bram36 / XC7S15_BRAM36,
                "lut": self.lut / XC7S15_LUTS}

    def fits(self) -> bool:
        return all(v <= 1.0 for v in self.utilization().values())


def brams_for(bits: int) -> int:
    """BRAM36 blocks needed for ``bits`` of weight/bias storage."""
    if bits < 0:
        raise ValueError(f"brams_for needs bits >= 0, got {bits}")
    return max(1, math.ceil(bits / BRAM36_BITS)) if bits else 0


def node_cost(node: Node) -> NodeCost:
    """Registry dispatch: the node's template owns its cost formula."""
    from repro.rtl.oplib import get_template

    return get_template(node.op).cost(node)


def estimate(graph: Graph, *, clock_hz: float = 100e6) -> ResourceReport:
    rep = ResourceReport(design=graph.name, target="xc7s15",
                         clock_hz=clock_hz)
    rep.per_node = [node_cost(n) for n in graph.nodes]
    return rep


def synthesize(graph: Graph, *, hw: HWSpec = XC7S15,
               model_flops: float = 0.0,
               n_artifacts: int = 0) -> SynthesisReport:
    """ResourceReport -> SynthesisReport, the stage-2 artifact the Workflow
    loop reads. Latency = cycles × clock; energy duty-cycled via HWSpec."""
    clock = hw.clock_hz or 100e6
    rr = estimate(graph, clock_hz=clock)
    latency = rr.latency_s
    energy = hw.energy_j(latency, duty=rr.duty)
    if not model_flops:
        model_flops = 2.0 * graph.total_macs()
    util = rr.utilization()
    weight_bits = sum(e.bits for e in graph.edges.values())
    return SynthesisReport(
        model=graph.name, target=hw.name, backend="rtl",
        argument_bytes=sum(graph.edges[e].bits for e in graph.inputs) // 8,
        output_bytes=sum(graph.edges[e].bits for e in graph.outputs) // 8,
        temp_bytes=weight_bits // 8,
        fits=rr.fits(), utilization=max(util.values()),
        flops=model_flops, bytes_accessed=float(weight_bits // 8),
        est_latency_s=latency,
        est_power_w=energy / latency if latency else 0.0,
        est_energy_j=energy,
        est_gop_per_j=(model_flops / 1e9) / energy if energy else 0.0,
        bottleneck="compute",
        resources={"dsp": rr.dsp, "bram36": rr.bram36, "lut": rr.lut,
                   "cycles": rr.cycles, "duty": round(rr.duty, 4),
                   **{f"util_{k}": round(v, 4) for k, v in util.items()}},
        n_artifacts=n_artifacts)
