"""``python -m repro.rtl.lint`` — ruff-style CLI over the static IR verifier.

Lowers the canonical design(s) and runs :func:`repro.rtl.analyze.analyze_graph`
(DESIGN.md §13), printing one diagnostic per line with its fix hint and a
per-design summary. Exit-code semantics for CI:

* ``0`` — every design analyzed clean at the failing severity
* ``1`` — at least one diagnostic at the failing severity (error by
  default; add ``--strict`` to fail on warnings too)
* ``2`` — usage error (argparse)

Examples::

    python -m repro.rtl.lint --arch lstm
    python -m repro.rtl.lint --arch lstm --arch conv1d --strict
    python -m repro.rtl.lint --json out/analysis.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Iterable, List, Optional

from repro.energy.hw import XC7S15
from repro.rtl.analyze import analyze_graph
from repro.rtl.diagnostics import AnalysisReport

#: CLI spelling -> registered arch id (the canonical shipped designs)
ARCH_ALIASES = {
    "lstm": "elastic-lstm",
    "conv1d": "elastic-conv1d",
}


def resolve_arch(name: str) -> str:
    """CLI arch spelling -> registry id; unknown spellings raise listing
    what IS accepted (registry convention)."""
    if name in ARCH_ALIASES:
        return ARCH_ALIASES[name]
    if name in ARCH_ALIASES.values():
        return name
    known = sorted(set(ARCH_ALIASES) | set(ARCH_ALIASES.values()))
    raise ValueError(f"unknown arch {name!r}; known archs: {known}")


def lint_archs(archs: Iterable[str]) -> List[AnalysisReport]:
    """Lower each canonical design and analyze it against the default
    fabric target (XC7S15)."""
    from repro.verify.vectors import canonical_graph

    reports = []
    for arch in archs:
        graph, _, _ = canonical_graph(resolve_arch(arch))
        reports.append(analyze_graph(graph, hw=XC7S15))
    return reports


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.rtl.lint",
        description="Static IR verifier over the canonical RTL designs "
                    "(abstract-interpretation range/overflow, Q-format, "
                    "LUT-domain and resource checks).")
    p.add_argument("--arch", action="append", metavar="{lstm,conv1d}",
                   help="design to lint (repeatable; default: both)")
    p.add_argument("--json", metavar="PATH",
                   help="also write the reports as a JSON array to PATH")
    p.add_argument("--strict", action="store_true",
                   help="fail (exit 1) on warnings too, not just errors")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    archs = args.arch or sorted(ARCH_ALIASES)
    try:
        reports = lint_archs(archs)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    for rep in reports:
        print(rep.format())
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump([r.to_dict() for r in reports], f, indent=2,
                      sort_keys=True)
            f.write("\n")
    failed = any((not r.passed) or (args.strict and r.warnings)
                 for r in reports)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
