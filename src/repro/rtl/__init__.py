"""RTL backend — the ElasticAI-Creator codegen analogue (DESIGN.md §3, §9).

Pipeline:  quantized model ──lower──▶ fixed-point dataflow IR (``ir``)
           ──instantiate──▶ VHDL-like template artifacts (``templates``,
           ``emit``) ──verify──▶ bit-exact int32 emulator (``emulator``)
           ──cost──▶ XC7S15 resource/cycle model (``resources``).

Every stage is a registry-dispatched walk over the hardware-template (op)
library (``oplib``): one :class:`~repro.rtl.oplib.HWTemplate` per layer kind
owns lowering, emission, emulation and cost, so a new layer plugs in with
one ``register_template`` call.

Entry point for users: ``Creator.translate(st, target="rtl",
options=RTLOptions(...))`` — "rtl" resolves to :data:`RTL_TARGET` through the
deployment-target registry (``repro.core.target``); the pieces are importable
here for direct use and tests.
"""
from repro.rtl.analyze import (AnalysisContext, AnalysisError,  # noqa: F401
                               Interval, analyze_graph)
from repro.rtl.backend import (RTL_TARGET, RTLExecutable,  # noqa: F401
                               RTLOptions, RTLTarget, measure_rtl,
                               translate_rtl)
from repro.rtl.diagnostics import (RULES, AnalysisReport,  # noqa: F401
                                   Diagnostic, make_diagnostic)
from repro.rtl.emit import emit_graph, write_artifacts  # noqa: F401
from repro.rtl.emulator import (EmulationResult, RTLEmulator,  # noqa: F401
                                assert_bit_exact, reference_apply)
from repro.rtl.ir import (ActApplyNode, ActLUTNode, Conv1dNode,  # noqa: F401
                          ElementwiseNode, Edge, Graph, LinearNode,
                          LSTMCellNode, iso_key, lower_conv_model,
                          lower_conv_stack, lower_linear_stack,
                          lower_lstm_model, lower_model, validate_formats)
from repro.rtl.multi import (MultiDesignEmulator,  # noqa: F401
                             assert_isomorphic, stack_params)
from repro.rtl.program_cache import ProgramLRU  # noqa: F401
from repro.rtl.oplib import (HWTemplate, get_template,  # noqa: F401
                             list_templates, lowerable_families,
                             register_template, unregister_template)
from repro.rtl.resources import (NodeCost, ResourceReport,  # noqa: F401
                                 estimate, node_cost, synthesize)
