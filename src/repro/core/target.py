"""Pluggable deployment targets — the registry behind ``Creator.translate``.

The paper's promise is one button for many substrates: the developer designs
a model once and the toolchain translates it to whatever accelerator the
deployment calls for. This module is that boundary, as two first-class
abstractions (DESIGN.md §8):

* A :class:`Target` — a named translation backend. Each target declares its
  ``name``, a ``default_hw`` :class:`HWSpec`, an ``options_cls`` dataclass
  (the *only* place target-specific knobs live; nothing leaks into the
  shared ``Creator.translate`` signature), an ``options_from_knobs`` hook
  that maps Workflow knob dicts onto valid options, and
  ``translate(cfg, params, stepper, options) -> (SynthesisReport,
  Deployment)``.

* A :class:`Deployment` — the uniform stage-3 artifact every target returns.
  It is callable on inputs, measurable (:meth:`Deployment.measure`, one
  documented ``n_runs`` default for every target), savable
  (:meth:`Deployment.save`), and carries ``target``/``cycles`` metadata.

Targets register by name (:func:`register_target`); the RTL target is a
lazy entry so ``repro.rtl`` only imports when first requested. Adding a new
backend (multi-device XLA, a per-FPGA-part RTL variant, ...) means writing
one Target class and registering it — ``Creator`` and ``Workflow`` never
change again.

The RTL target applies the same pattern one level down: inside it, each
*layer kind* is a registered hardware template (``repro.rtl.oplib``,
DESIGN.md §9), and its options dataclass (``RTLOptions``) carries per-kind
knobs such as ``w_fmt_overrides`` validated against that registry.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import (Any, Dict, Optional, Protocol, Tuple, Type,
                    runtime_checkable)

import jax

from repro.core.report import MeasurementReport, SynthesisReport
from repro.energy.hw import HWSpec, TPU_V5E
from repro.energy.meter import meter_channels
from repro.energy.roofline import roofline
from repro.obs import get_metrics, get_tracer, percentile

#: The single documented stage-3 measurement default, shared by every
#: target. (Pre-redesign the XLA path used 20 and the RTL path used 1; the
#: RTL emulator replays a cached compiled program per repeat, so 20 is cheap
#: there too and both substrates now average over the same sample count.)
DEFAULT_N_RUNS = 20


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (forward-only serving)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch          # decode: one token per seq


# --------------------------------------------------------------------------- #
# Options — the per-target translate knobs
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class TargetOptions:
    """Base for every target's options dataclass.

    ``hw`` / ``model_flops`` are shared across targets; ``Creator.translate``
    fills them (from its own ``hw`` and the cfg/shape FLOP estimate) when the
    caller leaves them ``None``. Target-specific knobs (Q-formats, emulator
    modes, ...) live on subclasses, never on ``Creator.translate`` itself.
    """

    hw: Optional[HWSpec] = None
    model_flops: Optional[float] = None

    def filled(self, *, hw: Optional[HWSpec],
               model_flops: Optional[float]) -> "TargetOptions":
        """Return a copy with unset shared fields defaulted."""
        return dataclasses.replace(
            self,
            hw=self.hw if self.hw is not None else hw,
            model_flops=(self.model_flops if self.model_flops is not None
                         else model_flops))


@dataclass(frozen=True)
class XLAOptions(TargetOptions):
    """Options for the jit/XLA target.

    ``kind`` overrides the stepper shape's program kind
    ("train" | "prefill" | "decode"); ``None`` uses ``stepper.shape.kind``.
    """

    kind: Optional[str] = None

    _KINDS = (None, "train", "prefill", "decode")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError("XLAOptions.kind must be one of "
                             f"{self._KINDS[1:]} or None, got {self.kind!r}")


# --------------------------------------------------------------------------- #
# Deployment — the uniform stage-3 artifact
# --------------------------------------------------------------------------- #


class Deployment:
    """What ``Target.translate`` hands back next to the SynthesisReport.

    The uniform contract, regardless of substrate:

    * callable on inputs (``deployment(*args)`` runs the deployed design);
    * :meth:`measure` executes it and returns a :class:`MeasurementReport`
      that records ``n_runs`` and the target name;
    * :meth:`save` writes the deployable artifacts to a build directory;
    * ``target`` (name) and ``cycles`` (cycle-schedule length, ``None`` when
      the substrate has no fabric clock) are inspectable metadata;
    * :meth:`bind_step` lets the Workflow hand over the concrete step
      function it wants timed — host-executed targets (XLA) measure that
      callable, targets with their own execution substrate (the RTL
      emulator) ignore it, because their measurement must come off the
      deployed design itself.
    """

    target = ""
    cycles: Optional[int] = None

    def __call__(self, *args):
        raise NotImplementedError

    def bind_step(self, fn) -> "Deployment":
        """Default: the deployment is its own executor."""
        return self

    def measure(self, args, *, model: str, model_flops: float,
                n_runs: int = DEFAULT_N_RUNS, warmup: int = 1,
                hw: Optional[HWSpec] = None) -> MeasurementReport:
        """Execute ``warmup`` unrecorded runs, then ``n_runs`` timed ones.

        Warmup runs are part of the contract, not a courtesy: compile /
        trace / first-touch cost must be excluded from the latency samples,
        so ``latency_p50_s``/``latency_p99_s`` characterize steady-state
        tails only (the serving layer's admission decisions read them)."""
        raise NotImplementedError

    def save(self, build_dir: str) -> None:
        raise NotImplementedError

    def verify(self, args=None, *, model: str, model_flops: float,
               hw: Optional[HWSpec] = None, protocol=None, oracle=None):
        """Elastic Node conformance: run this deployment through the
        verification subsystem (:mod:`repro.verify`) and return its
        :class:`~repro.verify.ConformanceReport`.

        Part of the uniform Deployment contract, like :meth:`measure`:
        self-executing targets (RTL) get the full differential check —
        every emulator mode mutually bit-exact over the design's golden
        vectors, int output within the error budget of the float oracle —
        plus the measurement protocol (warmup, ``n_runs``, latency/energy
        bands vs the XC7S15 model and Table I); host-executed targets get
        the protocol plus an ``oracle`` comparison when one is supplied.
        ``args`` follows the :meth:`measure` convention and may be omitted
        for self-executing targets (the golden stimulus stands in).
        """
        from repro.verify import verify_deployment

        return verify_deployment(self, args, model=model,
                                 model_flops=model_flops, hw=hw,
                                 protocol=protocol, oracle=oracle)

    def guarded(self, **kwargs) -> "Deployment":
        """Wrap this deployment for fault-tolerant serving: per-call
        timeout, bounded retry, circuit breaker, golden-vector canary
        probes, and graceful fallback (``repro.resilience``, DESIGN.md
        §12). Keyword arguments go to
        :class:`~repro.resilience.GuardedDeployment` (``policy=``,
        ``fallback=``, ``canary=``, injectable ``clock``/``rng``, ...).
        Part of the uniform contract so a pool can guard any target the
        registry produces.
        """
        from repro.resilience import GuardedDeployment

        return GuardedDeployment(self, **kwargs)


@dataclass
class XLADeployment(Deployment):
    """The jitted-executable deployment: wall-clock timing on the container
    (our Elastic-Node proxy) with duty-1 power from the HWSpec."""

    fn: Any                                     # compiled/jitted callable
    hw: HWSpec = TPU_V5E
    hlo_text: str = ""
    cost: Dict[str, float] = field(default_factory=dict)

    target = "xla"

    def __call__(self, *args):
        return self.fn(*args)

    def bind_step(self, fn) -> "XLADeployment":
        """Measure ``fn`` instead of the translated executable, keeping the
        translate-time metadata (HLO, cost) on the new artifact."""
        return dataclasses.replace(self, fn=fn)

    def measure(self, args, *, model: str, model_flops: float,
                n_runs: int = DEFAULT_N_RUNS, warmup: int = 1,
                hw: Optional[HWSpec] = None) -> MeasurementReport:
        """Time ``n_runs`` executions, keeping every per-run latency (each
        run is individually synchronized) so the report carries real
        p50/p99 tail percentiles, not just the mean. The ``warmup`` runs
        execute first and never enter the samples — compile time is a
        deployment cost, not a steady-state tail."""
        hw = hw or self.hw
        n_runs = max(1, n_runs)
        samples = []
        with get_tracer().span("xla.measure", model=model, n_runs=n_runs,
                               warmup=warmup):
            for _ in range(max(0, warmup)):     # excluded from percentiles
                jax.block_until_ready(self.fn(*args))
            for _ in range(n_runs):
                t0 = time.perf_counter()
                out = self.fn(*args)
                jax.block_until_ready(out)
                samples.append(time.perf_counter() - t0)
        hist = get_metrics().histogram("measure.latency_s.xla")
        for s in samples:
            hist.observe(s)
        lat = sum(samples) / n_runs
        energy = hw.energy_j(lat)
        return MeasurementReport(
            model=model, platform="container-cpu(Elastic-Node proxy)",
            latency_s=lat, power_w=hw.active_w, energy_j=energy,
            gop_per_j=(model_flops / 1e9) / energy if energy else 0.0,
            n_runs=n_runs, target=self.target,
            latency_p50_s=percentile(samples, 50),
            latency_p99_s=percentile(samples, 99))

    def save(self, build_dir: str) -> None:
        """Artifacts for this substrate: the compiled HLO plus a manifest."""
        os.makedirs(build_dir, exist_ok=True)
        with open(os.path.join(build_dir, "module.hlo.txt"), "w") as f:
            f.write(self.hlo_text)
        with open(os.path.join(build_dir, "deployment.json"), "w") as f:
            json.dump({"target": self.target, "hw": self.hw.name,
                       "cost": self.cost}, f, indent=2)


# --------------------------------------------------------------------------- #
# Target protocol + registry
# --------------------------------------------------------------------------- #


@runtime_checkable
class Target(Protocol):
    """What a translation backend must provide to plug into the toolchain."""

    name: str
    default_hw: HWSpec
    options_cls: Type[TargetOptions]
    #: Workflow refuses step-fn-only operation for targets that must lower a
    #: real Stepper (e.g. RTL needs the model graph, not a closed-over fn).
    requires_stepper: bool

    def options_from_knobs(self, knobs: Dict[str, Any]) -> TargetOptions:
        """Map Workflow knobs onto a *valid* options instance (this replaces
        the old per-Workflow ``fmt_builder`` hook)."""
        ...

    def translate(self, cfg, params, stepper,
                  options: TargetOptions) -> Tuple[SynthesisReport,
                                                   Deployment]:
        ...


_REGISTRY: Dict[str, Target] = {}
#: name -> (module, attribute); resolved on first get_target() so heavyweight
#: backends don't import until requested.
_LAZY: Dict[str, Tuple[str, str]] = {}


def register_target(target: Target, *, overwrite: bool = False) -> Target:
    """Register ``target`` under ``target.name``. Registering a name twice is
    an error unless ``overwrite=True`` (lazy placeholders may be overwritten
    by the concrete target they resolve to)."""
    name = target.name
    if not overwrite and (name in _REGISTRY or name in _LAZY):
        raise ValueError(f"target {name!r} already registered "
                         f"(registered: {list_targets()})")
    _LAZY.pop(name, None)
    _REGISTRY[name] = target
    return target


def register_lazy_target(name: str, module: str, attr: str) -> None:
    """Register a target import path, deferring the import to first use."""
    if name in _REGISTRY or name in _LAZY:
        raise ValueError(f"target {name!r} already registered "
                         f"(registered: {list_targets()})")
    _LAZY[name] = (module, attr)


def list_targets() -> list:
    """Names of every registered target (lazy ones included), sorted."""
    return sorted(set(_REGISTRY) | set(_LAZY))


def get_target(name) -> Target:
    """Resolve a target by name (or pass a Target instance through).

    Unknown names raise ``ValueError`` listing what *is* registered, so the
    error message doubles as discovery.
    """
    if not isinstance(name, str):               # already a Target
        return name
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name in _LAZY:
        module, attr = _LAZY[name]
        target = getattr(importlib.import_module(module), attr)
        register_target(target, overwrite=True)
        return target
    raise ValueError(f"unknown target {name!r}; "
                     f"registered targets: {list_targets()}")


# --------------------------------------------------------------------------- #
# The XLA target (the former Creator.translate backend="xla" body)
# --------------------------------------------------------------------------- #


class XLATarget:
    """jit/XLA lowering against a TPU-class HWSpec; the SynthesisReport is
    the Vivado-estimation analogue (memory_analysis as resource utilization,
    roofline + 8-channel meter as timing/power estimation)."""

    name = "xla"
    default_hw = TPU_V5E
    options_cls = XLAOptions
    requires_stepper = False

    def options_from_knobs(self, knobs: Dict[str, Any]) -> XLAOptions:
        return XLAOptions()

    def translate(self, cfg, params, st,
                  options: XLAOptions) -> Tuple[SynthesisReport,
                                                XLADeployment]:
        hw = options.hw or self.default_hw
        kind = options.kind or st.shape.kind
        abstract = st.abstract_inputs()
        if st.mesh is not None:
            from jax.sharding import NamedSharding
            from repro.model.lm import batch_pspecs

            param_sh = st.shardings(st.schema)
            bspecs = batch_pspecs(st.cfg, st.shape, st.mesh_cfg)
            batch_sh = {k: NamedSharding(st.mesh, v)
                        for k, v in bspecs.items()}
            ctxmgr = st.mesh
        else:
            param_sh = batch_sh = None
            import contextlib

            ctxmgr = contextlib.nullcontext()

        trc = get_tracer()
        t0 = time.perf_counter()
        with ctxmgr:
            with trc.span("xla.lower", arch=st.cfg.name, kind=kind):
                if kind == "train":
                    if param_sh is not None:
                        from jax.sharding import NamedSharding
                        from repro.model.layers import tree_map_pspec
                        from repro.optim.adamw import opt_state_schema

                        opt_sh = tree_map_pspec(
                            lambda s: NamedSharding(st.mesh, s.pspec),
                            opt_state_schema(st.schema, st.mesh_cfg))
                        fn = jax.jit(st.train_fn(),
                                     in_shardings=(param_sh, opt_sh,
                                                   batch_sh),
                                     donate_argnums=(0, 1))
                    else:
                        fn = jax.jit(st.train_fn(), donate_argnums=(0, 1))
                    lowered = fn.lower(abstract["params"],
                                       abstract["opt_state"],
                                       abstract["batch"])
                elif kind == "prefill":
                    fn = jax.jit(st.prefill_fn()) if param_sh is None \
                        else jax.jit(st.prefill_fn(),
                                     in_shardings=(param_sh, batch_sh))
                    lowered = fn.lower(abstract["params"], abstract["batch"])
                else:
                    if param_sh is not None:
                        from jax.sharding import NamedSharding
                        from repro.model.layers import tree_map_pspec

                        cache_sh = tree_map_pspec(
                            lambda s: NamedSharding(st.mesh, s.pspec),
                            st.cache_schema())
                        fn = jax.jit(st.decode_fn(),
                                     in_shardings=(param_sh,
                                                   batch_sh["tokens"],
                                                   cache_sh),
                                     donate_argnums=(2,))
                    else:
                        fn = jax.jit(st.decode_fn(), donate_argnums=(2,))
                    lowered = fn.lower(abstract["params"],
                                       abstract["batch"]["tokens"],
                                       abstract["cache"])
            with trc.span("xla.compile", arch=st.cfg.name, kind=kind):
                compiled = lowered.compile()
        compile_s = time.perf_counter() - t0

        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        n_dev = st.mesh.size if st.mesh is not None else 1

        model_flops = options.model_flops
        if model_flops is None:
            model_flops = model_flops_estimate(st.cfg, st.shape)
        rep = roofline(arch=st.cfg.name, shape=st.shape.name,
                       mesh=f"{n_dev}dev", n_devices=n_dev, cost=cost,
                       hlo_text=hlo, model_flops=model_flops, hw=hw)
        ch = meter_channels(hlo, n_dev, hw)

        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        est_latency = rep.step_s
        est_energy = ch.total_joules + hw.idle_w * est_latency
        syn = SynthesisReport(
            model=st.cfg.name, target=hw.name,
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            fits=peak <= hw.hbm_bytes,
            utilization=peak / hw.hbm_bytes,
            flops=rep.flops_per_device, bytes_accessed=rep.bytes_per_device,
            wire_bytes=rep.wire_bytes_per_device,
            est_latency_s=est_latency,
            est_power_w=est_energy / est_latency if est_latency else 0.0,
            est_energy_j=est_energy,
            est_gop_per_j=(rep.model_flops / 1e9) / est_energy / max(n_dev, 1)
            if est_energy else 0.0,
            bottleneck=rep.bottleneck,
            channels=ch.seconds, channel_joules=ch.joules,
            compile_seconds=compile_s, backend=self.name)
        dep = XLADeployment(fn=compiled, hw=hw, hlo_text=hlo,
                            cost={"flops": rep.flops_per_device,
                                  "bytes_accessed": rep.bytes_per_device,
                                  "wire_bytes": rep.wire_bytes_per_device})
        return syn, dep


XLA_TARGET = register_target(XLATarget())
register_lazy_target("rtl", "repro.rtl.backend", "RTL_TARGET")
