"""Core configuration dataclasses for the ElasticAI-JAX framework.

Everything in the system — model construction, parameter schemas, sharding,
dry-run input specs, the energy model — derives from these frozen configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (routed + optional shared)."""

    n_experts: int
    top_k: int
    d_expert: int                  # per-routed-expert FFN hidden size
    n_shared: int = 0              # number of always-on shared experts
    d_shared: int = 0              # hidden size of EACH shared expert
    capacity_factor: float = 1.25  # per-expert token capacity multiplier
    aux_loss_coef: float = 0.01    # load-balance auxiliary loss weight
    router_dtype: str = "float32"  # router math always runs in f32
    impl: str = "psum"             # "psum" | "a2a" | "dense" (oracle)
    first_dense: int = 0           # number of leading dense (non-MoE) layers
    d_ff_dense: int = 0            # FFN hidden of those leading dense layers


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""

    d_state: int = 64
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    chunk: int = 256               # SSD chunk length (parallel scan blocking)
    conv_width: int = 4


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 ("Finch") block configuration."""

    head_size: int = 64
    decay_lora: int = 64           # rank of the data-dependent decay LoRA
    chunk: int = 128               # chunked-recurrence block length


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder-decoder models (whisper)."""

    n_layers: int
    n_heads: int
    d_ff: int
    n_positions: int = 1500        # precomputed frame embeddings (stub frontend)


@dataclass(frozen=True)
class LSTMConfig:
    """The paper's own model family: LSTM for time-series (traffic flow)."""

    hidden: int = 20
    n_layers: int = 1
    in_features: int = 6           # lags of the traffic-flow series
    out_features: int = 1
    seq_len: int = 6


@dataclass(frozen=True)
class Conv1dConfig:
    """TCN-style depthwise conv stack for multichannel sensor windows.

    The paper's pervasive-computing setting beyond the LSTM: ``n_blocks``
    depthwise, strided 1-D conv blocks (one ``kernel``-tap filter per
    channel) with a hard activation between, then a dense readout over the
    flattened final feature map.
    """

    channels: int = 3              # sensor channels (e.g. 3-axis IMU)
    seq_len: int = 16              # window length in samples
    kernel: int = 3                # taps per channel filter
    stride: int = 2
    n_blocks: int = 2
    out_features: int = 1
    act: str = "hard_tanh"

    def block_lens(self) -> Tuple[int, ...]:
        """Per-block output lengths: t' = (t - kernel)//stride + 1."""
        lens, t = [], self.seq_len
        for _ in range(self.n_blocks):
            t = (t - self.kernel) // self.stride + 1
            if t < 1:
                raise ValueError(
                    f"conv1d window collapses: seq_len={self.seq_len} "
                    f"kernel={self.kernel} stride={self.stride} "
                    f"n_blocks={self.n_blocks}")
            lens.append(t)
        return tuple(lens)

    @property
    def flat_features(self) -> int:
        """Input width of the dense head (last block length × channels)."""
        return self.block_lens()[-1] * self.channels


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "audio", "vlm", "hybrid", "ssm", "lstm",
            "conv1d")
BLOCK_KINDS = ("attn", "moe", "mamba2", "rwkv6", "shared_attn")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default: d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"                   # "rmsnorm" | "layernorm"
    act: str = "silu"                       # "silu" (swiglu) | "gelu" (2-matrix)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None
    lstm: Optional[LSTMConfig] = None
    conv1d: Optional[Conv1dConfig] = None
    frontend: Optional[str] = None          # "audio" | "vision" (stub embeddings)
    n_frontend_tokens: int = 0              # visual/audio tokens prepended/encoded
    frontend_dim: int = 0                   # raw embedding dim from the stub
    shared_attn_every: int = 0              # zamba2: shared attn block cadence
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 128
    dtype: str = "bfloat16"
    # Remat policy for the layer stack: "full" | "dots" | "none"
    remat: str = "full"
    # perf levers (see EXPERIMENTS.md §Perf):
    # replicate the input embedding table (vocab-sharded gather lowers to a
    # masked-select + all-reduce pattern; the table is ~1 GB f32)
    embed_replicated: bool = False
    # chunk the CE loss over positions (needed only when the vocab cannot be
    # sharded; the chunk-slice transpose pads cotangents back to full size)
    ce_chunked: bool = True

    # ---- derived ----------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind sequence (length n_layers)."""
        if self.family in ("lstm", "conv1d"):
            return ()
        if self.family == "ssm":
            return ("rwkv6",) * self.n_layers
        if self.family == "hybrid":
            return ("mamba2",) * self.n_layers
        if self.family == "moe":
            assert self.moe is not None
            k = ["attn"] * self.moe.first_dense
            k += ["moe"] * (self.n_layers - self.moe.first_dense)
            return tuple(k)
        return ("attn",) * self.n_layers

    def shared_attn_points(self) -> Tuple[int, ...]:
        """Layer indices AFTER which the zamba2 shared block is applied."""
        if self.shared_attn_every <= 0:
            return ()
        return tuple(
            i for i in range(self.n_layers) if (i + 1) % self.shared_attn_every == 0
        )

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Rough parameter counts (used by the energy model / MODEL_FLOPS).
    def param_count(self) -> int:
        from repro.model.lm import param_schema  # local import: avoid cycle

        schema = param_schema(self)
        import math

        import jax
        from repro.model.layers import is_pspec

        return sum(
            math.prod(leaf.shape)          # python ints: no int32 overflow
            for leaf in jax.tree.leaves(schema, is_leaf=is_pspec)
        )

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        total = self.param_count()
        if self.moe is None:
            return total
        m = self.moe
        per_expert = 3 * self.d_model * m.d_expert
        n_moe_layers = self.n_layers - m.first_dense
        inactive = (m.n_experts - m.top_k) * per_expert * n_moe_layers
        return total - inactive


# ---------------------------------------------------------------------------
# Input-shape config (the assigned shape grid)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                      # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Paper's own workload: one LSTM inference (time-series window).
SHAPES_LSTM = {
    "infer_1": ShapeConfig("infer_1", "prefill", 6, 1),
    "train_batch": ShapeConfig("train_batch", "train", 6, 64),
}

# TCN-style sensor workload: one conv1d inference (multichannel window).
SHAPES_CONV1D = {
    "infer_1": ShapeConfig("infer_1", "prefill", 16, 1),
    "train_batch": ShapeConfig("train_batch", "train", 16, 64),
}


def shape_table_for(cfg: ModelConfig) -> dict:
    """The {name: ShapeConfig} table this arch family draws from — the one
    place the family→table mapping lives (dryrun/examples look shapes up
    here instead of re-spelling the family switch)."""
    if cfg.family == "lstm":
        return SHAPES_LSTM
    if cfg.family == "conv1d":
        return SHAPES_CONV1D
    return SHAPES


def shapes_for(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which assigned shapes run for this arch (skips documented in DESIGN.md)."""
    if cfg.family in ("lstm", "conv1d"):
        return tuple(shape_table_for(cfg))
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):  # sub-quadratic: run long_500k
        names.append("long_500k")
    return tuple(names)


def skipped_shapes_for(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family in ("ssm", "hybrid", "lstm", "conv1d"):
        return ()
    return ("long_500k",)


# ---------------------------------------------------------------------------
# Mesh / parallelism config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def tp_axis(self) -> str:
        return "model"

    def axis_size(self, name: str) -> int:
        return dict(zip(self.axes, self.shape)).get(name, 1)


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))
SMOKE_MESH = MeshConfig((1, 1), ("data", "model"))


@dataclass(frozen=True)
class ParallelismConfig:
    """Runtime parallelism knobs (hillclimb levers)."""

    grad_compression: bool = False     # int8 ring DP all-reduce (optim.compress)
    pipeline_stages: int = 0           # >0: pod axis becomes PP
    # shard the KV cache's seq axis over "model" when kv heads don't divide
    # tp (otherwise the cache is replicated 16×) — §Perf cell B lever
    seq_shard_decode: bool = False
    scan_layers: bool = False          # scan (fast compile) vs unroll (exact cost)
    param_dtype: str = "float32"       # master params
    compute_dtype: str = "bfloat16"
    # attention implementation: "ref" (XLA, exact cost) | "flash" (Pallas
    # template; TPU execution) | "template_stub" (negligible-cost stand-in
    # for dry-run lowering; the hillclimb adds the template's analytic cost)
    attn_impl: str = "ref"
    # grouped-GQA attention: contract q-head groups against UNREPEATED K/V
    # instead of materializing H/KV-times-repeated K/V (hillclimb lever;
    # exactness asserted in tests/test_gqa_grouped.py)
    gqa_grouped: bool = False
