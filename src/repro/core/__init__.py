# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# The deployment-target API (DESIGN.md §8) is re-exported here as the
# public surface: register a Target, translate through the registry, get
# back the uniform Deployment artifact.
from repro.core.target import (DEFAULT_N_RUNS, Deployment,  # noqa: F401
                               Target, TargetOptions, XLADeployment,
                               XLAOptions, get_target, list_targets,
                               register_lazy_target, register_target)
