"""The ElasticAI-Creator analogue: build → translate → estimate.

The paper: *"the trained and optimized model can be translated to a hardware
accelerator in the RTL representation by simply pressing a button"*. Here the
button is :meth:`Creator.translate` — a thin dispatcher over the
deployment-target registry (:mod:`repro.core.target`). Every registered
target turns a built stepper into the same two artifacts: a
:class:`SynthesisReport` (the Vivado-estimation analogue) and a
:class:`~repro.core.target.Deployment` (callable, measurable, savable).

No FPGA knowledge needed from the developer: pick a registered arch config
(or compose one from registered components), call ``translate`` with a
target name, read the report, iterate (see :mod:`repro.core.workflow`).
The pre-registry spellings — ``translate(st, backend="rtl", **rtl_formats)``
and :meth:`Creator.measure_rtl` — still work but emit a
``DeprecationWarning`` and forward to the registry path.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core import registry
from repro.core.report import MeasurementReport, SynthesisReport
from repro.core.target import (DEFAULT_N_RUNS, Deployment, TargetOptions,
                               XLADeployment, get_target,
                               model_flops_estimate)
from repro.core.types import (MeshConfig, ModelConfig, ParallelismConfig,
                              ShapeConfig, SMOKE_MESH)
from repro.energy.hw import HWSpec, TPU_V5E
from repro.model.lm import Stepper


@dataclass
class Creator:
    """Builds steppers from registered components and translates them."""

    hw: HWSpec = TPU_V5E

    def validate(self, cfg: ModelConfig) -> Dict[str, registry.Component]:
        return registry.validate_config(cfg)

    def build(self, cfg: ModelConfig, shape: ShapeConfig,
              mesh_cfg: MeshConfig = SMOKE_MESH,
              par: Optional[ParallelismConfig] = None,
              mesh=None) -> Stepper:
        self.validate(cfg)
        return Stepper(cfg, shape, mesh_cfg, par or ParallelismConfig(),
                       mesh=mesh)

    # ------------------------------------------------------------------ #
    # Stage 2: translate (= synthesize) + estimation report
    # ------------------------------------------------------------------ #
    def translate(self, st: Stepper, *, target="xla",
                  options: Optional[TargetOptions] = None,
                  params=None, kind: Optional[str] = None,
                  model_flops: Optional[float] = None,
                  backend: Optional[str] = None,
                  **rtl_formats) -> Tuple[SynthesisReport, Deployment]:
        """Press the button: returns (SynthesisReport, Deployment).

        ``target`` is a registered target name (``"xla"``, ``"rtl"``, ...;
        see :func:`repro.core.target.list_targets`) or a Target instance.
        Target-specific knobs ride in ``options`` — the target's options
        dataclass (e.g. ``RTLOptions(w_fmt=..., emulator_mode=...)``);
        ``None`` means the target's defaults. ``params`` are the trained
        weights (targets that need them initialize from the stepper when
        omitted). ``kind`` / ``model_flops`` are convenience spellings for
        the matching options fields; precedence: a value already set on
        ``options`` wins over the loose argument, and ``kind`` is ignored
        by targets whose options have no ``kind`` field (the RTL target
        always lowers the full model graph, as before the redesign).

        ``backend=`` and loose Q-format kwargs are the deprecated PR-1/2
        spelling; they forward here after a ``DeprecationWarning``.
        """
        if backend is not None or rtl_formats:
            warnings.warn(
                "Creator.translate(backend=..., **rtl_formats) is "
                "deprecated; use translate(st, target=..., "
                "options=<TargetOptions>)", DeprecationWarning, stacklevel=2)
            target = backend or target
            if rtl_formats:
                if target != "rtl":
                    raise TypeError(
                        f"unexpected kwargs {sorted(rtl_formats)} for "
                        f"target {target!r}")
                if options is not None:
                    raise TypeError(
                        "pass either options= or loose Q-format kwargs "
                        f"({sorted(rtl_formats)}), not both — the loose "
                        "kwargs would silently rebuild options from "
                        "defaults")
                from repro.rtl.backend import RTLOptions

                options = RTLOptions(**rtl_formats)
        tgt = get_target(target)
        if options is None:
            options = tgt.options_cls()
        if not isinstance(options, tgt.options_cls):
            raise TypeError(
                f"target {tgt.name!r} expects options of type "
                f"{tgt.options_cls.__name__}, got "
                f"{type(options).__name__}")
        if kind is not None and hasattr(options, "kind"):
            options = dataclasses.replace(options, kind=kind)
        if model_flops is None and options.model_flops is None:
            model_flops = model_flops_estimate(st.cfg, st.shape)
        options = options.filled(hw=self.hw, model_flops=model_flops)
        from repro.obs import get_tracer

        with get_tracer().span("creator.translate", target=tgt.name,
                               arch=st.cfg.name):
            return tgt.translate(st.cfg, params, st, options)

    # ------------------------------------------------------------------ #
    # Stage 3: execute + measure (container hardware = our Elastic Node)
    # ------------------------------------------------------------------ #
    def measure(self, fn, args, *, model: str, model_flops: float,
                n_runs: int = DEFAULT_N_RUNS, hw: Optional[HWSpec] = None
                ) -> MeasurementReport:
        """Thin wrapper over :meth:`Deployment.measure`: a raw callable is
        wrapped into an :class:`XLADeployment` on the Creator's HWSpec."""
        dep = fn if isinstance(fn, Deployment) else XLADeployment(
            fn=fn, hw=hw or self.hw)
        return dep.measure(tuple(args), model=model,
                           model_flops=model_flops, n_runs=n_runs,
                           hw=hw or getattr(dep, "hw", self.hw))

    def measure_rtl(self, exe, x, *, model: str, model_flops: float,
                    hw: Optional[HWSpec] = None,
                    n_runs: int = DEFAULT_N_RUNS) -> MeasurementReport:
        """Deprecated: the RTL Deployment measures itself —
        ``deployment.measure((x,), model=..., model_flops=...)``."""
        warnings.warn(
            "Creator.measure_rtl is deprecated; call "
            "deployment.measure((x,), ...) on the Deployment returned by "
            "translate(st, target='rtl')", DeprecationWarning, stacklevel=2)
        return exe.measure((x,), model=model, model_flops=model_flops,
                           n_runs=n_runs, hw=hw)
