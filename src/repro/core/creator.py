"""The ElasticAI-Creator analogue: build → translate → estimate.

The paper: *"the trained and optimized model can be translated to a hardware
accelerator in the RTL representation by simply pressing a button"*. Here the
button is :meth:`Creator.translate` — ``jax.jit(step).lower().compile()``
against the target mesh — and the returned :class:`SynthesisReport` is the
Vivado-estimation analogue (resource utilization from ``memory_analysis``,
timing/power from the roofline + 8-channel meter).

No FPGA knowledge needed from the developer: pick a registered arch config
(or compose one from registered components), call ``translate``, read the
report, iterate (see :mod:`repro.core.workflow`).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core import registry
from repro.core.report import MeasurementReport, SynthesisReport
from repro.core.types import (MeshConfig, ModelConfig, ParallelismConfig,
                              ShapeConfig, SMOKE_MESH)
from repro.energy.hw import HWSpec, TPU_V5E
from repro.energy.meter import meter_channels
from repro.energy.roofline import roofline
from repro.model.lm import Stepper


@dataclass
class Creator:
    """Builds steppers from registered components and translates them."""

    hw: HWSpec = TPU_V5E

    def validate(self, cfg: ModelConfig) -> Dict[str, registry.Component]:
        return registry.validate_config(cfg)

    def build(self, cfg: ModelConfig, shape: ShapeConfig,
              mesh_cfg: MeshConfig = SMOKE_MESH,
              par: Optional[ParallelismConfig] = None,
              mesh=None) -> Stepper:
        self.validate(cfg)
        return Stepper(cfg, shape, mesh_cfg, par or ParallelismConfig(),
                       mesh=mesh)

    # ------------------------------------------------------------------ #
    # Stage 2: translate (= synthesize) + estimation report
    # ------------------------------------------------------------------ #
    def translate(self, st: Stepper, *, kind: Optional[str] = None,
                  model_flops: Optional[float] = None,
                  backend: str = "xla", params=None, **rtl_formats):
        """Returns (SynthesisReport, compiled_executable).

        ``backend="xla"`` (default) lowers through jit/XLA against the TPU
        HWSpec.  ``backend="rtl"`` runs the ElasticAI-Creator codegen
        analogue instead: lower to the fixed-point dataflow IR, emit the
        VHDL-like template artifacts, and return an
        :class:`~repro.rtl.backend.RTLExecutable` whose bit-exact integer
        emulator stands in for the deployed accelerator. ``params`` (trained
        weights), Q-format kwargs (``w_fmt``/``act_fmt``/``state_fmt``) and
        ``emulator_mode`` ("fused" single-dispatch kernel, default, or the
        "pallas"/"jnp" per-step cross-check schedules) are only meaningful
        for the RTL backend.
        """
        if backend == "rtl":
            from repro.energy.hw import XC7S15
            from repro.rtl.backend import translate_rtl

            if params is None:
                params, _ = st.init()
            if model_flops is None:
                from repro.launch.dryrun import model_flops_estimate

                model_flops = model_flops_estimate(st.cfg, st.shape)
            hw = self.hw if self.hw.clock_hz else XC7S15
            return translate_rtl(st.cfg, params, hw=hw,
                                 model_flops=model_flops, **rtl_formats)
        if backend != "xla":
            raise ValueError(f"unknown backend {backend!r}; "
                             f"expected 'xla' or 'rtl'")
        kind = kind or st.shape.kind
        abstract = st.abstract_inputs()
        if st.mesh is not None:
            from jax.sharding import NamedSharding
            from repro.model.layers import tree_map_pspec
            from repro.model.lm import batch_pspecs
            from repro.optim.adamw import opt_state_schema

            param_sh = st.shardings(st.schema)
            bspecs = batch_pspecs(st.cfg, st.shape, st.mesh_cfg)
            batch_sh = {k: NamedSharding(st.mesh, v)
                        for k, v in bspecs.items()}
            ctxmgr = st.mesh
        else:
            param_sh = batch_sh = None
            import contextlib

            ctxmgr = contextlib.nullcontext()

        t0 = time.time()
        with ctxmgr:
            if kind == "train":
                if param_sh is not None:
                    from jax.sharding import NamedSharding
                    from repro.model.layers import tree_map_pspec
                    from repro.optim.adamw import opt_state_schema

                    opt_sh = tree_map_pspec(
                        lambda s: NamedSharding(st.mesh, s.pspec),
                        opt_state_schema(st.schema, st.mesh_cfg))
                    fn = jax.jit(st.train_fn(),
                                 in_shardings=(param_sh, opt_sh, batch_sh),
                                 donate_argnums=(0, 1))
                else:
                    fn = jax.jit(st.train_fn(), donate_argnums=(0, 1))
                lowered = fn.lower(abstract["params"], abstract["opt_state"],
                                   abstract["batch"])
            elif kind == "prefill":
                fn = jax.jit(st.prefill_fn()) if param_sh is None else jax.jit(
                    st.prefill_fn(), in_shardings=(param_sh, batch_sh))
                lowered = fn.lower(abstract["params"], abstract["batch"])
            else:
                if param_sh is not None:
                    from jax.sharding import NamedSharding
                    from repro.model.layers import tree_map_pspec

                    cache_sh = tree_map_pspec(
                        lambda s: NamedSharding(st.mesh, s.pspec),
                        st.cache_schema())
                    fn = jax.jit(st.decode_fn(),
                                 in_shardings=(param_sh,
                                               batch_sh["tokens"], cache_sh),
                                 donate_argnums=(2,))
                else:
                    fn = jax.jit(st.decode_fn(), donate_argnums=(2,))
                lowered = fn.lower(abstract["params"],
                                   abstract["batch"]["tokens"],
                                   abstract["cache"])
            compiled = lowered.compile()
        compile_s = time.time() - t0

        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        n_dev = st.mesh.size if st.mesh is not None else 1

        if model_flops is None:
            from repro.launch.dryrun import model_flops_estimate

            model_flops = model_flops_estimate(st.cfg, st.shape)
        rep = roofline(arch=st.cfg.name, shape=st.shape.name,
                       mesh=f"{n_dev}dev", n_devices=n_dev, cost=cost,
                       hlo_text=hlo, model_flops=model_flops, hw=self.hw)
        ch = meter_channels(hlo, n_dev, self.hw)

        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        est_latency = rep.step_s
        est_energy = ch.total_joules + self.hw.idle_w * est_latency
        gop = 2.0 * model_flops / 1e9 / max(n_dev, 1)  # OP = 2×MAC convention
        return SynthesisReport(
            model=st.cfg.name, target=self.hw.name,
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            fits=peak <= self.hw.hbm_bytes,
            utilization=peak / self.hw.hbm_bytes,
            flops=rep.flops_per_device, bytes_accessed=rep.bytes_per_device,
            wire_bytes=rep.wire_bytes_per_device,
            est_latency_s=est_latency,
            est_power_w=est_energy / est_latency if est_latency else 0.0,
            est_energy_j=est_energy,
            est_gop_per_j=(rep.model_flops / 1e9) / est_energy / max(n_dev, 1)
            if est_energy else 0.0,
            bottleneck=rep.bottleneck,
            channels=ch.seconds, channel_joules=ch.joules,
            compile_seconds=compile_s), compiled

    # ------------------------------------------------------------------ #
    # Stage 3: execute + measure (container hardware = our Elastic Node)
    # ------------------------------------------------------------------ #
    def measure(self, fn, args, *, model: str, model_flops: float,
                n_runs: int = 20, hw: Optional[HWSpec] = None
                ) -> MeasurementReport:
        hw = hw or self.hw
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(n_runs):
            out = fn(*args)
        jax.block_until_ready(out)
        lat = (time.time() - t0) / n_runs
        energy = hw.energy_j(lat)
        return MeasurementReport(
            model=model, platform="container-cpu(Elastic-Node proxy)",
            latency_s=lat, power_w=hw.active_w, energy_j=energy,
            gop_per_j=(model_flops / 1e9) / energy if energy else 0.0,
            n_runs=n_runs)

    def measure_rtl(self, exe, x, *, model: str, model_flops: float,
                    hw: Optional[HWSpec] = None,
                    n_runs: int = 1) -> MeasurementReport:
        """Stage 3 for the RTL backend: execute the bit-exact emulator (the
        deployed accelerator's proxy) and read latency/power off its
        cycle-accurate schedule — emulator cycles × clock, duty-cycled
        power via :meth:`HWSpec.energy_j`. Repeated measurement replays the
        emulator's compiled program — no retrace, no weight re-upload."""
        from repro.rtl.backend import measure_rtl

        return measure_rtl(exe, x, model=model, model_flops=model_flops,
                           hw=hw, n_runs=n_runs)
