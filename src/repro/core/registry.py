"""Component registry — what "supported by the ElasticAI-Creator" means.

A *translatable component* carries up to three implementations:
  ref       — pure-jnp definition (trainable, the oracle)
  template  — the hand-optimized hardware template (Pallas kernel), the RTL
              analogue; ``None`` where plain XLA lowering is already optimal
  quantized — fixed-point / int8 variant

``Creator.validate`` walks a model config's block kinds and fails fast if a
kind has no registered component — the paper's "models must be built from
supported components" rule, enforced mechanically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.types import ModelConfig


@dataclass(frozen=True)
class Component:
    name: str
    ref: str                         # dotted path of the jnp reference impl
    template: Optional[str] = None   # dotted path of the Pallas template ops
    quantized: Optional[str] = None
    notes: str = ""


_REGISTRY: Dict[str, Component] = {}


def register(c: Component) -> None:
    _REGISTRY[c.name] = c


def get(name: str) -> Component:
    if name not in _REGISTRY:
        raise KeyError(
            f"component {name!r} is not supported by the creator; "
            f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_components() -> Dict[str, Component]:
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Built-in component library
# ---------------------------------------------------------------------------

register(Component(
    "attn", ref="repro.model.attention.attn_apply",
    template="repro.kernels.flash_attention.ops",
    quantized="repro.quant.ptq",
    notes="GQA self/cross attention; flash template for long sequences"))
register(Component(
    "attn_dense", ref="repro.model.attention.attn_apply",
    template="repro.kernels.flash_attention.ops"))
register(Component(
    "moe", ref="repro.model.moe.moe_apply",
    notes="EP dispatch is collective-bound, no kernel template needed"))
register(Component(
    "mamba2", ref="repro.model.ssm.mamba_apply",
    template="repro.kernels.mamba2.ops"))
register(Component(
    "rwkv6", ref="repro.model.rwkv.rwkv_time_mix",
    template="repro.kernels.rwkv6.ops"))
register(Component(
    "enc", ref="repro.model.transformer._apply_enc_block"))
register(Component(
    "dec", ref="repro.model.transformer._apply_dec_block"))
register(Component(
    "lstm", ref="repro.model.lstm.lstm_apply",
    template="repro.kernels.lstm_cell.ops",
    quantized="repro.quant.qat.make_qat_lstm_apply",
    notes="the paper's own accelerator (Table I)"))
register(Component(
    "conv1d", ref="repro.model.conv1d.conv1d_apply",
    template="repro.rtl.oplib",
    notes="TCN-style depthwise sensor stack (rtl 'conv1d' hw template)"))
register(Component(
    "mlp", ref="repro.model.layers.apply_mlp",
    quantized="repro.kernels.quant_matmul.ops"))


def validate_config(cfg: ModelConfig) -> Dict[str, Component]:
    """Every block kind of this model must be a registered component."""
    from repro.model.transformer import group_structure

    used = {}
    if cfg.family in ("lstm", "conv1d"):
        used[cfg.family] = get(cfg.family)
        return used
    for kind, _ in group_structure(cfg):
        used[kind] = get(kind)
    used["mlp"] = get("mlp")
    return used
