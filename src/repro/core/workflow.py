"""The ElasticAI-Workflow: three stages + feedback loop, as a first-class API.

Stage 1  design/train/quantize (PyTorch in the paper; JAX here)
Stage 2  translate + synthesize -> estimation reports
Stage 3  deploy + measure (per-region channels) -> measurement reports

"The optimization loop will not terminate until the developers are satisfied
with the reports" — :meth:`Workflow.run` iterates candidate tweaks (provided
by an ``optimizer`` callback) until the requirement predicate accepts the
stage-3 measurement or the tweak budget is exhausted. This same loop, run
manually against the roofline reports, is the §Perf hillclimbing methodology
in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.creator import Creator
from repro.core.report import (DesignReport, MeasurementReport,
                               SynthesisReport, compare)
from repro.core.types import ModelConfig, ShapeConfig, SMOKE_MESH
from repro.energy.hw import HWSpec, TPU_V5E


@dataclass
class Requirement:
    """What "the application requires" — the workflow's stop condition."""

    max_latency_s: float = float("inf")
    max_energy_j: float = float("inf")
    min_gop_per_j: float = 0.0
    max_eval_loss: float = float("inf")

    def satisfied(self, d: DesignReport, m: MeasurementReport) -> bool:
        return (m.latency_s <= self.max_latency_s
                and m.energy_j <= self.max_energy_j
                and m.gop_per_j >= self.min_gop_per_j
                and d.eval_loss <= self.max_eval_loss)


@dataclass
class WorkflowRecord:
    """One trip around the loop — design, estimate, measurement, verdict."""

    iteration: int
    knobs: Dict[str, Any]
    design: DesignReport
    synthesis: SynthesisReport
    measurement: MeasurementReport
    est_vs_meas: Dict[str, float]
    satisfied: bool


@dataclass
class Workflow:
    """Drives stage1/stage2/stage3 for one model family.

    The user supplies three callables, mirroring how a DL developer plugs
    their task into the ElasticAI toolchain:
      train_fn(knobs)  -> (params, DesignReport, apply_fn)
      step_builder(knobs, params) -> (fn, args, model_flops)   # deployable
    """

    creator: Creator
    train_fn: Callable[[Dict[str, Any]], Tuple[Any, DesignReport, Any]]
    step_builder: Callable[[Dict[str, Any], Any], Tuple[Any, tuple, float]]
    stepper_builder: Optional[Callable[[Dict[str, Any]], Any]] = None
    # "xla" measures the jitted step on the container; "rtl" runs the
    # codegen backend: template artifacts + cycle-accurate emulator
    # (requires stepper_builder; fmt_builder maps knobs -> Q-format kwargs).
    backend: str = "xla"
    fmt_builder: Optional[Callable[[Dict[str, Any]], Dict[str, Any]]] = None
    history: List[WorkflowRecord] = field(default_factory=list)

    def run_once(self, knobs: Dict[str, Any], it: int = 0) -> WorkflowRecord:
        # Stage 1 — design / train / quantize
        params, design, _ = self.train_fn(knobs)
        if self.backend == "rtl":
            return self._run_once_rtl(knobs, it, params, design)
        # Stage 2 — translate + estimate
        if self.stepper_builder is not None:
            st = self.stepper_builder(knobs)
            syn, _ = self.creator.translate(st)
        else:
            fn, args, model_flops = self.step_builder(knobs, params)
            syn = self._synth_from_fn(fn, args, model_flops)
        # Stage 3 — deploy + measure
        fn, args, model_flops = self.step_builder(knobs, params)
        meas = self.creator.measure(jax.jit(fn), args,
                                    model=design.model,
                                    model_flops=model_flops)
        rec = WorkflowRecord(
            iteration=it, knobs=dict(knobs), design=design, synthesis=syn,
            measurement=meas, est_vs_meas=compare(syn, meas),
            satisfied=False)
        self.history.append(rec)
        return rec

    def _run_once_rtl(self, knobs, it, params, design) -> WorkflowRecord:
        """Stages 2+3 against the generated accelerator instead of XLA."""
        assert self.stepper_builder is not None, \
            "backend='rtl' needs stepper_builder (the model to lower)"
        st = self.stepper_builder(knobs)
        fmts = self.fmt_builder(knobs) if self.fmt_builder else {}
        syn, exe = self.creator.translate(st, backend="rtl", params=params,
                                          **fmts)
        _, args, model_flops = self.step_builder(knobs, params)
        meas = self.creator.measure_rtl(exe, args[-1], model=design.model,
                                        model_flops=model_flops)
        rec = WorkflowRecord(
            iteration=it, knobs=dict(knobs), design=design, synthesis=syn,
            measurement=meas, est_vs_meas=compare(syn, meas),
            satisfied=False)
        self.history.append(rec)
        return rec

    def _synth_from_fn(self, fn, args, model_flops) -> SynthesisReport:
        from repro.energy.meter import meter_channels
        from repro.energy.roofline import roofline
        import time

        t0 = time.time()
        lowered = jax.jit(fn).lower(*jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), args))
        compiled = lowered.compile()
        dt = time.time() - t0
        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        hw = self.creator.hw
        rep = roofline(arch="wf", shape="wf", mesh="1dev", n_devices=1,
                       cost=cost, hlo_text=hlo, model_flops=model_flops,
                       hw=hw)
        ch = meter_channels(hlo, 1, hw)
        est_latency = max(rep.step_s, 1e-12)
        est_energy = ch.total_joules + hw.idle_w * est_latency
        return SynthesisReport(
            model="wf", target=hw.name,
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            fits=mem.temp_size_in_bytes <= hw.hbm_bytes,
            utilization=mem.temp_size_in_bytes / hw.hbm_bytes,
            flops=rep.flops_per_device,
            bytes_accessed=rep.bytes_per_device,
            wire_bytes=rep.wire_bytes_per_device,
            est_latency_s=est_latency,
            est_power_w=est_energy / est_latency,
            est_energy_j=est_energy,
            est_gop_per_j=(model_flops / 1e9) / est_energy if est_energy else 0,
            bottleneck=rep.bottleneck, channels=ch.seconds,
            channel_joules=ch.joules, compile_seconds=dt)

    def run(self, requirement: Requirement,
            optimizer: Callable[[List[WorkflowRecord]], Optional[Dict[str, Any]]],
            initial_knobs: Dict[str, Any], max_iters: int = 8
            ) -> List[WorkflowRecord]:
        """The feedback loop: tweak → retrain → retranslate → remeasure."""
        knobs = dict(initial_knobs)
        for it in range(max_iters):
            rec = self.run_once(knobs, it)
            rec.satisfied = requirement.satisfied(rec.design, rec.measurement)
            if rec.satisfied:
                break
            nxt = optimizer(self.history)
            if nxt is None:
                break
            knobs = nxt
        return self.history
