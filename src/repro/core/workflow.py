"""The ElasticAI-Workflow: three stages + feedback loop, as a first-class API.

Stage 1  design/train/quantize (PyTorch in the paper; JAX here)
Stage 2  translate + synthesize -> estimation reports
Stage 3  deploy + measure (per-region channels) -> measurement reports

"The optimization loop will not terminate until the developers are satisfied
with the reports" — :meth:`Workflow.run` iterates candidate tweaks (provided
by an ``optimizer`` callback) until the requirement predicate accepts the
stage-3 measurement or the tweak budget is exhausted. This same loop, run
manually against the roofline reports, is the §Perf hillclimbing methodology
in EXPERIMENTS.md.

Every deployment target runs through the *same* :meth:`Workflow.run_once`:
stage 2 resolves the target from the registry and translates to the uniform
:class:`~repro.core.target.Deployment` artifact, stage 3 measures that
artifact. Target-specific knob mapping lives on the target
(``Target.options_from_knobs``), overridable per-workflow via
``options_from_knobs``. The PR-1/2 spellings (``backend=``, ``fmt_builder=``)
still construct but emit a ``DeprecationWarning`` and forward.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.creator import Creator
from repro.core.report import (DesignReport, MeasurementReport,
                               SynthesisReport, compare)
from repro.core.target import TargetOptions, XLADeployment, get_target


@dataclass
class Requirement:
    """What "the application requires" — the workflow's stop condition."""

    max_latency_s: float = float("inf")
    max_energy_j: float = float("inf")
    min_gop_per_j: float = 0.0
    max_eval_loss: float = float("inf")

    def satisfied(self, d: DesignReport, m: MeasurementReport) -> bool:
        return (m.latency_s <= self.max_latency_s
                and m.energy_j <= self.max_energy_j
                and m.gop_per_j >= self.min_gop_per_j
                and d.eval_loss <= self.max_eval_loss)


@dataclass
class WorkflowRecord:
    """One trip around the loop — design, estimate, measurement, verdict."""

    iteration: int
    knobs: Dict[str, Any]
    design: DesignReport
    synthesis: SynthesisReport
    measurement: MeasurementReport
    est_vs_meas: Dict[str, float]
    satisfied: bool
    #: ConformanceReport from the verify stage (None when verify=False)
    conformance: Optional[Any] = None
    #: ResilienceReport from the chaos stage (None when resilience=None)
    resilience: Optional[Any] = None
    #: AnalysisReport from the static-verifier stage (None for targets
    #: without one, or when the workflow runs with analyze="off")
    analysis: Optional[Any] = None


@dataclass
class Workflow:
    """Drives stage1/stage2/stage3 for one model family.

    The user supplies three callables, mirroring how a DL developer plugs
    their task into the ElasticAI toolchain:
      train_fn(knobs)  -> (params, DesignReport, apply_fn)
      step_builder(knobs, params) -> (fn, args, model_flops)   # deployable
    ``target`` names any registered deployment target; targets that must
    lower the real model graph (e.g. "rtl") additionally need
    ``stepper_builder``. ``options_from_knobs`` overrides the target's own
    knob→options mapping.
    """

    creator: Creator
    train_fn: Callable[[Dict[str, Any]], Tuple[Any, DesignReport, Any]]
    step_builder: Callable[[Dict[str, Any], Any], Tuple[Any, tuple, float]]
    stepper_builder: Optional[Callable[[Dict[str, Any]], Any]] = None
    target: str = "xla"
    options_from_knobs: Optional[
        Callable[[Dict[str, Any]], TargetOptions]] = None
    #: run the Elastic Node conformance stage (Deployment.verify) after
    #: every stage-3 measurement and attach its report to the record
    verify: bool = False
    #: optional scripted chaos stage: a ``repro.resilience.ChaosSpec`` to
    #: run against the deployed artifact after measurement (with graceful
    #: degradation to the XLA step fn); attaches a ResilienceReport
    resilience: Optional[Any] = None
    #: static-verifier gate override ("error" | "warn" | "off"): forwarded
    #: into the target options when they carry an ``analyze`` field (the
    #: RTL target does); the report lands in ``WorkflowRecord.analysis``
    analyze: Optional[str] = None
    # deprecated spellings (forwarded in __post_init__):
    backend: Optional[str] = None
    fmt_builder: Optional[Callable[[Dict[str, Any]], Dict[str, Any]]] = None
    history: List[WorkflowRecord] = field(default_factory=list)

    def __post_init__(self):
        if self.backend is not None:
            warnings.warn("Workflow(backend=...) is deprecated; use "
                          "Workflow(target=...)", DeprecationWarning,
                          stacklevel=3)
            self.target = self.backend
        if self.fmt_builder is not None:
            warnings.warn(
                "Workflow(fmt_builder=...) is deprecated; use "
                "options_from_knobs returning the target's options "
                "dataclass (or rely on Target.options_from_knobs)",
                DeprecationWarning, stacklevel=3)
            # the old loop only consumed fmt_builder on the RTL fork and
            # silently ignored it elsewhere — preserve that
            if self.options_from_knobs is None and self.target == "rtl":
                fb = self.fmt_builder

                def _from_fmts(knobs: Dict[str, Any]) -> TargetOptions:
                    from repro.rtl.backend import RTLOptions

                    return RTLOptions(**fb(knobs))

                self.options_from_knobs = _from_fmts

    def run_once(self, knobs: Dict[str, Any], it: int = 0) -> WorkflowRecord:
        """One loop iteration — the single code path for every target.

        Instrumented (DESIGN.md §11): the iteration runs under a
        ``workflow.run_once`` span with one child per stage
        (``workflow.stage1`` … ``workflow.stage3``, ``workflow.verify``),
        knobs attached as attrs — so a :class:`~repro.obs.RunTrace`
        captured around this call decomposes exactly where the loop spends
        its time, down to the emulator dispatches nested inside stage 3.
        """
        from repro.obs import get_tracer

        trc = get_tracer()
        with trc.span("workflow.run_once", iteration=it, target=self.target,
                      **{f"knob.{k}": v for k, v in knobs.items()}):
            # Stage 1 — design / train / quantize
            with trc.span("workflow.stage1", stage="design/train/quantize"):
                params, design, _ = self.train_fn(knobs)
            # Stage 2 — translate + estimate via the target registry
            with trc.span("workflow.stage2",
                          stage="translate/estimate") as s2:
                tgt = get_target(self.target)
                opts_fn = self.options_from_knobs or tgt.options_from_knobs
                options = opts_fn(knobs)
                if self.analyze is not None:
                    options = self._with_analyze(options)
                fn, args, model_flops = self.step_builder(knobs, params)
                if self.stepper_builder is not None:
                    st = self.stepper_builder(knobs)
                    syn, dep = self.creator.translate(
                        st, target=tgt, options=options, params=params,
                        model_flops=model_flops)
                elif getattr(tgt, "requires_stepper", False):
                    raise ValueError(f"target {tgt.name!r} needs "
                                     "stepper_builder (the model to lower)")
                else:
                    syn = self._synth_from_fn(fn, args, model_flops,
                                              model=design.model)
                    dep = XLADeployment(fn=None, hw=self.creator.hw)
                s2.set_attrs(model=design.model,
                             compile_seconds=syn.compile_seconds)
            # Stage 3 — deploy + measure through the uniform Deployment
            # artifact. Host-executed targets time the jitted step fn;
            # self-executing targets (the RTL emulator) ignore the bind
            # and measure themselves.
            with trc.span("workflow.stage3", stage="deploy/measure") as s3:
                dep = dep.bind_step(jax.jit(fn)) if fn is not None else dep
                meas = dep.measure(args, model=design.model,
                                   model_flops=model_flops)
                s3.set_attrs(latency_s=meas.latency_s,
                             latency_p99_s=meas.latency_p99_s)
            # Verify stage — the Elastic Node half of the paper's loop: the
            # same uniform Deployment API, so every target is conformance-
            # checked the same way the reference design is.
            conf = None
            if self.verify:
                with trc.span("workflow.verify") as sv:
                    conf = dep.verify(args, model=design.model,
                                      model_flops=model_flops)
                    sv.set_attrs(passed=conf.passed)
            # Analyze stage — the static verifier's report, produced by
            # graph-lowering targets during translate (DESIGN.md §13).
            # Surfaced as its own span so a RunTrace shows the gate even
            # though the work happened inside stage 2.
            analysis = getattr(dep, "analysis", None)
            if analysis is not None:
                with trc.span("workflow.analyze") as sa:
                    sa.set_attrs(passed=analysis.passed,
                                 errors=len(analysis.errors),
                                 warnings=len(analysis.warnings))
            # Resilience stage — scripted chaos against the deployed
            # artifact: fault injection under a guarded wrapper with
            # graceful RTL→XLA degradation, scored on the golden vectors.
            resil = None
            if self.resilience is not None:
                with trc.span("workflow.resilience") as sr:
                    resil = self._run_resilience(dep)
                    sr.set_attrs(passed=resil.passed,
                                 detected=resil.detected,
                                 degraded=resil.requests_degraded,
                                 lost=resil.requests_lost)
            rec = WorkflowRecord(
                iteration=it, knobs=dict(knobs), design=design,
                synthesis=syn, measurement=meas,
                est_vs_meas=compare(syn, meas), satisfied=False,
                conformance=conf, resilience=resil, analysis=analysis)
        self.history.append(rec)
        return rec

    def _with_analyze(self, options: TargetOptions) -> TargetOptions:
        """Force the workflow's ``analyze`` gate into the target options.
        ``"off"`` is a universal no-op; asking a target whose options have
        no ``analyze`` field (e.g. XLA's) to gate raises, so a knob that
        silently does nothing can't pass CI."""
        import dataclasses

        if not any(f.name == "analyze"
                   for f in dataclasses.fields(options)):
            if self.analyze == "off":
                return options
            raise ValueError(
                f"Workflow(analyze={self.analyze!r}): target "
                f"{self.target!r} options {type(options).__name__} have "
                "no 'analyze' field — only graph-lowering targets "
                "support the static-verifier gate")
        return dataclasses.replace(options, analyze=self.analyze)

    def _run_resilience(self, dep):
        """Run the configured :class:`~repro.resilience.ChaosSpec` against
        the deployed artifact. The fallback is the float oracle of the
        *same lowered graph* (``reference_apply``), jitted — the XLA
        deployment of the same model, same ``SynthesisReport`` lineage, so
        degradation changes the substrate (and its energy/accuracy class),
        not the function being served.
        """
        from repro.resilience import FallbackPolicy, run_chaos

        graph = getattr(dep, "graph", None)
        if graph is None:
            raise ValueError(
                "Workflow(resilience=...) needs a graph-carrying deployment"
                " (a self-executing target such as 'rtl') to generate "
                "golden vectors and an XLA fallback of the same design; "
                f"target {self.target!r} produced none")
        from repro.rtl.emulator import reference_apply

        fb = XLADeployment(fn=jax.jit(lambda x: reference_apply(graph, x)),
                           hw=self.creator.hw)
        return run_chaos(dep, self.resilience,
                         fallback=FallbackPolicy.to_xla(fb))

    def _synth_from_fn(self, fn, args, model_flops, *, model: str = "wf",
                       arch: Optional[str] = None) -> SynthesisReport:
        from repro.energy.meter import meter_channels
        from repro.energy.roofline import roofline
        from repro.obs import get_tracer
        import time

        arch = arch or model                 # attribute history to the model
        trc = get_tracer()
        t0 = time.perf_counter()             # monotonic: this is a duration
        with trc.span("xla.lower", arch=arch, kind="step_fn"):
            lowered = jax.jit(fn).lower(*jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), args))
        with trc.span("xla.compile", arch=arch, kind="step_fn"):
            compiled = lowered.compile()
        dt = time.perf_counter() - t0
        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        hw = self.creator.hw
        rep = roofline(arch=arch, shape="wf", mesh="1dev", n_devices=1,
                       cost=cost, hlo_text=hlo, model_flops=model_flops,
                       hw=hw)
        ch = meter_channels(hlo, 1, hw)
        est_latency = max(rep.step_s, 1e-12)
        est_energy = ch.total_joules + hw.idle_w * est_latency
        return SynthesisReport(
            model=model, target=hw.name,
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            fits=mem.temp_size_in_bytes <= hw.hbm_bytes,
            utilization=mem.temp_size_in_bytes / hw.hbm_bytes,
            flops=rep.flops_per_device,
            bytes_accessed=rep.bytes_per_device,
            wire_bytes=rep.wire_bytes_per_device,
            est_latency_s=est_latency,
            est_power_w=est_energy / est_latency,
            est_energy_j=est_energy,
            est_gop_per_j=(model_flops / 1e9) / est_energy if est_energy else 0,
            bottleneck=rep.bottleneck, channels=ch.seconds,
            channel_joules=ch.joules, compile_seconds=dt)

    def run(self, requirement: Requirement,
            optimizer: Callable[[List[WorkflowRecord]], Optional[Dict[str, Any]]],
            initial_knobs: Dict[str, Any], max_iters: int = 8
            ) -> List[WorkflowRecord]:
        """The feedback loop: tweak → retrain → retranslate → remeasure."""
        knobs = dict(initial_knobs)
        for it in range(max_iters):
            rec = self.run_once(knobs, it)
            rec.satisfied = requirement.satisfied(rec.design, rec.measurement)
            if rec.satisfied:
                break
            nxt = optimizer(self.history)
            if nxt is None:
                break
            knobs = nxt
        return self.history
