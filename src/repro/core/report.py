"""Stage reports — the artifacts the ElasticAI-Workflow's feedback loop reads.

Stage 1 (design/train)   -> DesignReport      (accuracy, quantization error)
Stage 2 (translate/synth)-> SynthesisReport   (resources, estimated time/energy)
Stage 3 (deploy/measure) -> MeasurementReport (measured time/energy)

The paper's Table I is exactly a (SynthesisReport, MeasurementReport) pair
for one accelerator; ``benchmarks/table1_energy.py`` reproduces it.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict



@dataclass
class DesignReport:
    model: str
    train_loss: float
    eval_loss: float
    quant_rms_error: float = 0.0
    weight_fmt: str = ""
    act_fmt: str = ""
    params: int = 0
    notes: str = ""

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=2)


@dataclass
class SynthesisReport:
    """What "Vivado" (here: XLA lower+compile) estimates before deployment."""

    model: str
    target: str                      # hw spec name
    # resource utilization analogue
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    fits: bool = True
    utilization: float = 0.0         # peak bytes / device memory
    # timing/power estimation analogue
    flops: float = 0.0
    bytes_accessed: float = 0.0
    wire_bytes: float = 0.0
    est_latency_s: float = 0.0
    est_power_w: float = 0.0
    est_energy_j: float = 0.0
    est_gop_per_j: float = 0.0
    bottleneck: str = ""
    channels: Dict[str, float] = field(default_factory=dict)  # per-region s
    channel_joules: Dict[str, float] = field(default_factory=dict)
    compile_seconds: float = 0.0
    # RTL backend extras (backend="xla" reports leave these at defaults)
    backend: str = "xla"
    resources: Dict[str, float] = field(default_factory=dict)  # dsp/bram/lut
    n_artifacts: int = 0             # emitted template files (rtl only)

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=2)


@dataclass
class MeasurementReport:
    """What the Elastic Node measures (here: wall-clock execution on the
    container hardware + the power model; honest proxy, see DESIGN.md)."""

    model: str
    platform: str
    latency_s: float
    power_w: float
    energy_j: float
    gop_per_j: float = 0.0
    n_runs: int = 0
    target: str = ""                 # deployment-target name ("xla"/"rtl"/…)
    # tail latency: percentiles over the per-run execution latencies on the
    # measuring substrate (host wall-clock for XLA; the emulator proxy's
    # per-dispatch wall-clock for RTL, where ``latency_s`` itself stays the
    # fabric cycle model). Deployment readiness is a tail question, not a
    # mean — Venieris et al. 2018 (PAPERS.md).
    latency_p50_s: float = 0.0
    latency_p99_s: float = 0.0
    per_channel_j: Dict[str, float] = field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=2)


def compare(syn: SynthesisReport, meas: MeasurementReport) -> Dict[str, float]:
    """Estimation-vs-measurement deltas — the paper's Table I format."""
    def rel(est, m):
        return (est - m) / m if m else 0.0

    return {
        "latency_rel_err": rel(syn.est_latency_s, meas.latency_s),
        "power_rel_err": rel(syn.est_power_w, meas.power_w),
        "energy_rel_err": rel(syn.est_energy_j, meas.energy_j),
    }
