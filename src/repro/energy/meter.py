"""8-channel energy meter — the Elastic Node PAC1934 analogue.

The Elastic Node's defining feature is *per-function-region* power
measurement (two PAC1934 meters → 8 channels), so developers can see where
the energy goes and optimize that region. Our per-device compiled HLO is
partitioned into 8 "function regions"; each gets a roofline-derived time and
an energy estimate from :class:`HWSpec` power numbers.

Channels (region → what the PAC1934 channel would be wired to):
  1 mxu        — dot/convolution FLOPs (the DSP-slice array)
  2 vpu        — elementwise math (exp/tanh/mul/…)
  3 reduce     — reductions (softmax/norm sums)
  4 hbm        — main-memory traffic (bytes accessed)
  5 ici        — inter-chip collectives (wire bytes)
  6 gather     — embedding/cache gathers + scatters
  7 layout     — copies/transposes/reshapes (data movement)
  8 other      — control, host transfer, everything else

Dot FLOPs are exact (contracting dims parsed from the HLO); elementwise and
reduce channels are element-count estimates — attribution granularity, the
same honesty level as a shunt-resistor channel.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

from repro.energy.hw import HWSpec, TPU_V5E
from repro.energy.roofline import _DTYPE_BYTES, _SHAPE_RE, parse_collectives

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "exponential", "log",
    "tanh", "logistic", "maximum", "minimum", "select", "compare", "and",
    "or", "not", "xor", "negate", "abs", "sign", "rsqrt", "sqrt", "convert",
    "clamp", "floor", "ceil", "round-nearest-afz", "exponential-minus-one",
    "cosine", "sine", "is-finite",
}
_REDUCE = {"reduce", "reduce-window"}
_GATHER = {"gather", "scatter", "dynamic-slice", "dynamic-update-slice"}
_LAYOUT = {"copy", "transpose", "reshape", "broadcast", "concatenate",
           "slice", "pad", "reverse", "iota", "bitcast", "bitcast-convert"}

_OP_RE = re.compile(r"=\s*((?:\()?[\w\[\],{}\s]*?(?:\))?)\s*([\w-]+)\(")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

VPU_FLOPS = 4e12          # v5e vector unit estimate (8 lanes × …): assumption
GATHER_BW_FRACTION = 0.5  # gathers achieve ~half of streaming HBM bandwidth

# per-channel active power split (ASSUMPTION, sums to ~TPU_V5E.active_w)
CHANNEL_WATTS = {
    "mxu": 90.0, "vpu": 25.0, "reduce": 10.0, "hbm": 40.0,
    "ici": 15.0, "gather": 8.0, "layout": 7.0, "other": 5.0,
}


def _shape_elems(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclass
class ChannelReport:
    """Per-channel work, time and energy for one compiled step."""

    work: Dict[str, float] = field(default_factory=dict)     # flops or bytes
    seconds: Dict[str, float] = field(default_factory=dict)
    joules: Dict[str, float] = field(default_factory=dict)
    op_counts: Dict[str, int] = field(default_factory=dict)

    @property
    def total_seconds(self) -> float:
        return max(self.seconds.values()) if self.seconds else 0.0

    @property
    def serial_seconds(self) -> float:
        return sum(self.seconds.values())

    @property
    def total_joules(self) -> float:
        return sum(self.joules.values())

    def table(self) -> str:
        rows = [f"{'channel':>8} {'work':>12} {'ms':>9} {'mJ':>9} {'ops':>6}"]
        for ch in CHANNEL_WATTS:
            rows.append(
                f"{ch:>8} {self.work.get(ch, 0):12.3e} "
                f"{self.seconds.get(ch, 0)*1e3:9.3f} "
                f"{self.joules.get(ch, 0)*1e3:9.3f} "
                f"{self.op_counts.get(ch, 0):6d}")
        return "\n".join(rows)


_DEF_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?[\w\[\]\{\},\s]*?\)?)\s*[\w\-]+\(")
_OPND_RE = re.compile(r"\(\s*%([\w\.\-]+)")


def _dot_flops(line: str, out_elems: int, defs) -> float:
    """Exact dot FLOPs: 2 · output_elems · contraction size. Operand shapes
    are looked up in the definition table (compiled HLO references operands
    by name only)."""
    dims_m = _DOT_DIMS_RE.search(line)
    if not dims_m:
        return 2.0 * out_elems  # unknown: count 1 MAC/elem
    lhs_dims = None
    om = _OPND_RE.search(line.split("=", 1)[1])
    if om and om.group(1) in defs:
        shapes = _SHAPE_RE.findall(defs[om.group(1)])
        if shapes:
            lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
    if lhs_dims is None:  # fallback: operand shapes inline (unoptimized HLO)
        shapes = _SHAPE_RE.findall(line.split("(", 1)[1])
        if not shapes:
            return 2.0 * out_elems
        lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
    contract = 1
    for idx in dims_m.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def meter_channels(hlo_text: str, n_devices: int,
                   hw: HWSpec = TPU_V5E) -> ChannelReport:
    rep = ChannelReport()
    w = {k: 0.0 for k in CHANNEL_WATTS}
    counts = {k: 0 for k in CHANNEL_WATTS}

    # pass 1: definition table %name -> output-shape string
    defs = {}
    for line in hlo_text.splitlines():
        dm = _DEF_RE.match(line.strip())
        if dm:
            defs[dm.group(1)] = dm.group(2)

    for line in hlo_text.splitlines():
        ls = line.strip()
        m = _OP_RE.search(ls)
        if not m or ls.startswith("ENTRY") or ls.startswith("HloModule"):
            continue
        out_shape, op = m.group(1), m.group(2)
        elems = _shape_elems(out_shape)
        byts = sum(_DTYPE_BYTES.get(d, 4) * max(1, _shape_elems(f"{d}[{dim}]"))
                   for d, dim in _SHAPE_RE.findall(out_shape)) if elems else 0
        byts = 0
        for d, dim in _SHAPE_RE.findall(out_shape):
            if d in _DTYPE_BYTES:
                n = 1
                for x in dim.split(","):
                    if x:
                        n *= int(x)
                byts += n * _DTYPE_BYTES[d]
        if op in ("dot", "convolution"):
            w["mxu"] += _dot_flops(ls, elems, defs)
            counts["mxu"] += 1
        elif op in _REDUCE:
            w["reduce"] += elems * 8.0      # ~input elems (est. 8× output)
            counts["reduce"] += 1
        elif op in _ELEMENTWISE or op == "fusion":
            w["vpu"] += elems
            counts["vpu"] += 1
        elif op in _GATHER:
            w["gather"] += byts * 2.0       # read + write
            counts["gather"] += 1
        elif op in _LAYOUT:
            w["layout"] += byts * 2.0
            counts["layout"] += 1
        elif any(op.startswith(k) for k in
                 ("all-", "reduce-scatter", "collective")):
            pass                             # handled via parse_collectives
        else:
            w["other"] += byts
            counts["other"] += 1

    coll = parse_collectives(hlo_text, n_devices)
    w["ici"] = coll.total_wire_bytes
    counts["ici"] = sum(coll.counts.values())
    # HBM channel: all bytes touched by compute ops (approximation: fusion
    # outputs + layout + gather traffic)
    w["hbm"] = (w["vpu"] * 2.0      # elementwise read+write, ~1B/elem avg…
                + w["layout"] + w["gather"])

    secs = {
        "mxu": w["mxu"] / hw.peak_flops,
        "vpu": w["vpu"] / VPU_FLOPS,
        "reduce": w["reduce"] / VPU_FLOPS,
        "hbm": w["hbm"] / hw.hbm_bw,
        "ici": (w["ici"] / hw.link_bw) if hw.link_bw else 0.0,
        "gather": w["gather"] / (hw.hbm_bw * GATHER_BW_FRACTION),
        "layout": w["layout"] / hw.hbm_bw,
        "other": w["other"] / hw.hbm_bw,
    }
    # energy: channel power × channel active time
    joules = {ch: CHANNEL_WATTS[ch] * secs[ch] for ch in CHANNEL_WATTS}
    rep.work, rep.seconds, rep.joules, rep.op_counts = w, secs, joules, counts
    return rep
