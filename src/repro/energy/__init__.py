from repro.energy.hw import HWSpec, TPU_V5E, XC7S15
from repro.energy.meter import ChannelReport, meter_channels
from repro.energy.roofline import (CollectiveStats, RooflineReport,
                                   parse_collectives, roofline)
