"""Hardware specs — the constants behind every estimate in the system.

TPU v5e numbers are the brief's three roofline constants; the power split is
an assumption (marked) used only for GOP/J-style energy reporting, never for
roofline fractions. The XC7S15 entry reproduces the paper's Table-I platform
so ``benchmarks/table1_energy.py`` can compare like for like.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HWSpec:
    name: str
    peak_flops: float            # FLOP/s (bf16 for TPU; DSP MAC*2 for FPGA)
    hbm_bw: float                # bytes/s main-memory bandwidth
    link_bw: float               # bytes/s per ICI link (0: single device)
    vmem_bytes: int              # on-chip fast memory (VMEM / BRAM)
    hbm_bytes: int               # device memory capacity
    active_w: float              # power while computing (ASSUMPTION for v5e)
    idle_w: float                # power while gated/idle
    mxu_align: int = 128         # matmul tile alignment
    clock_hz: float = 0.0        # fabric clock (FPGA targets; 0 for TPU)

    def energy_j(self, seconds: float, duty: float = 1.0) -> float:
        return seconds * (self.active_w * duty + self.idle_w * (1 - duty))


TPU_V5E = HWSpec(
    name="tpu-v5e",
    peak_flops=197e12,           # bf16, per brief
    hbm_bw=819e9,                # per brief
    link_bw=50e9,                # per brief (~50 GB/s/link ICI)
    vmem_bytes=128 * 1024 * 1024,
    hbm_bytes=16 * 1024 ** 3,
    active_w=200.0,              # ASSUMPTION — documented in DESIGN.md §6
    idle_w=60.0,                 # ASSUMPTION
)

# The paper's platform: Spartan-7 XC7S15 @ 100 MHz (Table I).
# 20 DSP48 slices * 100 MHz * 2 OP/MAC = 4 GOP/s peak; 10 BRAM36 = 45 KiB.
XC7S15 = HWSpec(
    name="xc7s15",
    peak_flops=4e9,
    hbm_bw=0.4e9,                # BRAM-fed, effectively on-chip
    link_bw=0.0,
    vmem_bytes=45 * 1024,
    hbm_bytes=45 * 1024,
    active_w=0.071,              # Table I: 71 mW measured
    idle_w=0.010,
    clock_hz=100e6,              # Table I: 100 MHz fabric clock
)

# Named-spec lookup: Deployment manifests record ``hw`` by name; targets and
# artifact loaders resolve it back through here.
HW_BY_NAME = {spec.name: spec for spec in (TPU_V5E, XC7S15)}


def get_hw(name: str) -> HWSpec:
    try:
        return HW_BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown HWSpec {name!r}; "
                       f"known: {sorted(HW_BY_NAME)}") from None
