"""Three-term roofline model driven by the compiled dry-run artifact.

    compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
    memory_s     = HLO_bytes_per_device / HBM_bw
    collective_s = wire_bytes_per_device / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (post-SPMD per-device
module). Collective bytes are NOT in cost_analysis — we parse the compiled
HLO text and convert each collective's *local operand size* into per-device
wire bytes with the standard ring formulas (group size parsed from
``replica_groups``). Collectives inside ``while`` bodies are flagged — the
production paths here deliberately unroll, so trip-count multiplication is
never silently wrong.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.energy.hw import HWSpec, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "f4e2m1fn": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]' -> bytes; tuples handled by caller via findall."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [groups, group_size]
        return int(m.group(2))
    return default


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    local_bytes: Dict[str, int] = field(default_factory=dict)   # operand bytes
    wire_bytes: Dict[str, float] = field(default_factory=dict)  # per-device
    in_while: int = 0
    ops: List[Tuple[str, int, int, float]] = field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_local_bytes(self) -> int:
        return sum(self.local_bytes.values())


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Sum collective operand sizes + ring-model wire bytes from (post-SPMD)
    compiled HLO text."""
    st = CollectiveStats()
    in_while_depth = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        # crude while-body tracking: computations are emitted as blocks whose
        # names contain "while" when XLA outlines loop bodies/conditions
        if ls.startswith("%") and "while" in ls.split("(")[0] and ls.endswith("{"):
            in_while_depth += 1
        if in_while_depth and ls == "}":
            in_while_depth -= 1
        m = re.search(r"=\s*((?:\()?[\w\[\]\{\},\s]*(?:\))?)\s*("
                      + "|".join(_COLLECTIVE_KINDS) + r")(-start|-done)?\(", ls)
        if not m:
            continue
        out_shape, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":  # -start carries the shapes; don't double count
            continue
        if phase == "-start":
            # async start outputs a (operand, result) tuple: take the result
            # (the larger element) rather than summing both
            sizes = [_shape_bytes(f"{d}[{dims}]")
                     for d, dims in _SHAPE_RE.findall(out_shape)]
            out_b = max(sizes) if sizes else 0
        else:
            out_b = _shape_bytes(out_shape)
        n = _group_size(ls, n_devices)
        # per-device wire bytes (ring algorithms)
        if kind == "all-reduce":
            opnd = out_b
            wire = 2 * opnd * (n - 1) / max(n, 1)
        elif kind == "all-gather":
            opnd = out_b // max(n, 1)
            wire = out_b * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            opnd = out_b * n                       # input is n× the output
            wire = out_b * (n - 1)
        elif kind == "all-to-all":
            opnd = out_b
            wire = opnd * (n - 1) / max(n, 1)
        else:  # collective-permute
            opnd = out_b
            wire = opnd
        st.counts[kind] = st.counts.get(kind, 0) + 1
        st.local_bytes[kind] = st.local_bytes.get(kind, 0) + opnd
        st.wire_bytes[kind] = st.wire_bytes.get(kind, 0.0) + wire
        if in_while_depth:
            st.in_while += 1
        st.ops.append((kind, n, opnd, wire))
    return st


def normalize_cost(cost) -> Dict[str, float]:
    """jax's ``Compiled.cost_analysis()`` returned ``[dict]`` per-partition in
    older releases and a bare dict in newer ones — accept both, everywhere."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float            # 6·N·D (train) / 2·N_active·D (serve)
    useful_ratio: float           # model_flops / (flops_per_device * chips)
    step_s: float                 # max of the three terms (no-overlap bound)
    mfu: float                    # model_flops / (chips*peak*step_s)
    memory_analysis: str = ""
    collectives: Optional[CollectiveStats] = None

    def row(self) -> str:
        return (f"{self.arch:>18} {self.shape:>11} {self.mesh:>8} "
                f"{self.compute_s*1e3:9.2f} {self.memory_s*1e3:9.2f} "
                f"{self.collective_s*1e3:9.2f}  {self.bottleneck:>10} "
                f"{self.useful_ratio:6.2f} {self.mfu*100:6.1f}%")


def roofline(
    *, arch: str, shape: str, mesh: str, n_devices: int,
    cost: Dict[str, float], hlo_text: str, model_flops: float,
    hw: HWSpec = TPU_V5E, memory_analysis: str = "",
) -> RooflineReport:
    cost = normalize_cost(cost)
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text, n_devices)
    wire = coll.total_wire_bytes

    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    collective_s = wire / hw.link_bw if hw.link_bw else 0.0
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    total_flops = flops * n_devices
    useful = model_flops / total_flops if total_flops else 0.0
    mfu = (model_flops / (n_devices * hw.peak_flops * step_s)
           if step_s > 0 else 0.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, n_devices=n_devices,
        flops_per_device=flops, bytes_per_device=byts,
        wire_bytes_per_device=wire, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bottleneck=bottleneck,
        model_flops=model_flops, useful_ratio=useful, step_s=step_s, mfu=mfu,
        memory_analysis=memory_analysis, collectives=coll)


HEADER = (f"{'arch':>18} {'shape':>11} {'mesh':>8} {'comp_ms':>9} "
          f"{'mem_ms':>9} {'coll_ms':>9}  {'bottleneck':>10} {'useful':>6} "
          f"{'MFU':>6}")
