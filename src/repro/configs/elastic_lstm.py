"""The paper's own accelerator workload: LSTM traffic-flow predictor.

Sized to match Table I / ref [11]: hidden=20, window=6, univariate input —
≈21.1 kOP per inference, matching the paper's 5.33 GOP/J at 71 mW / 57.25 µs
(5.33e9 OP/J x 71e-3 W x 57.25e-6 s = 21.7 kOP).
"""
from repro.core.types import LSTMConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="elastic-lstm",
        family="lstm",
        n_layers=1,
        d_model=20,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=0,
        lstm=LSTMConfig(hidden=20, n_layers=1, in_features=1, out_features=1,
                        seq_len=6),
    )


def smoke() -> ModelConfig:
    return config()  # already tiny — the paper's scale IS smoke scale
