"""Architecture config registry (``--arch <id>``).

Ten assigned architectures from the public pool + the paper's own LSTM model.
Each module exposes ``config()`` (the exact published configuration) and
``smoke()`` (a reduced same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.core.types import ModelConfig

_ARCH_MODULES = {
    "stablelm-12b": "stablelm_12b",
    "stablelm-3b": "stablelm_3b",
    "yi-9b": "yi_9b",
    "qwen3-32b": "qwen3_32b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-tiny": "whisper_tiny",
    "internvl2-1b": "internvl2_1b",
    "zamba2-7b": "zamba2_7b",
    "rwkv6-7b": "rwkv6_7b",
    "elastic-lstm": "elastic_lstm",
    "elastic-conv1d": "elastic_conv1d",
}

_PAPER_IDS = ("elastic-lstm", "elastic-conv1d")
ARCH_IDS = tuple(k for k in _ARCH_MODULES if k not in _PAPER_IDS)
ALL_IDS = tuple(_ARCH_MODULES)


def _mod(arch_id: str):
    if arch_id not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}"
        )
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    m = _mod(arch_id)
    return m.smoke() if smoke else m.config()


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke=smoke) for a in ALL_IDS}
