"""The conv1d sensor workload: TCN-style depthwise stack on the XC7S15.

A 3-channel (IMU-like) 16-sample window through two depthwise, stride-2
conv blocks (3 taps/channel) with hard_tanh between, then a dense readout —
the kind of always-on wearable pipeline the paper's pervasive-computing
setting targets. Sized like the LSTM reference design: a few hundred MACs
per inference, comfortably inside one DSP slice + one BRAM on the XC7S15.
"""
from repro.core.types import Conv1dConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="elastic-conv1d",
        family="conv1d",
        n_layers=2,
        d_model=3,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=0,
        conv1d=Conv1dConfig(channels=3, seq_len=16, kernel=3, stride=2,
                            n_blocks=2, out_features=1, act="hard_tanh"),
    )


def smoke() -> ModelConfig:
    return config()  # already tiny — the edge scale IS smoke scale
