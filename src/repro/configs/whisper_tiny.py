"""Whisper-tiny — encoder-decoder backbone; conv/mel frontend is a STUB.

``input_specs()`` feeds precomputed (batch, 1500, 384) frame embeddings to the
encoder per the brief. Positional scheme simplified to RoPE (backbone-only
reproduction; noted in DESIGN.md). [arXiv:2212.04356; unverified]
"""
from repro.core.types import EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,                     # decoder layers
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51_865,
        norm="layernorm",
        act="gelu",
        frontend="audio",
        n_frontend_tokens=1500,
        frontend_dim=384,
        encoder=EncoderConfig(n_layers=4, n_heads=6, d_ff=1536, n_positions=1500),
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
        n_frontend_tokens=16, frontend_dim=64,
        encoder=EncoderConfig(n_layers=2, n_heads=4, d_ff=128, n_positions=16),
    )
