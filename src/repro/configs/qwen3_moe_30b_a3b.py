"""Qwen3-30B-A3B — 128-expert top-8 MoE with qk-norm GQA. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.core.types import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,                       # routed-expert hidden
        vocab_size=151_936,
        qk_norm=True,
        norm="rmsnorm",
        act="silu",
        rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=128, top_k=8, d_expert=768),
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=512, vocab_pad_multiple=16,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32),
    )
