"""Yi-9B — llama-arch GQA decoder. [arXiv:2403.04652; hf]"""
from repro.core.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        family="dense",
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64_000,
        norm="rmsnorm",
        act="silu",
        rope_theta=5_000_000.0,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
    )
