"""Qwen3-32B — dense GQA decoder with qk-norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.core.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab_size=151_936,
        qk_norm=True,
        norm="rmsnorm",
        act="silu",
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
    )
