"""Zamba2-7B — 81 Mamba2 layers + a shared attention block every 6 layers.

Shared-block weights are reused at each invocation (per-invocation LoRA
adapters omitted — simplification noted in DESIGN.md). [arXiv:2411.15242; unverified]
"""
from repro.core.types import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        head_dim=112,
        d_ff=14336,                     # shared-block MLP hidden
        vocab_size=32_000,
        norm="rmsnorm",
        act="silu",
        rope_theta=10_000.0,
        ssm=SSMConfig(d_state=64, expand=2, headdim=64),
        shared_attn_every=6,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
        ssm=SSMConfig(d_state=16, expand=2, headdim=16, chunk=8, conv_width=4),
        shared_attn_every=2,
    )
