"""StableLM-2-12B — dense GQA decoder. [hf:stabilityai/stablelm-2-1_6b; hf]"""
from repro.core.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=160,
        d_ff=13824,
        vocab_size=100_352,
        norm="layernorm",
        act="silu",
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
    )
