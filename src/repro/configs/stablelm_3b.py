"""StableLM-3B — dense MHA (kv == heads) decoder.

[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.core.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab_size=50_304,
        norm="layernorm",
        act="silu",
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
    )
