"""RWKV6-7B ("Finch") — attention-free, data-dependent decay. [arXiv:2404.05892; hf]"""
from repro.core.types import ModelConfig, RWKVConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,                     # d_model / head_size
        n_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65_536,
        norm="layernorm",
        act="relu_sq",                  # RWKV channel-mix uses relu^2
        rwkv=RWKVConfig(head_size=64, decay_lora=64, chunk=128),
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
        rwkv=RWKVConfig(head_size=16, decay_lora=8, chunk=8),
    )
