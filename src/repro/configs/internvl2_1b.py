"""InternVL2-1B — InternViT (STUB patch embeddings) + Qwen2-0.5B LM backbone.

``input_specs()`` provides precomputed (batch, 256, 1024) patch embeddings,
projected into the LM and prepended to the token sequence. [arXiv:2404.16821; hf]
"""
from repro.core.types import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab_size=151_655,
        norm="rmsnorm",
        act="silu",
        rope_theta=1_000_000.0,
        frontend="vision",
        n_frontend_tokens=256,
        frontend_dim=1024,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
        n_frontend_tokens=8, frontend_dim=32,
    )
