"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed top-6.

First layer is dense (first_k_dense_replace=1, d_ff=10944). [arXiv:2401.06066; hf]
"""
from repro.core.types import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,                      # routed-expert hidden
        vocab_size=102_400,
        norm="rmsnorm",
        act="silu",
        rope_theta=10_000.0,
        moe=MoEConfig(
            n_experts=64,
            top_k=6,
            d_expert=1408,
            n_shared=2,
            d_shared=1408,
            first_dense=1,
            d_ff_dense=10944,
        ),
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=512, vocab_pad_multiple=16,
        moe=MoEConfig(
            n_experts=8, top_k=2, d_expert=32, n_shared=2, d_shared=32,
            first_dense=1, d_ff_dense=128,
        ),
    )
