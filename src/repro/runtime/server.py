"""Batched serving runtime: continuous-batching-lite with a fixed slot pool.

The production pattern kept intact at container scale:
  * a fixed pool of ``batch_slots`` sequences decodes in lock-step (one
    jitted ``decode_step`` per tick over the whole pool);
  * new requests are prefilled (jitted prefill) and inserted into free slots
    with their KV/state caches padded to ``max_len``;
  * finished sequences (EOS or length) free their slot immediately;
  * caches are donated buffer-to-buffer each tick (no reallocation).

For SSM/RWKV archs the "cache" is the recurrent state — same code path, the
pad is a no-op. Greedy or temperature sampling.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import MeshConfig, ModelConfig, ParallelismConfig
from repro.model.lm import make_decode_step, make_prefill_step
from repro.model.transformer import pad_cache
from repro.obs import MetricsRegistry, get_tracer
# PoolStats is re-exported from its new home so old imports keep working
from repro.serving.pool import DeploymentPool as _ServingPool
from repro.serving.pool import PoolStats  # noqa: F401  (compat re-export)


@dataclass
class ServerConfig:
    batch_slots: int = 4
    max_len: int = 128
    eos_token: int = 1
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    # per-request latency instrumentation (server clock; None until set)
    t_submit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


@dataclass
class ServerStats:
    """What one drain actually did — built from the server's metrics so
    callers stop re-deriving it from the request list.

    ``ttft_s`` / ``latency_s`` are histogram summaries
    (count/mean/p50/p95/p99...): time-to-first-token is submit → first
    token out of prefill; total latency is submit → retire.
    """

    ticks: int = 0
    submitted: int = 0
    admitted: int = 0
    retired: int = 0
    max_queue_depth: int = 0
    max_slots_busy: int = 0
    ttft_s: Dict[str, float] = field(default_factory=dict)
    latency_s: Dict[str, float] = field(default_factory=dict)


class DrainResult(list):
    """The retired requests (a plain list, as before) with the drain's
    :class:`ServerStats` riding along as ``.stats``.

    ``drained`` says whether the server actually emptied; a drain that
    tripped ``max_ticks`` comes back with ``drained=False`` and the
    still-in-flight requests in ``pending`` — partial progress instead of
    an exception that loses every retired request.
    """

    def __init__(self, requests, stats: ServerStats, *,
                 drained: bool = True, pending=()):
        super().__init__(requests)
        self.stats = stats
        self.drained = drained
        self.pending = list(pending)


class Server:
    def __init__(self, cfg: ModelConfig, params, scfg: ServerConfig,
                 mesh_cfg: MeshConfig, par: Optional[ParallelismConfig] = None,
                 mesh=None, metrics: Optional[MetricsRegistry] = None,
                 clock=time.perf_counter):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        par = par or ParallelismConfig(compute_dtype="float32")
        self._prefill = jax.jit(make_prefill_step(cfg, mesh_cfg, par, mesh))
        self._decode = jax.jit(make_decode_step(cfg, mesh_cfg, par, mesh),
                               donate_argnums=(2,))
        self._rng = np.random.default_rng(scfg.seed)
        self._slots: List[Optional[Request]] = [None] * scfg.batch_slots
        self._cache = None            # batched cache across slots
        self._last_tok = np.zeros((scfg.batch_slots, 1), np.int32)
        self._queue: List[Request] = []
        self._next_rid = 0
        self.requests: Dict[int, Request] = {}
        # observability: the server owns its registry (injectable for
        # tests); the clock is injectable too so latency histograms are
        # deterministic under test.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.clock = clock

    # ------------------------------------------------------------------ #
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, list(prompt), max_new_tokens,
                      t_submit=self.clock())
        self._queue.append(req)
        self.requests[rid] = req
        self.metrics.counter("server.submitted").inc()
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self._queue:
                break
            req = self._queue.pop(0)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            with get_tracer().span("server.prefill", rid=req.rid,
                                   prompt_len=len(req.prompt)):
                logits, cache = self._prefill(self.params,
                                              {"tokens": tokens})
                cache = pad_cache(cache, self.scfg.max_len)
                tok = self._sample(np.asarray(logits))
            req.out_tokens.append(int(tok[0]))
            req.t_first_token = self.clock()
            self.metrics.counter("server.admitted").inc()
            self.metrics.histogram("server.ttft_s").observe(
                req.t_first_token - req.t_submit)
            self._install(slot, req, cache, tok)

    def _install(self, slot: int, req, cache, tok) -> None:
        self._slots[slot] = req
        self._last_tok[slot, 0] = tok[0]
        if self._cache is None:
            # materialize the pool cache by tiling the first request's cache
            self._cache = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a] * self.scfg.batch_slots, axis=0), cache)
        else:
            self._cache = jax.tree.map(
                lambda pool, one: jax.lax.dynamic_update_slice_in_dim(
                    pool, one.astype(pool.dtype), slot, axis=0),
                self._cache, cache)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.scfg.temperature <= 0.0:
            return logits.argmax(-1).astype(np.int32)
        z = logits / self.scfg.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(len(row), p=row) for row in p],
                        np.int32)

    # ------------------------------------------------------------------ #
    def step(self) -> None:
        """One server tick: admit new work, decode the pool, retire done.

        Each tick records queue depth and slot occupancy (gauges track the
        max) plus admit/retire counters; every retiring request observes
        its total submit→retire latency.
        """
        mx = self.metrics
        mx.counter("server.ticks").inc()
        mx.gauge("server.queue_depth").set(len(self._queue))
        trc = get_tracer()
        with trc.span("server.tick", queue_depth=len(self._queue),
                      slots_busy=self._busy_slots()):
            self._admit()
            mx.gauge("server.slots_busy").set(self._busy_slots())
            if all(s is None for s in self._slots):
                return
            with trc.span("server.decode", slots_busy=self._busy_slots()):
                logits, self._cache = self._decode(
                    self.params, jnp.asarray(self._last_tok), self._cache)
                toks = self._sample(np.asarray(logits))
            for i, req in enumerate(self._slots):
                if req is None:
                    continue
                t = int(toks[i])
                req.out_tokens.append(t)
                self._last_tok[i, 0] = t
                if (t == self.scfg.eos_token
                        or len(req.out_tokens) >= req.max_new_tokens):
                    req.done = True
                    req.t_done = self.clock()
                    self._slots[i] = None
                    mx.counter("server.retired").inc()
                    mx.histogram("server.latency_s").observe(
                        req.t_done - req.t_submit)

    def _busy_slots(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    def stats(self) -> ServerStats:
        """The drain summary, straight from the metrics registry."""
        mx = self.metrics

        def _count(name):
            return mx.counter(name).value

        def _gmax(name):
            g = mx.gauge(name)
            return int(g.max) if g.max is not None else 0

        return ServerStats(
            ticks=_count("server.ticks"),
            submitted=_count("server.submitted"),
            admitted=_count("server.admitted"),
            retired=_count("server.retired"),
            max_queue_depth=_gmax("server.queue_depth"),
            max_slots_busy=_gmax("server.slots_busy"),
            ttft_s=mx.histogram("server.ttft_s").summary(),
            latency_s=mx.histogram("server.latency_s").summary())

    def run_until_drained(self, max_ticks: int = 10_000, *,
                          strict: bool = False) -> DrainResult:
        """Tick until queue and slots are empty. Returns the retired
        requests (list-compatible, as before) with ``.stats`` attached.

        Tripping ``max_ticks`` no longer throws away the work already done:
        the default returns a *partial* :class:`DrainResult` with
        ``drained=False`` and the in-flight requests in ``pending``.
        ``strict=True`` restores the old behavior — raise with the live
        queue/slot state so a wedged drain is diagnosable from the message
        alone."""
        ticks = 0
        while self._queue or any(s is not None for s in self._slots):
            self.step()
            ticks += 1
            if ticks > max_ticks:
                busy = [(i, s.rid, len(s.out_tokens), s.max_new_tokens)
                        for i, s in enumerate(self._slots) if s is not None]
                if strict:
                    raise RuntimeError(
                        "server did not drain within max_ticks="
                        f"{max_ticks}: {len(self._queue)} queued "
                        f"(rids {[r.rid for r in self._queue[:8]]}), "
                        f"{len(busy)} slots busy "
                        f"(slot, rid, out/max: {busy}); "
                        f"stats={self.stats()}")
                self.metrics.counter("server.drain_truncated").inc()
                pending = ([s for s in self._slots if s is not None]
                           + list(self._queue))
                done = [r for r in self.requests.values() if r.done]
                return DrainResult(sorted(done, key=lambda r: r.rid),
                                   self.stats(), drained=False,
                                   pending=sorted(pending,
                                                  key=lambda r: r.rid))
        return DrainResult(sorted(self.requests.values(),
                                  key=lambda r: r.rid), self.stats())


class DeploymentPool(_ServingPool):
    """Deprecated import site for the health-aware pool.

    The pool lives in :mod:`repro.serving.pool` now, rebuilt on the shared
    serving primitives (admission queue + router); this subclass keeps the
    old constructor and ``run_until_drained`` spellings alive as thin
    forwarding shims. Import :class:`repro.serving.DeploymentPool` and call
    :meth:`~repro.serving.pool.DeploymentPool.drain` instead.
    """

    def __init__(self, members, *, max_queue: int = 64,
                 max_wait_ticks: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None):
        warnings.warn(
            "repro.runtime.server.DeploymentPool moved to "
            "repro.serving.DeploymentPool (and run_until_drained() to "
            "drain()); this forwarding shim will be removed",
            DeprecationWarning, stacklevel=2)
        super().__init__(members, max_queue=max_queue,
                         max_wait_ticks=max_wait_ticks, metrics=metrics)

    def run_until_drained(self, max_ticks: int = 10_000) -> PoolStats:
        warnings.warn(
            "DeploymentPool.run_until_drained() is deprecated; use "
            "repro.serving.DeploymentPool.drain()",
            DeprecationWarning, stacklevel=2)
        return self.drain(max_ticks)
