"""Batched serving runtime: continuous-batching-lite with a fixed slot pool.

The production pattern kept intact at container scale:
  * a fixed pool of ``batch_slots`` sequences decodes in lock-step (one
    jitted ``decode_step`` per tick over the whole pool);
  * new requests are prefilled (jitted prefill) and inserted into free slots
    with their KV/state caches padded to ``max_len``;
  * finished sequences (EOS or length) free their slot immediately;
  * caches are donated buffer-to-buffer each tick (no reallocation).

For SSM/RWKV archs the "cache" is the recurrent state — same code path, the
pad is a no-op. Greedy or temperature sampling.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import MeshConfig, ModelConfig, ParallelismConfig, ShapeConfig
from repro.model.lm import make_decode_step, make_prefill_step
from repro.model.transformer import pad_cache


@dataclass
class ServerConfig:
    batch_slots: int = 4
    max_len: int = 128
    eos_token: int = 1
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg: ModelConfig, params, scfg: ServerConfig,
                 mesh_cfg: MeshConfig, par: Optional[ParallelismConfig] = None,
                 mesh=None):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        par = par or ParallelismConfig(compute_dtype="float32")
        self._prefill = jax.jit(make_prefill_step(cfg, mesh_cfg, par, mesh))
        self._decode = jax.jit(make_decode_step(cfg, mesh_cfg, par, mesh),
                               donate_argnums=(2,))
        self._rng = np.random.default_rng(scfg.seed)
        self._slots: List[Optional[Request]] = [None] * scfg.batch_slots
        self._cache = None            # batched cache across slots
        self._last_tok = np.zeros((scfg.batch_slots, 1), np.int32)
        self._queue: List[Request] = []
        self._next_rid = 0
        self.requests: Dict[int, Request] = {}

    # ------------------------------------------------------------------ #
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, list(prompt), max_new_tokens)
        self._queue.append(req)
        self.requests[rid] = req
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self._queue:
                break
            req = self._queue.pop(0)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, cache = self._prefill(self.params, {"tokens": tokens})
            cache = pad_cache(cache, self.scfg.max_len)
            tok = self._sample(np.asarray(logits))
            req.out_tokens.append(int(tok[0]))
            self._install(slot, req, cache, tok)

    def _install(self, slot: int, req, cache, tok) -> None:
        self._slots[slot] = req
        self._last_tok[slot, 0] = tok[0]
        if self._cache is None:
            # materialize the pool cache by tiling the first request's cache
            self._cache = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a] * self.scfg.batch_slots, axis=0), cache)
        else:
            self._cache = jax.tree.map(
                lambda pool, one: jax.lax.dynamic_update_slice_in_dim(
                    pool, one.astype(pool.dtype), slot, axis=0),
                self._cache, cache)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.scfg.temperature <= 0.0:
            return logits.argmax(-1).astype(np.int32)
        z = logits / self.scfg.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(len(row), p=row) for row in p],
                        np.int32)

    # ------------------------------------------------------------------ #
    def step(self) -> None:
        """One server tick: admit new work, decode the pool, retire done."""
        self._admit()
        if all(s is None for s in self._slots):
            return
        logits, self._cache = self._decode(
            self.params, jnp.asarray(self._last_tok), self._cache)
        toks = self._sample(np.asarray(logits))
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            t = int(toks[i])
            req.out_tokens.append(t)
            self._last_tok[i, 0] = t
            if (t == self.scfg.eos_token
                    or len(req.out_tokens) >= req.max_new_tokens):
                req.done = True
                self._slots[i] = None

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while self._queue or any(s is not None for s in self._slots):
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("server did not drain")
        return sorted(self.requests.values(), key=lambda r: r.rid)
