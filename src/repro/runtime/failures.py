"""Failure injection — how we test fault tolerance without a cluster.

``FailureInjector`` raises :class:`PreemptionError` at configured steps
(deterministically or with a seeded probability), standing in for SIGTERM
preemptions / ICI link flaps / host OOMs. The trainer must recover from any
of these by restoring the last checkpoint and replaying the data stream.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Set

import numpy as np


class PreemptionError(RuntimeError):
    """A node went away (SIGTERM / hardware fault)."""


class StragglerWarning(RuntimeWarning):
    """A step exceeded the straggler threshold."""


@dataclass
class FailureInjector:
    fail_at_steps: Set[int] = field(default_factory=set)
    fail_prob: float = 0.0
    seed: int = 0
    max_failures: int = 10
    _rng: Optional[np.random.Generator] = None
    _count: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def maybe_fail(self, step: int) -> None:
        if self._count >= self.max_failures:
            return
        if step in self.fail_at_steps:
            self.fail_at_steps = self.fail_at_steps - {step}  # fire once
            self._count += 1
            raise PreemptionError(f"injected preemption at step {step}")
        if self.fail_prob > 0 and self._rng.random() < self.fail_prob:
            self._count += 1
            raise PreemptionError(f"injected preemption at step {step}")
