"""Fault-tolerant training loop: checkpoint/restart, deterministic replay,
straggler monitoring, elastic mesh restart.

The recovery contract:
  * batches are a pure function of ``(seed, step)`` (see repro.data), so a
    restore at step k replays batch k exactly — no data loss or duplication;
  * checkpoints are atomic and async (repro.checkpoint);
  * on :class:`PreemptionError` (or any device error) the loop restores the
    last checkpoint and continues — the same path a real cluster agent takes
    after rescheduling;
  * ``Trainer.resume_elastic`` restores the same checkpoint onto a *new*
    mesh (different device count / topology) — elastic scaling.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import LMDataConfig, lm_batch_for_step
from repro.model.lm import Stepper
from repro.runtime.failures import FailureInjector, PreemptionError


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0      # step > factor×median -> straggler
    max_recoveries: int = 100


@dataclass
class Trainer:
    stepper: Stepper
    data_cfg: LMDataConfig
    cfg: TrainerConfig = field(default_factory=TrainerConfig)
    injector: Optional[FailureInjector] = None
    batch_fn: Optional[Callable[[Any, int], Dict[str, np.ndarray]]] = None

    def __post_init__(self):
        self.ckpt = CheckpointManager(self.cfg.ckpt_dir, keep=self.cfg.keep)
        self._step_fn = jax.jit(self.stepper.train_fn(),
                                donate_argnums=(0, 1))
        self._step_times: List[float] = []
        self.metrics_log: List[Dict[str, float]] = []
        self.recoveries = 0
        self.stragglers = 0

    # ------------------------------------------------------------------ #
    def _batch(self, step: int):
        if self.batch_fn is not None:
            return self.batch_fn(self.data_cfg, step)
        return lm_batch_for_step(self.data_cfg, step)

    def _init_state(self):
        params, opt = self.stepper.init()
        return {"params": params, "opt": opt}

    def _try_restore(self, state):
        latest = self.ckpt.latest()
        if latest is None:
            return 0, state
        step, restored = self.ckpt.restore(state)
        return step + 1, restored

    # ------------------------------------------------------------------ #
    def train(self) -> Dict[str, Any]:
        """Run to total_steps, surviving injected/real failures."""
        state = self._init_state()
        step, state = self._try_restore(state)
        while step < self.cfg.total_steps:
            try:
                step, state = self._run_span(step, state)
            except PreemptionError:
                self.recoveries += 1
                if self.recoveries > self.cfg.max_recoveries:
                    raise
                self.ckpt.wait()
                state = self._init_state()      # fresh process, fresh memory
                step, state = self._try_restore(state)
        self.ckpt.wait()
        return {"state": state, "steps": step, "recoveries": self.recoveries,
                "stragglers": self.stragglers, "metrics": self.metrics_log}

    def _run_span(self, step: int, state):
        while step < self.cfg.total_steps:
            if self.injector is not None:
                self.injector.maybe_fail(step)
            batch = self._batch(step)
            t0 = time.perf_counter()
            params, opt, m = self._step_fn(state["params"], state["opt"],
                                           batch)
            jax.block_until_ready(m["loss"])
            dt = time.perf_counter() - t0
            state = {"params": params, "opt": opt}
            self._watch_stragglers(dt)
            if step % self.cfg.log_every == 0 or step == self.cfg.total_steps - 1:
                self.metrics_log.append(
                    {"step": step, "loss": float(m["loss"]),
                     "gnorm": float(m.get("gnorm", 0.0)), "sec": dt})
            if step % self.cfg.ckpt_every == 0 and step > 0:
                self.ckpt.save_async(step, state)
            step += 1
        return step, state

    def _watch_stragglers(self, dt: float) -> None:
        self._step_times.append(dt)
        hist = self._step_times[-50:]
        if len(hist) >= 10:
            med = float(np.median(hist))
            if dt > self.cfg.straggler_factor * med:
                self.stragglers += 1

    # ------------------------------------------------------------------ #
    def resume_elastic(self, new_stepper: Stepper,
                       shardings: Optional[Any] = None):
        """Restore the latest checkpoint onto a different mesh/stepper."""
        state_like = {"params": new_stepper.init()[0], "opt": None}
        params, opt = new_stepper.init()
        like = {"params": params, "opt": opt}
        step, restored = self.ckpt.restore(like, shardings)
        return step + 1, restored
