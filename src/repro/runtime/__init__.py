from repro.runtime.failures import FailureInjector, PreemptionError
from repro.runtime.server import Server, ServerConfig, Request
from repro.runtime.trainer import Trainer, TrainerConfig
