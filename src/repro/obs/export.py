"""RunTrace — the per-run observability artifact written next to the
``Deployment.save`` bundle.

A :class:`RunTrace` freezes one run's spans and metric snapshot into a
saveable artifact:

* ``trace.json``   — Chrome trace-event JSON (open in Perfetto);
* ``trace.jsonl``  — one span per line for line-oriented tooling;
* ``metrics.json`` — the registry snapshot (counters/gauges/histograms);
* ``summary.txt``  — the human-readable table printed by :meth:`summary`.

:class:`capture` is the one-liner entry point: it installs a fresh enabled
tracer + registry as the process defaults for the ``with`` body, then
restores the previous ones and leaves the finished :class:`RunTrace` on
``cap.trace``::

    with obs.capture("workflow") as cap:
        wf.run_once(knobs)
    cap.trace.save(build_dir)
    print(cap.trace.summary())
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.obs.metrics import MetricsRegistry, get_metrics, set_metrics
from repro.obs.trace import (Span, Tracer, get_tracer, set_tracer,
                             span_tree, to_chrome_trace, to_jsonl)

__all__ = ["RunTrace", "capture"]


@dataclass
class RunTrace:
    """One run's spans + metrics, as a saveable artifact."""

    name: str
    spans: List[Span] = field(default_factory=list)
    metrics: Dict[str, dict] = field(default_factory=dict)

    @classmethod
    def from_tracer(cls, name: str, tracer: Optional[Tracer] = None,
                    metrics: Optional[MetricsRegistry] = None) -> "RunTrace":
        tracer = tracer if tracer is not None else get_tracer()
        metrics = metrics if metrics is not None else get_metrics()
        return cls(name=name, spans=list(tracer.spans),
                   metrics=metrics.snapshot())

    def chrome(self) -> dict:
        return to_chrome_trace(self.spans)

    def jsonl(self) -> str:
        return to_jsonl(self.spans)

    def summary(self, max_depth: int = 4) -> str:
        """Human-readable span tree + metric table (what CI logs show)."""
        lines = [f"RunTrace {self.name!r}: {len(self.spans)} spans, "
                 f"{len(self.metrics)} metrics"]
        tree = span_tree(self.spans)
        if tree:
            lines.append(f"{'span':<48} {'ms':>10} {'attrs'}")
            for s, depth in tree:
                if depth > max_depth:
                    continue
                label = "  " * depth + s.name
                attrs = " ".join(f"{k}={v}" for k, v in sorted(
                    s.attrs.items()))
                lines.append(f"{label:<48} {s.duration * 1e3:>10.3f} "
                             f"{attrs}".rstrip())
        if self.metrics:
            lines.append("")
            lines.append(f"{'metric':<44} {'value'}")
            for name, snap in self.metrics.items():
                kind = snap.get("type")
                if kind == "counter":
                    val = str(snap["value"])
                elif kind == "gauge":
                    val = (f"last={snap['value']:g} min={snap['min']:g} "
                           f"max={snap['max']:g}"
                           if snap["n"] else "unset")
                else:
                    val = (f"n={snap['count']} mean={snap['mean']:.3g} "
                           f"p50={snap['p50']:.3g} p95={snap['p95']:.3g} "
                           f"p99={snap['p99']:.3g}")
                lines.append(f"{name:<44} {val}")
        return "\n".join(lines)

    def save(self, build_dir: str) -> Dict[str, str]:
        """Write the artifact files into ``build_dir``; returns the paths."""
        os.makedirs(build_dir, exist_ok=True)
        paths = {
            "trace.json": os.path.join(build_dir, "trace.json"),
            "trace.jsonl": os.path.join(build_dir, "trace.jsonl"),
            "metrics.json": os.path.join(build_dir, "metrics.json"),
            "summary.txt": os.path.join(build_dir, "summary.txt"),
        }
        with open(paths["trace.json"], "w") as f:
            json.dump(self.chrome(), f, indent=2, sort_keys=True)
        with open(paths["trace.jsonl"], "w") as f:
            f.write(self.jsonl())
        with open(paths["metrics.json"], "w") as f:
            json.dump(self.metrics, f, indent=2, sort_keys=True)
        with open(paths["summary.txt"], "w") as f:
            f.write(self.summary() + "\n")
        return paths


class capture:
    """Enable tracing + fresh metrics for a ``with`` body; yields itself,
    with the finished :class:`RunTrace` on ``.trace`` after exit. The
    previously-installed tracer/registry are restored on the way out, so a
    capture never leaks an enabled tracer into later code."""

    def __init__(self, name: str = "run",
                 clock: Optional[Callable[[], float]] = None):
        self.name = name
        self._clock = clock
        self.tracer: Optional[Tracer] = None
        self.metrics: Optional[MetricsRegistry] = None
        self.trace: Optional[RunTrace] = None

    def __enter__(self) -> "capture":
        kw = {"clock": self._clock} if self._clock is not None else {}
        self.tracer = Tracer(enabled=True, **kw)
        self.metrics = MetricsRegistry()
        self._prev_tracer = set_tracer(self.tracer)
        self._prev_metrics = set_metrics(self.metrics)
        return self

    def __exit__(self, *exc) -> bool:
        set_tracer(self._prev_tracer)
        set_metrics(self._prev_metrics)
        self.trace = RunTrace(name=self.name, spans=list(self.tracer.spans),
                              metrics=self.metrics.snapshot())
        return False
