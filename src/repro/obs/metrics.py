"""Named counters, gauges and latency histograms — the metrics half.

Spans answer "where did this run spend its time"; metrics answer "how often
and how much" across a whole run: program-cache hits vs misses, per-mode
dispatch counts, queue depth per server tick, per-request latency
distributions. Deployment readiness is a *tail*-latency question (Venieris
et al. 2018), so histograms keep every observation and summarize as
p50/p95/p99, not just a mean.

Instruments:

* :class:`Counter`   — monotonically increasing count (``inc``);
* :class:`Gauge`     — last value plus running min/max (``set``);
* :class:`Histogram` — all observations (``observe``), percentile
  summaries interpolated the same way as ``numpy.percentile``'s default
  linear method (tested against it).

A :class:`MetricsRegistry` is a get-or-create namespace of instruments with
a single ``snapshot()`` for export. Components that own their metrics
(the Server) hold their own registry; pipeline-wide instrumentation
(emulator cache, verify, measure) records into the process-default registry
(:func:`get_metrics`), swappable for test isolation via
:func:`set_metrics`. Everything is plain Python ints/floats/lists — cost
per update is a dict lookup and an append, cheap enough to stay always-on
outside the innermost dispatch loops.
"""
from __future__ import annotations

from typing import Dict, List, Optional

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_metrics", "set_metrics", "percentile",
]


def percentile(values: List[float], p: float) -> float:
    """The p-th percentile with linear interpolation (numpy's default).

    ``p`` in [0, 100]. Empty input returns 0.0 rather than raising so a
    summary of an untouched histogram stays well-formed.
    """
    if not values:
        return 0.0
    xs = sorted(values)
    if len(xs) == 1:
        return float(xs[0])
    rank = (p / 100.0) * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    __slots__ = ("name", "value", "min", "max", "n")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.n = 0

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self.n += 1

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value, "min": self.min,
                "max": self.max, "n": self.n}


class Histogram:
    """Keeps every observation; summaries are exact order statistics."""

    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def sum(self) -> float:
        return float(sum(self.values))

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.values else 0.0

    def percentile(self, p: float) -> float:
        return percentile(self.values, p)

    def summary(self) -> dict:
        vs = self.values
        return {
            "count": len(vs),
            "sum": float(sum(vs)),
            "mean": self.mean,
            "min": float(min(vs)) if vs else 0.0,
            "max": float(max(vs)) if vs else 0.0,
            "p50": percentile(vs, 50),
            "p95": percentile(vs, 95),
            "p99": percentile(vs, 99),
        }

    def snapshot(self) -> dict:
        return {"type": "histogram", **self.summary()}


class MetricsRegistry:
    """Get-or-create namespace of instruments, one ``snapshot()`` out."""

    __slots__ = ("counters", "gauges", "histograms")

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name)
        return h

    def snapshot(self) -> Dict[str, dict]:
        """``{metric name: snapshot dict}``, sorted for stable artifacts."""
        out: Dict[str, dict] = {}
        for group in (self.counters, self.gauges, self.histograms):
            for name, inst in group.items():
                out[name] = inst.snapshot()
        return dict(sorted(out.items()))

    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()


#: Process default — pipeline-wide instrumentation records here.
_METRICS = MetricsRegistry()


def get_metrics() -> MetricsRegistry:
    return _METRICS


def set_metrics(registry: MetricsRegistry) -> MetricsRegistry:
    """Install ``registry`` as the process default; returns the previous."""
    global _METRICS
    prev = _METRICS
    _METRICS = registry
    return prev
