"""Nested context-manager spans on a monotonic clock — the tracing half.

The workflow's whole premise is a feedback loop, but until now a
``Workflow.run_once`` was a black box: one mean latency out, nothing about
where the time went. A :class:`Tracer` records *spans* — named, attributed,
nested intervals on a monotonic clock — so a run decomposes into
stage1 → stage2 → stage3 → verify, with emulator dispatches nested inside
the stage that issued them.

Design contract (DESIGN.md §11):

* **near-zero overhead when disabled** — the process-default tracer starts
  disabled; ``tracer.span(...)`` is guarded by one attribute check
  (``tracer.enabled``) and returns a shared no-op context manager, so
  instrumented hot paths (the emulator dispatch, the server tick) pay a
  function call and an attribute load, nothing else. Hot loops may hoist
  the check themselves (``if trc.enabled: ...``) to skip even the kwargs
  dict.
* **deterministic span trees in tests** — the clock is injectable
  (``Tracer(clock=...)``), so tests drive a fake counter and assert exact
  start/end/parentage.
* **single-threaded by design** — the span stack is per-tracer; the
  toolchain's pipelines are single-threaded, and a concurrent consumer
  should install one Tracer per thread.

Exporters: :func:`to_chrome_trace` emits Chrome trace-event JSON (the
``{"traceEvents": [...]}`` envelope, ``ph:"X"`` complete events with µs
timestamps) viewable in Perfetto / ``chrome://tracing``;
:func:`to_jsonl` emits one JSON object per span for line-oriented tooling;
:func:`from_chrome_trace` parses the Chrome form back (round-trip tested).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional

__all__ = [
    "Span", "Tracer", "get_tracer", "set_tracer", "span",
    "to_chrome_trace", "to_jsonl", "from_chrome_trace",
    "span_tree", "find_spans",
]


@dataclass
class Span:
    """One finished interval: ``[start, end]`` seconds on the tracer clock.

    ``parent_id`` links the nesting tree (``None`` for roots); ``attrs``
    carry the knobs/shapes/modes the instrumented site attached.
    """

    name: str
    start: float
    end: float
    attrs: Dict[str, Any] = field(default_factory=dict)
    span_id: int = 0
    parent_id: Optional[int] = None

    @property
    def duration(self) -> float:
        return self.end - self.start


class _NullSpan:
    """The shared disabled-path context manager: enters/exits to itself,
    swallows attribute updates. One instance for the whole process."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set_attrs(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _ActiveSpan:
    """A span being recorded; created by :meth:`Tracer.span`."""

    __slots__ = ("_tracer", "name", "attrs", "span_id", "parent_id", "start")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "_ActiveSpan":
        t = self._tracer
        self.parent_id = t._stack[-1].span_id if t._stack else None
        self.span_id = t._next_id
        t._next_id += 1
        t._stack.append(self)
        self.start = t.clock()
        return self

    def __exit__(self, *exc) -> bool:
        t = self._tracer
        end = t.clock()
        t._stack.pop()
        t.spans.append(Span(name=self.name, start=self.start, end=end,
                            attrs=self.attrs, span_id=self.span_id,
                            parent_id=self.parent_id))
        return False

    def set_attrs(self, **attrs) -> None:
        """Attach values discovered mid-span (e.g. a cache-hit flag)."""
        self.attrs.update(attrs)


class Tracer:
    """Collects spans. ``enabled=False`` makes every call a no-op.

    ``clock`` must be monotonic; it defaults to :func:`time.perf_counter`
    and is injectable for deterministic tests.
    """

    __slots__ = ("enabled", "clock", "spans", "_stack", "_next_id")

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = enabled
        self.clock = clock
        self.spans: List[Span] = []          # finished, in completion order
        self._stack: List[_ActiveSpan] = []
        self._next_id = 1

    def span(self, name: str, **attrs):
        """Context manager recording one nested span (no-op when disabled)."""
        if not self.enabled:                 # the one-attribute-check guard
            return _NULL_SPAN
        return _ActiveSpan(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        """A zero-duration instant (recorded as a 0-length span)."""
        if not self.enabled:
            return
        now = self.clock()
        parent = self._stack[-1].span_id if self._stack else None
        self.spans.append(Span(name=name, start=now, end=now, attrs=attrs,
                               span_id=self._next_id, parent_id=parent))
        self._next_id += 1

    def reset(self) -> None:
        self.spans = []
        self._stack = []
        self._next_id = 1


#: Process default: disabled until someone opts in (``obs.capture`` or
#: ``set_tracer``); instrumented sites call ``get_tracer()`` every time so
#: an install is picked up immediately.
_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process default; returns the previous one."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev


def span(name: str, **attrs):
    """Convenience: a span on the process-default tracer."""
    return _TRACER.span(name, **attrs)


# --------------------------------------------------------------------------- #
# Exporters
# --------------------------------------------------------------------------- #


def _json_safe(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return repr(v)


def to_chrome_trace(spans: Iterable[Span], *, pid: int = 1,
                    tid: int = 1) -> dict:
    """Chrome trace-event JSON (open in Perfetto / ``chrome://tracing``).

    Each span becomes a ``ph:"X"`` complete event; timestamps/durations are
    microseconds relative to the earliest span start. Span/parent ids ride
    in ``args`` so the exact tree survives the format.
    """
    spans = list(spans)
    t0 = min((s.start for s in spans), default=0.0)
    events = []
    for s in spans:
        args = {k: _json_safe(v) for k, v in s.attrs.items()}
        args["span_id"] = s.span_id
        if s.parent_id is not None:
            args["parent_id"] = s.parent_id
        events.append({
            "name": s.name, "ph": "X", "cat": "repro",
            "ts": (s.start - t0) * 1e6, "dur": s.duration * 1e6,
            "pid": pid, "tid": tid, "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def from_chrome_trace(doc: dict) -> List[Span]:
    """Parse :func:`to_chrome_trace` output back into spans (µs → s)."""
    spans = []
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "X":
            continue
        args = dict(ev.get("args", {}))
        span_id = args.pop("span_id", 0)
        parent_id = args.pop("parent_id", None)
        start = ev["ts"] / 1e6
        spans.append(Span(name=ev["name"], start=start,
                          end=start + ev["dur"] / 1e6, attrs=args,
                          span_id=span_id, parent_id=parent_id))
    return spans


def to_jsonl(spans: Iterable[Span]) -> str:
    """One JSON object per span, newline-delimited."""
    lines = []
    for s in spans:
        lines.append(json.dumps({
            "name": s.name, "start": s.start, "end": s.end,
            "duration": s.duration, "span_id": s.span_id,
            "parent_id": s.parent_id,
            "attrs": {k: _json_safe(v) for k, v in s.attrs.items()},
        }, sort_keys=True))
    return "\n".join(lines) + ("\n" if lines else "")


# --------------------------------------------------------------------------- #
# Tree helpers (tests + the human-readable summary)
# --------------------------------------------------------------------------- #


def find_spans(spans: Iterable[Span], name: str) -> List[Span]:
    return [s for s in spans if s.name == name]


def children_of(spans: Iterable[Span], parent: Span) -> List[Span]:
    return sorted((s for s in spans if s.parent_id == parent.span_id),
                  key=lambda s: s.start)


def span_tree(spans: Iterable[Span]) -> List[tuple]:
    """The nesting forest as ``(span, depth)`` pairs in start order."""
    spans = list(spans)
    roots = sorted((s for s in spans if s.parent_id is None),
                   key=lambda s: s.start)
    out: List[tuple] = []

    def walk(s: Span, depth: int) -> None:
        out.append((s, depth))
        for c in children_of(spans, s):
            walk(c, depth + 1)

    for r in roots:
        walk(r, 0)
    return out


def ancestors(spans: Iterable[Span], s: Span) -> List[Span]:
    """Parent chain of ``s``, nearest first."""
    by_id = {x.span_id: x for x in spans}
    out = []
    cur = s
    while cur.parent_id is not None and cur.parent_id in by_id:
        cur = by_id[cur.parent_id]
        out.append(cur)
    return out
