"""Workflow-wide observability: spans, counters, latency histograms
(DESIGN.md §11).

Dependency-free tracing + metrics threaded through every pipeline layer —
the telemetry substrate the serving runtime and the DSE engine consume:

* :mod:`repro.obs.trace`   — nested context-manager spans on a monotonic
  (injectable) clock, a process-default :class:`Tracer` that is a no-op
  until enabled, exporters for Chrome trace-event JSON (Perfetto) and
  JSONL;
* :mod:`repro.obs.metrics` — named counters / gauges / histograms with
  p50/p95/p99 summaries;
* :mod:`repro.obs.export`  — the :class:`RunTrace` artifact written next
  to ``Deployment.save`` bundles, and :class:`capture`, the one-liner that
  scopes an enabled tracer + fresh registry to a ``with`` body.

Overhead contract: with tracing disabled (the default) every instrumented
site costs one function call and one attribute check — the fused-emulator
throughput trajectory (``BENCH_rtl_emulator.json``) is the regression
guard.

Metric namespaces by layer: ``rtl.*`` (emulator), ``measure.*``
(Deployment.measure), ``resilience.*`` (guards, §12), ``server.*`` (the
batched LM server + the pool shims), and ``serving.*`` (the accelerator
farm, §14: ``serving.queue.admitted/shed_full/expired/depth``, per-router
``serving.router.<design>.<len>.affinity_hit|miss``, histograms
``serving.latency_s[.<design>]``, ``serving.queue_wait_s``,
``serving.batch_fill``, ``serving.batch_size``).
"""
from repro.obs.export import RunTrace, capture  # noqa: F401
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, get_metrics, percentile,
                               set_metrics)
from repro.obs.trace import (Span, Tracer, ancestors,  # noqa: F401
                             children_of, find_spans, from_chrome_trace,
                             get_tracer, set_tracer, span, span_tree,
                             to_chrome_trace, to_jsonl)
