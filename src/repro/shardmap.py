"""``jax.shard_map`` across jax generations — one import site for the repo.

The model/optimizer code is written against the current top-level
``jax.shard_map`` API (``axis_names=`` partial-manual mode, ``check_vma=``,
``jax.lax.pvary``). Older jaxlib builds (0.4.x, this container) ship the
same machinery as ``jax.experimental.shard_map.shard_map`` with the
pre-VMA spellings (``auto=``, ``check_rep=``) and no ``pvary``. This module
maps one onto the other so every caller — ``repro.model.moe``,
``repro.optim.compress``, the multi-device tests — writes the current API
once and runs on either jax.

Mapping notes for the legacy path:

* ``axis_names={...}`` (manual only over those axes) becomes
  ``auto = mesh.axis_names - axis_names``;
* ``check_vma`` maps to ``check_rep``, except that partial-auto mode
  predates reliable replication checking, so any ``auto`` set forces
  ``check_rep=False``;
* ``pvary`` is an identity: it only exists to annotate varying-ness for the
  VMA checker, which the legacy path doesn't run.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):                     # current API (jax >= 0.6)

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)

    pvary = jax.lax.pvary
    axis_size = jax.lax.axis_size
    #: current jaxlib partitions ppermute inside partial-auto regions fine
    PARTIAL_AUTO_PPERMUTE_OK = True

else:                                             # legacy experimental API
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        auto = (frozenset(mesh.axis_names) - set(axis_names)
                if axis_names is not None else frozenset())
        check_rep = True if check_vma is None else bool(check_vma)
        if auto:
            check_rep = False
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep,
                          auto=auto)

    def pvary(x, axis_names):                     # noqa: ARG001
        return x

    def axis_size(axis_name):
        """``jax.lax.axis_size`` does not exist yet on 0.4.x jax;
        psum(1) over the axis is its identity."""
        return jax.lax.psum(1, axis_name)

    #: 0.4.x jaxlib hard-aborts (spmd_partitioner.cc Check failure) on a
    #: ppermute inside a partially-manual region — callers that mix manual
    #: DP with auto TP must pick a gather-based collective instead.
    PARTIAL_AUTO_PPERMUTE_OK = False
