"""The paper's own accelerator workload: LSTM time-series predictor.

Matches ref [11] (traffic-flow LSTM on the XC7S15): ``hidden=20`` cell,
window of 6 univariate lags, single dense output neuron. This is the model
behind Table I, reproduced in ``benchmarks/table1_energy.py``.

The cell is written gate-fused (one (in+hidden) × 4·hidden matmul) — the same
formulation the paper's RTL template uses (and our Pallas template in
``kernels/lstm_cell`` mirrors), so estimation and "hardware" agree
structurally. The fixed-point path quantizes exactly this graph.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ModelConfig
from repro.model.layers import PSpec


def lstm_schema(cfg: ModelConfig, tp: int = 0):
    c = cfg.lstm
    layers = []
    for i in range(c.n_layers):
        d_in = c.in_features if i == 0 else c.hidden
        layers.append({
            # gate order: i, f, g, o (fused)
            "w": PSpec((d_in + c.hidden, 4 * c.hidden), P(), dtype=jnp.float32),
            "b": PSpec((4 * c.hidden,), P(), dtype=jnp.float32, init="zeros"),
        })
    return {
        "cells": layers,
        "head_w": PSpec((c.hidden, c.out_features), P(), dtype=jnp.float32),
        "head_b": PSpec((c.out_features,), P(), dtype=jnp.float32, init="zeros"),
    }


def lstm_cell_step(w, b, x_t, h, c):
    """x_t: (B, D_in); h/c: (B, hidden). Returns (h', c')."""
    hidden = h.shape[-1]
    z = jnp.concatenate([x_t, h], axis=-1) @ w + b          # (B, 4*hidden)
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def lstm_apply(
    p,
    x: jax.Array,                    # (B, S, in_features) f32
    cfg: ModelConfig,
    state: Optional[Tuple] = None,
) -> Tuple[jax.Array, Tuple]:
    """Runs the stacked LSTM over the window; returns (pred (B, out), state)."""
    c = cfg.lstm
    B, S, _ = x.shape
    h_states = []
    seq = x
    for li, cell in enumerate(p["cells"]):
        h = jnp.zeros((B, c.hidden), seq.dtype) if state is None else state[li][0]
        cc = jnp.zeros((B, c.hidden), seq.dtype) if state is None else state[li][1]
        outs = []
        for t in range(S):  # unrolled: window is 6 — exact cost accounting
            h, cc = lstm_cell_step(cell["w"], cell["b"], seq[:, t], h, cc)
            outs.append(h)
        seq = jnp.stack(outs, axis=1)
        h_states.append((h, cc))
    pred = seq[:, -1] @ p["head_w"] + p["head_b"]
    return pred, tuple(h_states)


def lstm_flops(cfg: ModelConfig) -> int:
    """MAC-counted ops per single inference (the paper counts OP = MAC*2)."""
    c = cfg.lstm
    total = 0
    for i in range(c.n_layers):
        d_in = c.in_features if i == 0 else c.hidden
        per_step = 2 * (d_in + c.hidden) * 4 * c.hidden + 4 * c.hidden
        total += per_step * c.seq_len
    total += 2 * c.hidden * c.out_features
    return total
