"""Mamba2 (SSD) block — chunked state-space dual form, TPU-native.

The SSD algorithm is reformulated so that everything quadratic-in-chunk is a
batched einsum (MXU-friendly) and only the O(n_chunks) state carry is a
``lax.scan`` / segsum matmul.  This is the hardware adaptation of the paper's
"RTL template" idea for the SSM family: the chunk-local part has a Pallas
template (kernels/mamba2) and this file is the exact jnp reference the
template is validated against.

Layout notes (TP over the "model" axis):
- z/x/dt projections are column-sharded over d_inner / heads,
- B/C projections are per-group (n_groups=1 here) and replicated,
- out_proj is row-sharded; XLA inserts the single block all-reduce.
State cache (decode): {"ssm": (B,H,P,N) f32, "conv_x/B/C": rolling windows}.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ModelConfig
from repro.model.layers import Ctx, PSpec, shard_axis

# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    return d_inner, n_heads, s.headdim, s.d_state


def mamba_schema(cfg: ModelConfig, tp: int = 16):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, Pd, N = mamba_dims(cfg)
    gN = s.n_groups * N
    ia = shard_axis(d_inner, tp)
    ha = shard_axis(H, tp)
    w = s.conv_width
    return {
        "w_z": PSpec((d, d_inner), P(None, ia)),
        "w_x": PSpec((d, d_inner), P(None, ia)),
        "w_B": PSpec((d, gN), P(None, None)),
        "w_C": PSpec((d, gN), P(None, None)),
        "w_dt": PSpec((d, H), P(None, ha)),
        "conv_x": PSpec((w, d_inner), P(None, ia), scale=0.5),
        "conv_B": PSpec((w, gN), P(None, None), scale=0.5),
        "conv_C": PSpec((w, gN), P(None, None), scale=0.5),
        "A_log": PSpec((H,), P(ha), init="zeros"),       # A = -exp(A_log) = -1
        "dt_bias": PSpec((H,), P(ha), init="zeros"),
        "D": PSpec((H,), P(ha), init="ones"),
        "norm_scale": PSpec((d_inner,), P(ia), init="ones"),
        "w_out": PSpec((d_inner, d), P(ia, None)),
    }


def mamba_state_schema(cfg: ModelConfig, batch: int, dp_axes, tp: int = 16):
    s = cfg.ssm
    d_inner, H, Pd, N = mamba_dims(cfg)
    gN = s.n_groups * N
    ha = shard_axis(H, tp)
    ia = shard_axis(d_inner, tp)
    # batch-replicated states are tiny for B=1 (long_500k); shard otherwise
    bspec = dp_axes if batch >= 16 else None
    w = s.conv_width
    return {
        "ssm": PSpec((batch, H, Pd, N), P(bspec, ha, None, None),
                     dtype=jnp.float32, init="zeros"),
        "conv_x": PSpec((batch, w - 1, d_inner), P(bspec, None, ia),
                        dtype=jnp.bfloat16, init="zeros"),
        "conv_B": PSpec((batch, w - 1, gN), P(bspec, None, None),
                        dtype=jnp.bfloat16, init="zeros"),
        "conv_C": PSpec((batch, w - 1, gN), P(bspec, None, None),
                        dtype=jnp.bfloat16, init="zeros"),
    }


# ---------------------------------------------------------------------------
# Depthwise causal conv (width 4) — train/prefill (full seq) and decode (step)
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, S, C), w: (W, C) depthwise. Causal: y_t = sum_k w[k] x_{t-W+1+k}."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = jnp.zeros_like(x)
    for k in range(W):
        y = y + pad[:, k : k + x.shape[1], :] * w[k][None, None, :]
    return jax.nn.silu(y)


def _conv_step(x_t: jax.Array, prev: jax.Array, w: jax.Array):
    """x_t: (B, C); prev: (B, W-1, C) rolling window. Returns (y_t, new_prev)."""
    window = jnp.concatenate([prev, x_t[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32))
    return jax.nn.silu(y).astype(x_t.dtype), window[:, 1:, :]


# ---------------------------------------------------------------------------
# SSD chunked scan (the matmul-form state-space dual)
# ---------------------------------------------------------------------------


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., L) log-decays -> (..., L, L) lower-tri pairwise sums."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: jax.Array,        # (B, S, H, P)   pre-multiplied by nothing (raw)
    dt: jax.Array,       # (B, S, H)      post-softplus, f32
    A: jax.Array,        # (H,)           negative, f32
    Bm: jax.Array,       # (B, S, G, N)
    Cm: jax.Array,       # (B, S, G, N)
    chunk: int,
    h0: Optional[jax.Array] = None,      # (B, H, P, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)).

    Steps 1/2/4 are chunk-parallel einsums (counted exactly by
    ``cost_analysis``); only step 3 (inter-chunk state carry, O(nc·N·P))
    is sequential via a small segsum matmul over the chunk axis.
    """
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    S0 = S
    if S % chunk:  # pad tail: dt=0 -> decay exp(0)=1, contribution dt*x=0
        extra = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, extra), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, extra), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, extra), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, extra), (0, 0), (0, 0)))
        S = S + extra
    nc = S // chunk
    rep = H // G

    cdt = x.dtype           # caller's compute dtype (bf16 on TPU, f32 on CPU)

    def to_chunks(t):
        return t.reshape(t.shape[0], nc, chunk, *t.shape[2:])

    xc = to_chunks(x).astype(cdt)                        # (B,c,l,H,P)
    dtc = to_chunks(dt.astype(jnp.float32))              # (B,c,l,H)
    Bc = to_chunks(Bm).astype(cdt)                       # (B,c,l,G,N)
    Cc = to_chunks(Cm).astype(cdt)                       # (B,c,l,G,N)
    # broadcast groups -> heads
    Bh = jnp.repeat(Bc, rep, axis=3)                     # (B,c,l,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    a = dtc * A.astype(jnp.float32)[None, None, None, :]  # (B,c,l,H) log-decay
    a_t = jnp.moveaxis(a, -1, 1)                          # (B,H,c,l)
    a_cs = jnp.cumsum(a_t, axis=-1)                       # inclusive

    xdt = xc * dtc.astype(cdt)[..., None]                 # dt·x  (B,c,l,H,P)

    # 1. intra-chunk (diagonal blocks): Y_diag[i] = sum_{j<=i} C_i·B_j L_ij xdt_j
    Lmat = jnp.exp(_segsum(a_t.reshape(Bsz, H, nc, chunk))).astype(cdt)
    scores = jnp.einsum("bclhn,bcshn->bhcls", Ch, Bh,
                        preferred_element_type=jnp.float32)
    scores = (scores * Lmat.astype(jnp.float32)).astype(cdt)
    y_diag = jnp.einsum("bhcls,bcshp->bclhp", scores, xdt,
                        preferred_element_type=jnp.float32)

    # 2. chunk-final states: state_c = sum_j exp(a_end - a_j) B_j xdt_j
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs).astype(cdt)   # (B,H,c,l)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bh, decay_states, xdt,
                        preferred_element_type=jnp.float32)     # (B,c,H,P,N)

    # 3. inter-chunk recurrence over the (small) chunk axis
    chunk_decay = a_cs[..., -1]                                  # (B,H,c)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    states = jnp.concatenate([h0[:, None].astype(jnp.float32),
                              states.astype(jnp.float32)], axis=1)
    pad_decay = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))   # (B,H,c+1)
    dmat = jnp.exp(_segsum(pad_decay))                           # (B,H,c+1,c+1)
    dmat = jnp.where(jnp.isfinite(dmat), dmat, 0.0)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", dmat, states,
                            preferred_element_type=jnp.float32)
    h_prev, h_final = new_states[:, :-1], new_states[:, -1]      # (B,c,H,P,N)

    # 4. state -> output for each position (decay from chunk start)
    out_decay = jnp.exp(a_cs).astype(cdt)                        # (B,H,c,l)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Ch,
                       h_prev.astype(cdt), out_decay,
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)
    return y[:, :S0], h_final


def ssd_step(
    x: jax.Array,        # (B, H, P)
    dt: jax.Array,       # (B, H) f32 post-softplus
    A: jax.Array,        # (H,)
    Bm: jax.Array,       # (B, G, N)
    Cm: jax.Array,       # (B, G, N)
    h: jax.Array,        # (B, H, P, N) f32
) -> Tuple[jax.Array, jax.Array]:
    """Single decode step of the recurrence. Returns (y (B,H,P), h')."""
    G = Bm.shape[1]
    rep = x.shape[1] // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    da = jnp.exp(dt * A[None, :])                           # (B,H)
    xf = x.astype(jnp.float32)
    h_new = h * da[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xf * dt[..., None], Bh
    )
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch)
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Full block apply
# ---------------------------------------------------------------------------


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float = 1e-5) -> jax.Array:
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def mamba_apply(
    p,
    hx: jax.Array,                       # (B, S, D) normed input
    ctx: Ctx,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    cfg = ctx.cfg
    s = cfg.ssm
    dt_ = ctx.compute_dtype
    d_inner, H, Pd, N = mamba_dims(cfg)
    gN = s.n_groups * N
    B, S, _ = hx.shape
    hc = hx.astype(dt_)

    z = hc @ p["w_z"].astype(dt_)                        # (B,S,d_inner)
    x = hc @ p["w_x"].astype(dt_)
    Bm = hc @ p["w_B"].astype(dt_)                       # (B,S,gN)
    Cm = hc @ p["w_C"].astype(dt_)
    dt_raw = hc @ p["w_dt"].astype(dt_)                  # (B,S,H)
    dt_f = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_state = None
    if ctx.mode == "decode":
        assert state is not None and S == 1
        xs, cx = _conv_step(x[:, 0], state["conv_x"].astype(dt_), p["conv_x"])
        Bs, cB = _conv_step(Bm[:, 0], state["conv_B"].astype(dt_), p["conv_B"])
        Cs, cC = _conv_step(Cm[:, 0], state["conv_C"].astype(dt_), p["conv_C"])
        y, h_new = ssd_step(
            xs.reshape(B, H, Pd), dt_f[:, 0], A,
            Bs.reshape(B, s.n_groups, N), Cs.reshape(B, s.n_groups, N),
            state["ssm"],
        )
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xs.reshape(B, H, Pd)
        y = y.reshape(B, 1, d_inner).astype(dt_)
        new_state = {"ssm": h_new, "conv_x": cx.astype(x.dtype),
                     "conv_B": cB.astype(x.dtype),
                     "conv_C": cC.astype(x.dtype)}
    else:
        xc = _causal_conv(x, p["conv_x"].astype(dt_))
        Bc = _causal_conv(Bm, p["conv_B"].astype(dt_))
        Cc = _causal_conv(Cm, p["conv_C"].astype(dt_))
        h0 = state["ssm"] if state is not None else None
        y4, h_final = ssd_chunked(
            xc.reshape(B, S, H, Pd), dt_f, A,
            Bc.reshape(B, S, s.n_groups, N), Cc.reshape(B, S, s.n_groups, N),
            chunk=min(s.chunk, S), h0=h0,
        )
        y4 = y4 + (p["D"].astype(jnp.float32)[None, None, :, None]
                   * xc.reshape(B, S, H, Pd).astype(jnp.float32)).astype(y4.dtype)
        y = y4.reshape(B, S, d_inner).astype(dt_)
        if ctx.mode == "prefill":
            W = s.conv_width
            padx = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))[:, -(W - 1):, :] \
                if S < W - 1 else x[:, -(W - 1):, :]
            padB = Bm[:, -(W - 1):, :] if S >= W - 1 else \
                jnp.pad(Bm, ((0, 0), (W - 1 - S, 0), (0, 0)))
            padC = Cm[:, -(W - 1):, :] if S >= W - 1 else \
                jnp.pad(Cm, ((0, 0), (W - 1 - S, 0), (0, 0)))
            new_state = {"ssm": h_final,
                         "conv_x": padx.astype(x.dtype),
                         "conv_B": padB.astype(x.dtype),
                         "conv_C": padC.astype(x.dtype)}

    yn = _gated_rmsnorm(y, z, p["norm_scale"])
    out = (yn @ p["w_out"].astype(dt_)).astype(hx.dtype)
    return out, new_state


# ---------------------------------------------------------------------------
# Pure-recurrence oracle (smoke-scale ground truth for ssd_chunked)
# ---------------------------------------------------------------------------


def ssd_reference(x, dt, A, Bm, Cm, h0=None):
    """Naive per-step recurrence. x:(B,S,H,P) dt:(B,S,H) B/C:(B,S,G,N)."""
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32)

    def step(h, t):
        y, h_new = ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        return h_new, y

    h_final, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), h_final
