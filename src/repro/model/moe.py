"""Mixture-of-Experts with expert parallelism over the "model" mesh axis.

Three dispatch implementations, selectable via ``MoEConfig.impl``:

- ``dense``  — oracle: every expert runs on every token, combined by routing
               weights. O(E·T) compute; smoke scale only. Ground truth for
               the other two.
- ``psum``   — default EP: activations stay model-replicated (matching the
               Megatron-TP layout between blocks); each TP shard computes its
               local experts on the tokens routed to them (capacity-bounded
               top-k gather), partial outputs are ``psum``-combined. Zero
               extra collectives beyond the TP all-reduce.
- ``a2a``    — classic expert-parallel dispatch: tokens are split over the
               model axis (sequence-parallel), routed, exchanged with
               ``all_to_all`` to their expert's shard, computed, returned and
               ``all_gather``-ed. More collective traffic, less redundant
               router/gather compute. A §Perf hillclimb lever.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ModelConfig
from repro.model.layers import Ctx, PSpec, shard_axis
from repro.shardmap import pvary, shard_map


def moe_schema(cfg: ModelConfig, tp: int = 16):
    m = cfg.moe
    d = cfg.d_model
    ea = shard_axis(m.n_experts, tp)
    sch = {
        "router": PSpec((d, m.n_experts), P(), dtype=jnp.float32),
        "w_gate": PSpec((m.n_experts, d, m.d_expert), P(ea, None, None)),
        "w_up": PSpec((m.n_experts, d, m.d_expert), P(ea, None, None)),
        "w_down": PSpec((m.n_experts, m.d_expert, d), P(ea, None, None)),
    }
    if m.n_shared > 0:
        fs = m.n_shared * m.d_shared
        fa = shard_axis(fs, tp)
        sch["shared"] = {
            "w_gate": PSpec((d, fs), P(None, fa)),
            "w_up": PSpec((d, fs), P(None, fa)),
            "wo": PSpec((fs, d), P(fa, None)),
        }
    return sch


def _router(p, x, m, dtype=jnp.float32):
    """x: (T, D) -> (weights (T,k), ids (T,k), aux_loss). Router math in f32."""
    logits = x.astype(dtype) @ p["router"].astype(dtype)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e mean_prob_e * mean_frac_e
    frac = jnp.zeros((m.n_experts,), dtype).at[top_i.reshape(-1)].add(
        1.0 / top_i.size
    )
    aux = m.n_experts * jnp.sum(probs.mean(0) * frac) * m.aux_loss_coef
    return top_w, top_i, aux


def _expert_ffn(xg, wg, wu, wd, dt):
    h = jax.nn.silu(xg @ wg.astype(dt)) * (xg @ wu.astype(dt))
    return h @ wd.astype(dt)


def _shared_ffn(p, x, dt):
    h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    return h @ p["wo"].astype(dt)


def _capacity(n_tokens: int, m) -> int:
    per_expert = n_tokens * m.top_k / m.n_experts
    return max(4, int(per_expert * m.capacity_factor + 0.999))


# ---------------------------------------------------------------------------
# dense oracle
# ---------------------------------------------------------------------------


def moe_dense(p, x: jax.Array, cfg: ModelConfig, ctx: Ctx):
    """(B,S,D) -> (B,S,D); every expert on every token. Oracle."""
    m = cfg.moe
    dt = ctx.compute_dtype
    b, s, d = x.shape
    xt = x.reshape(-1, d).astype(dt)
    top_w, top_i, aux = _router(p, xt, m)
    # full (T, E) combine weights
    w_full = jnp.zeros((xt.shape[0], m.n_experts), jnp.float32)
    w_full = jax.vmap(lambda w, i, row: row.at[i].set(w))(
        top_w, top_i, w_full
    )
    ys = jnp.einsum(
        "ted,te->td",
        jnp.stack([
            _expert_ffn(xt, p["w_gate"][e], p["w_up"][e], p["w_down"][e], dt)
            for e in range(m.n_experts)
        ], axis=1),
        w_full.astype(dt),
    )
    if m.n_shared > 0:
        ys = ys + _shared_ffn(p["shared"], xt, dt)
    return ys.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# psum EP (default)
# ---------------------------------------------------------------------------


def _local_expert_pass(xt, top_w, top_i, wg, wu, wd, e_lo, n_local, cap, dt):
    """Capacity-bounded compute of `n_local` experts [e_lo, e_lo+n_local)."""
    t = xt.shape[0]
    y = jnp.zeros((t, xt.shape[1]), dt)
    for j in range(n_local):
        e = e_lo + j
        w_e = jnp.sum(jnp.where(top_i == e, top_w, 0.0), axis=-1)  # (T,)
        sel_w, sel_i = jax.lax.top_k(w_e, min(cap, t))
        xe = jnp.take(xt, sel_i, axis=0)
        ye = _expert_ffn(xe, wg[j], wu[j], wd[j], dt)
        y = y.at[sel_i].add(sel_w[:, None].astype(dt) * ye)
    return y


def moe_psum(p, x: jax.Array, cfg: ModelConfig, ctx: Ctx):
    m = cfg.moe
    dt = ctx.compute_dtype
    b, s, d = x.shape
    mesh = ctx.mesh
    tp = ctx.tp_size
    ea = shard_axis(m.n_experts, tp)
    if mesh is None or ea is None:
        return moe_dense(p, x, cfg, ctx)
    n_local = m.n_experts // tp
    dp = ctx.dp

    def body(xt, router, wg, wu, wd):
        t = xt.shape[0] * xt.shape[1]
        xf = xt.reshape(t, d).astype(dt)
        top_w, top_i, aux = _router({"router": router}, xf, m)
        cap = _capacity(t, m)
        mi = jax.lax.axis_index("model")
        y = _local_expert_pass(
            xf, top_w, top_i, wg, wu, wd, mi * n_local, n_local, cap, dt
        )
        y = jax.lax.psum(y, "model")
        # aux is value-identical across model shards (router inputs are
        # replicated); mark it varying then mean so the VMA checker can
        # prove the P() out_spec
        aux = jax.lax.pmean(pvary(aux, ("model",)), dp + ("model",))
        return y.reshape(xt.shape).astype(xt.dtype), aux

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp, None, None), P()),
    )
    y, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if m.n_shared > 0:
        y = y + _shared_ffn(p["shared"], x.astype(dt), dt).astype(x.dtype)
    return y, aux


# ---------------------------------------------------------------------------
# all_to_all EP
# ---------------------------------------------------------------------------


def moe_a2a(p, x: jax.Array, cfg: ModelConfig, ctx: Ctx):
    m = cfg.moe
    dt = ctx.compute_dtype
    b, s, d = x.shape
    mesh = ctx.mesh
    tp = ctx.tp_size
    ea = shard_axis(m.n_experts, tp)
    if mesh is None or ea is None or (b * s) % tp != 0:
        return moe_psum(p, x, cfg, ctx)
    n_local = m.n_experts // tp
    dp = ctx.dp

    def body(xt, router, wg, wu, wd):
        t_loc = xt.shape[0] * xt.shape[1]
        xf = xt.reshape(t_loc, d).astype(dt)
        mi = jax.lax.axis_index("model")
        t_m = t_loc // tp
        # sequence-split across the model axis: this shard's token slice
        xs = jax.lax.dynamic_slice_in_dim(xf, mi * t_m, t_m, axis=0)
        top_w, top_i, aux = _router({"router": router}, xs, m)
        # flatten (token, k) assignments
        a_tok = jnp.repeat(jnp.arange(t_m), m.top_k)
        a_exp = top_i.reshape(-1)
        a_w = top_w.reshape(-1)
        a_dst = a_exp // n_local
        cs = _capacity(t_m, m) * max(1, m.top_k)  # per-destination slots
        cs = min(cs, t_m * m.top_k)
        send_x, send_meta, send_tok, send_w = [], [], [], []
        for dst in range(tp):
            w_d = jnp.where(a_dst == dst, a_w, -1.0)
            sel_w, sel = jax.lax.top_k(w_d, cs)
            valid = sel_w > 0
            send_x.append(jnp.take(xs, a_tok[sel], axis=0) * valid[:, None])
            send_meta.append(jnp.where(valid, a_exp[sel] % n_local, n_local))
            send_tok.append(a_tok[sel])
            send_w.append(jnp.where(valid, sel_w, 0.0))
        sx = jnp.stack(send_x)                      # (tp, cs, d)
        sm = jnp.stack(send_meta)                   # (tp, cs) local expert id
        # exchange tokens with expert owners
        rx = jax.lax.all_to_all(sx, "model", 0, 0, tiled=False)
        rm = jax.lax.all_to_all(sm, "model", 0, 0, tiled=False)
        rxf = rx.reshape(tp * cs, d)
        rmf = rm.reshape(tp * cs)
        ry = jnp.zeros_like(rxf)
        for j in range(n_local):
            mask = (rmf == j).astype(dt)[:, None]
            ry = ry + mask * _expert_ffn(rxf, wg[j], wu[j], wd[j], dt)
        # return outputs to the token owners
        back = jax.lax.all_to_all(ry.reshape(tp, cs, d), "model", 0, 0,
                                  tiled=False)
        ys = jnp.zeros((t_m, d), dt)
        for dst in range(tp):
            ys = ys.at[send_tok[dst]].add(send_w[dst][:, None].astype(dt)
                                          * back[dst])
        # restore model-replicated activations
        y = jax.lax.all_gather(ys, "model", axis=0, tiled=True)
        aux = jax.lax.pmean(aux, dp + ("model",))
        return y.reshape(xt.shape).astype(xt.dtype), aux

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,   # all_to_all round-trip defeats replication inference
    )
    y, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if m.n_shared > 0:
        y = y + _shared_ffn(p["shared"], x.astype(dt), dt).astype(x.dtype)
    return y, aux


IMPLS = {"dense": moe_dense, "psum": moe_psum, "a2a": moe_a2a}


def moe_apply(p, x: jax.Array, cfg: ModelConfig, ctx: Ctx):
    impl = cfg.moe.impl
    return IMPLS[impl](p, x, cfg, ctx)
