"""Modality frontends — STUBS per the brief.

``[audio]``/``[vlm]`` architectures specify the transformer BACKBONE only;
``input_specs()`` provides precomputed frame/patch embeddings. What lives
here is only the learned glue: the projector from frontend embedding space
into the LM, and learned positional embeddings for the whisper encoder.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ModelConfig
from repro.model.layers import Ctx, PSpec


def frontend_schema(cfg: ModelConfig, tp: int = 16):
    if cfg.frontend == "vision":
        # InternVL-style pixel-unshuffle + 2-layer MLP projector (mlp1)
        fd = cfg.frontend_dim
        return {
            "norm_scale": PSpec((fd,), P(), init="ones"),
            "norm_bias": PSpec((fd,), P(), init="zeros"),
            "w1": PSpec((fd, cfg.d_model), P()),
            "b1": PSpec((cfg.d_model,), P(), init="zeros"),
            "w2": PSpec((cfg.d_model, cfg.d_model), P()),
            "b2": PSpec((cfg.d_model,), P(), init="zeros"),
        }
    if cfg.frontend == "audio":
        # whisper: conv stem is stubbed; learned encoder position embeddings
        assert cfg.encoder is not None
        return {
            "pos_emb": PSpec((cfg.encoder.n_positions, cfg.d_model), P(),
                             init="embed"),
            "in_proj": PSpec((cfg.frontend_dim, cfg.d_model), P()),
        }
    return {}


def project_vision(p, patch_emb: jax.Array, ctx: Ctx) -> jax.Array:
    """patch_emb: (B, n_tokens, frontend_dim) -> (B, n_tokens, d_model)."""
    dt = ctx.compute_dtype
    x = patch_emb.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + 1e-5)
    x = x * p["norm_scale"] + p["norm_bias"]
    x = x.astype(dt)
    h = jax.nn.gelu(x @ p["w1"].astype(dt) + p["b1"].astype(dt))
    return h @ p["w2"].astype(dt) + p["b2"].astype(dt)


def embed_audio(p, frames: jax.Array, ctx: Ctx) -> jax.Array:
    """frames: (B, n_pos, frontend_dim) precomputed -> encoder input."""
    dt = ctx.compute_dtype
    h = frames.astype(dt) @ p["in_proj"].astype(dt)
    return h + p["pos_emb"].astype(dt)[None, : frames.shape[1]]
