"""TCN-style depthwise conv1d stack — the sensor workload beyond the LSTM.

The paper's pervasive-computing setting includes wearable/IoT sensor
pipelines; this is the minimal translatable model for them: ``n_blocks``
depthwise, strided 1-D convolutions (one ``kernel``-tap filter per channel,
exactly what one BRAM + one DSP slice per template instance computes) with a
hard activation between, then a dense readout over the flattened final
feature map.

The block is written so the generated RTL template (``repro.rtl.oplib``
``conv1d`` kind) matches it structurally: the same tap loop, the same hard
activation the ROM implements, the same flatten-then-dense head. Uses the
FPGA-friendly ``hard_tanh``/``hard_sigmoid`` activations directly, so what
Stage 1 trains is what the fixed-point lowering quantizes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ModelConfig
from repro.model.layers import PSpec
from repro.quant.qat import hard_sigmoid, hard_tanh


def conv1d_schema(cfg: ModelConfig, tp: int = 0):
    c = cfg.conv1d
    blocks = [{
        "w": PSpec((c.kernel, c.channels), P(), dtype=jnp.float32),
        "b": PSpec((c.channels,), P(), dtype=jnp.float32, init="zeros"),
    } for _ in range(c.n_blocks)]
    return {
        "blocks": blocks,
        "head_w": PSpec((c.flat_features, c.out_features), P(),
                        dtype=jnp.float32),
        "head_b": PSpec((c.out_features,), P(), dtype=jnp.float32,
                        init="zeros"),
    }


def conv1d_frames(x: jax.Array, kernel: int, stride: int) -> jax.Array:
    """(B, S, C) -> (B, T, K, C) strided tap windows, T=(S-K)//stride+1.

    THE framing of the conv1d vertical: the float model below and the RTL
    template's emulator/oracle (``repro.rtl.oplib.Conv1dTemplate``) both go
    through this helper, so "what QAT trains" and "what the lowering
    quantizes" cannot drift apart.
    """
    t_out = (x.shape[1] - kernel) // stride + 1
    return jnp.stack([x[:, t * stride: t * stride + kernel]
                      for t in range(t_out)], axis=1)


def depthwise_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                     stride: int) -> jax.Array:
    """x (B, S, C) ⊛ w (K, C) + b (C,), per-channel taps, stride ≥ 1."""
    frames = conv1d_frames(x, int(w.shape[0]), stride)    # (B, T, K, C)
    return jnp.einsum("btkc,kc->btc", frames, w) + b


def conv1d_apply(p, x: jax.Array, cfg: ModelConfig,
                 state=None) -> Tuple[jax.Array, Tuple]:
    """Runs the conv stack over the window; returns (pred (B, out), ())."""
    c = cfg.conv1d
    act = hard_tanh if c.act == "hard_tanh" else hard_sigmoid
    h = x
    for blk in p["blocks"]:
        h = act(depthwise_conv1d(h, blk["w"], blk["b"], c.stride))
    B = h.shape[0]
    pred = h.reshape(B, -1) @ p["head_w"] + p["head_b"]
    return pred, ()


def conv1d_flops(cfg: ModelConfig) -> int:
    """MAC-counted ops per single inference (OP = MAC*2, paper convention)."""
    c = cfg.conv1d
    total = 0
    for t in c.block_lens():
        total += 2 * t * c.kernel * c.channels + t * c.channels  # taps + act
    total += 2 * c.flat_features * c.out_features
    return total
