"""Grouped-query attention with KV cache, qk-norm, RoPE and chunked long-seq path.

The reference path is pure jnp/einsum so the dry-run's cost analysis is exact;
``ctx.attn_impl == "flash"`` dispatches to the Pallas flash-attention template
(the paper's "RTL template" analogue — see kernels/flash_attention).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ModelConfig
from repro.model.layers import (Ctx, PSpec, apply_rope, rms_head_norm,
                                rope_angles, shard_axis)

# Sequences longer than this use the q-chunked (flash-style, O(S) memory) path.
FULL_ATTN_MAX_SEQ = 1024
Q_CHUNK = 512


def attn_schema(cfg: ModelConfig, tp: int = 16, cross: bool = False,
                d_in: int = 0, d_out: int = 0, n_heads: int = 0,
                n_kv_heads: int = 0):
    d = d_in or cfg.d_model
    h = n_heads or cfg.n_heads
    kv = n_kv_heads or cfg.n_kv_heads
    hd = cfg.hd
    ha, kva = shard_axis(h, tp), shard_axis(kv, tp)
    # If q-heads shard but kv-heads don't, keep kv replicated (GQA reality on
    # a 16-way TP axis); if q-heads don't shard (whisper 6H, internvl2 14H),
    # the whole attention block is replicated (tiny models — see DESIGN.md).
    sch = {
        "wq": PSpec((d, h * hd), P(None, ha)),
        "wk": PSpec((d, kv * hd), P(None, kva)),
        "wv": PSpec((d, kv * hd), P(None, kva)),
        "wo": PSpec((h * hd, d_out or d), P(ha, None)),
    }
    if cfg.qk_norm:
        sch["q_norm"] = PSpec((hd,), P(), init="ones")
        sch["k_norm"] = PSpec((hd,), P(), init="ones")
    return sch


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def _repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return x
    return jnp.repeat(x, groups, axis=2)


def attention_core(
    q: jax.Array,           # (B, Sq, H, hd)
    k: jax.Array,           # (B, Sk, H, hd)  (already GQA-repeated)
    v: jax.Array,           # (B, Sk, H, hd)
    ctx: Ctx,
    causal: bool,
    q_offset: jax.Array | int = 0,   # absolute position of q[.., 0]
    kv_len: Optional[jax.Array] = None,  # valid cache length (decode)
) -> jax.Array:
    """Softmax attention; dispatches ref-einsum / chunked / Pallas template."""
    if ctx.attn_impl == "flash" and causal and q.shape[1] == k.shape[1]:
        from repro.kernels.flash_attention import ops as flash_ops

        return flash_ops.flash_attention(q, k, v, causal=True)
    if ctx.attn_impl == "template_stub":
        # negligible-cost placeholder keeping all data deps + output shape;
        # the hillclimb adds the flash template's analytic flops/bytes
        # (see experiments/hillclimb.py §template model)
        return (q + jnp.mean(k, axis=1, keepdims=True).mean(
            axis=2, keepdims=True) + jnp.mean(v, axis=1, keepdims=True).mean(
            axis=2, keepdims=True)).astype(v.dtype)
    # auto-dispatch: un-repeated K/V (fewer kv heads) -> grouped GQA path
    block = _attn_block_grouped if k.shape[2] != q.shape[2] else _attn_block
    scale = q.shape[-1] ** -0.5
    sq, sk = q.shape[1], k.shape[1]
    if sq <= FULL_ATTN_MAX_SEQ or sq != sk:
        return block(q, k, v, scale, causal, q_offset, kv_len)
    # q-chunked flash-style path: O(S) live memory, exact softmax per row.
    n_chunks = (sq + Q_CHUNK - 1) // Q_CHUNK
    q_pad = q
    if sq % Q_CHUNK:
        q_pad = jnp.pad(q, ((0, 0), (0, n_chunks * Q_CHUNK - sq),
                            (0, 0), (0, 0)))

    def chunk(i):
        qs = jax.lax.dynamic_slice_in_dim(q_pad, i * Q_CHUNK, Q_CHUNK, axis=1)
        return block(qs, k, v, scale, causal, i * Q_CHUNK, kv_len)

    body = jax.checkpoint(chunk) if ctx.mode == "train" else chunk
    out = jnp.concatenate([body(i) for i in range(n_chunks)], axis=1)
    return out[:, :sq]


def _attn_block(q, k, v, scale, causal, q_offset, kv_len):
    sq, sk = q.shape[1], k.shape[1]
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    mask = None
    if causal:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        mask = kpos <= qpos
    if kv_len is not None:
        valid = jnp.arange(sk)[None, :] < jnp.reshape(kv_len, (-1, 1))
        valid = valid[:, None, None, :]  # (B,1,1,Sk)
        mask = valid if mask is None else (mask[None, None] & valid)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None]
        logits = jnp.where(mask, logits, jnp.float32(-1e30))
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def _attn_block_grouped(q, k, v, scale, causal, q_offset, kv_len):
    """GQA without repeated K/V: q folded to (B,Sq,KV,G,hd) and contracted
    against the raw (B,Sk,KV,hd) cache — removes the G× K/V traffic blowup
    the repeat-based reference pays (the dominant decode HBM term)."""
    B, sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, sq, KV, G, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = None
    if causal:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk if (sk := k.shape[1]) else 0)[None, :]
        mask = (kpos <= qpos)[None, None, None]        # (1,1,1,Sq,Sk)
    if kv_len is not None:
        valid = jnp.arange(k.shape[1])[None, :] < jnp.reshape(kv_len, (-1, 1))
        valid = valid[:, None, None, None, :]          # (B,1,1,1,Sk)
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.float32(-1e30))
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return o.reshape(B, sq, H, hd)


def attn_apply(
    p,
    h: jax.Array,            # (B, S, D) — normed input
    ctx: Ctx,
    cache: Optional[Dict[str, jax.Array]] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,     # False: encoder self-attention
    use_rope: bool = True,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self- or cross-attention. Returns (out, updated_cache).

    Cache layout: {"k": (B, S_max, KV, hd), "v": ..., "pos": (B,) int32}.
    Head counts are derived from the param shapes so the zamba2 shared block
    (2·d_model input) and whisper cross-attention reuse this code path.
    """
    cfg = ctx.cfg
    dt = ctx.compute_dtype
    hd = cfg.hd
    H = p["wq"].shape[1] // hd
    KV = p["wk"].shape[1] // hd
    hx = h.astype(dt)

    q = _split_heads(hx @ p["wq"].astype(dt), H, hd)
    if cross_kv is not None:
        k, v = cross_kv  # (B, S_enc, KV, hd) — precomputed by the encoder
    else:
        k = _split_heads(hx @ p["wk"].astype(dt), KV, hd)
        v = _split_heads(hx @ p["wv"].astype(dt), KV, hd)

    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        if cross_kv is None:
            k = rms_head_norm(p["k_norm"], k)

    causal = causal and cross_kv is None
    new_cache = None
    kv_len = None
    q_offset = 0

    if cross_kv is None and cfg.rope_theta > 0 and use_rope:
        assert ctx.positions is not None
        cos, sin = rope_angles(ctx.positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if cross_kv is None and ctx.mode in ("prefill", "decode"):
        if ctx.mode == "decode":
            assert cache is not None, "decode requires a KV cache"
            # scatter the new K/V at position `pos`, then attend over the
            # cache (in-place dynamic-update-slice: O(1) extra traffic with
            # buffer donation, matching a production decode engine)
            pos = cache["pos"]  # (B,) current lengths

            def upd(buf, new):
                f = lambda b1, n1, p1: jax.lax.dynamic_update_slice(
                    b1, n1, (p1, jnp.int32(0), jnp.int32(0))
                )
                return jax.vmap(f)(buf, new, pos)

            k_cache = upd(cache["k"].astype(dt), k)
            v_cache = upd(cache["v"].astype(dt), v)
            new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
            k, v = k_cache, v_cache
            kv_len = pos + 1
            causal = False  # masking handled via kv_len
            q_offset = 0
        else:  # prefill: return the populated cache
            new_cache = {
                "k": k,
                "v": v,
                "pos": jnp.full((h.shape[0],), h.shape[1], jnp.int32),
            }

    if not ctx.par.gqa_grouped:        # baseline: materialized repeat
        k = _repeat_kv(k, H // KV)
        v = _repeat_kv(v, H // KV)
    o = attention_core(q, k, v, ctx, causal=causal, q_offset=q_offset, kv_len=kv_len)
    o = o.reshape(h.shape[0], h.shape[1], H * hd)
    out = (o @ p["wo"].astype(dt)).astype(h.dtype)
    return out, new_cache


def cache_schema(cfg: ModelConfig, batch: int, seq: int, tp: int, dp_axes,
                 seq_shard: bool = False):
    """Abstract KV-cache schema for one attention layer (serving)."""
    kva = shard_axis(cfg.n_kv_heads, tp)
    # batch over dp when it divides; otherwise shard the long seq axis over
    # "data" (flash-decoding style — XLA inserts the partial-softmax combine).
    if batch >= 16:
        if seq_shard and kva is None:
            # kv heads don't divide tp -> cache otherwise REPLICATED over
            # "model": shard the seq axis there instead (flash-decoding
            # layout; §Perf cell B)
            kspec = P(dp_axes, "model", None, None)
        else:
            kspec = P(dp_axes, None, kva, None)
    else:
        kspec = P(None, "data", kva, None)
    return {
        "k": PSpec((batch, seq, cfg.n_kv_heads, cfg.hd), kspec, dtype=jnp.bfloat16),
        "v": PSpec((batch, seq, cfg.n_kv_heads, cfg.hd), kspec, dtype=jnp.bfloat16),
        "pos": PSpec((batch,), P(), dtype=jnp.int32, init="zeros"),
    }
