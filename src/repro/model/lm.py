"""Step functions + abstract input specs — the single entry point used by the
trainer, the server, and the multi-pod dry-run.

Everything here is built from the same :mod:`repro.model.layers` PSpec
schemas, so ``init_params`` (smoke), ``abstract_params`` (dry-run) and
``in_shardings`` can never diverge.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.types import (MeshConfig, ModelConfig, ParallelismConfig,
                              ShapeConfig)
from repro.model.layers import Ctx, abstract_params, init_params, pspecs, tree_map_pspec
from repro.model.transformer import (apply_model, model_cache_schema,
                                     param_schema)
from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               opt_state_schema)

__all__ = [
    "param_schema", "make_train_step", "make_prefill_step", "make_decode_step",
    "input_specs", "batch_pspecs", "cross_entropy", "Stepper",
]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, targets: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """logits (B,S,V) f32, targets (B,S) int32 (-1 = masked). -> (loss, n_tok)."""
    mask = (targets >= 0)
    t = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    n = jnp.maximum(mask.sum(), 1)
    return ce.sum() / n, n


# Positions per CE chunk: bounds live f32 logits to (B, CE_CHUNK, V).
CE_CHUNK = 512


def chunked_ce_loss(hidden: jax.Array, targets: jax.Array,
                    head_fn) -> Tuple[jax.Array, jax.Array]:
    """Memory-bounded LM loss: the (B,S,V) logits tensor is never alive at
    once — per-chunk logits+CE under ``jax.checkpoint`` (bwd recomputes the
    chunk's logits instead of keeping them)."""
    B, S, _ = hidden.shape
    ck = min(CE_CHUNK, S)

    def chunk_loss(h_c, t_c):
        logits = head_fn(h_c)
        mask = (t_c >= 0)
        t = jnp.maximum(t_c, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return ((lse - gold) * mask).sum(), mask.sum()

    chunk_loss = jax.checkpoint(chunk_loss)
    tot, n = jnp.float32(0.0), jnp.int32(0)
    for i in range(0, S, ck):
        li, ni = chunk_loss(jax.lax.dynamic_slice_in_dim(hidden, i, min(ck, S - i), 1),
                            jax.lax.dynamic_slice_in_dim(targets, i, min(ck, S - i), 1))
        tot, n = tot + li, n + ni
    n = jnp.maximum(n, 1)
    return tot / n, n


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def _mk_ctx(cfg, mesh_cfg, mode, mesh, par, attn_impl=None):
    return Ctx(cfg=cfg, mesh_cfg=mesh_cfg, mode=mode, mesh=mesh, par=par,
               attn_impl=attn_impl or par.attn_impl)


def make_loss_fn(cfg: ModelConfig, mesh_cfg: MeshConfig,
                 par: ParallelismConfig, mesh: Optional[Mesh]):
    if cfg.family in ("lstm", "conv1d"):
        if cfg.family == "lstm":
            from repro.model.lstm import lstm_apply as apply_fn
        else:
            from repro.model.conv1d import conv1d_apply as apply_fn

        def window_loss(params, batch):
            pred, _ = apply_fn(params, batch["x"], cfg)
            loss = jnp.mean(jnp.square(pred - batch["y"]))
            return loss, {"loss": loss}

        return window_loss

    def loss_fn(params, batch):
        ctx = _mk_ctx(cfg, mesh_cfg, "train", mesh, par)
        hidden, _, aux = apply_model(params, batch, ctx, return_hidden=True)
        from repro.model.transformer import head_logits

        if cfg.ce_chunked:
            ce, n_tok = chunked_ce_loss(hidden, batch["targets"],
                                        lambda h: head_logits(params, h, ctx))
        else:
            ce, n_tok = cross_entropy(head_logits(params, hidden, ctx),
                                      batch["targets"])
        loss = ce + aux
        return loss, {"loss": ce, "aux": aux, "n_tok": n_tok}

    return loss_fn


def make_train_step(cfg: ModelConfig, mesh_cfg: MeshConfig,
                    par: ParallelismConfig, opt_cfg: AdamWConfig,
                    mesh: Optional[Mesh] = None):
    """(params, opt_state, batch) -> (params', opt_state', metrics)."""
    loss_fn = make_loss_fn(cfg, mesh_cfg, par, mesh)

    if par.grad_compression and mesh is not None and mesh.size > 1:
        # int8-ring gradient reduction: manual over DP, auto over model
        from repro.optim.compress import make_compressed_grad_fn

        def step_c(params, opt_state, batch):
            bspec = {k: P(mesh_cfg.dp_axes, *([None] * (v.ndim - 1)))
                     for k, v in batch.items()}
            grad_fn = make_compressed_grad_fn(loss_fn, mesh, mesh_cfg, bspec)
            loss, metrics, grads = grad_fn(params, batch)
            new_params, new_opt, info = adamw_update(grads, opt_state,
                                                     params, opt_cfg)
            return new_params, new_opt, dict(metrics, **info)

        return step_c

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, info = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
        metrics = dict(metrics, **info)
        return new_params, new_opt, metrics

    return step


def make_prefill_step(cfg: ModelConfig, mesh_cfg: MeshConfig,
                      par: ParallelismConfig, mesh: Optional[Mesh] = None):
    """(params, batch) -> (last_logits (B,V), cache).

    For the window families (lstm/conv1d) "prefill" is one window
    inference: (params, batch) -> (pred (B, out_features), state) — the
    deployable step the XLA target translates for ``infer_1`` shapes,
    mirroring what the RTL target lowers.
    """
    if cfg.family in ("lstm", "conv1d"):
        if cfg.family == "lstm":
            from repro.model.lstm import lstm_apply as apply_fn
        else:
            from repro.model.conv1d import conv1d_apply as apply_fn

        def window_step(params, batch):
            return apply_fn(params, batch["x"], cfg)

        return window_step

    def step(params, batch):
        ctx = _mk_ctx(cfg, mesh_cfg, "prefill", mesh, par)
        logits, cache, _ = apply_model(params, batch, ctx)
        return logits[:, -1], cache

    return step


def make_decode_step(cfg: ModelConfig, mesh_cfg: MeshConfig,
                     par: ParallelismConfig, mesh: Optional[Mesh] = None):
    """(params, tokens (B,1), cache) -> (logits (B,V), cache')."""

    def step(params, tokens, cache):
        ctx = _mk_ctx(cfg, mesh_cfg, "decode", mesh, par)
        logits, new_cache, _ = apply_model(params, {"tokens": tokens}, ctx,
                                           cache=cache)
        return logits[:, -1], new_cache

    return step


# ---------------------------------------------------------------------------
# Abstract input specs (dry-run stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _batch_axis(mesh_cfg: MeshConfig, batch: int) -> Optional[Tuple[str, ...]]:
    dp = mesh_cfg.dp_axes
    n = 1
    for a in dp:
        n *= mesh_cfg.axis_size(a)
    return dp if (n > 1 and batch % n == 0) else None


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig,
                 mesh_cfg: MeshConfig) -> Dict[str, P]:
    ba = _batch_axis(mesh_cfg, shape.global_batch)
    if cfg.family in ("lstm", "conv1d"):
        return {"x": P(ba, None, None), "y": P(ba, None)}
    specs: Dict[str, P] = {"tokens": P(ba, None)}
    if shape.kind == "train":
        specs["targets"] = P(ba, None)
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "vision":
            specs["patches"] = P(ba, None, None)
        if cfg.frontend == "audio":
            specs["frames"] = P(ba, None, None)
    return specs


def input_specs(cfg: ModelConfig,
                shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "lstm":
        c = cfg.lstm
        return {"x": jax.ShapeDtypeStruct((B, c.seq_len, c.in_features),
                                          jnp.float32),
                "y": jax.ShapeDtypeStruct((B, c.out_features), jnp.float32)}
    if cfg.family == "conv1d":
        c = cfg.conv1d
        return {"x": jax.ShapeDtypeStruct((B, c.seq_len, c.channels),
                                          jnp.float32),
                "y": jax.ShapeDtypeStruct((B, c.out_features), jnp.float32)}
    sds: Dict[str, jax.ShapeDtypeStruct] = {}
    tok_s = 1 if shape.kind == "decode" else S
    sds["tokens"] = jax.ShapeDtypeStruct((B, tok_s), jnp.int32)
    if shape.kind == "train":
        sds["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "vision":
            sds["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
        if cfg.frontend == "audio":
            sds["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder.n_positions, cfg.frontend_dim), jnp.float32)
    return sds


# ---------------------------------------------------------------------------
# Stepper — bundles schemas, shardings and jitted callables for one cell
# ---------------------------------------------------------------------------


@dataclass
class Stepper:
    """Everything needed to lower/run one (arch × shape × mesh) cell."""

    cfg: ModelConfig
    shape: ShapeConfig
    mesh_cfg: MeshConfig
    par: ParallelismConfig
    mesh: Optional[Mesh] = None
    opt_cfg: AdamWConfig = AdamWConfig()

    def __post_init__(self):
        tp = self.mesh_cfg.axis_size("model")
        self.schema = param_schema(self.cfg, tp=tp)
        self.param_pspecs = pspecs(self.schema)

    # --- abstract (dry-run) -------------------------------------------------
    def abstract_inputs(self):
        sds = input_specs(self.cfg, self.shape)
        if self.shape.kind == "train":
            params = abstract_params(self.schema)
            opt = tree_map_pspec(
                lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                opt_state_schema(self.schema, self.mesh_cfg))
            return {"params": params, "opt_state": opt, "batch": sds}
        params = abstract_params(self.schema)
        out = {"params": params, "batch": sds}
        if self.shape.kind == "decode":
            cache_schema = self.cache_schema()
            out["cache"] = tree_map_pspec(
                lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), cache_schema)
        return out

    def cache_schema(self):
        tp = self.mesh_cfg.axis_size("model")
        return model_cache_schema(self.cfg, self.shape.global_batch,
                                  self.shape.seq_len, self.mesh_cfg, tp=tp,
                                  stacked=self.par.scan_layers,
                                  seq_shard=self.par.seq_shard_decode)

    def shardings(self, tree_schema):
        assert self.mesh is not None
        return tree_map_pspec(
            lambda s: NamedSharding(self.mesh, s.pspec), tree_schema)

    # --- step functions -----------------------------------------------------
    def train_fn(self):
        return make_train_step(self.cfg, self.mesh_cfg, self.par,
                               self.opt_cfg, self.mesh)

    def prefill_fn(self):
        return make_prefill_step(self.cfg, self.mesh_cfg, self.par, self.mesh)

    def decode_fn(self):
        return make_decode_step(self.cfg, self.mesh_cfg, self.par, self.mesh)

    # --- concrete init (smoke scale only) ------------------------------------
    def init(self, seed: int = 0):
        params = init_params(self.schema, jax.random.PRNGKey(seed))
        opt = init_opt_state(params)
        return params, opt
