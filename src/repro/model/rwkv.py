"""RWKV6 ("Finch") — attention-free time-mix with data-dependent decay.

TPU adaptation (the paper's "RTL template" idea applied to the WKV op):
the WKV recurrence is evaluated in *chunked* form — within a chunk the
quadratic part is computed over small subchunks (exact pairwise decay,
bounded (l, l, N) working set that fits VMEM), the subchunk state carry is a
python-unrolled loop (exact ``cost_analysis`` accounting), and the
chunk-level state carry is a parallel segsum matmul over the chunk axis (no
``lax.scan``, so the dry-run's FLOP counts are exact). All decay factors are
differences of cumulative log-decays with the later boundary subtracted, so
every ``exp`` argument is ≤ 0 — stable in bf16/f32 without rescaling hacks.

``kernels/rwkv6`` holds the Pallas template for the intra-chunk part;
``ssd``-style state layout: per layer {"wkv": (B,H,N,N) f32, "shift_att",
"shift_ffn": (B, D)}.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ModelConfig
from repro.model.layers import Ctx, PSpec, shard_axis

SUBCHUNK = 16
MIX_RANK = 32


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def rwkv_dims(cfg: ModelConfig) -> Tuple[int, int]:
    N = cfg.rwkv.head_size
    H = cfg.d_model // N
    return H, N


def rwkv_time_schema(cfg: ModelConfig, tp: int = 16):
    d = cfg.d_model
    H, N = rwkv_dims(cfg)
    da = d  # d_att == d_model in rwkv6
    ha = shard_axis(H, tp)
    aa = shard_axis(da, tp)
    lora = cfg.rwkv.decay_lora
    return {
        "maa_x": PSpec((d,), P(), init="zeros"),
        "maa_wkvrg": PSpec((5, d), P(), init="zeros"),
        "maa_w1": PSpec((d, 5 * MIX_RANK), P(), scale=0.01),
        "maa_w2": PSpec((5, MIX_RANK, d), P(), scale=0.01),
        "decay": PSpec((da,), P(aa), init="zeros"),          # resting log-log decay
        "decay_w1": PSpec((d, lora), P(), scale=0.01),
        "decay_w2": PSpec((lora, da), P(None, aa), scale=0.01),
        "u": PSpec((H, N), P(ha, None), init="zeros"),       # time_faaaa bonus
        "wr": PSpec((d, da), P(None, aa)),
        "wk": PSpec((d, da), P(None, aa)),
        "wv": PSpec((d, da), P(None, aa)),
        "wg": PSpec((d, da), P(None, aa)),
        "ln_x_scale": PSpec((da,), P(aa), init="ones"),
        "ln_x_bias": PSpec((da,), P(aa), init="zeros"),
        "wo": PSpec((da, d), P(aa, None)),
    }


def rwkv_channel_schema(cfg: ModelConfig, tp: int = 16):
    d, f = cfg.d_model, cfg.d_ff
    fa = shard_axis(f, tp)
    return {
        "maa_k": PSpec((d,), P(), init="zeros"),
        "maa_r": PSpec((d,), P(), init="zeros"),
        "wk": PSpec((d, f), P(None, fa)),
        "wv": PSpec((f, d), P(fa, None)),
        "wr": PSpec((d, d), P()),
    }


def rwkv_state_schema(cfg: ModelConfig, batch: int, dp_axes, tp: int = 16):
    H, N = rwkv_dims(cfg)
    ha = shard_axis(H, tp)
    bspec = dp_axes if batch >= 16 else None
    return {
        "wkv": PSpec((batch, H, N, N), P(bspec, ha, None, None),
                     dtype=jnp.float32, init="zeros"),
        "shift_att": PSpec((batch, cfg.d_model), P(bspec, None),
                           dtype=jnp.bfloat16, init="zeros"),
        "shift_ffn": PSpec((batch, cfg.d_model), P(bspec, None),
                           dtype=jnp.bfloat16, init="zeros"),
    }


# ---------------------------------------------------------------------------
# WKV6: chunked evaluation + single-step recurrence
# ---------------------------------------------------------------------------


def wkv6_chunked(
    r: jax.Array,      # (B, S, H, N)
    k: jax.Array,      # (B, S, H, N)
    v: jax.Array,      # (B, S, H, N)
    w_log: jax.Array,  # (B, S, H, N) log-decay, ≤ 0, f32
    u: jax.Array,      # (H, N)
    h0: Optional[jax.Array] = None,   # (B, H, N, N) key->value state
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,N), final_state (B,H,N,N)). See module docstring."""
    B, S, H, N = r.shape
    chunk = min(chunk, S)
    chunk = ((chunk + SUBCHUNK - 1) // SUBCHUNK) * SUBCHUNK  # SUB multiple
    S0 = S
    if S % chunk:  # pad tail: w_log=0 (no decay) and k=0 (no contribution)
        extra = chunk - S % chunk
        pad4 = ((0, 0), (0, extra), (0, 0), (0, 0))
        r, k, v = (jnp.pad(t, pad4) for t in (r, k, v))
        w_log = jnp.pad(w_log, pad4)
        S = S + extra
    nc = S // chunk
    l = min(SUBCHUNK, chunk)
    ns = chunk // l
    assert chunk % l == 0

    dt = r.dtype            # caller's compute dtype (bf16 on TPU, f32 on CPU)
    f32 = jnp.float32

    def shape_cs(t):  # (B,S,H,N) -> (B,nc,ns,l,H,N)
        return t.reshape(B, nc, ns, l, H, N)

    rc, kc, vc = shape_cs(r.astype(dt)), shape_cs(k.astype(dt)), shape_cs(v.astype(dt))
    wc = shape_cs(w_log.astype(f32))

    csub = jnp.cumsum(wc, axis=3)                     # within-subchunk inclusive
    cprev = csub - wc                                 # exclusive (≤ 0 diffs)
    sub_tot = csub[:, :, :, -1]                       # (B,nc,ns,H,N) subchunk decay

    # ---- intra-subchunk exact pairwise (l × l, bounded working set) --------
    # A[i,j] = sum_n r_i k_j exp(cprev_i - csub_j)   (j < i), diag uses u.
    pair = cprev[:, :, :, :, None] - csub[:, :, :, None, :]   # (B,nc,ns,l,l,H,N)
    mask = jnp.tril(jnp.ones((l, l), bool), -1)[None, None, None, :, :, None, None]
    dec = jnp.exp(jnp.where(mask, pair, -jnp.inf)).astype(dt)  # exp(-inf)=0, grad-safe
    A = jnp.einsum("bcsihn,bcsijhn,bcsjhn->bcsijh", rc, dec, kc,
                   preferred_element_type=f32)
    A_diag = jnp.einsum("bcsihn,hn,bcsihn->bcsih", rc, u.astype(dt), kc,
                        preferred_element_type=f32)
    A = A + jnp.einsum("bcsih,ij->bcsijh", A_diag,
                       jnp.eye(l, dtype=f32))
    y = jnp.einsum("bcsijh,bcsjhn->bcsihn", A.astype(dt), vc,
                   preferred_element_type=f32).astype(f32)

    # ---- per-subchunk totals T_a = sum_j (k_j ⊙ exp(sub_tot - csub_j)) v_j^T
    kdec = (kc.astype(f32) * jnp.exp(sub_tot[:, :, :, None] - csub)).astype(dt)
    T = jnp.einsum("bcsjhn,bcsjhp->bcshnp", kdec, vc,
                   preferred_element_type=f32)        # (B,nc,ns,H,N,N)

    # ---- within-chunk subchunk state carry (python-unrolled, exact cost) ---
    # s_a = state at start of subchunk a relative to chunk start
    s = jnp.zeros((B, nc, H, N, N), f32)
    s_list = []
    for a in range(ns):
        s_list.append(s)
        s = s * jnp.exp(sub_tot[:, :, a])[..., None] + T[:, :, a]
    chunk_T = s                            # contribution of chunk, decayed to end
    s_stack = jnp.stack(s_list, axis=2)                # (B,nc,ns,H,N,N)
    rdec = (rc.astype(f32) * jnp.exp(cprev)).astype(dt)
    y = y + jnp.einsum("bcsihn,bcshnp->bcsihp", rdec,
                       s_stack.astype(dt), preferred_element_type=f32)

    # ---- chunk-level state carry: parallel segsum over the chunk axis ------
    chunk_tot = jnp.sum(wc, axis=(2, 3))               # (B,nc,H,N) log decay/chunk
    if h0 is None:
        h0 = jnp.zeros((B, H, N, N), f32)
    states = jnp.concatenate([h0[:, None], chunk_T], axis=1)  # (B,nc+1,H,N,N)
    pad_tot = jnp.pad(chunk_tot, ((0, 0), (1, 0), (0, 0), (0, 0)))
    cs = jnp.cumsum(pad_tot, axis=1)                   # (B,nc+1,H,N)
    seg = cs[:, :, None] - cs[:, None, :]              # (B,z,c,H,N) z≥c valid
    zmask = jnp.tril(jnp.ones((nc + 1, nc + 1), bool), 0)[None, :, :, None, None]
    segd = jnp.where(zmask, jnp.exp(seg), 0.0)
    h_all = jnp.einsum("bzchn,bchnp->bzhnp", segd, states)    # (B,nc+1,H,N,N)
    h_prev, h_final = h_all[:, :-1], h_all[:, -1]

    # decay of r relative to chunk start = cumulative over prior subchunks + cprev
    sub_cum = jnp.cumsum(sub_tot, axis=2) - sub_tot    # exclusive over subchunks
    r_chunk_dec = (rc.astype(f32)
                   * jnp.exp(sub_cum[:, :, :, None] + cprev)).astype(dt)
    y = y + jnp.einsum("bcsihn,bchnp->bcsihp", r_chunk_dec,
                       h_prev.astype(dt), preferred_element_type=f32)
    return y.reshape(B, S, H, N)[:, :S0], h_final


def wkv6_step(r, k, v, w_log, u, h):
    """Single decode step. r/k/v/w_log: (B,H,N); h: (B,H,N,N) key->value."""
    f32 = jnp.float32
    rf, kf, vf = r.astype(f32), k.astype(f32), v.astype(f32)
    bonus = jnp.einsum("bhn,hn,bhn->bh", rf, u.astype(f32), kf)
    y = jnp.einsum("bhn,bhnp->bhp", rf, h) + bonus[..., None] * vf
    h_new = h * jnp.exp(w_log.astype(f32))[..., None] \
        + jnp.einsum("bhn,bhp->bhnp", kf, vf)
    return y.astype(r.dtype), h_new


def wkv6_reference(r, k, v, w_log, u, h0=None):
    """Naive scan oracle. r/k/v/w_log: (B,S,H,N)."""
    B, S, H, N = r.shape
    if h0 is None:
        h0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(h, t):
        y, h_new = wkv6_step(r[:, t], k[:, t], v[:, t], w_log[:, t], u, h)
        return h_new, y

    h_final, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), h_final


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x: (B,S,D); prev: (B,D) last token of the previous segment (or None)."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None, :].astype(x.dtype)
    first = (jnp.zeros_like(x[:, :1]) if prev is None
             else prev[:, None, :].astype(x.dtype))
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(p, x, xprev):
    """Data-dependent token-shift interpolation -> (x_w, x_k, x_v, x_r, x_g)."""
    delta = xprev - x
    xxx = x + delta * p["maa_x"].astype(x.dtype)
    B, S, d = x.shape
    mix = jnp.tanh(xxx @ p["maa_w1"].astype(x.dtype)).reshape(B, S, 5, MIX_RANK)
    adj = jnp.einsum("bsfr,frd->bsfd", mix, p["maa_w2"].astype(x.dtype))
    mu = p["maa_wkvrg"].astype(x.dtype)[None, None] + adj      # (B,S,5,d)
    return tuple(x + delta * mu[:, :, i] for i in range(5))


def _per_head_groupnorm(y, scale, bias, H, N, eps=1e-5):
    B, S = y.shape[0], y.shape[1]
    yf = y.reshape(B, S, H, N).astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(B, S, H * N)
    return (yn * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(y.dtype)


def rwkv_time_mix(
    p,
    hx: jax.Array,                     # (B,S,D) normed input
    ctx: Ctx,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    cfg = ctx.cfg
    dt = ctx.compute_dtype
    H, N = rwkv_dims(cfg)
    B, S, d = hx.shape
    x = hx.astype(dt)

    prev = state["shift_att"] if state is not None else None
    xprev = _token_shift(x, prev)
    x_w, x_k, x_v, x_r, x_g = _ddlerp(p, x, xprev)

    dlora = jnp.tanh(x_w @ p["decay_w1"].astype(dt)) @ p["decay_w2"].astype(dt)
    w_log = -jnp.exp(p["decay"].astype(jnp.float32)
                     + dlora.astype(jnp.float32))              # (B,S,da) ≤ 0
    r = (x_r @ p["wr"].astype(dt)).reshape(B, S, H, N)
    k = (x_k @ p["wk"].astype(dt)).reshape(B, S, H, N)
    v = (x_v @ p["wv"].astype(dt)).reshape(B, S, H, N)
    g = jax.nn.silu(x_g @ p["wg"].astype(dt))

    new_state = None
    if ctx.mode == "decode":
        assert state is not None and S == 1
        y1, h_new = wkv6_step(r[:, 0], k[:, 0], v[:, 0],
                              w_log.reshape(B, 1, H, N)[:, 0], p["u"],
                              state["wkv"])
        y = y1[:, None]
        new_state = {"wkv": h_new, "shift_att": x[:, -1]}
    else:
        h0 = state["wkv"] if state is not None else None
        y, h_final = wkv6_chunked(r, k, v, w_log.reshape(B, S, H, N),
                                  p["u"], h0=h0, chunk=cfg.rwkv.chunk)
        if ctx.mode == "prefill":
            new_state = {"wkv": h_final, "shift_att": x[:, -1]}

    y = y.reshape(B, S, H * N).astype(dt)
    y = _per_head_groupnorm(y, p["ln_x_scale"], p["ln_x_bias"], H, N) * g
    out = (y @ p["wo"].astype(dt)).astype(hx.dtype)
    return out, new_state


def rwkv_channel_mix(
    p,
    hx: jax.Array,
    ctx: Ctx,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    dt = ctx.compute_dtype
    x = hx.astype(dt)
    prev = state["shift_ffn"] if state is not None else None
    xprev = _token_shift(x, prev)
    delta = xprev - x
    x_k = x + delta * p["maa_k"].astype(dt)
    x_r = x + delta * p["maa_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(x_k @ p["wk"].astype(dt)))
    kv = kk @ p["wv"].astype(dt)
    out = (jax.nn.sigmoid(x_r @ p["wr"].astype(dt)) * kv).astype(hx.dtype)
    new_state = None
    if ctx.mode in ("prefill", "decode"):
        new_state = {"shift_ffn": x[:, -1]}
    return out, new_state
