"""Shared layers + the parameter-schema machinery.

A model's parameters are described once as a pytree of :class:`PSpec` leaves
(shape, partition spec, dtype, init). ``init_params`` / ``abstract_params`` /
``shardings`` all derive from the same schema, so the three can never diverge
— the dry-run lowers against exactly the tree the trainer would allocate.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.types import MeshConfig, ModelConfig, ParallelismConfig

# ---------------------------------------------------------------------------
# Parameter schema
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PSpec:
    """One parameter leaf: shape + sharding + init, the single source of truth."""

    shape: Tuple[int, ...]
    pspec: P = P()
    dtype: Any = jnp.float32
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override (default: 1/sqrt(fan_in))


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def tree_map_pspec(fn: Callable[[PSpec], Any], schema):
    return jax.tree.map(fn, schema, is_leaf=is_pspec)


def _init_leaf(spec: PSpec, key, dtype_override=None) -> jax.Array:
    dtype = dtype_override or spec.dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 0.02
        return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)
    # fan-in normal
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale if spec.scale is not None else fan_in ** -0.5
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)


def init_params(schema, key, dtype_override=None):
    """Materialize real arrays from a schema (smoke scale only)."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_pspec)
    out = []
    for i, leaf in enumerate(leaves):
        out.append(_init_leaf(leaf, jax.random.fold_in(key, i), dtype_override))
    return jax.tree.unflatten(treedef, out)


def abstract_params(schema, dtype_override=None):
    """ShapeDtypeStruct stand-ins — no allocation; used by the dry-run."""
    return tree_map_pspec(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype_override or s.dtype), schema
    )


def shardings(schema, mesh: Mesh):
    return tree_map_pspec(lambda s: NamedSharding(mesh, s.pspec), schema)


def pspecs(schema):
    return tree_map_pspec(lambda s: s.pspec, schema)


def param_count(schema) -> int:
    import math

    return sum(math.prod(leaf.shape)
               for leaf in jax.tree.leaves(schema, is_leaf=is_pspec))


# ---------------------------------------------------------------------------
# Apply-time context
# ---------------------------------------------------------------------------


@dataclass
class Ctx:
    """Threaded through every block's ``apply``."""

    cfg: ModelConfig
    mesh_cfg: MeshConfig
    mode: str                                # "train" | "prefill" | "decode"
    mesh: Optional[Mesh] = None
    par: ParallelismConfig = dataclasses.field(default_factory=ParallelismConfig)
    positions: Optional[jax.Array] = None    # (B, S) absolute positions
    attn_impl: str = "ref"                   # "ref" | "flash" (Pallas template)

    @property
    def dp(self) -> Tuple[str, ...]:
        if self.par.grad_compression:
            return ()   # inside the manual-DP shard_map: batch dims are local
        return self.mesh_cfg.dp_axes

    @property
    def tp_size(self) -> int:
        return self.mesh_cfg.axis_size("model")

    @property
    def compute_dtype(self):
        return jnp.dtype(self.par.compute_dtype)

    def constrain(self, x: jax.Array, spec: Optional[P] = None) -> jax.Array:
        """Pin activation layout: (batch over dp, rest replicated) by default."""
        if self.mesh is None or self.mesh.size == 1:
            return x
        if spec is None:
            spec = P(self.dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


def shard_axis(n: int, tp: int) -> Optional[str]:
    """'model' if n shards evenly over the TP axis, else replicate (None)."""
    return "model" if tp > 0 and n % tp == 0 and n >= tp else None


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_schema(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": PSpec((d,), P(), init="ones"),
                "bias": PSpec((d,), P(), init="zeros")}
    return {"scale": PSpec((d,), P(), init="ones")}


def apply_norm(p, x: jax.Array, cfg: ModelConfig, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over head_dim (qwen3 qk-norm)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions: (B, S) -> cos/sin (B, S, head_dim/2), f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd). Rotates pairs (even, odd) halves (llama convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU-2mat / relu^2)
# ---------------------------------------------------------------------------


def mlp_schema(cfg: ModelConfig, d_ff: Optional[int] = None, tp: int = 16):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    fa = shard_axis(f, tp)
    if cfg.act == "gelu":
        return {"wi": PSpec((d, f), P(None, fa)),
                "wo": PSpec((f, d), P(fa, None))}
    # swiglu (silu) and relu_sq share the gated 3-matrix layout for silu,
    # 2-matrix for relu_sq
    if cfg.act == "relu_sq":
        return {"wi": PSpec((d, f), P(None, fa)),
                "wo": PSpec((f, d), P(fa, None))}
    return {"w_gate": PSpec((d, f), P(None, fa)),
            "w_up": PSpec((d, f), P(None, fa)),
            "wo": PSpec((f, d), P(fa, None))}


def apply_mlp(p, x: jax.Array, cfg: ModelConfig, ctx: Ctx) -> jax.Array:
    dt = ctx.compute_dtype
    xd = x.astype(dt)
    if "w_gate" in p:
        g = xd @ p["w_gate"].astype(dt)
        u = xd @ p["w_up"].astype(dt)
        h = jax.nn.silu(g) * u
    else:
        h = xd @ p["wi"].astype(dt)
        if cfg.act == "gelu":
            h = jax.nn.gelu(h)
        else:  # relu^2 (RWKV channel-mix nonlinearity)
            h = jnp.square(jax.nn.relu(h))
    return (h @ p["wo"].astype(dt)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_schema(cfg: ModelConfig, tp: int = 16):
    v = cfg.padded_vocab
    va = None if cfg.embed_replicated else shard_axis(v, tp)
    sch = {"embedding": PSpec((v, cfg.d_model), P(va, None), init="embed")}
    if not cfg.tie_embeddings:
        head_a = shard_axis(v, tp)
        sch["lm_head"] = PSpec((cfg.d_model, v), P(None, head_a))
    return sch


def embed_tokens(p, tokens: jax.Array, cfg: ModelConfig, ctx: Ctx) -> jax.Array:
    e = p["embedding"]
    h = jnp.take(e, tokens, axis=0)
    return h.astype(ctx.compute_dtype)


def lm_logits(p, h: jax.Array, cfg: ModelConfig, ctx: Ctx) -> jax.Array:
    dt = ctx.compute_dtype
    if cfg.tie_embeddings:
        w = p["embedding"].astype(dt).T
    else:
        w = p["lm_head"].astype(dt)
    return (h.astype(dt) @ w).astype(jnp.float32)
