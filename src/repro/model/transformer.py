"""Unified layer-stack assembly for all 10 assigned families + the LSTM.

A model is a sequence of *groups* of homogeneous blocks. Parameters for a
group are stacked with a leading layer axis (one pytree leaf per tensor, so
checkpointing/resharding see a flat stable structure); the stack is applied
either **unrolled** (python loop — exact ``cost_analysis``; the dry-run
default) or via ``lax.scan`` (fast compile; ``ParallelismConfig.scan_layers``).

Block kinds:
  attn      — pre-norm attention + MLP (dense archs; d_ff per group)
  moe       — pre-norm attention + MoE FFN (incl. shared experts)
  mamba2    — pre-norm Mamba2 (zamba2 hybrid); zamba2 additionally applies a
              *shared* full attention block every ``shared_attn_every`` layers
              on concat(h, h_emb0) (weights shared across invocations)
  rwkv6     — RWKV6 time-mix + channel-mix
  enc/dec   — whisper encoder (non-causal) and decoder (causal + cross-attn)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ModelConfig
from repro.model import frontend as fe
from repro.model import moe as moe_mod
from repro.model import rwkv as rwkv_mod
from repro.model import ssm as ssm_mod
from repro.model.attention import attn_apply, attn_schema, cache_schema
from repro.model.layers import (Ctx, PSpec, apply_mlp, apply_norm,
                                embed_schema, embed_tokens, lm_logits,
                                mlp_schema, norm_schema, tree_map_pspec)

# ---------------------------------------------------------------------------
# Group structure
# ---------------------------------------------------------------------------


def group_structure(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """[(block_kind, count)] — the stable decomposition of the layer stack."""
    if cfg.family == "audio":
        assert cfg.encoder is not None
        return [("enc", cfg.encoder.n_layers), ("dec", cfg.n_layers)]
    if cfg.family == "moe":
        m = cfg.moe
        groups: List[Tuple[str, int]] = []
        if m.first_dense:
            groups.append(("attn_dense", m.first_dense))
        groups.append(("moe", cfg.n_layers - m.first_dense))
        return groups
    if cfg.family == "hybrid":
        return [("mamba2", cfg.n_layers)]
    if cfg.family == "ssm":
        return [("rwkv6", cfg.n_layers)]
    return [("attn", cfg.n_layers)]


def block_schema(cfg: ModelConfig, kind: str, tp: int):
    if kind in ("attn", "attn_dense"):
        d_ff = cfg.moe.d_ff_dense if (kind == "attn_dense" and cfg.moe) else cfg.d_ff
        return {
            "norm1": norm_schema(cfg),
            "attn": attn_schema(cfg, tp),
            "norm2": norm_schema(cfg),
            "mlp": mlp_schema(cfg, d_ff=d_ff, tp=tp),
        }
    if kind == "moe":
        return {
            "norm1": norm_schema(cfg),
            "attn": attn_schema(cfg, tp),
            "norm2": norm_schema(cfg),
            "moe": moe_mod.moe_schema(cfg, tp),
        }
    if kind == "mamba2":
        return {"norm1": norm_schema(cfg), "mamba": ssm_mod.mamba_schema(cfg, tp)}
    if kind == "rwkv6":
        return {
            "ln1": norm_schema(cfg),
            "att": rwkv_mod.rwkv_time_schema(cfg, tp),
            "ln2": norm_schema(cfg),
            "ffn": rwkv_mod.rwkv_channel_schema(cfg, tp),
        }
    if kind == "enc":
        return {
            "norm1": norm_schema(cfg),
            "attn": attn_schema(cfg, tp),
            "norm2": norm_schema(cfg),
            "mlp": mlp_schema(cfg, tp=tp),
        }
    if kind == "dec":
        return {
            "norm1": norm_schema(cfg),
            "self_attn": attn_schema(cfg, tp),
            "norm2": norm_schema(cfg),
            "cross_attn": attn_schema(cfg, tp),
            "norm3": norm_schema(cfg),
            "mlp": mlp_schema(cfg, tp=tp),
        }
    raise ValueError(kind)


def shared_block_schema(cfg: ModelConfig, tp: int):
    """zamba2 shared attention block on concat(h, emb0) — width 2·d_model."""
    d2 = 2 * cfg.d_model
    ff_tp = cfg.d_ff % tp == 0 and tp > 1
    return {
        "norm1": norm_schema(cfg, d=d2),
        "attn": attn_schema(cfg, tp, d_in=d2, d_out=d2),
        "norm2": norm_schema(cfg, d=d2),
        "mlp": {
            "w_gate": PSpec((d2, cfg.d_ff),
                            P(None, "model" if ff_tp else None)),
            "w_up": PSpec((d2, cfg.d_ff),
                          P(None, "model" if ff_tp else None)),
            "wo": PSpec((cfg.d_ff, d2),
                        P("model" if ff_tp else None, None)),
        },
        "out_proj": PSpec((d2, cfg.d_model), P()),
    }


def _stack(n: int, tree):
    """Prepend a layer axis (replicated) to every PSpec leaf."""
    return tree_map_pspec(
        lambda s: dataclasses.replace(
            s, shape=(n,) + tuple(s.shape), pspec=P(None, *tuple(s.pspec))
        ),
        tree,
    )


def param_schema(cfg: ModelConfig, tp: int = 16):
    if cfg.family == "lstm":
        from repro.model.lstm import lstm_schema

        return lstm_schema(cfg)
    if cfg.family == "conv1d":
        from repro.model.conv1d import conv1d_schema

        return conv1d_schema(cfg)
    sch: Dict[str, Any] = {"embed": embed_schema(cfg, tp)}
    for gi, (kind, count) in enumerate(group_structure(cfg)):
        sch[f"g{gi}"] = _stack(count, block_schema(cfg, kind, tp))
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        sch["shared"] = shared_block_schema(cfg, tp)
    if cfg.family == "ssm":
        sch["ln0"] = norm_schema(cfg)
    if cfg.frontend:
        sch["frontend"] = fe.frontend_schema(cfg, tp)
    if cfg.family == "audio":
        sch["enc_norm"] = norm_schema(cfg)
    sch["final_norm"] = norm_schema(cfg)
    return sch


# ---------------------------------------------------------------------------
# Cache schema (serving)
# ---------------------------------------------------------------------------


def model_cache_schema(cfg: ModelConfig, batch: int, seq: int, mesh_cfg,
                       tp: int = 16, stacked: bool = False,
                       seq_shard: bool = False):
    """Abstract cache pytree for prefill/decode of `batch` seqs of `seq` max.

    ``stacked=True`` returns the scan-layers layout: one entry per group with
    a leading layer axis (``{"g0": ..., "shared": ...}``) instead of the
    per-layer tuple.
    """
    if stacked:
        return _stacked_cache_schema(cfg, batch, seq, mesh_cfg, tp, seq_shard)
    dp = mesh_cfg.dp_axes
    layers: List[Any] = []
    for kind, count in group_structure(cfg):
        for _ in range(count):
            if kind in ("attn", "attn_dense", "moe"):
                layers.append(cache_schema(cfg, batch, seq, tp, dp,
                                           seq_shard=seq_shard))
            elif kind == "mamba2":
                layers.append(ssm_mod.mamba_state_schema(cfg, batch, dp, tp))
            elif kind == "rwkv6":
                layers.append(rwkv_mod.rwkv_state_schema(cfg, batch, dp, tp))
            elif kind == "enc":
                layers.append(None)           # encoder is stateless
            elif kind == "dec":
                c = cache_schema(cfg, batch, seq, tp, dp,
                                 seq_shard=seq_shard)
                enc_pos = cfg.encoder.n_positions
                kva = c["k"].pspec[2]
                bspec = c["k"].pspec[0] if batch >= 16 else None
                c = dict(c)
                c["ck"] = PSpec((batch, enc_pos, cfg.n_kv_heads, cfg.hd),
                                P(bspec, None, kva, None), dtype=jnp.bfloat16)
                c["cv"] = PSpec((batch, enc_pos, cfg.n_kv_heads, cfg.hd),
                                P(bspec, None, kva, None), dtype=jnp.bfloat16)
                layers.append(c)
    out: Dict[str, Any] = {"layers": tuple(layers)}
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        out["shared"] = tuple(
            cache_schema(cfg, batch, seq, tp, dp)
            for _ in cfg.shared_attn_points()
        )
    return out


def _group_cache_entry(cfg, kind, batch, seq, mesh_cfg, tp,
                       seq_shard=False):
    dp = mesh_cfg.dp_axes
    if kind in ("attn", "attn_dense", "moe"):
        return cache_schema(cfg, batch, seq, tp, dp, seq_shard=seq_shard)
    if kind == "mamba2":
        return ssm_mod.mamba_state_schema(cfg, batch, dp, tp)
    if kind == "rwkv6":
        return rwkv_mod.rwkv_state_schema(cfg, batch, dp, tp)
    if kind == "enc":
        return None
    if kind == "dec":
        c = dict(cache_schema(cfg, batch, seq, tp, dp, seq_shard=seq_shard))
        enc_pos = cfg.encoder.n_positions
        kva = c["k"].pspec[2]
        bspec = c["k"].pspec[0] if batch >= 16 else None
        for key in ("ck", "cv"):
            c[key] = PSpec((batch, enc_pos, cfg.n_kv_heads, cfg.hd),
                           P(bspec, None, kva, None), dtype=jnp.bfloat16)
        return c
    raise ValueError(kind)


def _stacked_cache_schema(cfg, batch, seq, mesh_cfg, tp, seq_shard=False):
    out: Dict[str, Any] = {}
    for gi, (kind, count) in enumerate(group_structure(cfg)):
        entry = _group_cache_entry(cfg, kind, batch, seq, mesh_cfg, tp,
                                   seq_shard)
        out[f"g{gi}"] = None if entry is None else _stack(count, entry)
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        dp = mesh_cfg.dp_axes
        out["shared"] = _stack(len(cfg.shared_attn_points()),
                               cache_schema(cfg, batch, seq, tp, dp))
    return out


# ---------------------------------------------------------------------------
# Block applies
# ---------------------------------------------------------------------------


def _apply_attn_block(p, x, ctx: Ctx, cache, d_ff_override=None):
    a, new_cache = attn_apply(p["attn"], apply_norm(p["norm1"], x, ctx.cfg), ctx,
                              cache=cache)
    x = ctx.constrain(x + a)
    m = apply_mlp(p["mlp"], apply_norm(p["norm2"], x, ctx.cfg), ctx.cfg, ctx)
    return ctx.constrain(x + m), new_cache, jnp.float32(0.0)


def _apply_moe_block(p, x, ctx: Ctx, cache):
    a, new_cache = attn_apply(p["attn"], apply_norm(p["norm1"], x, ctx.cfg), ctx,
                              cache=cache)
    x = ctx.constrain(x + a)
    m, aux = moe_mod.moe_apply(p["moe"], apply_norm(p["norm2"], x, ctx.cfg),
                               ctx.cfg, ctx)
    return ctx.constrain(x + m), new_cache, aux


def _apply_mamba_block(p, x, ctx: Ctx, cache):
    m, new_cache = ssm_mod.mamba_apply(p["mamba"],
                                       apply_norm(p["norm1"], x, ctx.cfg), ctx,
                                       state=cache)
    return ctx.constrain(x + m), new_cache, jnp.float32(0.0)


def _apply_rwkv_block(p, x, ctx: Ctx, cache):
    a, st_a = rwkv_mod.rwkv_time_mix(p["att"], apply_norm(p["ln1"], x, ctx.cfg),
                                     ctx, state=cache)
    x = ctx.constrain(x + a)
    f, st_f = rwkv_mod.rwkv_channel_mix(p["ffn"],
                                        apply_norm(p["ln2"], x, ctx.cfg), ctx,
                                        state=cache)
    new_cache = None
    if st_a is not None or st_f is not None:
        new_cache = {**(st_a or {}), **(st_f or {})}
        if cache is not None:  # keep untouched entries (pytree stability)
            for k in cache:
                new_cache.setdefault(k, cache[k])
    return ctx.constrain(x + f), new_cache, jnp.float32(0.0)


def _apply_enc_block(p, x, ctx: Ctx):
    a, _ = attn_apply(p["attn"], apply_norm(p["norm1"], x, ctx.cfg), ctx,
                      causal=False)
    x = ctx.constrain(x + a)
    m = apply_mlp(p["mlp"], apply_norm(p["norm2"], x, ctx.cfg), ctx.cfg, ctx)
    return ctx.constrain(x + m)


def _apply_dec_block(p, x, ctx: Ctx, cache, enc_kv):
    a, new_cache = attn_apply(p["self_attn"],
                              apply_norm(p["norm1"], x, ctx.cfg), ctx,
                              cache=cache)
    x = ctx.constrain(x + a)
    c, _ = attn_apply(p["cross_attn"], apply_norm(p["norm2"], x, ctx.cfg), ctx,
                      cross_kv=enc_kv)
    x = ctx.constrain(x + c)
    m = apply_mlp(p["mlp"], apply_norm(p["norm3"], x, ctx.cfg), ctx.cfg, ctx)
    return ctx.constrain(x + m), new_cache, jnp.float32(0.0)


def _apply_shared_block(p, x, emb0, ctx: Ctx, cache):
    """zamba2 shared attention block; input concat(h, emb0), width 2d."""
    u = jnp.concatenate([x, emb0], axis=-1)
    a, new_cache = attn_apply(p["attn"], apply_norm(p["norm1"], u, ctx.cfg),
                              ctx, cache=cache)
    u = u + a
    dt = ctx.compute_dtype
    un = apply_norm(p["norm2"], u, ctx.cfg).astype(dt)
    mp = p["mlp"]
    h = jax.nn.silu(un @ mp["w_gate"].astype(dt)) * (un @ mp["w_up"].astype(dt))
    u = u + (h @ mp["wo"].astype(dt)).astype(u.dtype)
    out = (u.astype(dt) @ p["out_proj"].astype(dt)).astype(x.dtype)
    return ctx.constrain(x + out), new_cache


# ---------------------------------------------------------------------------
# Full model apply
# ---------------------------------------------------------------------------


def _maybe_ckpt(fn, ctx: Ctx):
    if ctx.mode != "train" or ctx.cfg.remat == "none":
        return fn
    if ctx.cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def apply_model(
    params,
    batch: Dict[str, jax.Array],
    ctx: Ctx,
    cache: Optional[Dict[str, Any]] = None,
    return_hidden: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """Returns (logits (B,S,V) f32 — or final hidden states if
    ``return_hidden`` (for memory-bounded chunked CE) —, new_cache, aux)."""
    cfg = ctx.cfg
    tokens = batch["tokens"]
    B, S = tokens.shape

    if ctx.positions is None:
        if ctx.mode == "decode":
            pos0 = _decode_positions(cfg, cache, ctx, B)
            ctx = dataclasses.replace(ctx, positions=jnp.reshape(pos0, (B, 1)))
        else:
            ctx = dataclasses.replace(
                ctx, positions=jnp.broadcast_to(jnp.arange(S)[None], (B, S)))

    x = embed_tokens(params["embed"], tokens, cfg, ctx)
    if cfg.family == "ssm":
        x = apply_norm(params["ln0"], x, cfg)
    aux = jnp.float32(0.0)

    # --- modality frontends (stub embeddings from input_specs) -------------
    if cfg.frontend == "vision" and "patches" in batch:
        vis = fe.project_vision(params["frontend"], batch["patches"], ctx)
        nf = min(cfg.n_frontend_tokens, S)   # short-seq smoke guards
        x = jnp.concatenate([vis[:, :nf].astype(x.dtype), x[:, nf:]], axis=1)

    enc_out = None
    if cfg.family == "audio" and "frames" in batch:
        enc_ctx = dataclasses.replace(
            ctx, mode="train" if ctx.mode == "train" else "prefill",
            positions=jnp.broadcast_to(
                jnp.arange(batch["frames"].shape[1])[None],
                (B, batch["frames"].shape[1])))
        e = fe.embed_audio(params["frontend"], batch["frames"], ctx)
        for gi, (kind, count) in enumerate(group_structure(cfg)):
            if kind != "enc":
                continue
            stacked = params[f"g{gi}"]
            if ctx.par.scan_layers:
                def enc_body(e_c, p_l):
                    return _apply_enc_block(p_l, e_c, enc_ctx), None

                if ctx.mode == "train" and cfg.remat != "none":
                    enc_body = jax.checkpoint(enc_body)
                e, _ = jax.lax.scan(enc_body, e, stacked)
            else:
                for i in range(count):
                    pl = jax.tree.map(lambda a: a[i], stacked)
                    e = _maybe_ckpt(
                        lambda p_, e_: _apply_enc_block(p_, e_, enc_ctx), ctx
                    )(pl, e)
        enc_out = apply_norm(params["enc_norm"], e, cfg)

    if ctx.par.scan_layers:
        x, new_cache, aux_s = _apply_groups_scanned(params, x, ctx, cache,
                                                    enc_out)
        aux = aux + aux_s
        x = apply_norm(params["final_norm"], x, cfg)
        logits = x if return_hidden else head_logits(params, x, ctx)
        if ctx.mode not in ("prefill", "decode"):
            new_cache = None
        return logits, new_cache, aux

    emb0 = x if cfg.family == "hybrid" else None
    shared_points = set(cfg.shared_attn_points())
    caches = cache["layers"] if cache is not None else None
    shared_caches = list(cache.get("shared", ())) if cache is not None else []
    new_layer_caches: List[Any] = []
    new_shared_caches: List[Any] = []

    li = 0          # global layer index (cache slot)
    si = 0          # shared-attn invocation index
    for gi, (kind, count) in enumerate(group_structure(cfg)):
        if kind == "enc":
            li += count
            new_layer_caches.extend([None] * count)
            continue
        stacked = params[f"g{gi}"]
        for i in range(count):
            pl = jax.tree.map(lambda a: a[i], stacked)
            c_in = caches[li] if caches is not None else None
            if kind in ("attn", "attn_dense"):
                fn = _maybe_ckpt(
                    lambda p_, x_, c_: _apply_attn_block(p_, x_, ctx, c_), ctx)
                x, c_new, a_ = fn(pl, x, c_in)
            elif kind == "moe":
                fn = _maybe_ckpt(
                    lambda p_, x_, c_: _apply_moe_block(p_, x_, ctx, c_), ctx)
                x, c_new, a_ = fn(pl, x, c_in)
            elif kind == "mamba2":
                fn = _maybe_ckpt(
                    lambda p_, x_, c_: _apply_mamba_block(p_, x_, ctx, c_), ctx)
                x, c_new, a_ = fn(pl, x, c_in)
            elif kind == "rwkv6":
                fn = _maybe_ckpt(
                    lambda p_, x_, c_: _apply_rwkv_block(p_, x_, ctx, c_), ctx)
                x, c_new, a_ = fn(pl, x, c_in)
            elif kind == "dec":
                enc_kv = None
                if enc_out is not None:
                    kvd = _dec_cross_kv(pl["cross_attn"], enc_out, ctx)
                elif c_in is not None and "ck" in c_in:
                    kvd = (c_in["ck"].astype(ctx.compute_dtype),
                           c_in["cv"].astype(ctx.compute_dtype))
                else:
                    raise ValueError("whisper decode needs frames or cache")
                fn = _maybe_ckpt(
                    lambda p_, x_, c_, kv_: _apply_dec_block(p_, x_, ctx, c_, kv_),
                    ctx)
                x, c_new, a_ = fn(pl, x, {k: v for k, v in (c_in or {}).items()
                                          if k in ("k", "v", "pos")} or None,
                                  kvd)
                if c_new is not None:
                    c_new = dict(c_new)
                    c_new["ck"], c_new["cv"] = kvd
            else:
                raise ValueError(kind)
            aux = aux + a_
            new_layer_caches.append(c_new)
            li += 1
            if cfg.family == "hybrid" and (li - 1) in shared_points:
                sc_in = shared_caches[si] if shared_caches else None
                x, sc_new = _apply_shared_block(params["shared"], x, emb0, ctx,
                                                sc_in)
                new_shared_caches.append(sc_new)
                si += 1

    x = apply_norm(params["final_norm"], x, cfg)
    if return_hidden:
        logits = x
    else:
        logits = head_logits(params, x, ctx)

    new_cache = None
    if ctx.mode in ("prefill", "decode"):
        new_cache = {"layers": tuple(new_layer_caches)}
        if new_shared_caches:
            new_cache["shared"] = tuple(new_shared_caches)
    return logits, new_cache, aux


def _block_apply_fn(kind: str):
    if kind in ("attn", "attn_dense"):
        return lambda p, x, ctx, c, enc: _apply_attn_block(p, x, ctx, c)
    if kind == "moe":
        return lambda p, x, ctx, c, enc: _apply_moe_block(p, x, ctx, c)
    if kind == "mamba2":
        return lambda p, x, ctx, c, enc: _apply_mamba_block(p, x, ctx, c)
    if kind == "rwkv6":
        return lambda p, x, ctx, c, enc: _apply_rwkv_block(p, x, ctx, c)
    raise ValueError(kind)


def _apply_groups_scanned(params, x, ctx: Ctx, cache, enc_out):
    """scan-over-layers path (``ParallelismConfig.scan_layers``) — fast
    compile for the full-config dry-run proof; per-layer costs are recovered
    by the reduced-L extrapolation compiles (launch/dryrun.py)."""
    cfg = ctx.cfg
    aux_total = jnp.float32(0.0)
    serving = ctx.mode in ("prefill", "decode")
    new_cache: Dict[str, Any] = {}

    for gi, (kind, count) in enumerate(group_structure(cfg)):
        pstack = params[f"g{gi}"]
        c_g = cache.get(f"g{gi}") if cache is not None else None
        if kind == "enc":
            new_cache[f"g{gi}"] = None
            continue  # encoder ran in the prologue
        if cfg.family == "hybrid":
            x, nc_g, nc_sh, aux_g = _scan_hybrid(params, pstack, x, ctx,
                                                 cache)
            new_cache[f"g{gi}"] = nc_g
            if nc_sh is not None:
                new_cache["shared"] = nc_sh
            aux_total = aux_total + aux_g
            continue

        blk = _block_apply_fn(kind) if kind != "dec" else None

        def body(x_c, xs):
            if c_g is not None:
                p_l, c_l = xs
            else:
                p_l, c_l = xs, None
            if kind == "dec":
                if enc_out is not None:
                    kvd = _dec_cross_kv(p_l["cross_attn"], enc_out, ctx)
                else:
                    kvd = (c_l["ck"].astype(ctx.compute_dtype),
                           c_l["cv"].astype(ctx.compute_dtype))
                sc = {k: v for k, v in (c_l or {}).items()
                      if k in ("k", "v", "pos")} or None
                y, c_new, a_ = _apply_dec_block(p_l, x_c, ctx, sc, kvd)
                if c_new is not None:
                    c_new = dict(c_new, ck=kvd[0].astype(jnp.bfloat16),
                                 cv=kvd[1].astype(jnp.bfloat16))
            else:
                y, c_new, a_ = blk(p_l, x_c, ctx, c_l, enc_out)
            if not serving:
                c_new = None
            return y, (c_new, a_)

        if ctx.mode == "train" and cfg.remat != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots
                if cfg.remat == "dots" else None)
        xs = (pstack, c_g) if c_g is not None else pstack
        x, (c_stacked, auxs) = jax.lax.scan(body, x, xs)
        new_cache[f"g{gi}"] = c_stacked
        aux_total = aux_total + jnp.sum(auxs)
    return x, (new_cache if serving else None), aux_total


def _scan_hybrid(params, pstack, x, ctx: Ctx, cache):
    """zamba2: scan over [shared_attn_every mamba layers + shared block]
    units, remainder layers unrolled."""
    cfg = ctx.cfg
    unit = cfg.shared_attn_every
    n_units = len(cfg.shared_attn_points())
    n_scan = n_units * unit
    rem = cfg.n_layers - n_scan
    emb0 = x
    serving = ctx.mode in ("prefill", "decode")

    p_scan = jax.tree.map(
        lambda a: a[:n_scan].reshape(n_units, unit, *a.shape[1:]), pstack)
    p_rem = jax.tree.map(lambda a: a[n_scan:], pstack)
    c_g = cache.get("g0") if cache is not None else None
    c_sh = cache.get("shared") if cache is not None else None
    c_scan = (jax.tree.map(
        lambda a: a[:n_scan].reshape(n_units, unit, *a.shape[1:]), c_g)
        if c_g is not None else None)
    c_rem = (jax.tree.map(lambda a: a[n_scan:], c_g)
             if c_g is not None else None)

    def unit_body(x_c, xs):
        if c_scan is not None:
            p_u, c_u, sc = xs
        else:
            p_u, c_u, sc = xs, None, None
        new_states = []
        a_tot = jnp.float32(0.0)
        for j in range(unit):
            p_l = jax.tree.map(lambda a: a[j], p_u)
            c_l = jax.tree.map(lambda a: a[j], c_u) if c_u is not None else None
            x_c, c_new, a_ = _apply_mamba_block(p_l, x_c, ctx, c_l)
            new_states.append(c_new)
            a_tot = a_tot + a_
        x_c, sc_new = _apply_shared_block(params["shared"], x_c, emb0, ctx, sc)
        if serving:
            stacked_states = jax.tree.map(
                lambda *ls: jnp.stack(ls), *new_states)
        else:
            stacked_states, sc_new = None, None
        return x_c, (stacked_states, sc_new, a_tot)

    if ctx.mode == "train" and cfg.remat != "none":
        unit_body = jax.checkpoint(unit_body)
    xs = (p_scan, c_scan, c_sh) if c_scan is not None else p_scan
    x, (states_s, sh_s, auxs) = jax.lax.scan(unit_body, x, xs)

    rem_states = []
    aux_rem = jnp.float32(0.0)
    for j in range(rem):
        p_l = jax.tree.map(lambda a: a[j], p_rem)
        c_l = jax.tree.map(lambda a: a[j], c_rem) if c_rem is not None else None
        fn = _maybe_ckpt(lambda p_, x_, c_: _apply_mamba_block(p_, x_, ctx, c_),
                         ctx)
        x, c_new, a_ = fn(p_l, x, c_l)
        rem_states.append(c_new)
        aux_rem = aux_rem + a_

    nc_g = None
    if serving:
        flat = jax.tree.map(
            lambda a: a.reshape(n_scan, *a.shape[2:]), states_s)
        if rem_states:
            rem_stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *rem_states)
            nc_g = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), flat,
                rem_stacked)
        else:
            nc_g = flat
    return x, nc_g, (sh_s if serving else None), jnp.sum(auxs) + aux_rem


def head_logits(params, x: jax.Array, ctx: Ctx) -> jax.Array:
    """LM head with vocab-sharded output constraint."""
    cfg = ctx.cfg
    logits = lm_logits(params["embed"], x, cfg, ctx)
    if ctx.mesh is not None and ctx.mesh.size > 1:
        from jax.sharding import NamedSharding

        va = "model" if cfg.padded_vocab % ctx.tp_size == 0 else None
        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(ctx.mesh, P(ctx.dp, None, va)))
    return logits


def _dec_cross_kv(p_cross, enc_out, ctx: Ctx):
    dt = ctx.compute_dtype
    hd = ctx.cfg.hd
    KV = p_cross["wk"].shape[1] // hd
    B, Se, _ = enc_out.shape
    k = (enc_out.astype(dt) @ p_cross["wk"].astype(dt)).reshape(B, Se, KV, hd)
    v = (enc_out.astype(dt) @ p_cross["wv"].astype(dt)).reshape(B, Se, KV, hd)
    return k, v


def pad_cache(cache, target_len: int):
    """Pad every attention KV cache in `cache` to `target_len` slots.

    Prefill returns caches sized to the prompt; decode scatters new K/V at
    ``pos`` so the buffers must be pre-extended to the serving max length.
    SSM/RWKV states (no seq axis) pass through untouched.
    """
    def pad_entry(c):
        if not (isinstance(c, dict) and "k" in c and "v" in c):
            return c
        out = dict(c)
        for key in ("k", "v"):
            buf = c[key]
            extra = target_len - buf.shape[1]
            if extra > 0:
                pad = [(0, 0)] * buf.ndim
                pad[1] = (0, extra)
                out[key] = jnp.pad(buf, pad)
        return out

    new = {"layers": tuple(pad_entry(c) for c in cache["layers"])}
    if "shared" in cache:
        new["shared"] = tuple(pad_entry(c) for c in cache["shared"])
    return new


def _decode_positions(cfg: ModelConfig, cache, ctx: Ctx, B: int) -> jax.Array:
    """Current sequence lengths (B,) from whichever cache entry tracks them."""
    if ctx.par.scan_layers:
        for gi, (kind, count) in enumerate(group_structure(cfg)):
            if kind in ("attn", "attn_dense", "moe", "dec"):
                return cache[f"g{gi}"]["pos"][0]
        if "shared" in cache:
            return cache["shared"]["pos"][0]
        return jnp.zeros((B,), jnp.int32)
    ai = _first_attn_idx(cfg)
    if ai is not None:
        return cache["layers"][ai]["pos"]
    if cache.get("shared"):
        return cache["shared"][0]["pos"]
    return jnp.zeros((B,), jnp.int32)   # rwkv: positions unused


def _first_attn_idx(cfg: ModelConfig) -> Optional[int]:
    li = 0
    for kind, count in group_structure(cfg):
        if kind in ("attn", "attn_dense", "moe", "dec"):
            return li
        li += count
    return None
