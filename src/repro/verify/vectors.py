"""Golden stimulus/response vectors — the portable half of the Elastic Node.

The paper's deployment loop closes with the Elastic Node replaying known
stimuli through the flashed accelerator and checking the responses. This
module generates those vector sets *deterministically* per design and
serializes them in a format a bring-up harness (or a later real-FPGA run)
can consume without any of this repo's code:

* ``vectors.npz``   — ``stimulus`` / ``response`` int32 code arrays (the
  exact BRAM/wire words, at the design's input/output Q-formats);
* ``manifest.json`` — design name, Q-formats, shapes, seeds, per-array
  SHA-256 — enough to validate a replay end-to-end.

Determinism is a contract, not an accident: stimulus comes from a seeded
``numpy`` PCG64 stream (platform-stable, jax-version-independent) and always
includes the corner rows (all-zero, all-min, all-max codes); responses are
integer emulator outputs (exact arithmetic); the ``.npz`` is written through
a fixed-timestamp zip writer so *generating the same design's vectors twice
yields byte-identical files* (snapshot-tested). Canonical per-arch designs
use numpy-seeded weights for the same reason.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import zipfile
from dataclasses import dataclass, field
from typing import Dict, Tuple

import numpy as np

from repro.quant.fixedpoint import FxpFormat

#: bump when the vector format changes incompatibly (recorded per manifest)
VECTOR_FORMAT_VERSION = 1
#: the one seed golden (checked-in) vector sets are generated with
GOLDEN_SEED = 2024
#: random rows per golden set, on top of the 3 corner rows
GOLDEN_N_RANDOM = 13

VECTORS_NPZ = "vectors.npz"
VECTORS_MANIFEST = "manifest.json"


def parse_fmt(s: str) -> FxpFormat:
    """Inverse of ``str(FxpFormat)`` — "Q8.4" -> FxpFormat(8, 4)."""
    if not s.startswith("Q") or "." not in s:
        raise ValueError(f"not a Q-format string: {s!r}")
    total, frac = s[1:].split(".", 1)
    return FxpFormat(int(total), int(frac))


@dataclass(frozen=True)
class VectorSet:
    """One design's golden vectors: int codes in, expected int codes out."""

    design: str
    stimulus: np.ndarray             # (B, *in_shape) int32, codes of in_fmt
    response: np.ndarray             # (B, *out_shape) int32, codes of out_fmt
    in_fmt: FxpFormat
    out_fmt: FxpFormat
    seed: int = GOLDEN_SEED
    meta: Dict = field(default_factory=dict)

    @property
    def n_vectors(self) -> int:
        return int(self.stimulus.shape[0])

    def stimulus_f(self) -> np.ndarray:
        """The float values the int stimulus codes represent (exact)."""
        return self.stimulus.astype(np.float32) / self.in_fmt.scale

    def head(self, n: int) -> "VectorSet":
        """The first ``n`` rows as a standalone set — the canary slice.

        Health probes (``repro.resilience``) replay a handful of golden
        rows per check; the leading rows are the corner patterns
        (zero, rail-low, rail-high), which exercise every memory's
        contribution before any random row would.
        """
        if n < 1:
            raise ValueError(f"head(n) needs n >= 1, got {n}")
        n = min(n, self.n_vectors)
        return VectorSet(design=self.design,
                         stimulus=self.stimulus[:n],
                         response=self.response[:n],
                         in_fmt=self.in_fmt, out_fmt=self.out_fmt,
                         seed=self.seed,
                         meta={**self.meta, "slice": f"head({n})"})


def _sha256(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


def corner_codes(shape: Tuple[int, ...], fmt: FxpFormat) -> np.ndarray:
    """The 3 rows every stimulus set leads with: silence, rail-low, rail-high
    (the classic bring-up patterns — they catch sign/saturation wiring bugs
    before any random vector would)."""
    return np.stack([np.zeros(shape, np.int32),
                     np.full(shape, fmt.lo, np.int32),
                     np.full(shape, fmt.hi, np.int32)])


def stimulus_codes(shape: Tuple[int, ...], fmt: FxpFormat, *,
                   n_random: int = GOLDEN_N_RANDOM,
                   seed: int = GOLDEN_SEED) -> np.ndarray:
    """Corner rows + ``n_random`` seeded uniform rows over the full code
    range — numpy PCG64, so the same (shape, fmt, seed) always yields the
    same bytes on every platform and jax version."""
    rng = np.random.Generator(np.random.PCG64(seed))
    rows = [corner_codes(shape, fmt)]
    if n_random > 0:
        rows.append(rng.integers(fmt.lo, fmt.hi + 1,
                                 size=(n_random, *shape),
                                 dtype=np.int64).astype(np.int32))
    return np.concatenate(rows, axis=0)


def generate_vectors(graph, *, n_random: int = GOLDEN_N_RANDOM,
                     seed: int = GOLDEN_SEED, mode: str = "jnp") -> VectorSet:
    """Build the golden set for a lowered design: deterministic stimulus at
    the input edge's format, responses from the bit-exact emulator (``jnp``
    mode by default — the plainest execution path; all modes are bit-exact,
    which is exactly what conformance re-checks)."""
    from repro.rtl.emulator import RTLEmulator
    from repro.rtl.oplib import get_template

    in_edge = graph.edges[graph.inputs[0]]
    out_edge = graph.edges[graph.outputs[0]]
    stim = stimulus_codes(in_edge.shape, in_edge.fmt,
                          n_random=n_random, seed=seed)
    resp = np.asarray(RTLEmulator(graph, mode=mode).run_int(stim).outputs,
                      np.int32)
    kinds = sorted({n.op for n in graph.nodes})
    meta = {
        "format_version": VECTOR_FORMAT_VERSION,
        "template_kinds": kinds,
        "sequential_kinds": sorted(
            k for k in kinds if get_template(k).sequential),
        "edges": {e.name: {"shape": list(e.shape), "fmt": str(e.fmt)}
                  for e in graph.edges.values()},
        "emulator_mode": mode,
        "n_corner": 3,
        "n_random": n_random,
    }
    return VectorSet(design=graph.name, stimulus=stim, response=resp,
                     in_fmt=in_edge.fmt, out_fmt=out_edge.fmt, seed=seed,
                     meta=meta)


# --------------------------------------------------------------------------- #
# Serialization: deterministic .npz + JSON manifest
# --------------------------------------------------------------------------- #


def _write_npz_deterministic(path: str, arrays: Dict[str, np.ndarray]) -> None:
    """``np.savez`` minus the nondeterminism: fixed zip timestamps, sorted
    member order, no compression — same arrays, same bytes, every time."""
    with zipfile.ZipFile(path, "w", zipfile.ZIP_STORED) as zf:
        for name in sorted(arrays):
            buf = io.BytesIO()
            np.save(buf, np.ascontiguousarray(arrays[name]))
            info = zipfile.ZipInfo(f"{name}.npy",
                                   date_time=(1980, 1, 1, 0, 0, 0))
            zf.writestr(info, buf.getvalue())


def save_vectors(vs: VectorSet, out_dir: str) -> Dict[str, str]:
    """Write ``vectors.npz`` + ``manifest.json``; returns {filename: path}.

    The manifest carries SHA-256 digests of both arrays so a bring-up
    harness can validate a transfer without trusting the transport.
    """
    os.makedirs(out_dir, exist_ok=True)
    npz_path = os.path.join(out_dir, VECTORS_NPZ)
    man_path = os.path.join(out_dir, VECTORS_MANIFEST)
    _write_npz_deterministic(npz_path, {"stimulus": vs.stimulus,
                                        "response": vs.response})
    manifest = {
        "design": vs.design,
        "format_version": VECTOR_FORMAT_VERSION,
        "seed": vs.seed,
        "n_vectors": vs.n_vectors,
        "stimulus": {"shape": list(vs.stimulus.shape), "dtype": "int32",
                     "fmt": str(vs.in_fmt), "sha256": _sha256(vs.stimulus)},
        "response": {"shape": list(vs.response.shape), "dtype": "int32",
                     "fmt": str(vs.out_fmt), "sha256": _sha256(vs.response)},
        "meta": vs.meta,
    }
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    return {VECTORS_NPZ: npz_path, VECTORS_MANIFEST: man_path}


def load_vectors(in_dir: str) -> VectorSet:
    """Read a saved set back, verifying shapes and SHA-256 digests (a golden
    set that fails its own checksums must never silently 'pass')."""
    with open(os.path.join(in_dir, VECTORS_MANIFEST)) as f:
        man = json.load(f)
    if man["format_version"] != VECTOR_FORMAT_VERSION:
        raise ValueError(
            f"vector set {in_dir!r} has format_version "
            f"{man['format_version']}, this reader understands "
            f"{VECTOR_FORMAT_VERSION}")
    with np.load(os.path.join(in_dir, VECTORS_NPZ)) as z:
        stim, resp = np.asarray(z["stimulus"]), np.asarray(z["response"])
    for name, arr in (("stimulus", stim), ("response", resp)):
        want = man[name]
        if list(arr.shape) != want["shape"]:
            raise ValueError(f"{name} shape {list(arr.shape)} != manifest "
                             f"{want['shape']}")
        got = _sha256(arr)
        if got != want["sha256"]:
            raise ValueError(f"{name} sha256 mismatch in {in_dir!r}: "
                             f"{got} != {want['sha256']}")
    return VectorSet(design=man["design"], stimulus=stim, response=resp,
                     in_fmt=parse_fmt(man["stimulus"]["fmt"]),
                     out_fmt=parse_fmt(man["response"]["fmt"]),
                     seed=man["seed"], meta=man.get("meta", {}))


# --------------------------------------------------------------------------- #
# Canonical per-arch designs (what the checked-in golden sets pin)
# --------------------------------------------------------------------------- #


def canonical_params(schema, *, seed: int = 0):
    """Materialize a schema with numpy-seeded weights (PCG64) — same role as
    ``model.layers.init_params`` but independent of the jax PRNG, so golden
    responses survive jax upgrades byte-for-byte."""
    import jax

    from repro.model.layers import is_pspec

    rng = np.random.Generator(np.random.PCG64(seed))
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_pspec)
    out = []
    for spec in leaves:
        if spec.init == "zeros":
            out.append(np.zeros(spec.shape, np.float32))
            continue
        scale = spec.scale if spec.scale is not None else \
            1.0 / np.sqrt(max(1, spec.shape[0]))
        out.append((rng.standard_normal(spec.shape) * scale)
                   .astype(np.float32))
    return jax.tree.unflatten(treedef, out)


def canonical_graph(arch: str, *, seed: int = 0,
                    **fmt_kwargs) -> Tuple[object, object, object]:
    """The reference design golden vectors are generated against: registered
    arch config + numpy-seeded canonical weights + default Q-formats,
    lowered through the hardware-template registry.

    Returns ``(graph, cfg, params)``.
    """
    from repro.configs import get_config
    from repro.rtl.ir import lower_model

    cfg = get_config(arch)
    schema = _schema_for(cfg)
    params = canonical_params(schema, seed=seed)
    return lower_model(cfg, params, **fmt_kwargs), cfg, params


def _schema_for(cfg):
    """Family -> parameter schema, for the families the RTL registry lowers."""
    if cfg.family == "lstm":
        from repro.model.lstm import lstm_schema

        return lstm_schema(cfg)
    if cfg.family == "conv1d":
        from repro.model.conv1d import conv1d_schema

        return conv1d_schema(cfg)
    from repro.rtl.oplib import lowerable_families

    raise NotImplementedError(
        f"no canonical schema for family {cfg.family!r}; "
        f"lowerable families: {lowerable_families()}")


def golden_dir(root: str, arch: str) -> str:
    """Layout convention for checked-in sets: ``<root>/<arch>/``."""
    return os.path.join(root, arch)


def emit_golden(arch: str, root: str, *,
                seed: int = GOLDEN_SEED) -> VectorSet:
    """Generate + save the canonical golden set for ``arch`` under
    ``root/<arch>/``; the one entry point both the snapshot tests and a
    regeneration run use (so they cannot drift apart)."""
    graph, _, _ = canonical_graph(arch)
    vs = generate_vectors(graph, seed=seed)
    save_vectors(vs, golden_dir(root, arch))
    return vs
