"""Differential conformance — the Elastic Node's pass/fail logic.

One design, three independent implementations of its integer semantics (the
``fused``/``pallas``/``jnp`` emulator paths) and one float oracle
(``reference_apply``, built only from ``fxp_quantize``). Conformance means:

1. **mutual bit-exactness** — every execution mode produces the *same int32
   codes* for the same stimulus (a divergence is a miscompiled schedule);
2. **oracle agreement within budget** — int output vs the float oracle stays
   within a per-design error budget in output LSBs, derived from the fixed-
   point wordlengths: inside the §4 exactness envelope the budget is 0
   (exact equality is the contract), and any slack must be *declared* by a
   template (``HWTemplate.error_budget_lsb``), never assumed;
3. **golden replay** (when a stored vector set is supplied) — responses
   match the checked-in set integer-for-integer, i.e. the flashed design
   still behaves like the one that was signed off.

``run_conformance`` produces a structured :class:`ConformanceReport`;
``verify_deployment`` is the uniform ``Deployment.verify`` entry point that
adds the measurement protocol (latency/energy bands, ``protocol.py``) and
also covers host-executed targets (XLA), where the differential half reduces
to an oracle comparison at float precision.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.verify.vectors import VectorSet, generate_vectors

DEFAULT_MODES = ("fused", "pallas", "jnp")


@dataclass
class ConformanceReport:
    """The structured verdict ``Deployment.verify`` returns (and CI uploads).

    ``passed`` is the conjunction of every *enforced* sub-check; individual
    fields keep the evidence so a failure is debuggable from the artifact
    alone.
    """

    design: str
    target: str
    passed: bool = True
    # differential half (RTL targets; empty for host-executed targets)
    modes: Tuple[str, ...] = ()
    modes_bit_exact: bool = True
    mode_max_diff: Dict[str, int] = field(default_factory=dict)
    oracle_max_lsb: float = 0.0
    error_budget_lsb: int = 0
    oracle_within_budget: bool = True
    n_vectors: int = 0
    golden_match: Optional[bool] = None      # None: no stored set replayed
    # protocol half (both targets)
    protocol: Optional[dict] = None
    notes: List[str] = field(default_factory=list)

    def to_dict(self) -> dict:
        return asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    def summary(self) -> str:
        bits = [f"{self.design}[{self.target}]",
                "PASS" if self.passed else "FAIL"]
        if self.modes:
            bits.append(f"modes={'=='.join(self.modes)}"
                        f"{'(exact)' if self.modes_bit_exact else '(DIVERGED)'}")
            bits.append(f"oracle<= {self.oracle_max_lsb:g} LSB "
                        f"(budget {self.error_budget_lsb})")
            bits.append(f"vectors={self.n_vectors}")
        if self.golden_match is not None:
            bits.append(f"golden={'ok' if self.golden_match else 'MISMATCH'}")
        if self.protocol is not None:
            bits.append(f"protocol={'ok' if self.protocol.get('passed') else 'FAIL'}")
        return "  ".join(bits)


def graph_error_budget_lsb(graph) -> int:
    """The design's allowed |int − oracle| at the output, in output LSBs.

    Derivation (DESIGN.md §10): every built-in template is exact inside the
    §4 envelope — ``ir.validate_formats`` guarantees all accumulators stay
    below 2**24, where int32 arithmetic and the f32 oracle are the same
    function — so each contributes 0. Budgets compose additively along the
    dataflow: a node's declared slack (``HWTemplate.error_budget_lsb``)
    bounds its output error in its own LSBs, and downstream requantization
    never amplifies an LSB-scale error by more than 1 code. The sum is
    therefore a conservative bound for the whole graph.
    """
    from repro.rtl.oplib import get_template

    return int(sum(get_template(n.op).error_budget_lsb(n)
                   for n in graph.nodes))


def oracle_codes(graph, stimulus_f: np.ndarray) -> np.ndarray:
    """The float oracle's output, as int codes of the output edge format."""
    import jax.numpy as jnp

    from repro.rtl.emulator import reference_apply

    fmt = graph.edges[graph.outputs[0]].fmt
    ref = reference_apply(graph, jnp.asarray(stimulus_f, jnp.float32))
    return np.asarray(jnp.round(ref * fmt.scale), np.int64)


def run_conformance(graph, vectors: Optional[VectorSet] = None, *,
                    modes: Sequence[str] = DEFAULT_MODES,
                    target: str = "rtl",
                    extra_stimulus: Optional[np.ndarray] = None,
                    replay_golden: Optional[bool] = None
                    ) -> ConformanceReport:
    """Differential-execute ``graph`` over a golden vector set.

    ``vectors=None`` generates the design's deterministic set on the fly;
    passing a loaded set additionally replays its stored responses
    (``golden_match`` — ``replay_golden=False`` opts a freshly generated,
    never-stored set out of that check). ``extra_stimulus`` appends
    caller-provided int code rows (e.g. fuzz samples from a template's
    ``sample_inputs`` hook).

    Each differential sub-check runs in its own span (``verify.mode`` per
    execution mode, ``verify.oracle``, ``verify.golden_replay``) so a
    failing mode is attributable in the captured trace, not just the
    report.
    """
    from repro.obs import get_tracer
    from repro.rtl.emulator import outputs_by_mode

    trc = get_tracer()
    rep = ConformanceReport(design=graph.name, target=target,
                            modes=tuple(modes))
    with trc.span("verify.conformance", design=graph.name,
                  target=target) as root:
        if replay_golden is None:
            replay_golden = vectors is not None
        if vectors is None:
            with trc.span("verify.generate_vectors", design=graph.name):
                vectors = generate_vectors(graph)
        stim = vectors.stimulus
        if extra_stimulus is not None:
            stim = np.concatenate([stim,
                                   np.asarray(extra_stimulus, np.int32)],
                                  axis=0)
        rep.n_vectors = int(stim.shape[0])

        # 1 — every execution mode must agree integer-for-integer
        outs = {}
        for m in rep.modes:
            with trc.span("verify.mode", mode=m, design=graph.name):
                outs[m] = outputs_by_mode(graph, stim, modes=(m,))[m]
        base_mode = rep.modes[0]
        base = outs[base_mode]
        for m in rep.modes[1:]:
            diff = int(np.max(np.abs(outs[m] - base))) if base.size else 0
            rep.mode_max_diff[f"{base_mode}-vs-{m}"] = diff
            if diff != 0:
                rep.modes_bit_exact = False
                rep.notes.append(f"mode {m!r} diverges from {base_mode!r} "
                                 f"by up to {diff} codes")

        # 2 — int vs float oracle, within the declared LSB budget
        with trc.span("verify.oracle", design=graph.name) as so:
            ref_int = oracle_codes(graph, stim.astype(np.float32)
                                   / vectors.in_fmt.scale)
            rep.error_budget_lsb = graph_error_budget_lsb(graph)
            rep.oracle_max_lsb = float(np.max(np.abs(base - ref_int))) \
                if base.size else 0.0
            rep.oracle_within_budget = \
                rep.oracle_max_lsb <= rep.error_budget_lsb
            so.set_attrs(max_lsb=rep.oracle_max_lsb,
                         budget=rep.error_budget_lsb)
        if not rep.oracle_within_budget:
            rep.notes.append(
                "int output deviates from the fxp_quantize oracle by "
                f"{rep.oracle_max_lsb:g} LSB > budget "
                f"{rep.error_budget_lsb}")

        # 3 — golden replay: stored responses must still be what the
        # design does
        if replay_golden:
            with trc.span("verify.golden_replay", design=graph.name) as sg:
                n = vectors.response.shape[0]
                rep.golden_match = bool(np.array_equal(base[:n],
                                                       vectors.response))
                sg.set_attrs(match=rep.golden_match)
            if not rep.golden_match:
                bad = np.argwhere(base[:n] != vectors.response)
                rep.notes.append(
                    f"stored golden responses mismatch at {len(bad)} "
                    f"positions (first {bad[0].tolist()})")

        rep.passed = (rep.modes_bit_exact and rep.oracle_within_budget
                      and rep.golden_match is not False)
        root.set_attrs(passed=rep.passed)
    return rep


def run_conformance_batch(graphs, *,
                          modes: Sequence[str] = DEFAULT_MODES,
                          stimulus: Optional[np.ndarray] = None
                          ) -> List[ConformanceReport]:
    """Differential conformance over K program-isomorphic candidates in
    one batched sweep — the DSE feasibility oracle (DESIGN.md §15).

    The base path runs every design at once: one vmapped ``jnp`` dispatch
    through :class:`~repro.rtl.multi.MultiDesignEmulator` (one trace +
    compile for the whole set). Each per-design sequential mode then
    cross-checks its candidate through a *shared*
    :class:`~repro.rtl.program_cache.ProgramLRU` — isomorphic designs
    share the compiled program, so each mode traces once for all K, not
    once per candidate. Reports mirror :func:`run_conformance`: mutual
    bit-exactness (vmapped axis vs every sequential mode) plus the float
    oracle within the declared LSB budget, one report per design.
    """
    from repro.rtl.multi import MultiDesignEmulator
    from repro.rtl.emulator import RTLEmulator
    from repro.rtl.program_cache import ProgramLRU

    graphs = list(graphs)
    multi = MultiDesignEmulator(graphs)      # validates isomorphism
    if stimulus is None:
        stimulus = generate_vectors(graphs[0]).stimulus
    stim = np.asarray(stimulus, np.int32)
    in_fmt = graphs[0].edges[graphs[0].inputs[0]].fmt

    batched = np.asarray(multi.run_int(stim).outputs, np.int64)  # (K, B, .)
    shared = {m: ProgramLRU(4) for m in modes}
    reports: List[ConformanceReport] = []
    for kidx, g in enumerate(graphs):
        rep = ConformanceReport(design=g.name, target="rtl",
                                modes=("vmap-jnp",) + tuple(modes))
        rep.n_vectors = int(stim.shape[0])
        base = batched[kidx]
        for m in modes:
            em = RTLEmulator(g, mode=m, programs=shared[m])
            out = np.asarray(em.run_int(stim).outputs, np.int64)
            diff = int(np.max(np.abs(out - base))) if base.size else 0
            rep.mode_max_diff[f"vmap-jnp-vs-{m}"] = diff
            if diff != 0:
                rep.modes_bit_exact = False
                rep.notes.append(
                    f"sequential mode {m!r} diverges from the vmapped "
                    f"design axis by up to {diff} codes")
        ref_int = oracle_codes(g, stim.astype(np.float32) / in_fmt.scale)
        rep.error_budget_lsb = graph_error_budget_lsb(g)
        rep.oracle_max_lsb = float(np.max(np.abs(base - ref_int))) \
            if base.size else 0.0
        rep.oracle_within_budget = \
            rep.oracle_max_lsb <= rep.error_budget_lsb
        if not rep.oracle_within_budget:
            rep.notes.append(
                "int output deviates from the fxp_quantize oracle by "
                f"{rep.oracle_max_lsb:g} LSB > budget "
                f"{rep.error_budget_lsb}")
        rep.passed = rep.modes_bit_exact and rep.oracle_within_budget
        reports.append(rep)
    return reports


def fuzz_template(kind: str, *, seed: int = 0, batch: int = 8,
                  modes: Sequence[str] = DEFAULT_MODES
                  ) -> Optional[ConformanceReport]:
    """Property-check one registered hardware template.

    Builds the template's ``probe_graph`` with a seeded rng, draws stimulus
    from its ``sample_inputs`` hook (corner rows + seeded codes), and runs
    the full differential check. Returns ``None`` for templates with no
    standalone compute (``probe_graph() is None``) — they are covered
    through the kinds that instantiate them. This is how third-party
    templates inherit the harness: register, get fuzzed.
    """
    from repro.quant.fixedpoint import fxp_to_int
    from repro.rtl.oplib import get_template

    tmpl = get_template(kind)
    rng = np.random.Generator(np.random.PCG64(seed))
    graph = tmpl.probe_graph(rng)
    if graph is None:
        return None
    node = next(n for n in graph.nodes if n.op == kind)
    x = tmpl.sample_inputs(node, graph, rng, batch=batch)
    in_fmt = graph.edges[graph.inputs[0]].fmt
    codes = np.asarray(fxp_to_int(x, in_fmt), np.int32)
    return run_conformance(graph, modes=modes, extra_stimulus=codes)


# --------------------------------------------------------------------------- #
# Canary: the in-service health-check slice of the golden protocol
# --------------------------------------------------------------------------- #


@dataclass
class CanaryResult:
    """Verdict of one golden-slice health probe (``canary_check``)."""

    design: str
    n: int
    passed: bool
    n_mismatch: int = 0
    max_diff: int = 0
    path: str = "int"                # "int" (emulator codes) or "float"

    def to_dict(self) -> dict:
        return asdict(self)


def canary_check(dep, vectors: VectorSet, *, n: int = 4) -> CanaryResult:
    """Replay the first ``n`` golden rows through a *live* deployment and
    demand integer-exact responses — the in-service slice of the Elastic
    Node protocol that ``repro.resilience`` guards probe with.

    Unlike :func:`run_conformance` (which re-executes the *design*), this
    exercises the deployment instance actually serving traffic: for RTL
    deployments the int codes go straight through its emulator (whose
    prepared memories are exactly what an SEU corrupts); host-executed
    deployments answer in float and are re-encoded at the output format.
    A single flipped weight bit shows up here as a code mismatch on the
    rail rows long before any accuracy metric would move.
    """
    vs = vectors.head(n)
    emu = getattr(dep, "emulator", None)
    if emu is not None:
        got = np.asarray(emu.run_int(vs.stimulus).outputs, np.int64)
        path = "int"
    else:
        out = dep(np.asarray(vs.stimulus_f()))
        got = np.asarray(np.rint(np.asarray(out, np.float32)
                                 * vs.out_fmt.scale), np.int64)
        path = "float"
    want = np.asarray(vs.response, np.int64)
    got = got.reshape(want.shape)
    diff = np.abs(got - want)
    return CanaryResult(design=vs.design, n=vs.n_vectors,
                        passed=bool(np.array_equal(got, want)),
                        n_mismatch=int(np.count_nonzero(diff)),
                        max_diff=int(diff.max()) if diff.size else 0,
                        path=path)


# --------------------------------------------------------------------------- #
# Deployment-level entry (what Deployment.verify calls)
# --------------------------------------------------------------------------- #


def verify_deployment(dep, args=None, *, model: str, model_flops: float,
                      hw=None, protocol=None, oracle=None,
                      modes: Sequence[str] = DEFAULT_MODES,
                      vectors: Optional[VectorSet] = None
                      ) -> ConformanceReport:
    """Run any :class:`~repro.core.target.Deployment` through the Elastic
    Node conformance protocol; the uniform body behind ``Deployment.verify``.

    RTL deployments (anything carrying a lowered ``graph``) get the full
    differential check over golden vectors plus the measurement protocol.
    Host-executed deployments (XLA) get the measurement protocol plus, when
    an ``oracle`` callable is provided, a float comparison of the deployed
    executable against it.
    """
    from repro.verify.protocol import run_protocol

    graph = getattr(dep, "graph", None)
    if graph is not None:
        vs = vectors if vectors is not None else generate_vectors(graph)
        rep = run_conformance(graph, vs, modes=modes,
                              target=dep.target or "rtl",
                              replay_golden=vectors is not None)
        if args is None:
            args = (vs.stimulus_f()[:1],)
    else:
        rep = ConformanceReport(design=model, target=dep.target or "xla")
        if oracle is not None and args is not None:
            import jax

            got = [np.asarray(leaf, np.float32)
                   for leaf in jax.tree.leaves(dep(*args))]
            want = [np.asarray(leaf, np.float32)
                    for leaf in jax.tree.leaves(oracle(*args))]
            err, tol, shapes_ok = 0.0, 0.0, len(got) == len(want)
            for a, b in zip(got, want):
                if a.shape != b.shape:
                    shapes_ok = False
                    break
                if a.size:
                    err = max(err, float(np.max(np.abs(a - b))))
                    tol = max(tol, 1e-4 * max(1.0,
                                              float(np.max(np.abs(b)))))
            if not shapes_ok or err > tol:
                rep.passed = False
                rep.notes.append("deployed executable deviates from oracle "
                                 f"by {err:g} (tol {tol:g})"
                                 if shapes_ok else
                                 "deployed executable and oracle disagree "
                                 "on output structure")
            else:
                rep.notes.append(f"oracle agreement: max|Δ|={err:g} "
                                 f"<= {tol:g}")
    if args is not None:
        prot = run_protocol(dep, args, model=model, model_flops=model_flops,
                            hw=hw, protocol=protocol)
        rep.protocol = prot.to_dict()
        if not prot.passed:
            rep.passed = False
            rep.notes.append("measurement protocol failed: " + "; ".join(
                c.name for c in prot.checks if c.enforced and not c.passed))
    return rep
