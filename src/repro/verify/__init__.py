"""Elastic Node conformance subsystem (DESIGN.md §10).

The paper's workflow has two halves: the Creator *generates* accelerators,
the Elastic Node *verifies* them — "the performance of the accelerator can
be sufficiently guaranteed". This package is that verification half as a
first-class API, applied uniformly to every registered deployment target
and hardware template:

* :mod:`repro.verify.vectors`     — deterministic golden stimulus/response
  sets per design, serialized as portable ``.npz`` + JSON manifest (the
  hand-off artifact for real-FPGA bring-up);
* :mod:`repro.verify.conformance` — differential execution (all emulator
  modes mutually bit-exact; int vs float-oracle within the wordlength-
  derived error budget; golden replay) → :class:`ConformanceReport`;
* :mod:`repro.verify.protocol`    — the measurement procedure (warmup,
  ``n_runs``, latency/energy tolerance bands against the XC7S15 model and
  the paper's Table I numbers).

Entry points: ``Deployment.verify(...)`` on any translated artifact,
``Workflow(verify=True)`` for the feedback loop, and
``examples/elastic_workflow.py --verify`` / the CI conformance job for the
end-to-end run. :func:`canary_check` is the in-service slice of the same
protocol — a few golden rows replayed through a *live* deployment, the
health probe ``repro.resilience`` guards run between requests.
"""
from repro.verify.conformance import (CanaryResult,  # noqa: F401
                                      ConformanceReport, canary_check,
                                      fuzz_template, graph_error_budget_lsb,
                                      run_conformance, run_conformance_batch,
                                      verify_deployment)
from repro.verify.protocol import (TABLE1_GOP_PER_J,  # noqa: F401
                                   TABLE1_LATENCY_US, TABLE1_POWER_MW,
                                   MeasurementProtocol, ProtocolCheck,
                                   ProtocolReport, run_protocol)
from repro.verify.vectors import (GOLDEN_SEED, VectorSet,  # noqa: F401
                                  canonical_graph, emit_golden,
                                  generate_vectors, load_vectors,
                                  save_vectors)
