"""The paper's measurement protocol, as code — stage 3 with teeth.

Qian et al. validate each generated accelerator on the Elastic Node by
measuring latency and energy over repeated runs and holding them against
the estimates (their Table I pairs a Vivado estimate with an on-device
measurement within ~10%). :class:`MeasurementProtocol` pins that procedure:
``warmup`` discarded executions, ``n_runs`` averaged ones (through the
uniform ``Deployment.measure`` API, so both the XLA and RTL substrates run
the *same* protocol), then tolerance-band checks:

* RTL targets — measured latency/energy/power against the XC7S15
  resource/cycle model (``rtl.resources.estimate``), and, for the paper's
  reference design on the paper's part (elastic-lstm on xc7s15), against
  the Table I measured numbers themselves;
* host-executed targets (XLA) — sanity bands only (positive, finite,
  scaling with ``n_runs``); host wall-clock has no fabric model to hold it
  against, so the model-band entries are recorded as advisory
  (``enforced=False``) rather than silently skipped.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import List, Optional

from repro.core.target import DEFAULT_N_RUNS

#: Table I (measured row): the LSTM reference accelerator on the XC7S15.
TABLE1_LATENCY_US = 57.25
TABLE1_POWER_MW = 71.0
TABLE1_GOP_PER_J = 5.33


@dataclass(frozen=True)
class MeasurementProtocol:
    """The knobs of the verification measurement procedure."""

    warmup: int = 3                  # discarded executions before timing
    n_runs: int = DEFAULT_N_RUNS     # averaged executions (Deployment.measure)
    model_rtol: float = 0.05         # band: measurement vs the cycle model
    table1_rtol: float = 0.15        # band: estimate vs the paper's Table I


@dataclass
class ProtocolCheck:
    """One named band check. ``enforced=False`` records evidence without
    gating ``passed`` (advisory — e.g. host wall-clock vs a fabric model)."""

    name: str
    value: float
    reference: float
    rtol: float
    passed: bool
    enforced: bool = True


@dataclass
class ProtocolReport:
    target: str
    platform: str
    warmup: int
    n_runs: int
    latency_s: float
    energy_j: float
    power_w: float
    gop_per_j: float
    checks: List[ProtocolCheck] = field(default_factory=list)
    passed: bool = True

    def to_dict(self) -> dict:
        return asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)


def _band(name: str, value: float, reference: float, rtol: float,
          enforced: bool = True) -> ProtocolCheck:
    ok = (math.isfinite(value)
          and abs(value - reference) <= rtol * abs(reference))
    return ProtocolCheck(name=name, value=value, reference=reference,
                         rtol=rtol, passed=ok, enforced=enforced)


def run_protocol(dep, args, *, model: str, model_flops: float,
                 hw=None, protocol: Optional[MeasurementProtocol] = None
                 ) -> ProtocolReport:
    """Warmup → measure → band-check one Deployment. See module docstring.

    Runs under a ``verify.protocol`` span with the warmup and measurement
    phases as children, so the protocol's cost is attributable in a
    captured trace and a band failure points at a visible interval.
    """
    from repro.obs import get_tracer

    trc = get_tracer()
    proto = protocol or MeasurementProtocol()
    with trc.span("verify.protocol", model=model,
                  target=getattr(dep, "target", "")):
        # warmup is part of the measure contract now (PR 9): the runs
        # execute inside Deployment.measure but never enter its latency
        # samples, so latency_p50/p99_s are steady-state-only by
        # construction rather than by a hand-rolled loop out here.
        with trc.span("verify.protocol.measure", n_runs=proto.n_runs,
                      warmup=proto.warmup):
            meas = dep.measure(args, model=model, model_flops=model_flops,
                               n_runs=proto.n_runs, warmup=proto.warmup,
                               hw=hw)
    rep = ProtocolReport(
        target=meas.target, platform=meas.platform, warmup=proto.warmup,
        n_runs=meas.n_runs, latency_s=meas.latency_s, energy_j=meas.energy_j,
        power_w=meas.power_w, gop_per_j=meas.gop_per_j)

    graph = getattr(dep, "graph", None)
    if graph is not None:
        from repro.rtl.resources import estimate

        hw_spec = hw or dep.hw
        clock = hw_spec.clock_hz or 100e6
        rr = estimate(graph, clock_hz=clock)
        lat_model = rr.latency_s
        energy_model = hw_spec.energy_j(lat_model, duty=rr.duty)
        rep.checks.append(_band("latency_vs_cycle_model", meas.latency_s,
                                lat_model, proto.model_rtol))
        rep.checks.append(_band("energy_vs_cycle_model", meas.energy_j,
                                energy_model, proto.model_rtol))
        if model == "elastic-lstm" and hw_spec.name == "xc7s15":
            rep.checks.append(_band("latency_vs_table1_us",
                                    meas.latency_s * 1e6,
                                    TABLE1_LATENCY_US, proto.table1_rtol))
            rep.checks.append(_band("power_vs_table1_mw",
                                    meas.power_w * 1e3,
                                    TABLE1_POWER_MW, proto.table1_rtol))
            rep.checks.append(_band("gop_per_j_vs_table1",
                                    meas.gop_per_j,
                                    TABLE1_GOP_PER_J, proto.table1_rtol))
    else:
        # host wall-clock: sanity-enforced, model bands advisory
        rep.checks.append(ProtocolCheck(
            name="latency_positive_finite", value=meas.latency_s,
            reference=0.0, rtol=0.0,
            passed=math.isfinite(meas.latency_s) and meas.latency_s > 0))
        rep.checks.append(ProtocolCheck(
            name="energy_positive_finite", value=meas.energy_j,
            reference=0.0, rtol=0.0,
            passed=math.isfinite(meas.energy_j) and meas.energy_j > 0))
        syn_lat = getattr(dep, "cost", {}).get("est_latency_s", 0.0) \
            if isinstance(getattr(dep, "cost", None), dict) else 0.0
        if syn_lat:
            rep.checks.append(_band("latency_vs_estimate", meas.latency_s,
                                    syn_lat, 1.0, enforced=False))

    rep.passed = all(c.passed for c in rep.checks if c.enforced)
    return rep
