"""int8 ring all-reduce for gradients — the cross-pod wire-byte reducer.

A ring reduce-scatter + all-gather with int8 payloads (per-block f32 scales
sent alongside, re-quantized each hop): per-device wire bytes ≈ 2·size·1 B
vs ≈ 8·size for the f32 ring all-reduce XLA inserts — a 4× reduction on the
gradient collective, applied hierarchically (f32 over the fast intra-pod
"data" axis if desired, int8 over the slow "pod" axis).

Used inside a *partially-manual* ``jax.shard_map`` (manual over the DP axes,
auto over "model"), so the model-parallel sharding of the gradients is
untouched. Error feedback is available (``ef`` argument) for step-over-step
bias correction; the trainer integration keeps it optional because the
residual costs one params-sized f32 buffer.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.shardmap import (PARTIAL_AUTO_PPERMUTE_OK, axis_size,
                            shard_map)


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.reshape(1)


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_vec(x: jax.Array, axis: str) -> jax.Array:
    """int8 ring all-reduce of a flat f32 vector inside a manual region."""
    n = axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    m = -(-x.size // n)
    xp = jnp.pad(x.reshape(-1), (0, n * m - x.size)).reshape(n, m)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # ---- ring reduce-scatter (int8 wire, requantized partial sums) -------
    cur = jnp.take(xp, idx, axis=0)                    # partial of block idx
    for s in range(n - 1):
        q, sc = _quant(cur)
        q = jax.lax.ppermute(q, axis, perm)
        sc = jax.lax.ppermute(sc, axis, perm)
        rb = (idx - s - 1) % n
        cur = _dequant(q, sc) + jnp.take(xp, rb, axis=0)
    own = (idx + 1) % n                                # block this rank owns

    # ---- ring all-gather of the reduced blocks (int8 wire) ---------------
    out = jnp.zeros((n, m), jnp.float32)
    q, sc = _quant(cur)
    out = jax.lax.dynamic_update_slice_in_dim(out, _dequant(q, sc)[None],
                                              own, axis=0)
    for s in range(n - 1):
        q = jax.lax.ppermute(q, axis, perm)
        sc = jax.lax.ppermute(sc, axis, perm)
        blk = (own - s - 1) % n
        out = jax.lax.dynamic_update_slice_in_dim(out, _dequant(q, sc)[None],
                                                  blk, axis=0)
    return out.reshape(-1)[: x.size].reshape(x.shape)


def compressed_psum_tree(tree: Any, axis: str,
                         ef: Optional[Any] = None) -> Tuple[Any, Any]:
    """Flatten a grad pytree into one vector, ring-reduce it, unflatten.

    Returns (summed_tree, new_ef). With ``ef`` the local quantization error
    of the *input* quantization is fed back next step (error feedback).
    """
    leaves, tdef = jax.tree.flatten(tree)
    sizes = [l.size for l in leaves]
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                            for l in leaves])
    if ef is not None:
        flat = flat + ef
    summed = compressed_psum_vec(flat, axis)
    new_ef = None
    if ef is not None:
        # residual = what this device failed to contribute exactly
        q, sc = _quant(flat)
        new_ef = flat - _dequant(q, sc)
    outs = []
    off = 0
    for sz, shp in zip(sizes, shapes):
        outs.append(summed[off: off + sz].reshape(shp))
        off += sz
    return jax.tree.unflatten(tdef, outs), new_ef


def compressed_psum_butterfly(x: jax.Array, axis: str) -> jax.Array:
    """Recursive-doubling (butterfly) all-reduce with int8 payloads.

    Unlike the flat ring, this never reshapes the operand, so gradients that
    are TP-sharded along "model" keep their sharding (the ppermute runs over
    the DP axis only) — no model-axis all-gathers are induced. Wire bytes:
    log2(n)·size·1 B vs ~8·size for the f32 ring (≈2× for n=16, and the
    payload dtype drops 4× on the slow axis).
    """
    n = axis_size(axis)
    if n == 1:
        return x
    acc = x.astype(jnp.float32)
    r = 1
    while r < n:
        perm = [(i, i ^ r) for i in range(n)]
        q, sc = _quant(acc)
        q = jax.lax.ppermute(q, axis, perm)
        sc = jax.lax.ppermute(sc, axis, perm)
        acc = acc + _dequant(q, sc)
        r <<= 1
    return acc


def compressed_psum_tree_butterfly(tree: Any, axis: str) -> Any:
    return jax.tree.map(lambda g: compressed_psum_butterfly(g, axis), tree)


def compressed_psum_local_quant(x: jax.Array, axis: str) -> jax.Array:
    """Quantization numerics of the int8 all-reduce, via a plain psum.

    Each shard rounds its contribution through the same int8 codes the
    butterfly would put on the wire, then the dequantized values are
    psum-reduced — sum_i scale_i·q_i, bit-identical to gathering the int8
    payloads and summing locally. What training *sees* is therefore the
    compressed gradient; what the wire carries on this path is f32, because
    jaxlib 0.4.x's SPMD partitioner hard-aborts on ``ppermute``/``all_gather``
    inside a *partially-manual* region and only all-reduce collectives
    survive (``repro.shardmap.PARTIAL_AUTO_PPERMUTE_OK``). The wire-byte
    claim itself is measured on the fully-manual path, which keeps the real
    butterfly on every jax.
    """
    if axis_size(axis) == 1:
        return x
    q, sc = _quant(x)
    return jax.lax.psum(_dequant(q, sc), axis)


def compressed_psum_tree_local_quant(tree: Any, axis: str) -> Any:
    return jax.tree.map(lambda g: compressed_psum_local_quant(g, axis), tree)


def make_compressed_grad_fn(loss_fn, mesh, mesh_cfg, batch_pspec_tree):
    """Wrap value_and_grad in a partially-manual shard_map:
    manual over the DP axes (batch split, compressed grad reduction),
    auto over "model" (TP sharding untouched)."""
    dp_axes = tuple(mesh_cfg.dp_axes)

    def local_step(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        # hierarchical reduction: f32 psum over fast intra-pod axis, int8
        # butterfly over the slowest (outermost) axis. Butterfly (not ring):
        # it preserves each leaf's TP sharding — the flat ring was measured
        # to induce model-axis all-gathers (EXPERIMENTS.md §Perf cell C).
        # Where the partitioner can't take ppermute in partial-auto mode
        # (jaxlib 0.4.x), the local-quant psum reduction stands in.
        if len(dp_axes) > 1:
            grads = jax.tree.map(lambda g: jax.lax.psum(g, dp_axes[1:]),
                                 grads)
        reduce_tree = (compressed_psum_tree_butterfly
                       if PARTIAL_AUTO_PPERMUTE_OK
                       else compressed_psum_tree_local_quant)
        grads = reduce_tree(grads, dp_axes[0])
        grads = jax.tree.map(
            lambda g: g / axis_size(dp_axes[0]), grads)
        if len(dp_axes) > 1:
            grads = jax.tree.map(
                lambda g: g / axis_size(dp_axes[1:][0]), grads)
        loss = jax.lax.pmean(loss, dp_axes)
        metrics = jax.tree.map(lambda v: jax.lax.pmean(v, dp_axes), metrics)
        return loss, metrics, grads

    in_specs = (P(), batch_pspec_tree)
    out_specs = (P(), P(), P())
    # check_vma=False: the ring all-reduce produces identical values on all
    # devices, but value-based replication can't be inferred through ppermute
    return shard_map(local_step, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, axis_names=set(dp_axes),
                         check_vma=False)
