"""AdamW with warmup+cosine schedule and global-norm clipping.

Hand-rolled (no optax dependency): the optimizer state is a pytree with the
same structure (and sharding) as the parameters, so checkpointing and the
dry-run treat it uniformly. All optimizer math runs in f32 regardless of the
parameter dtype.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (s - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_schema(param_schema_tree, mesh_cfg=None):
    """PSpec tree for the optimizer state (mirrors the parameter schema).

    ZeRO-1: when a ``mesh_cfg`` is given, each moment tensor additionally
    shards its largest still-unsharded dimension over the data axes — Adam
    moments are touched only inside the (replicated-math) optimizer update,
    so sharding them over DP is free of extra collectives in the fwd/bwd
    and cuts per-device optimizer bytes by |dp|.
    """
    from jax.sharding import PartitionSpec as P

    from repro.model.layers import PSpec, tree_map_pspec

    dp_axes = tuple(mesh_cfg.dp_axes) if mesh_cfg is not None else ()
    dp_n = 1
    for a in dp_axes:
        dp_n *= mesh_cfg.axis_size(a)

    def zero_shard(s: PSpec) -> PSpec:
        spec = list(tuple(s.pspec)) + [None] * (len(s.shape) - len(tuple(s.pspec)))
        if dp_n > 1 and len(s.shape) >= 2:
            # shard the largest unsharded dim that divides the dp size
            cands = [i for i, ax in enumerate(spec) if ax is None
                     and s.shape[i] % dp_n == 0]
            if cands:
                best = max(cands, key=lambda i: s.shape[i])
                spec[best] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        return dataclasses.replace(s, dtype=jnp.float32, init="zeros",
                                   pspec=P(*spec))

    f32 = tree_map_pspec(zero_shard, param_schema_tree)
    return {"mu": f32, "nu": jax.tree.map(lambda x: x, f32,
                                          is_leaf=lambda x: isinstance(x, PSpec)),
            "step": PSpec((), dtype=jnp.int32, init="zeros")}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(
    grads, opt_state, params, cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        # decoupled weight decay — skip 1-d tensors (norms, biases)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) * (1 - lr * wd) - lr * delta
        return newp.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(opt_state["mu"])
    flat_nu = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    info = {"gnorm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, info
