"""Deterministic synthetic data pipeline.

Two corpora:

* **LM corpus** — a seeded Markov-ish token stream with learnable structure
  (bigram transitions over a banded matrix + topic drift), so a ~100M model
  visibly learns (loss drops well below ln(V)) without any external data.
  Batches are a pure function of ``(seed, step)`` — after a crash+restore the
  iterator resumes exactly, which is what makes checkpoint/restart exact.

* **Traffic-flow series** — the paper's LSTM workload: a daily-period signal
  with noise, windowed into (lag=6 → next) samples, matching ref [11].

Host-side prefetch is a small thread that stays ``n`` batches ahead.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


# ---------------------------------------------------------------------------
# LM corpus
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    band: int = 64              # bigram band width (structure to learn)
    n_topics: int = 16


def _bigram_next(tok: np.ndarray, rng: np.random.Generator, v: int,
                 band: int, topic: np.ndarray) -> np.ndarray:
    """Next token: banded bigram + topic bias — cheap but learnable."""
    base = (tok * 31 + 7) % v
    off = rng.integers(0, band, size=tok.shape)
    drift = (topic * 101) % v
    return (base + off + drift) % v


def lm_batch_for_step(cfg: LMDataConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure function of (cfg.seed, step) — restart-exact."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    topic = rng.integers(0, cfg.n_topics, size=(B, 1))
    toks = np.empty((B, S + 1), np.int32)
    toks[:, 0] = rng.integers(0, V, size=B)
    for t in range(S):
        toks[:, t + 1] = _bigram_next(toks[:, t], rng, V, cfg.band,
                                      topic[:, 0])
    return {"tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32)}


def make_lm_iterator(cfg: LMDataConfig, start_step: int = 0
                     ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield lm_batch_for_step(cfg, step)
        step += 1


# ---------------------------------------------------------------------------
# Traffic-flow series (the paper's workload)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrafficConfig:
    seq_len: int = 6
    batch: int = 64
    seed: int = 0
    period: int = 288           # 5-min samples per day
    noise: float = 0.05


def traffic_flow_batch(cfg: TrafficConfig, step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    starts = rng.integers(0, 10_000, size=cfg.batch)
    t = starts[:, None] + np.arange(cfg.seq_len + 1)[None, :]
    # two harmonics of the daily cycle + slow weekly trend + noise
    flow = (0.6 * np.sin(2 * np.pi * t / cfg.period)
            + 0.3 * np.sin(4 * np.pi * t / cfg.period + 1.0)
            + 0.1 * np.sin(2 * np.pi * t / (7 * cfg.period))
            + cfg.noise * rng.standard_normal(t.shape))
    x = flow[:, :-1, None].astype(np.float32)
    y = flow[:, -1:, ].astype(np.float32)
    return {"x": x, "y": y}


# ---------------------------------------------------------------------------
# Multichannel sensor windows (the conv1d workload)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SensorConfig:
    """IMU-style synthetic stream: per-channel harmonics + bursts + noise."""

    seq_len: int = 16
    channels: int = 3
    batch: int = 64
    seed: int = 0
    noise: float = 0.05


def sensor_window_batch(cfg: SensorConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure function of (cfg.seed, step) — restart-exact, like the others.

    The target is the window's mean motion intensity (channel-weighted mean
    of |x| over the last half of the window) — a burst-detection style
    regression a depthwise TCN can learn from local tap patterns.
    """
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, 7]))
    B, S, C = cfg.batch, cfg.seq_len, cfg.channels
    starts = rng.integers(0, 10_000, size=(B, 1, 1))
    t = starts + np.arange(S)[None, :, None]
    ch = np.arange(C)[None, None, :]
    phase = 2 * np.pi * t / (12.0 + 3.0 * ch)
    burst = (rng.random((B, 1, C)) < 0.3).astype(np.float32)
    x = (0.5 * np.sin(phase)
         + 0.25 * np.sin(2.1 * phase + ch)
         + 0.4 * burst * np.sin(5.0 * phase)
         + cfg.noise * rng.standard_normal((B, S, C)))
    w_ch = np.linspace(1.0, 0.5, C)[None, None, :]
    y = (np.abs(x[:, S // 2:, :]) * w_ch).mean(axis=(1, 2), keepdims=False)
    return {"x": x.astype(np.float32), "y": y[:, None].astype(np.float32)}


# ---------------------------------------------------------------------------
# Prefetch
# ---------------------------------------------------------------------------


class Prefetcher:
    """Thread that keeps ``depth`` host batches ready; ``.close()`` to stop."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
