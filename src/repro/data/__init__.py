from repro.data.pipeline import (LMDataConfig, lm_batch_for_step,
                                 traffic_flow_batch, TrafficConfig,
                                 make_lm_iterator, Prefetcher)
