"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations


import jax

from repro.core.types import MULTI_POD, SINGLE_POD, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import")
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    import numpy as np

    devices = jax.devices()[: shape[0] * shape[1]]
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)
