import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512"
    # memory-minimizing list scheduler: the CPU default overlaps remat chunks
    # concurrently, inflating temp_size ~5x vs what a TPU schedule would hold
    + " --xla_cpu_enable_concurrency_optimized_scheduler=false")
"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers, compiles,
fits, and report its roofline terms — without touching real hardware.

This is the TPU analogue of the paper's Stage-2 ("synthesize in Vivado,
read the estimation reports"): ``jax.jit(...).lower().compile()`` is our
synthesis, ``memory_analysis()`` the resource-utilization report and
``cost_analysis()`` + the collective parse the timing/power estimation.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json DIR]
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Optional
# (dataclasses used for ParallelismConfig.replace in extrapolate mode)

import jax

from repro.configs import ALL_IDS, get_config
# model_flops_estimate moved to repro.core.target (so the Creator/targets can
# import it without this module's XLA_FLAGS side effect); re-exported here
# for callers that learned the old address.
from repro.core.target import model_flops_estimate  # noqa: F401
from repro.core.types import ParallelismConfig, shape_table_for, shapes_for
from repro.energy.roofline import HEADER, RooflineReport, roofline
from repro.launch.mesh import make_production_mesh, mesh_config
from repro.model.lm import Stepper


def _compile_cell(cfg, shape, mcfg, mesh, par):
    """One lower+compile; returns (cost_dict, mem_stats, hlo_text, seconds)."""
    from jax.sharding import NamedSharding
    from repro.model.layers import tree_map_pspec
    from repro.model.lm import batch_pspecs
    from repro.optim.adamw import opt_state_schema

    st = Stepper(cfg, shape, mcfg, par, mesh=mesh)
    t0 = time.perf_counter()
    param_sh = st.shardings(st.schema)
    bspecs = batch_pspecs(cfg, shape, mcfg)
    batch_sh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}
    abstract = st.abstract_inputs()

    if cfg.family in ("lstm", "conv1d") and shape.kind != "train":
        # the paper's serving workloads: plain forward inference
        if cfg.family == "lstm":
            from repro.model.lstm import lstm_apply as window_apply
        else:
            from repro.model.conv1d import conv1d_apply as window_apply

        with mesh:
            ab = dict(abstract["batch"])
            ab.pop("y", None)
            bsh = dict(batch_sh)
            bsh.pop("y", None)
            fn = jax.jit(lambda p, b: window_apply(p, b["x"], cfg)[0],
                         in_shardings=(param_sh, bsh))
            lowered = fn.lower(abstract["params"], ab)
            compiled = lowered.compile()
        from repro.energy.roofline import normalize_cost

        return (normalize_cost(compiled.cost_analysis()),
                compiled.memory_analysis(),
                compiled.as_text(), time.perf_counter() - t0)

    with mesh:
        if shape.kind == "train":
            opt_sh = tree_map_pspec(lambda s: NamedSharding(mesh, s.pspec),
                                    opt_state_schema(st.schema, mcfg))
            fn = jax.jit(st.train_fn(),
                         in_shardings=(param_sh, opt_sh, batch_sh),
                         donate_argnums=(0, 1))
            lowered = fn.lower(abstract["params"], abstract["opt_state"],
                               abstract["batch"])
        elif shape.kind == "prefill":
            fn = jax.jit(st.prefill_fn(), in_shardings=(param_sh, batch_sh))
            lowered = fn.lower(abstract["params"], abstract["batch"])
        else:  # decode
            cache_sh = tree_map_pspec(
                lambda s: NamedSharding(mesh, s.pspec), st.cache_schema())
            fn = jax.jit(st.decode_fn(),
                         in_shardings=(param_sh, batch_sh["tokens"], cache_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(abstract["params"], abstract["batch"]["tokens"],
                               abstract["cache"])
        compiled = lowered.compile()
    dt = time.perf_counter() - t0
    from repro.energy.roofline import normalize_cost

    return (normalize_cost(compiled.cost_analysis()),
            compiled.memory_analysis(), compiled.as_text(), dt)


def extrapolation_plan(cfg):
    """[(n_layers, weight)] s.t. cost(full) = Σ w_i · cost(L_i).

    Per-layer HLO is identical within a homogeneous group, so cost is exactly
    affine in the group's layer count; two (three for the zamba2 unit
    structure) reduced-depth *unrolled* compiles recover the exact
    coefficients. Validated against full unrolled compiles in
    EXPERIMENTS.md §Dry-run.
    """
    T = cfg.n_layers
    if cfg.family in ("lstm", "conv1d"):
        return [(T, 1.0)]
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        # zamba2 unit structure: f(T) = a + n_units·c_unit + rem·b_layer.
        # Wide spacing (Δ=2 units / 2 layers) damps per-compile noise.
        u = cfg.shared_attn_every
        n_units = T // u
        rem = T - n_units * u
        # c_unit=(f(3u)-f(u))/2, b=(f(u+2)-f(u))/2, a=f(u)-c_unit
        w_u = 1.0 - (n_units - 1) / 2.0 - rem / 2.0
        return [(u, w_u), (3 * u, (n_units - 1) / 2.0), (u + 2, rem / 2.0)]
    k = cfg.moe.first_dense if (cfg.family == "moe" and cfg.moe) else 0
    L1 = k + 1
    delta = min(6, T - L1)
    L2 = L1 + delta
    if T <= L2 or delta <= 0:
        return [(T, 1.0)]
    w2 = (T - L1) / delta
    return [(L1, 1.0 - w2), (L2, w2)]


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               par: Optional[ParallelismConfig] = None, verbose: bool = True,
               mode: str = "extrapolate", cfg_transform=None):
    """Lower + compile one cell; returns (RooflineReport, compile_seconds).

    mode="unroll":      single full unrolled compile (exact, slow)
    mode="extrapolate": full-config compile with scan-over-layers (proves
                        lower/compile/sharding/memory at full scale) + 2-3
                        reduced-depth unrolled compiles whose affine
                        extrapolation gives exact flops/bytes/wire.
    """
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shape = shape_table_for(cfg)[shape_name]
    mcfg = mesh_config(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    par = par or ParallelismConfig()
    mesh_name = "2x16x16" if multi_pod else "16x16"

    if mode == "unroll" or cfg.family in ("lstm", "conv1d"):
        cost, mem, hlo, dt = _compile_cell(cfg, shape, mcfg, mesh, par)
        rep = roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, n_devices=mesh.size,
            cost=cost, hlo_text=hlo,
            model_flops=model_flops_estimate(cfg, shape),
            memory_analysis=str(mem))
        rep_dt = dt
    elif mode == "proof":
        # full-scale scan compile only: proves lower/compile/sharding/memory
        # (used for the multi-pod pass; §Roofline reads the single-pod table)
        par_scan = dataclasses.replace(par, scan_layers=True)
        cost, mem, hlo, dt = _compile_cell(cfg, shape, mcfg, mesh, par_scan)
        rep = roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, n_devices=mesh.size,
            cost=cost, hlo_text=hlo,
            model_flops=model_flops_estimate(cfg, shape),
            memory_analysis=str(mem))
        rep_dt = dt
    else:
        # 1) full-scale proof: scan-over-layers compile
        par_scan = dataclasses.replace(par, scan_layers=True)
        _, mem, hlo_scan, dt_scan = _compile_cell(cfg, shape, mcfg, mesh,
                                                  par_scan)
        # 2) exact costs: reduced-depth unrolled compiles + affine combine
        flops = byts = 0.0
        from repro.energy.roofline import parse_collectives

        wire = 0.0
        coll_counts: dict = {}
        dts = [dt_scan]
        for L, w in extrapolation_plan(cfg):
            cfg_L = cfg.with_(n_layers=L)
            cost_L, _, hlo_L, dt_L = _compile_cell(cfg_L, shape, mcfg, mesh,
                                                   par)
            st_L = parse_collectives(hlo_L, mesh.size)
            flops += w * float(cost_L.get("flops", 0.0))
            byts += w * float(cost_L.get("bytes accessed", 0.0))
            wire += w * st_L.total_wire_bytes
            for k2, v in st_L.counts.items():
                coll_counts[k2] = coll_counts.get(k2, 0) + w * v
            dts.append(dt_L)
        rep = roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, n_devices=mesh.size,
            cost={"flops": flops, "bytes accessed": byts}, hlo_text="",
            model_flops=model_flops_estimate(cfg, shape),
            memory_analysis=str(mem))
        # overwrite collective stats with the extrapolated ones
        rep.wire_bytes_per_device = wire
        rep.collective_s = wire / 50e9
        rep.collectives.counts = {k2: int(round(v))
                                  for k2, v in coll_counts.items()}
        terms = {"compute": rep.compute_s, "memory": rep.memory_s,
                 "collective": rep.collective_s}
        rep.bottleneck = max(terms, key=terms.get)
        rep.step_s = max(terms.values())
        rep.mfu = (rep.model_flops / (mesh.size * 197e12 * rep.step_s)
                   if rep.step_s > 0 else 0.0)
        rep_dt = sum(dts)

    if verbose:
        print(f"--- {arch} × {shape_name} × {mesh_name} "
              f"(compile {rep_dt:.1f}s, mode={mode}) ---")
        print(f"  memory_analysis: {rep.memory_analysis}")
        print(f"  flops/device={rep.flops_per_device:.3e} "
              f"bytes/device={rep.bytes_per_device:.3e} "
              f"wire/device={rep.wire_bytes_per_device:.3e}")
        print(f"  terms: compute={rep.compute_s*1e3:.2f}ms "
              f"memory={rep.memory_s*1e3:.2f}ms "
              f"collective={rep.collective_s*1e3:.2f}ms "
              f"-> bottleneck={rep.bottleneck} MFU={rep.mfu*100:.1f}%")
        print(f"  collectives: {rep.collectives.counts} "
              f"(in_while={rep.collectives.in_while})")
    return rep, rep_dt


def report_json(rep: RooflineReport, compile_s: float) -> dict:
    d = dataclasses.asdict(rep)
    d.pop("collectives", None)
    d["collective_counts"] = rep.collectives.counts
    d["collective_local_bytes"] = rep.collectives.local_bytes
    d["collective_wire_bytes"] = rep.collectives.wire_bytes
    d["collectives_in_while"] = rep.collectives.in_while
    d["compile_seconds"] = compile_s
    return d


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ALL_IDS))
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) for the chosen mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None, help="directory for per-cell JSON")
    ap.add_argument("--mode", default="extrapolate",
                    choices=["extrapolate", "unroll", "proof"],
                    help="extrapolate: full-scale scan compile + reduced-L "
                         "unrolled cost extrapolation; unroll: single exact "
                         "full unrolled compile (slow); proof: full-scale "
                         "scan compile only (multi-pod pass)")
    args = ap.parse_args(argv)

    par = ParallelismConfig()
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    cells = []
    if args.all:
        for arch in ALL_IDS:
            cfg = get_config(arch)
            for sh in shapes_for(cfg):
                cells.append((arch, sh))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    rows, failures = [], []
    for mp in meshes:
        for arch, sh in cells:
            try:
                rep, dt = lower_cell(arch, sh, multi_pod=mp, par=par,
                                     mode=args.mode)
                rows.append(rep)
                if args.json:
                    import pathlib

                    p = pathlib.Path(args.json)
                    p.mkdir(parents=True, exist_ok=True)
                    mesh_name = "2x16x16" if mp else "16x16"
                    (p / f"{arch}__{sh}__{mesh_name}.json").write_text(
                        json.dumps(report_json(rep, dt), indent=2))
            except Exception as e:  # noqa: BLE001 — report all failures at end
                failures.append((arch, sh, mp, repr(e)))
                print(f"FAILED {arch} × {sh} (multi_pod={mp}): {e}",
                      file=sys.stderr)

    print("\n" + HEADER)
    for r in rows:
        print(r.row())
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print(f"\nall {len(rows)} cells compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
