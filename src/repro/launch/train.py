"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 200 \
        [--smoke] [--mesh dp,tp] [--seq 256] [--batch 16] [--ckpt-dir DIR]

On the container this runs smoke-scale configs on 1 CPU device; on a real
cluster the same entrypoint builds the production mesh (``--production``)
and the identical Trainer drives the run — fault tolerance, async
checkpointing and deterministic replay included.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--production", action="store_true",
                    help="build the 16x16 production mesh (needs devices)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--scan", action="store_true",
                    help="scan-over-layers (fast compile)")
    ap.add_argument("--compute-dtype", default=None,
                    help="override (default bf16 on TPU, f32 on CPU)")
    args = ap.parse_args(argv)
    return _run(args)


def _run(args) -> int:
    import jax

    from repro.configs import get_config
    from repro.core.types import SMOKE_MESH, ParallelismConfig, ShapeConfig
    from repro.data.pipeline import LMDataConfig
    from repro.launch.mesh import make_production_mesh, mesh_config
    from repro.model.lm import Stepper
    from repro.optim.adamw import AdamWConfig
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch, smoke=args.smoke)
    dtype = args.compute_dtype or (
        "bfloat16" if jax.default_backend() == "tpu" else "float32")
    par = ParallelismConfig(compute_dtype=dtype, scan_layers=args.scan)

    if args.production:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        mcfg = mesh_config(multi_pod=args.multi_pod)
    else:
        mesh, mcfg = None, SMOKE_MESH

    shape = ShapeConfig("train", "train", args.seq, args.batch)
    st = Stepper(cfg, shape, mcfg, par, mesh=mesh,
                 opt_cfg=AdamWConfig(lr=args.lr, total_steps=args.steps,
                                     warmup_steps=max(10, args.steps // 20)))
    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                        global_batch=args.batch)
    tr = Trainer(st, dcfg,
                 TrainerConfig(total_steps=args.steps,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt_dir, log_every=10))
    out = tr.train()
    for m in out["metrics"]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['gnorm']:.3f}  {m['sec']*1e3:.0f} ms")
    print(f"done: {out['steps']} steps, {out['recoveries']} recoveries, "
          f"{out['stragglers']} straggler steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
