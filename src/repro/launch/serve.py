"""Serving launcher: batched generation with the continuous-batching server.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 8
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)


    from repro.configs import get_config
    from repro.core.types import SMOKE_MESH, ParallelismConfig, ShapeConfig
    from repro.model.lm import Stepper
    from repro.runtime.server import Server, ServerConfig

    cfg = get_config(args.arch, smoke=True)
    par = ParallelismConfig(compute_dtype="float32")
    st = Stepper(cfg, ShapeConfig("p", "prefill", 32, 1), SMOKE_MESH, par)
    params, _ = st.init()
    srv = Server(cfg, params,
                 ServerConfig(batch_slots=args.slots, max_len=args.max_len,
                              eos_token=-1, temperature=args.temperature),
                 SMOKE_MESH, par)
    t0 = time.perf_counter()
    for i in range(args.requests):
        srv.submit(list(range(3 + i, 19 + i)), max_new_tokens=args.max_new)
    reqs = srv.run_until_drained()
    dt = time.perf_counter() - t0
    tok = sum(len(r.out_tokens) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: {r.out_tokens}")
    print(f"{len(reqs)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s, {args.slots} slots)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
