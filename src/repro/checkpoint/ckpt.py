"""Sharded, atomic, async checkpointing with elastic resharding.

Layout:  <dir>/step_<n>/
             manifest.json       (pytree structure + shapes + dtypes + step)
             arrays.npz          (flat path-keyed tensors, host-gathered)
         <dir>/LATEST            (atomic pointer file)

Design points required at 1000-node scale, kept faithful here:
  * **atomic**: write into ``step_n.tmp-<pid>``, fsync, rename; the LATEST
    pointer is written last — a crash mid-save can never corrupt the tree.
  * **async**: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread — the train loop is blocked only for
    the device→host copy, as in production async checkpointing.
  * **elastic**: restore takes the *target* sharding tree; arrays are
    ``device_put`` against it, so a checkpoint written on one mesh restores
    onto any other mesh/topology (resharding = different NamedSharding).
  * **bounded**: keeps the last ``keep`` checkpoints, GC’s older ones.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the final directory."""
    flat = _flatten(tree)
    treedef = jax.tree.structure(tree)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(path, "LATEST.tmp"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(path, "LATEST.tmp"), os.path.join(path, "LATEST"))
    _gc(path, keep)
    return final


def _gc(path: str, keep: int) -> None:
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(path)
        if d.startswith("step_") and not d.endswith(".tmp")
        and "." not in d.split("_")[1])
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    p = os.path.join(path, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        s = int(f.read().strip())
    if not os.path.isdir(os.path.join(path, f"step_{s:08d}")):
        return None
    return s


def load_checkpoint(path: str, step: int, like: Any,
                    shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like``; if ``shardings`` given, arrays
    are placed against it (elastic resharding onto a new mesh)."""
    d = os.path.join(path, f"step_{step:08d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree.structure(like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves_paths))
    out = []
    for (path_k, leaf), sh in zip(leaves_paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = flat[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint {arr.shape} != {want_shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Async, bounded checkpoint manager for the trainer."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        os.makedirs(path, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot (blocking copy)

        def _write():
            try:
                save_checkpoint(self.path, step, host_tree, keep=self.keep)
            except BaseException as e:  # noqa: BLE001 — surfaced via wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def latest(self) -> Optional[int]:
        return latest_step(self.path)

    def restore(self, like: Any, shardings: Optional[Any] = None,
                step: Optional[int] = None) -> Tuple[int, Any]:
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.path}")
        return step, load_checkpoint(self.path, step, like, shardings)
