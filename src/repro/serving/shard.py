"""Optional multi-device sharding of large serving batches.

A farm dispatch is one ``(B, L, F)`` batch through one compiled program;
on a host with several devices (or forced host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) the batch axis is
embarrassingly parallel — every template is batch-row independent, the
same property that makes micro-batching bit-exact. This module wraps an
:class:`~repro.rtl.backend.RTLExecutable` so each dispatch shards the
batch over a 1-D device mesh with :func:`repro.shardmap.shard_map` (the
repo's one jax-version-portable import site) on a mesh built the
:mod:`repro.launch.mesh` way.

:class:`ShardedExecutable` keeps the Deployment duck type the farm needs:
callable on float windows, ``holds_program`` for router affinity, a
``trace_count`` observable, and bit-exactness — outputs are integer-
identical to the unsharded executable because every device runs the same
integer graph walk on its batch slice.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_smoke_mesh
from repro.quant.fixedpoint import fxp_to_int
from repro.rtl.program_cache import ProgramLRU
from repro.shardmap import shard_map


def make_serving_mesh(n_devices: Optional[int] = None):
    """A 1-D ``("batch", "model")`` mesh over the host's devices (model
    axis fixed at 1 — serving shards only the batch)."""
    n = len(jax.devices()) if n_devices is None else n_devices
    return make_smoke_mesh(shape=(n, 1), axes=("batch", "model"))


class ShardedExecutable:
    """An ``RTLExecutable`` whose dispatches shard the batch over a mesh.

    ``__call__`` pads the batch up to a multiple of the mesh's batch axis,
    splits it across devices with ``shard_map`` over the emulator's staged
    graph walk (``_execute`` is pure and traceable — the same function the
    per-shape program LRU jits), and slices the padding back off. Programs
    are cached per padded ``(shape, dtype)`` exactly like the unsharded
    executor, so :meth:`holds_program` keeps router affinity meaningful.
    """

    def __init__(self, exe, mesh=None, *, max_programs: int = 8):
        self.exe = exe
        self.mesh = mesh if mesh is not None else make_serving_mesh()
        self.n_shards = int(self.mesh.shape["batch"])
        # the same locked LRU the emulator uses — farm worker threads hit
        # this cache concurrently, and an unlocked pop/insert/evict dance
        # can drop or duplicate entries under contention
        self._programs = ProgramLRU(max_programs)
        self.trace_count = 0

    @property
    def emulator(self):
        return self.exe.emulator

    @property
    def graph(self):
        return self.exe.graph

    def holds_program(self, shape, dtype) -> bool:
        # programs are keyed on the padded int32 batch the dispatch actually
        # runs, not the caller's float dtype (same contract as
        # RTLExecutable.holds_program)
        b = self._padded_b(int(shape[0]))
        key = ((b,) + tuple(int(d) for d in shape[1:]),
               jnp.dtype(jnp.int32).name)
        return key in self._programs

    def _padded_b(self, b: int) -> int:
        n = self.n_shards
        return ((b + n - 1) // n) * n

    def _program(self, shape: Tuple[int, ...], dtype):
        def build():
            emu = self.exe.emulator
            out_edge = emu.graph.outputs[0]

            def walk(x_int):
                self.trace_count += 1        # python side effect: trace-time
                return emu._execute(x_int, mode=emu.mode)[out_edge]

            from jax.sharding import PartitionSpec as P

            sharded = shard_map(walk, mesh=self.mesh,
                                in_specs=P("batch"), out_specs=P("batch"),
                                check_vma=False)
            return jax.jit(sharded)

        prog, _hit, _evicted = self._programs.get_or_build(
            (tuple(shape), jnp.dtype(dtype).name), build)
        return prog

    def __call__(self, x) -> jax.Array:
        emu = self.exe.emulator
        in_fmt = emu.graph.edges[emu.graph.inputs[0]].fmt
        out_fmt = emu.graph.edges[emu.graph.outputs[0]].fmt
        x_int = jnp.asarray(fxp_to_int(jnp.asarray(x), in_fmt), jnp.int32)
        b = int(x_int.shape[0])
        pb = self._padded_b(b)
        if pb > b:                           # pad rows to a shard multiple
            filler = jnp.zeros((pb - b,) + x_int.shape[1:], x_int.dtype)
            x_int = jnp.concatenate([x_int, filler], axis=0)
        y_int = self._program(x_int.shape, x_int.dtype)(x_int)
        return y_int[:b].astype(jnp.float32) / out_fmt.scale

    def run_many(self, xs):
        """List-of-batches entry matching ``RTLExecutable.run_many``."""
        if not isinstance(xs, (list, tuple)):
            return self(xs)
        sizes = [int(np.asarray(x).shape[0]) for x in xs]
        out = self(jnp.concatenate([jnp.asarray(x) for x in xs], axis=0))
        res, off = [], 0
        for s in sizes:
            res.append(out[off:off + s])
            off += s
        return res
