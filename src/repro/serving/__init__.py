"""Fleet-scale serving over the uniform Deployment API (DESIGN.md §14).

The subsystem that turns single accelerators into a farm: a bounded
admission queue with deadlines (:mod:`repro.serving.queue`), a dynamic
micro-batcher packing ragged windows per (design, window-length bucket)
into single dispatches (:mod:`repro.serving.batcher`), a program-cache
affinity router over healthy pool members (:mod:`repro.serving.router`),
the tick-driven farm runtime composing them (:mod:`repro.serving.farm`),
optional multi-device batch sharding (:mod:`repro.serving.shard`), the
health-aware :class:`DeploymentPool` rebuilt on the same primitives
(:mod:`repro.serving.pool`), and the seeded mixed-traffic load generator
(``python -m repro.serving.loadgen``).
"""
from repro.serving.batcher import (MicroBatch, MicroBatcher, bucket_for,
                                   pack, pad_window, padded_batch_size,
                                   unpack)
from repro.serving.farm import (AcceleratorFarm, DesignPool, FarmConfig,
                                FarmStats)
from repro.serving.pool import DeploymentPool, PoolStats
from repro.serving.queue import (DONE, EXPIRED, FAILED, QUEUED, SHED,
                                 AdmissionQueue, ServeRequest)
from repro.serving.router import (AffinityRouter, NoServeableMember,
                                  member_holds_program)
from repro.serving.shard import ShardedExecutable, make_serving_mesh

__all__ = [
    "AcceleratorFarm", "AdmissionQueue", "AffinityRouter", "DeploymentPool",
    "DesignPool", "FarmConfig", "FarmStats", "MicroBatch", "MicroBatcher",
    "NoServeableMember", "PoolStats", "ServeRequest", "ShardedExecutable",
    "bucket_for", "make_serving_mesh", "member_holds_program", "pack",
    "pad_window", "padded_batch_size", "unpack",
    "QUEUED", "DONE", "SHED", "EXPIRED", "FAILED",
]
